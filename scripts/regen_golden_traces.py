"""Regenerate the golden control-plane traces under ``tests/golden/``.

Run after an INTENDED behaviour change in the control plane or the chaos
scenarios; the resulting git diff documents exactly which decisions moved.
CI's ``chaos`` job also runs this (into a scratch directory) when the
golden-trace tests fail, and uploads the regenerated traces as an
artifact so the drift can be inspected without a local checkout.

Usage:  PYTHONPATH=src python scripts/regen_golden_traces.py [--out DIR]
"""
from __future__ import annotations

import argparse
from pathlib import Path


def main(argv=None) -> None:
    """Write one golden JSONL trace per catalog entry into ``--out``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output directory (default: tests/golden/)")
    ap.add_argument("--serve", action="store_true",
                    help="regenerate ONLY the golden serve trace")
    args = ap.parse_args(argv)

    from repro.chaos.golden import golden_names, golden_trace
    from repro.core.numerics import enable_x64
    from repro.serve import GOLDEN_SERVE_SCENARIO, golden_serve_trace

    root = Path(__file__).resolve().parents[1]
    out = Path(args.out) if args.out else root / "tests" / "golden"
    with enable_x64():
        if not args.serve:
            for name in golden_names():
                trace = golden_trace(name)
                path = trace.save(out / f"{name}.jsonl")
                rungs = sorted({s.rung for s in trace.steps})
                print(f"{path}: {len(trace.steps)} steps, rungs {rungs}")
        serve = golden_serve_trace()
        path = serve.save(out / f"serve_{GOLDEN_SERVE_SCENARIO}.jsonl")
        print(f"{path}: {len(serve.requests)} requests, "
              f"{len(serve.batches)} batches")


if __name__ == "__main__":
    main()
