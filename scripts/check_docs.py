"""Execute every fenced ``python`` block in the repo's markdown docs.

CI runs this so README/docs snippets can never rot: each file's blocks run
top-to-bottom in ONE shared namespace (so a later snippet can use objects
an earlier one built, exactly as a reader would).  Shell blocks are not
executed.  Keep snippets small — this is a smoke check, not a benchmark.

Usage:  PYTHONPATH=src python scripts/check_docs.py [files...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

DEFAULT_FILES = ("README.md", "docs/architecture.md", "docs/api.md")
FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def extract_blocks(text: str) -> list:
    """The contents of every ```python fenced block, in order."""
    return [m.group(1) for m in FENCE.finditer(text)]


def run_file(path: Path) -> int:
    """Run one markdown file's python blocks; return the block count.

    Raises:
        SystemExit: with a pointer to the failing block on any exception.
    """
    blocks = extract_blocks(path.read_text())
    namespace: dict = {"__name__": f"docsnippet:{path.name}"}
    for i, block in enumerate(blocks, 1):
        try:
            exec(compile(block, f"{path}:block{i}", "exec"), namespace)
        except Exception as e:  # noqa: BLE001 - report and fail the build
            sys.stderr.write(f"FAIL {path} block {i}: {e!r}\n{block}\n")
            raise SystemExit(1)
    return len(blocks)


def main(argv: list) -> None:
    """Check the given markdown files (default: README + docs/)."""
    root = Path(__file__).resolve().parents[1]
    files = [Path(a) for a in argv] or [root / f for f in DEFAULT_FILES]
    total = 0
    for path in files:
        n = run_file(path)
        print(f"{path}: {n} python block(s) OK")
        total += n
    if total == 0:
        raise SystemExit("no python blocks found — check the fence regex")


if __name__ == "__main__":
    main(sys.argv[1:])
