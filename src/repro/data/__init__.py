"""Data substrate: deterministic synthetic token pipeline."""
from repro.data.pipeline import DataConfig, SyntheticLM, make_pipeline

__all__ = ["DataConfig", "SyntheticLM", "make_pipeline"]
