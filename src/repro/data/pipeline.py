"""Deterministic, restartable synthetic LM data pipeline.

Real deployments stream tokenized shards; for a self-contained framework we
generate sequences from a FIXED seeded bigram process (each symbol has
``branching`` allowed successors, plus a little uniform noise).  The
transition table is global, so the task is genuinely learnable - a model
reduces loss from ln(V) toward the bigram entropy ln(branching) within tens
of steps, which the e2e examples assert.  Pure uniform noise would be
unlearnable and useless for validation.

Determinism + fault tolerance: batch t is a pure function of (seed, t), so
restart-from-checkpoint resumes the exact stream by restoring the step
counter alone.  Sharding: each data-parallel host slice can be produced
independently via the batch index (``host_slice``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4       # successors per symbol (bigram entropy ln(b))
    noise: float = 0.02      # uniform-replacement rate
    n_symbols: int = 0       # 0 = vocab


class SyntheticLM:
    """Batch t -> {"tokens", "labels"} (next-token shifted)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._sym = cfg.n_symbols or cfg.vocab
        # global seeded bigram table: symbol -> ``branching`` successors
        trng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 777]))
        self._table = trng.integers(0, self._sym,
                                    size=(self._sym, cfg.branching))

    def batch(self, step: int,
              host_slice: Optional[Tuple[int, int]] = None) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        lo, hi = host_slice or (0, cfg.global_batch)
        rows = []
        for b in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, b]))
            n = cfg.seq_len + 1
            choices = rng.integers(0, cfg.branching, size=n)
            seq = np.empty(n, dtype=np.int64)
            seq[0] = rng.integers(0, self._sym)
            for t in range(1, n):
                seq[t] = self._table[seq[t - 1], choices[t]]
            noise = rng.random(n) < cfg.noise
            seq = np.where(noise, rng.integers(0, self._sym, n), seq)
            rows.append(seq)
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        t = 0
        while True:
            yield self.batch(t)
            t += 1


def make_pipeline(vocab: int, seq_len: int, global_batch: int,
                  seed: int = 0) -> SyntheticLM:
    return SyntheticLM(DataConfig(vocab=vocab, seq_len=seq_len,
                                  global_batch=global_batch, seed=seed))
