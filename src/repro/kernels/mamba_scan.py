"""Pallas TPU kernel: fused Mamba selective-scan (beyond-paper optimization).

The pure-JAX chunked scan (models/mamba.py) materialises (B, c, d, s) decay
/update/state tensors in HBM every chunk plus O(log c) associative-scan
passes - the roofline shows it DOMINATES HBM traffic for Jamba training
(EXPERIMENTS.md SecPerf).  This kernel keeps the (d_blk, s) state resident
in VMEM scratch across a sequential grid walk over sequence chunks, so HBM
traffic collapses to: read dt/x/B/C once + write y once (~48 B per (t, d)
element instead of several hundred).

Grid (B, d/d_blk, S/c): the LAST axis is the sequence walk - TPU executes
it in order, so the h scratch legally carries state between steps (standard
revisiting pattern).  Inside a step a fori_loop runs the c-step recurrence
on VMEM tiles:
    h   = exp(dt_t * A) * h + (dt_t * x_t) B_t
    y_t = (h . C_t) + D * x_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba_scan_pallas"]


def _scan_kernel(dt_ref, x_ref, b_ref, c_ref, a_log_ref, d_ref,
                 y_ref, hout_ref, hbound_ref, h_ref,
                 *, c_steps: int, n_chunks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    # chunk-ENTRY state checkpoint (h0 of this chunk) - the backward pass
    # recomputes within-chunk states from these
    hbound_ref[0, 0] = h_ref[...]

    A = -jnp.exp(a_log_ref[...])          # (d_blk, s)
    D = d_ref[...]                        # (d_blk,)

    def step(t, h):
        dt_t = dt_ref[0, t]               # (d_blk,)
        x_t = x_ref[0, t]                 # (d_blk,)
        b_t = b_ref[0, t]                 # (s,)
        c_t = c_ref[0, t]                 # (s,)
        a_t = jnp.exp(dt_t[:, None] * A)  # (d_blk, s)
        upd = (dt_t * x_t)[:, None] * b_t[None, :]
        h = a_t * h + upd
        y_t = jnp.sum(h * c_t[None, :], axis=1) + D * x_t
        y_ref[0, t] = y_t
        return h

    h = jax.lax.fori_loop(0, c_steps, step, h_ref[...])
    h_ref[...] = h

    @pl.when(pl.program_id(2) == n_chunks - 1)
    def _flush():
        hout_ref[0] = h


@functools.partial(
    jax.jit, static_argnames=("chunk", "d_blk", "interpret"))
def mamba_scan_pallas(
    dt: jnp.ndarray,     # (B, S, d) f32 - post-softplus step sizes
    x: jnp.ndarray,      # (B, S, d) f32 - conv+silu activations
    Bm: jnp.ndarray,     # (B, S, s) f32 - input projections
    Cm: jnp.ndarray,     # (B, S, s) f32 - output projections
    A_log: jnp.ndarray,  # (d, s) f32
    D: jnp.ndarray,      # (d,)   f32
    *,
    chunk: int = 128,
    d_blk: int = 256,
    interpret: bool = False,
):
    """Returns (y (B, S, d) f32, h_final (B, d, s) f32)."""
    B, S, d = dt.shape
    s = A_log.shape[1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    d_blk = min(d_blk, d)
    while d % d_blk:
        d_blk //= 2
    n_chunks = S // chunk

    kern = functools.partial(_scan_kernel, c_steps=chunk, n_chunks=n_chunks)
    grid = (B, d // d_blk, n_chunks)
    y, h_fin, h_bounds = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_blk), lambda b, i, k: (b, k, i)),  # dt
            pl.BlockSpec((1, chunk, d_blk), lambda b, i, k: (b, k, i)),  # x
            pl.BlockSpec((1, chunk, s), lambda b, i, k: (b, k, 0)),      # B
            pl.BlockSpec((1, chunk, s), lambda b, i, k: (b, k, 0)),      # C
            pl.BlockSpec((d_blk, s), lambda b, i, k: (i, 0)),            # A_log
            pl.BlockSpec((d_blk,), lambda b, i, k: (i,)),                # D
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_blk), lambda b, i, k: (b, k, i)),  # y
            pl.BlockSpec((1, d_blk, s), lambda b, i, k: (b, i, 0)),      # h
            pl.BlockSpec((1, 1, d_blk, s), lambda b, i, k: (b, k, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d, s), jnp.float32),
            jax.ShapeDtypeStruct((B, n_chunks, d, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_blk, s), jnp.float32)],
        interpret=interpret,
    )(dt, x, Bm, Cm, A_log, D)
    return y, h_fin, h_bounds
