"""Jit'd public wrappers around the Pallas kernels.

Pad-to-tile, backend dispatch (interpret=True off-TPU so the kernel bodies
execute on CPU for tests/benches), and plan-level convenience entry points
used by the distributed runtime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import ref
from repro.kernels.block_matmul import matmul_t_pallas
from repro.kernels.coded_decode import decode_pallas, decode_partial_pallas
from repro.kernels.coded_encode import encode_pallas
from repro.kernels.coded_fused import fused_worker_pallas

__all__ = ["encode", "decode", "decode_partial", "matmul_t", "fused_worker",
           "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _instrumented(op: str):
    """Kernel timing hook: count every call, time the eager ones.

    Nearly every ops.* call happens INSIDE a jit trace, where wall-clock
    timing would measure tracing, not execution — those calls are only
    counted (``kernel.call{op, traced=1}``).  Eager calls (operands are
    concrete arrays, e.g. benches poking a kernel directly) get a real
    span: the result is blocked on inside the span so the interval covers
    device execution, attributable separately from surrounding XLA time.
    The decorator is identity-cheap while obs is disabled — one global
    check, no tracer inspection, bit-identical results.
    """
    def wrap(fn):
        @functools.wraps(fn)
        def inner(*args, **kwargs):
            if not obs.enabled():
                return fn(*args, **kwargs)
            traced = any(isinstance(a, jax.core.Tracer)
                         for a in jax.tree_util.tree_leaves(args))
            obs.count("kernel.call", op=op, traced=int(traced))
            if traced:
                return fn(*args, **kwargs)
            with obs.span(f"kernel.{op}", lane="kernels"):
                out = fn(*args, **kwargs)
                return jax.block_until_ready(out)
        return inner
    return wrap


def _pow2_tile(cap: int, dim: int) -> int:
    """Clamp a tile size to the next pow2 >= dim (floor 8), capped at cap."""
    return min(cap, int(2 ** np.ceil(np.log2(max(dim, 8)))))


def _pad_last(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-x.shape[-1]) % multiple
    if pad == 0:
        return x
    width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, width)


@_instrumented("encode")
def encode(coeff: jnp.ndarray, blocks: jnp.ndarray, *, e_blk: int = 2048) -> jnp.ndarray:
    """coeff: (K, P), blocks: (P, E) -> (K, E) coded blocks (flattened)."""
    if jnp.iscomplexobj(coeff):
        # Pallas TPU has no complex support; unit-circle plans use the oracle.
        return ref.encode_ref(coeff, blocks)
    E = blocks.shape[-1]
    e_blk = _pow2_tile(e_blk, E)
    bp = _pad_last(blocks, e_blk)
    out = encode_pallas(coeff, bp, e_blk=e_blk, interpret=_interpret())
    return out[:, :E]


@_instrumented("decode")
def decode(W: jnp.ndarray, Y: jnp.ndarray, s: float, *, extract: bool = True,
           e_blk: int = 2048) -> jnp.ndarray:
    """W: (mn, tau), Y: (tau, E) -> (mn, E) decoded + digit-extracted."""
    if jnp.iscomplexobj(W) or jnp.iscomplexobj(Y):
        return ref.decode_ref(W, Y, s)
    E = Y.shape[-1]
    e_blk = _pow2_tile(e_blk, E)
    Yp = _pad_last(Y, e_blk)
    out = decode_pallas(W, Yp, s=float(s), extract=extract, e_blk=e_blk,
                        interpret=_interpret())
    return out[:, :E]


@_instrumented("decode_partial")
def decode_partial(W_stack: jnp.ndarray, Y: jnp.ndarray, s: float, *,
                   extract: bool = True, e_blk: int = 2048) -> jnp.ndarray:
    """W_stack: (Q, mn, K), Y: (Q, K, Ec) -> (Q, mn, Ec) per-chunk decode.

    The partial-straggler decode stage: chunk q's worker outputs hit chunk
    q's panel, with digit extraction fused.  Complex panels (unit-circle
    plans) fall back to the per-chunk jnp oracle.
    """
    if jnp.iscomplexobj(W_stack) or jnp.iscomplexobj(Y):
        return jnp.stack([ref.decode_ref(W_stack[q], Y[q], s)
                          for q in range(W_stack.shape[0])])
    Ec = Y.shape[-1]
    e_blk = _pow2_tile(e_blk, Ec)
    Yp = _pad_last(Y, e_blk)
    out = decode_partial_pallas(W_stack, Yp, s=float(s), extract=extract,
                                e_blk=e_blk, interpret=_interpret())
    return out[:, :, :Ec]


@_instrumented("fused_worker")
def fused_worker(
    coeff_a: jnp.ndarray,
    coeff_b: jnp.ndarray,
    a_blocks: jnp.ndarray,
    b_blocks: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    out_dtype=None,
) -> jnp.ndarray:
    """All-K fused encode+product: coeff_a (K, P), coeff_b (K, Q),
    a_blocks (P, v, r), b_blocks (Q, v, t) -> (K, r, t).

    Pads v/r/t to tile multiples; promotes blocks to the coefficient dtype
    (encode semantics).  Complex plans (unit-circle points) fall back to the
    jnp oracle - Pallas TPU has no complex support.
    """
    if any(jnp.iscomplexobj(x) for x in (coeff_a, coeff_b, a_blocks, b_blocks)):
        return ref.fused_worker_ref(coeff_a, coeff_b, a_blocks, b_blocks,
                                    out_dtype)
    dt = jnp.result_type(coeff_a.dtype, coeff_b.dtype,
                         a_blocks.dtype, b_blocks.dtype)
    ca = coeff_a.astype(dt)
    cb = coeff_b.astype(dt)
    P, v, r = a_blocks.shape
    Q, _, t = b_blocks.shape
    bm_ = _pow2_tile(bm, r)
    bn_ = _pow2_tile(bn, t)
    # Keep the streamed (P, bk, bm) + (Q, bk, bn) tiles under ~4 MiB f32 so
    # the double-buffered pipeline fits VMEM even for fat block grids.
    bk_cap = max(8, int(2 ** np.floor(np.log2(
        max((4 << 20) // (4 * max(P * bm_ + Q * bn_, 1)), 8)))))
    bk_ = min(_pow2_tile(bk, v), bk_cap)
    pad_a = [(0, 0), (0, (-v) % bk_), (0, (-r) % bm_)]
    pad_b = [(0, 0), (0, (-v) % bk_), (0, (-t) % bn_)]
    ap = jnp.pad(a_blocks.astype(dt), pad_a)
    bp = jnp.pad(b_blocks.astype(dt), pad_b)
    out = fused_worker_pallas(ca, cb, ap, bp, bm=bm_, bn=bn_, bk=bk_,
                              out_dtype=out_dtype, interpret=_interpret())
    return out[:, :r, :t]


@_instrumented("matmul_t")
def matmul_t(A: jnp.ndarray, B: jnp.ndarray, *, bm: int = 128, bn: int = 128,
             bk: int = 512, out_dtype=None) -> jnp.ndarray:
    """A: (v, r), B: (v, t) -> A^T B with MXU tiling; pads to tile multiples."""
    if jnp.iscomplexobj(A) or jnp.iscomplexobj(B):
        return ref.matmul_t_ref(A, B, out_dtype)
    v, r = A.shape
    _, t = B.shape
    bm_ = _pow2_tile(bm, r)
    bn_ = _pow2_tile(bn, t)
    bk_ = _pow2_tile(bk, v)
    Ap = jnp.pad(A, (((-v) % bk_ and (0, (-v) % bk_)) or (0, 0),
                     ((-r) % bm_ and (0, (-r) % bm_)) or (0, 0)))
    Bp = jnp.pad(B, (((-v) % bk_ and (0, (-v) % bk_)) or (0, 0),
                     ((-t) % bn_ and (0, (-t) % bn_)) or (0, 0)))
    out = matmul_t_pallas(Ap, Bp, bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype,
                          interpret=_interpret())
    return out[:r, :t]
