"""Jit'd public wrappers around the Pallas kernels.

Pad-to-tile, backend dispatch (interpret=True off-TPU so the kernel bodies
execute on CPU for tests/benches), and plan-level convenience entry points
used by the distributed runtime.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.block_matmul import matmul_t_pallas
from repro.kernels.coded_decode import decode_pallas
from repro.kernels.coded_encode import encode_pallas

__all__ = ["encode", "decode", "matmul_t", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _pad_last(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-x.shape[-1]) % multiple
    if pad == 0:
        return x
    width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, width)


def encode(coeff: jnp.ndarray, blocks: jnp.ndarray, *, e_blk: int = 2048) -> jnp.ndarray:
    """coeff: (K, P), blocks: (P, E) -> (K, E) coded blocks (flattened)."""
    if jnp.iscomplexobj(coeff):
        # Pallas TPU has no complex support; unit-circle plans use the oracle.
        return ref.encode_ref(coeff, blocks)
    E = blocks.shape[-1]
    e_blk = min(e_blk, int(2 ** np.ceil(np.log2(max(E, 8)))))
    bp = _pad_last(blocks, e_blk)
    out = encode_pallas(coeff, bp, e_blk=e_blk, interpret=_interpret())
    return out[:, :E]


def decode(W: jnp.ndarray, Y: jnp.ndarray, s: float, *, extract: bool = True,
           e_blk: int = 2048) -> jnp.ndarray:
    """W: (mn, tau), Y: (tau, E) -> (mn, E) decoded + digit-extracted."""
    if jnp.iscomplexobj(W) or jnp.iscomplexobj(Y):
        return ref.decode_ref(W, Y, s)
    E = Y.shape[-1]
    e_blk = min(e_blk, int(2 ** np.ceil(np.log2(max(E, 8)))))
    Yp = _pad_last(Y, e_blk)
    out = decode_pallas(W, Yp, s=float(s), extract=extract, e_blk=e_blk,
                        interpret=_interpret())
    return out[:, :E]


def matmul_t(A: jnp.ndarray, B: jnp.ndarray, *, bm: int = 128, bn: int = 128,
             bk: int = 512, out_dtype=None) -> jnp.ndarray:
    """A: (v, r), B: (v, t) -> A^T B with MXU tiling; pads to tile multiples."""
    if jnp.iscomplexobj(A) or jnp.iscomplexobj(B):
        return ref.matmul_t_ref(A, B, out_dtype)
    v, r = A.shape
    _, t = B.shape
    bm_ = min(bm, int(2 ** np.ceil(np.log2(max(r, 8)))))
    bn_ = min(bn, int(2 ** np.ceil(np.log2(max(t, 8)))))
    bk_ = min(bk, int(2 ** np.ceil(np.log2(max(v, 8)))))
    Ap = jnp.pad(A, (((-v) % bk_ and (0, (-v) % bk_)) or (0, 0),
                     ((-r) % bm_ and (0, (-r) % bm_)) or (0, 0)))
    Bp = jnp.pad(B, (((-v) % bk_ and (0, (-v) % bk_)) or (0, 0),
                     ((-t) % bn_ and (0, (-t) % bn_)) or (0, 0)))
    out = matmul_t_pallas(Ap, Bp, bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype,
                          interpret=_interpret())
    return out[:r, :t]
