"""Pallas TPU megakernel: fused ENCODE + WORKER-PRODUCT stage.

Computes, for every worker k at once,

    Y_k = (sum_P ca[k, P] * A_P)^T @ (sum_Q cb[k, Q] * B_Q)

directly from the raw block tensors A (P, v, r) and B (Q, v, t).  The coded
matrices A~_k, B~_k exist only as (bk, bm)/(bk, bn) tiles in VMEM inside the
(r, t, v) matmul tiling - they never round-trip through HBM.  Versus the
staged encode_pallas -> matmul_t_pallas schedule this saves, per worker,
2*bv*(br + bt) floats of HBM write+read traffic (the full coded operands)
plus one kernel-dispatch boundary, and lets the encode FLOPs (VPU
scalar-broadcast multiply-adds, P*bk*bm per tile) overlap the MXU matmul in
the same pipeline stage.

Grid: (K, r/bm, t/bn, v/bk) with the contraction axis innermost so the
(bm, bn) accumulator stays resident across the k sweep (output revisiting).
The (K, P)/(K, Q) coefficient tables live in SMEM; row k is prefetched per
grid step and read as scalars.

VMEM budget per grid step (f32 words):
    P*bk*bm  (A block tiles)  +  Q*bk*bn  (B block tiles)
  + bk*(bm + bn)              (coded tiles, transient)
  + bm*bn                     (accumulator scratch)
With the default bm = bn = 128, bk = 256 and P = Q = 8 this is
2*8*256*128 + 256*256 + 128*128 = ~4.4 MiB f32 - inside the ~16 MiB v5e
VMEM with double buffering.  ops.fused_worker shrinks bk automatically when
P or Q is large so the streamed block tiles stay under ~4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_worker_pallas"]


def _fused_kernel(ca_ref, cb_ref, a_ref, b_ref, out_ref, acc_ref, *,
                  k_steps: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ENCODE in VMEM: coded tiles a~ (bk, bm), b~ (bk, bn) as coefficient-
    # weighted sums of the P (resp. Q) source-block tiles.  P, Q are static
    # and small, so the loop unrolls into scalar-broadcast multiply-adds on
    # the VPU; coefficients are scalar reads from the SMEM row.
    P = a_ref.shape[0]
    Q = b_ref.shape[0]
    a_tilde = ca_ref[0, 0] * a_ref[0]
    for pp in range(1, P):
        a_tilde += ca_ref[0, pp] * a_ref[pp]
    b_tilde = cb_ref[0, 0] * b_ref[0]
    for qq in range(1, Q):
        b_tilde += cb_ref[0, qq] * b_ref[qq]

    # WORKER product on the MXU; accumulate across the v sweep.
    acc_ref[...] += jnp.dot(
        a_tilde.T, b_tilde, preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def fused_worker_pallas(
    coeff_a: jnp.ndarray,
    coeff_b: jnp.ndarray,
    a_blocks: jnp.ndarray,
    b_blocks: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """coeff_a: (K, P), coeff_b: (K, Q), a_blocks: (P, v, r),
    b_blocks: (Q, v, t) -> (K, r, t) all worker products, encode fused in.

    Dims must tile evenly (ops.fused_worker pads); dtypes must match.
    bf16 inputs accumulate in f32.
    """
    K, P = coeff_a.shape
    K2, Q = coeff_b.shape
    P2, v, r = a_blocks.shape
    Q2, v2, t = b_blocks.shape
    assert K == K2, (coeff_a.shape, coeff_b.shape)
    assert P == P2 and Q == Q2, (coeff_a.shape, a_blocks.shape,
                                 coeff_b.shape, b_blocks.shape)
    assert v == v2, (a_blocks.shape, b_blocks.shape)
    assert r % bm == 0 and t % bn == 0 and v % bk == 0, (
        a_blocks.shape, b_blocks.shape, (bm, bn, bk))
    out_dtype = out_dtype or a_blocks.dtype
    acc_dtype = (jnp.float32 if a_blocks.dtype in (jnp.bfloat16, jnp.float16)
                 else a_blocks.dtype)
    k_steps = v // bk
    kern = functools.partial(_fused_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kern,
        grid=(K, r // bm, t // bn, k_steps),
        in_specs=[
            pl.BlockSpec((1, P), lambda kw, i, j, k: (kw, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Q), lambda kw, i, j, k: (kw, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((P, bk, bm), lambda kw, i, j, k: (0, k, i)),
            pl.BlockSpec((Q, bk, bn), lambda kw, i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda kw, i, j, k: (kw, i, j)),
        out_shape=jax.ShapeDtypeStruct((K, r, t), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(coeff_a, coeff_b, a_blocks, b_blocks)
