"""Pallas TPU kernel: fused RWKV-6 WKV scan (beyond-paper optimization #2).

Same structure as kernels/mamba_scan.py, applied to the Finch recurrence
(state S is a (dk, dv) matrix per head, decay w is per-dk):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

The pure-JAX chunked path (models/rwkv6._wkv_chunked) materialises
(B, c, H, dk, dv) decay/update tensors plus O(log c) associative-scan
passes per chunk - the roofline shows rwkv6-3b train_4k memory-bound at
160 s (worst remaining cell).  Here the (dk, dv) state stays in VMEM
scratch across a sequential grid walk over sequence chunks; HBM traffic
collapses to reading w/k/v/r once and writing y once.

Grid (B, H, S/c), last axis sequential.  Chunk-ENTRY state checkpoints are
emitted for the custom-VJP backward (models/rwkv6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv_scan_pallas"]


def _wkv_kernel(w_ref, k_ref, v_ref, r_ref, u_ref,
                y_ref, sout_ref, sbound_ref, s_ref,
                *, c_steps: int, n_chunks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    sbound_ref[0, 0, 0] = s_ref[...]
    u = u_ref[0]                                   # (dk,)

    def step(t, S):
        w_t = w_ref[0, t, 0]                       # (dk,)
        k_t = k_ref[0, t, 0]                       # (dk,)
        v_t = v_ref[0, t, 0]                       # (dv,)
        r_t = r_ref[0, t, 0]                       # (dk,)
        b_t = k_t[:, None] * v_t[None, :]          # (dk, dv)
        eff = S + u[:, None] * b_t
        y_ref[0, t, 0] = jnp.sum(r_t[:, None] * eff, axis=0)
        return w_t[:, None] * S + b_t

    S = jax.lax.fori_loop(0, c_steps, step, s_ref[...])
    s_ref[...] = S

    @pl.when(pl.program_id(2) == n_chunks - 1)
    def _flush():
        sout_ref[0, 0] = S


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_scan_pallas(
    w: jnp.ndarray,   # (B, S, H, dk) f32 per-step decay in (0, 1)
    k: jnp.ndarray,   # (B, S, H, dk) f32
    v: jnp.ndarray,   # (B, S, H, dv) f32
    r: jnp.ndarray,   # (B, S, H, dk) f32
    u: jnp.ndarray,   # (H, dk) f32 bonus
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Returns (y (B,S,H,dv), S_fin (B,H,dk,dv), S_bounds (B,nc,H,dk,dv))."""
    B, S, H, dk = k.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    kern = functools.partial(_wkv_kernel, c_steps=chunk, n_chunks=n_chunks)
    grid = (B, H, n_chunks)
    in_spec_k = pl.BlockSpec((1, chunk, 1, dk), lambda b, h, c: (b, c, h, 0))
    in_spec_v = pl.BlockSpec((1, chunk, 1, dv), lambda b, h, c: (b, c, h, 0))
    y, s_fin, s_bounds = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[in_spec_k, in_spec_k, in_spec_v, in_spec_k,
                  pl.BlockSpec((1, dk), lambda b, h, c: (h, 0))],
        out_specs=[
            in_spec_v,                                            # y
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, dk, dv), lambda b, h, c: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, dv), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((B, n_chunks, H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(w, k, v, r, u)
    return y, s_fin, s_bounds
