"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (interpret mode on
CPU, real lowering on TPU).  Keep them boring and obviously correct.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["encode_ref", "decode_ref", "matmul_t_ref", "fused_worker_ref"]


def encode_ref(coeff: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    """coeff: (K, P), blocks: (P, E) -> (K, E).

    The encode stage of the coded matmul: worker k's coded block is the
    coefficient-weighted sum of all P = p*m (or p*n) source blocks.
    """
    return jnp.dot(coeff, blocks.astype(coeff.dtype),
                   preferred_element_type=coeff.dtype)


def decode_ref(W: jnp.ndarray, Y: jnp.ndarray, s: float) -> jnp.ndarray:
    """W: (mn, tau) useful rows of the inverse Vandermonde; Y: (tau, E)
    survivor outputs -> (mn, E) decoded C blocks (digit-extracted).

    X = W @ Y, then the paper's Sec. III-C extraction:
    round -> mod s in [0, s) -> recenter to (-s/2, s/2].
    """
    X = jnp.dot(W, Y.astype(W.dtype), preferred_element_type=W.dtype)
    if jnp.iscomplexobj(X):
        X = X.real
    R = jnp.round(X)
    C_hat = jnp.mod(R, s)
    return jnp.where(C_hat <= s / 2, C_hat, C_hat - s)


def fused_worker_ref(coeff_a: jnp.ndarray, coeff_b: jnp.ndarray,
                     a_blocks: jnp.ndarray, b_blocks: jnp.ndarray,
                     out_dtype=None) -> jnp.ndarray:
    """coeff_a: (K, P), coeff_b: (K, Q), a_blocks: (P, v, r),
    b_blocks: (Q, v, t) -> (K, r, t).

    The fused encode+product stage: worker k's output is
    Y_k = (sum_P ca[k,P] A_P)^T (sum_Q cb[k,Q] B_Q), staged explicitly here
    (coded matrices materialised) as ground truth for the megakernel.
    """
    dt = coeff_a.dtype
    a_tilde = jnp.einsum("kp,pvr->kvr", coeff_a, a_blocks.astype(dt))
    b_tilde = jnp.einsum("kq,qvt->kvt", coeff_b, b_blocks.astype(dt))
    Y = jnp.einsum("kvr,kvt->krt", a_tilde, b_tilde)
    return Y.astype(out_dtype or dt)


def matmul_t_ref(A: jnp.ndarray, B: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """A: (v, r), B: (v, t) -> A^T @ B: (r, t) - one worker's task."""
    acc = jnp.float32 if A.dtype in (jnp.bfloat16, jnp.float16) else A.dtype
    out = jnp.dot(A.T, B, preferred_element_type=acc)
    return out.astype(out_dtype or A.dtype)


def mamba_scan_ref(dt, x, Bm, Cm, A_log, D):
    """Sequential selective-scan oracle for the fused Pallas kernel.

    dt/x: (B, S, d) f32; Bm/Cm: (B, S, s) f32 -> (y (B,S,d), h (B,d,s))."""
    import jax

    A = -jnp.exp(A_log)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp
        a = jnp.exp(dt_t[:, :, None] * A[None])
        bb = (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        h = a * h + bb
        y = jnp.sum(h * c_t[:, None, :], -1) + D[None] * x_t
        return h, y

    h0 = jnp.zeros((dt.shape[0], dt.shape[2], A_log.shape[1]), jnp.float32)
    hf, ys = jax.lax.scan(
        step, h0, (dt.swapaxes(0, 1), x.swapaxes(0, 1),
                   Bm.swapaxes(0, 1), Cm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), hf
