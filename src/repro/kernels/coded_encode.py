"""Pallas TPU kernel: coded-matmul ENCODE stage.

Worker k's coded block A~_k = sum_P coeff[k, P] * blocks[P] - a skinny
(K x P) @ (P x E) matmul with tiny K, P and huge E (= block elements).
Arithmetic intensity is ~K flops/byte of streamed block data, i.e. the stage
is HBM-bandwidth-bound: the kernel's job is to stream `blocks` through VMEM
exactly once while keeping the (K x P) coefficient matrix resident.

Tiling: grid over E; per step the (P, E_blk) tile of `blocks` and the whole
(K, P) coefficient panel live in VMEM; the MXU computes (K, P) @ (P, E_blk).
E_blk defaults to 2048 lanes (f32: P=16 -> 128 KiB in + 256 KiB out for
K=32, comfortably inside the ~16 MiB v5e VMEM with double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["encode_pallas"]


def _encode_kernel(coeff_ref, blocks_ref, out_ref):
    # coeff: (K, P) resident; blocks tile: (P, E_blk); out tile: (K, E_blk).
    out_ref[...] = jnp.dot(
        coeff_ref[...], blocks_ref[...],
        preferred_element_type=out_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("e_blk", "interpret"))
def encode_pallas(
    coeff: jnp.ndarray,
    blocks: jnp.ndarray,
    *,
    e_blk: int = 2048,
    interpret: bool = False,
) -> jnp.ndarray:
    """coeff: (K, P), blocks: (P, E) -> (K, E).  E must divide by e_blk
    (wrappers in ops.py pad); dtypes must match."""
    K, P = coeff.shape
    P2, E = blocks.shape
    assert P == P2, (coeff.shape, blocks.shape)
    assert E % e_blk == 0, f"E={E} not a multiple of e_blk={e_blk}"
    grid = (E // e_blk,)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, P), lambda e: (0, 0)),        # resident panel
            pl.BlockSpec((P, e_blk), lambda e: (0, e)),    # streamed
        ],
        out_specs=pl.BlockSpec((K, e_blk), lambda e: (0, e)),
        out_shape=jax.ShapeDtypeStruct((K, E), coeff.dtype),
        interpret=interpret,
    )(coeff, blocks)
