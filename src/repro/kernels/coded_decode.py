"""Pallas TPU kernel: coded-matmul DECODE stage, fused digit extraction.

X_useful = W @ Y  followed IN-REGISTER by the paper's Sec. III-C extraction
(round -> mod s -> sign recenter).  W is the (mn x tau) panel of the inverse
Vandermonde restricted to the useful z-powers - decoding only ever needs
those mn rows, a tau/mn-fold FLOP and VMEM saving over materialising the
full inverse (for BEC tau = mn so it is square; for the tradeoff scheme the
saving is (mnp'+p'-1)/mn).

Fusing the extraction means the large X intermediate never round-trips to
HBM: the stage reads Y once, writes C once - the memory-optimal schedule.
Grid over E (output elements per C block); W resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["decode_pallas", "decode_partial_pallas"]


def _decode_kernel(w_ref, y_ref, out_ref, *, s: float, extract: bool):
    X = jnp.dot(w_ref[...], y_ref[...], preferred_element_type=out_ref.dtype)
    R = jnp.round(X)
    if extract:
        C_hat = R - jnp.floor(R / s) * s          # mod s in [0, s)
        C = jnp.where(C_hat <= s / 2, C_hat, C_hat - s)
    else:
        C = R
    out_ref[...] = C


@functools.partial(
    jax.jit, static_argnames=("s", "extract", "e_blk", "interpret"))
def decode_pallas(
    W: jnp.ndarray,
    Y: jnp.ndarray,
    *,
    s: float,
    extract: bool = True,
    e_blk: int = 2048,
    interpret: bool = False,
) -> jnp.ndarray:
    """W: (mn, tau) decode panel, Y: (tau, E) survivor outputs -> (mn, E).

    ``extract=False`` skips digit extraction (baseline polynomial code:
    useful coefficients are C directly, only rounding applies).
    """
    mn, tau = W.shape
    tau2, E = Y.shape
    assert tau == tau2, (W.shape, Y.shape)
    assert E % e_blk == 0, f"E={E} not a multiple of e_blk={e_blk}"
    kern = functools.partial(_decode_kernel, s=s, extract=extract)
    return pl.pallas_call(
        kern,
        grid=(E // e_blk,),
        in_specs=[
            pl.BlockSpec((mn, tau), lambda e: (0, 0)),     # resident panel
            pl.BlockSpec((tau, e_blk), lambda e: (0, e)),  # streamed
        ],
        out_specs=pl.BlockSpec((mn, e_blk), lambda e: (0, e)),
        out_shape=jax.ShapeDtypeStruct((mn, E), W.dtype),
        interpret=interpret,
    )(W, Y)


def _decode_partial_kernel(w_ref, y_ref, out_ref, *, s: float, extract: bool):
    X = jnp.dot(w_ref[0], y_ref[0], preferred_element_type=out_ref.dtype)
    R = jnp.round(X)
    if extract:
        C_hat = R - jnp.floor(R / s) * s          # mod s in [0, s)
        C = jnp.where(C_hat <= s / 2, C_hat, C_hat - s)
    else:
        C = R
    out_ref[0] = C


@functools.partial(
    jax.jit, static_argnames=("s", "extract", "e_blk", "interpret"))
def decode_partial_pallas(
    W_stack: jnp.ndarray,
    Y: jnp.ndarray,
    *,
    s: float,
    extract: bool = True,
    e_blk: int = 2048,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-chunk decode: W_stack (Q, mn, K), Y (Q, K, Ec) -> (Q, mn, Ec).

    The partial-straggler decode applies a DIFFERENT weight panel to each
    output-row chunk (chunk c only uses the workers whose completed prefix
    covers it).  Grid is (Q, Ec // e_blk): each step loads chunk q's panel
    resident in VMEM and streams one e-block of its worker outputs, with
    the Sec. III-C digit extraction fused in-register as in
    :func:`decode_pallas`.  ``Q = 1`` degenerates to the binary kernel.
    """
    Q, mn, K = W_stack.shape
    Q2, K2, Ec = Y.shape
    assert (Q, K) == (Q2, K2), (W_stack.shape, Y.shape)
    assert Ec % e_blk == 0, f"Ec={Ec} not a multiple of e_blk={e_blk}"
    kern = functools.partial(_decode_partial_kernel, s=s, extract=extract)
    return pl.pallas_call(
        kern,
        grid=(Q, Ec // e_blk),
        in_specs=[
            pl.BlockSpec((1, mn, K), lambda q, e: (q, 0, 0)),     # panel q
            pl.BlockSpec((1, K, e_blk), lambda q, e: (q, 0, e)),  # streamed
        ],
        out_specs=pl.BlockSpec((1, mn, e_blk), lambda q, e: (q, 0, e)),
        out_shape=jax.ShapeDtypeStruct((Q, mn, Ec), W_stack.dtype),
        interpret=interpret,
    )(W_stack, Y)
