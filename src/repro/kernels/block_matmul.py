"""Pallas TPU kernel: the WORKER stage - one coded block product A~^T B~.

Classic MXU-tiled matmul with a transposed LHS: C = A^T @ B where
A: (v, r), B: (v, t).  Grid (r/bm, t/bn, v/bk) with the contraction axis
innermost so the (bm, bn) output tile stays resident in VMEM across the k
sweep (output revisiting); a float32 scratch accumulator gives full-precision
accumulation for bf16 inputs.

Tile defaults (128, 128, 512) are MXU-aligned (multiples of 128 on the lane
axis, 8/16 on the sublane axis) and keep VMEM use ~
bk*bm + bk*bn + bm*bn floats ~ 0.6 MiB f32 - small enough for the
double-buffered pipeline to hide HBM latency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["matmul_t_pallas"]


def _matmul_t_kernel(a_ref, b_ref, out_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # a tile: (bk, bm) - already the transposed orientation; b tile: (bk, bn).
    acc_ref[...] += jnp.dot(
        a_ref[...].T, b_ref[...], preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def matmul_t_pallas(
    A: jnp.ndarray,
    B: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """A: (v, r), B: (v, t) -> A^T @ B: (r, t).  Dims must tile evenly
    (ops.py pads).  bf16 inputs accumulate in f32."""
    v, r = A.shape
    v2, t = B.shape
    assert v == v2, (A.shape, B.shape)
    assert r % bm == 0 and t % bn == 0 and v % bk == 0, (A.shape, B.shape, (bm, bn, bk))
    out_dtype = out_dtype or A.dtype
    acc_dtype = jnp.float32 if A.dtype in (jnp.bfloat16, jnp.float16) else A.dtype
    k_steps = v // bk
    kern = functools.partial(_matmul_t_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kern,
        grid=(r // bm, t // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, t), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(A, B)
