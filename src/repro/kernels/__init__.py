"""Pallas TPU kernels for the coded-matmul hot spots.

Three stages of the paper's pipeline, each with a pure-jnp oracle in ref.py
and a padded/jit'd wrapper in ops.py:

  coded_encode  - (K x P) @ (P x E) coefficient combine (bandwidth-bound)
  block_matmul  - per-worker A~^T B~ MXU-tiled matmul (compute-bound)
  coded_fused   - encode + worker product in ONE kernel: coded tiles are
                  formed in VMEM inside the matmul tiling, so A~/B~ never
                  touch HBM (the preferred execution mode, DESIGN.md Sec. 3)
  coded_decode  - inverse-Vandermonde panel @ survivor outputs with FUSED
                  digit extraction (round/mod-s/recenter) - the decode never
                  materialises X in HBM.

Off-TPU the wrappers run the kernels in interpret mode (kernel bodies
execute on CPU), so correctness tests sweep real code paths.
"""
from repro.kernels import ops, ref
from repro.kernels.block_matmul import matmul_t_pallas
from repro.kernels.coded_decode import decode_pallas
from repro.kernels.coded_encode import encode_pallas
from repro.kernels.coded_fused import fused_worker_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas

__all__ = ["ops", "ref", "matmul_t_pallas", "decode_pallas", "encode_pallas",
           "fused_worker_pallas", "mamba_scan_pallas"]
