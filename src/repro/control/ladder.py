"""``PlanLadder``: the paper's L <-> tau plan family as one switchable unit.

One ladder freezes the shared geometry ``(p, m, n, K)`` and entry bound
``L`` and instantiates every rung of the paper's tradeoff:

    bec                    tau = m n                (Sec. III-B, deepest digits)
    tradeoff(p' | p)       tau = m n p' + p' - 1    (Sec. IV, one per divisor)
    polycode               tau = p m n + p - 1      (Yu et al., no digits)

Every rung gets its own ``CodedMatmul`` facade, but all facades share ONE
``runtime.CacheGroup``: decode panels persist per plan and the
jit-executable memo spans the family (keys fold in the plan token), so
after ``prewarm()`` compiles each rung once, ``switch()`` is recompile-free
— the group's build counter staying flat across switches is asserted by
tests and the control bench.

``prewarm(..., batch_sizes=...)`` extends the same contract to vmap-batched
serving: each listed size becomes a leading-dim BUCKET compiled per rung,
and a batched call is rounded UP to the smallest covering bucket (zero
rows padded onto A, sliced back off the result), so variable per-request
batch sizes hit the fixed set of prewarmed executables instead of
compiling one program per distinct batch dimension.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bounds as bounds_mod
from repro.core.api import CodedMatmulPlan, extend_plan, make_plan
from repro.core.points import make_points
from repro.core.schemes import make_scheme
from repro.runtime import CacheGroup, CodedMatmul

__all__ = ["PlanLadder"]


def _divisors(p: int) -> Tuple[int, ...]:
    return tuple(d for d in range(1, p + 1) if p % d == 0)


class PlanLadder:
    """The bec <-> tradeoff(p') <-> polycode family over shared (p, m, n, K).

    Rungs whose recovery threshold exceeds ``K`` are dropped at
    construction (they could never decode).  ``rungs`` lists the survivors
    in ascending-tau order; ``active`` starts at the lowest threshold.
    """

    def __init__(self, p: int, m: int, n: int, K: int, L: int, *,
                 backend: str = "reference", dtype=jnp.float64,
                 points: str = "chebyshev", mesh=None,
                 include: Optional[Sequence[str]] = None):
        self.grid = (p, m, n)
        self.K = K
        self.L = L
        self.dtype = jnp.dtype(dtype)
        self.group = CacheGroup()
        self.switch_count = 0
        self.step_overhead_s: dict = {}
        self._buckets: Tuple[int, ...] = ()
        self._backend = backend
        self._mesh = mesh
        self._include = None if include is None else tuple(include)
        self._prewarm_args: Optional[dict] = None

        specs = [("bec", dict(kind="bec"))]
        specs += [(f"tradeoff(p'={pp})", dict(kind="tradeoff", p_prime=pp))
                  for pp in _divisors(p) if 1 < pp < p]
        specs.append(("polycode", dict(kind="polycode")))
        self._specs = tuple(specs)

        # one shared point set for every rung: the pool IS the points, and
        # the elastic paths resize them as a unit (respecialize).
        self.z_points = make_points(points, K)
        self._plans: dict = {}
        self._facades: dict = {}
        for name, spec in specs:
            if include is not None and name not in include:
                continue
            if make_scheme(spec["kind"], p, m, n,
                           p_prime=spec.get("p_prime", 1)).tau > K:
                continue  # this rung can never decode with K workers
            plan = make_plan(spec["kind"], p, m, n, K=K, L=L,
                             p_prime=spec.get("p_prime", 1),
                             z_points=self.z_points)
            self._plans[name] = plan
            self._facades[name] = CodedMatmul(
                plan, backend, dtype=dtype, mesh=mesh, cache_group=self.group)
        if not self._plans:
            raise ValueError(
                f"no rung of grid (p={p}, m={m}, n={n}) fits K={K} workers")
        self._order = tuple(sorted(self._plans, key=lambda r: self.tau(r)))
        # start on the lowest-threshold rung that can decode EXACTLY at this
        # entry bound (an infeasible-only ladder still constructs; selection
        # through ExpectedLatencyPolicy will refuse it).
        self._active = next((r for r in self._order if self.feasible(r)),
                            self._order[0])

    # -- rung accessors -----------------------------------------------------
    @property
    def rungs(self) -> Tuple[str, ...]:
        """Rung names in ascending-tau order."""
        return self._order

    def plan(self, rung: str) -> CodedMatmulPlan:
        """The frozen ``CodedMatmulPlan`` backing ``rung``."""
        return self._plans[self._check(rung)]

    def facade(self, rung: str) -> CodedMatmul:
        """The rung's ``CodedMatmul`` facade (shares the ladder's caches)."""
        return self._facades[self._check(rung)]

    def tau(self, rung: str) -> int:
        """The rung's recovery threshold."""
        return self._plans[self._check(rung)].tau

    def budget(self, rung: str) -> int:
        """The rung's erasure budget K - tau."""
        return self.K - self.tau(rung)

    def feasible(self, rung: str) -> bool:
        """Exact decode possible at the ladder's entry bound L: the rung's
        digit stack must fit the dtype mantissa (paper Sec. III-D/IV)."""
        plan = self._plans[self._check(rung)]
        return bounds_mod.is_safe(self.L, plan.s, plan.scheme.digit_depth,
                                  str(self.dtype), tau=plan.tau)

    def _check(self, rung: str) -> str:
        if rung not in self._plans:
            raise KeyError(f"unknown rung {rung!r}; have {list(self._plans)}")
        return rung

    # -- the switchable facade ---------------------------------------------
    @property
    def active(self) -> str:
        """Name of the rung currently serving calls."""
        return self._active

    def switch(self, rung: str) -> CodedMatmul:
        """Make ``rung`` the active scheme (no recompile after prewarm)."""
        rung = self._check(rung)
        if rung != self._active:
            obs.count("ladder.switch", rung=rung)
            self._active = rung
            self.switch_count += 1
        return self._facades[rung]

    # -- elastic handoff ----------------------------------------------------
    def respecialize(self, z_new, *, prewarm: bool = True) -> dict:
        """Re-lower the rung family onto a resized worker pool.

        ``z_new`` is the new pool's evaluation points: a survivor SUBSET
        of the current points (shrink) or a Leja EXTENSION of them (grow,
        ``core.points.extend_points``).  Rungs whose tau exceeds the new
        K drop out; rungs that fit again rejoin.  Respecialisation
        deliberately ignores the construction-time ``include`` filter —
        the filter models the operator's preferred rungs, but a handoff's
        job is to keep the job decodable on whatever pool remains, and
        the paper's L <-> tau tradeoff is exactly what makes a
        lower-threshold rung available when the preferred one no longer
        fits.

        The shared ``CacheGroup`` is REUSED: executable keys fold in the
        plan token (worker count + points), so nothing built for the old
        pool is evicted or aliased, and replaying an old-pool pattern
        still hits its compiled executable.  On grow, plans extend
        incrementally (``extend_plan`` — surviving workers' coefficient
        rows are reused bit-exactly) and each surviving rung's decode
        panels seed the grown plan's cache by zero-column padding
        (``CacheGroup.seed_extended_panels``), so no old-pool pattern is
        ever refactored.  When ``prewarm`` is True and the ladder was
        prewarmed before, the same prewarm arguments re-run so the
        post-handoff pool is warm before serving resumes.

        Returns ``cache_info()`` for the post-handoff group.

        Raises:
            ValueError: on a non-1-D/empty ``z_new`` or a pool too small
                for every rung in the family.
        """
        z = np.asarray(z_new)
        if z.ndim != 1 or z.size < 1:
            raise ValueError(f"need 1-D non-empty points, got shape {z.shape}")
        K_new = int(z.size)
        growing = K_new > self.K and np.array_equal(z[:self.K], self.z_points)
        p, m, n = self.grid
        plans: dict = {}
        facades: dict = {}
        for name, spec in self._specs:
            if make_scheme(spec["kind"], p, m, n,
                           p_prime=spec.get("p_prime", 1)).tau > K_new:
                continue
            old = self._plans.get(name)
            if growing and old is not None:
                plan = extend_plan(old, K_new - self.K, z_new=z)
                self.group.seed_extended_panels(old, plan)
            else:
                plan = make_plan(spec["kind"], p, m, n, K=K_new, L=self.L,
                                 p_prime=spec.get("p_prime", 1), z_points=z)
            plans[name] = plan
            facades[name] = CodedMatmul(
                plan, self._backend, dtype=self.dtype, mesh=self._mesh,
                cache_group=self.group)
        if not plans:
            raise ValueError(
                f"no rung of grid (p={p}, m={m}, n={n}) fits K={K_new} "
                "workers")
        self._plans = plans
        self._facades = facades
        self.K = K_new
        self.z_points = z
        self._order = tuple(sorted(plans, key=lambda r: self.tau(r)))
        if self._active not in plans or not self.feasible(self._active):
            self._active = next((r for r in self._order if self.feasible(r)),
                                self._order[0])
        obs.count("ladder.respecialize",
                  direction="grow" if growing else "shrink")
        if prewarm and self._prewarm_args is not None:
            self.prewarm(**self._prewarm_args)
        return self.cache_info()

    def __call__(self, A, B, **erasure) -> jnp.ndarray:
        """Coded C = A^T B on the ACTIVE rung.

        A single leading batch dimension on A is served through the
        prewarmed batch buckets when any were compiled: the batch is
        zero-padded up to the smallest covering bucket and the pad rows are
        sliced off the result, so the call hits an existing executable.
        Batches with no covering bucket — and batched-B calls, which the
        buckets do not compile for — run at their true size (compiling a
        new executable on first use).
        """
        A = jnp.asarray(A)
        B = jnp.asarray(B)
        padded = self._bucketed_batch(A, B)
        if padded is None:
            return self._facades[self._active](A, B, **erasure)
        n, bucket = padded
        pad = jnp.zeros((bucket - n,) + A.shape[1:], A.dtype)
        C = self._facades[self._active](
            jnp.concatenate([A, pad], axis=0), B, **erasure)
        return C[:n]

    def worker_stage(self, A, B) -> Tuple[jnp.ndarray, dict]:
        """Stages 1+2 (encode + worker products) on the ACTIVE rung.

        Applies the same bucket round-up padding as ``__call__``, then
        stops BEFORE erase/decode.  Returns ``(Y, ctx)``: the (*batch, K,
        br, bt) worker products and the context :meth:`decode_stage` needs
        to finish the step later — the rung that produced Y (so a rung
        switch between the stages decodes with the RIGHT plan), the
        original trailing dims, and the true batch size to slice back to.
        Composing the two stages is bit-identical to ``__call__``.
        """
        A = jnp.asarray(A)
        B = jnp.asarray(B)
        rt = (int(A.shape[-1]), int(B.shape[-1]))
        padded = self._bucketed_batch(A, B)
        n = None
        if padded is not None:
            n, bucket = padded
            pad = jnp.zeros((bucket - n,) + A.shape[1:], A.dtype)
            A = jnp.concatenate([A, pad], axis=0)
        Y = self._facades[self._active].worker_stage(A, B)
        return Y, {"rung": self._active, "rt": rt, "batch": n}

    def decode_stage(self, Y, ctx: dict, **erasure) -> jnp.ndarray:
        """Stages 3+4 for a :meth:`worker_stage` result (+ bucket unslice).

        ``ctx`` is the context dict ``worker_stage`` returned; the erasure
        keywords are those of ``CodedMatmul.decode_stage`` (binary specs
        only).  Decodes on the rung that PRODUCED Y even if the ladder has
        since switched.
        """
        C = self._facades[ctx["rung"]].decode_stage(Y, ctx["rt"], **erasure)
        n = ctx["batch"]
        return C if n is None else C[:n]

    def _bucketed_batch(self, A, B) -> Optional[Tuple[int, int]]:
        """(batch size, covering bucket) when padding applies, else None.

        Padding applies only to the prewarmed shape family: batched A with
        UNBATCHED B (buckets compile exactly that), and only when the batch
        is not already a bucket size.
        """
        if not self._buckets or A.ndim != 3 or B.ndim != 2:
            return None
        n = int(A.shape[0])
        bucket = self.bucket_for(n)
        return (n, bucket) if bucket is not None and bucket != n else None

    def bucket_for(self, batch: int) -> Optional[int]:
        """Smallest prewarmed batch bucket covering ``batch`` (None if none)."""
        covering = [b for b in self._buckets if b >= batch]
        return min(covering) if covering else None

    @property
    def batch_buckets(self) -> Tuple[int, ...]:
        """Prewarmed leading-dim bucket sizes, ascending."""
        return self._buckets

    # -- compilation --------------------------------------------------------
    def prewarm(self, a_shape: Sequence[int], b_shape: Sequence[int],
                reps: int = 1, batch_sizes: Sequence[int] = (),
                sub_tasks: int = 1, stages: bool = False) -> dict:
        """Compile every rung for one problem shape; measure warm step cost.

        One call per rung with the full-survivor concrete pattern builds the
        (plan, backend, shape, dtype, kind="concrete") executable; any later
        concrete mask is pure data against it, so subsequent ``switch()``es
        never recompile.  The timed warm repetition per rung is stored in
        ``step_overhead_s`` — the measured per-rung decode/step cost the
        latency policies add to their order-statistic estimates.

        Args:
            a_shape/b_shape: unbatched operand shapes ``(v, r)`` / ``(v, t)``.
            reps: warm repetitions per rung for the overhead measurement.
            batch_sizes: leading-dim BUCKETS to additionally compile per
                rung (batched A, shared B).  Later batched calls round up
                to the smallest covering bucket, so serving stays
                recompile-free across batch sizes up to the largest bucket.
            sub_tasks: when > 1, additionally compile each rung's
                partial-straggler executable for Q = ``sub_tasks`` (and per
                bucket), so serving with fractional progress is as
                recompile-free as binary serving — any concrete progress
                vector is pure data against the one ("partial", Q)
                executable.
            stages: when True, additionally compile the SPLIT-STAGE
                executables per rung (and per bucket): the "products"
                worker stage and the ("decode", r, t) stage the serve
                tier's pipelined dispatch uses, so pipelined serving is as
                recompile-free as one-shot serving.

        Returns:
            ``cache_info()`` plus the measured ``overhead_s`` per rung.

        Raises:
            ValueError: if any batch bucket is < 1.
        """
        if any(b < 1 for b in batch_sizes):
            raise ValueError(f"batch buckets must be >= 1, got {batch_sizes}")
        # remembered so an elastic respecialize() can re-prewarm the
        # post-handoff pool with the same shape family.
        self._prewarm_args = dict(
            a_shape=tuple(a_shape), b_shape=tuple(b_shape), reps=reps,
            batch_sizes=tuple(batch_sizes), sub_tasks=sub_tasks,
            stages=stages)
        self._buckets = tuple(sorted(set(int(b) for b in batch_sizes)))
        A = jnp.zeros(tuple(a_shape), self.dtype)
        B = jnp.zeros(tuple(b_shape), self.dtype)
        with obs.span("ladder.prewarm", rungs=len(self._order),
                      buckets=len(self._buckets), stages=int(stages)):
            for rung in self._order:
                cm = self._facades[rung]
                with obs.span("ladder.prewarm.rung", rung=rung):
                    jax.block_until_ready(cm(A, B, erased=[]))  # compile
                    t0 = time.perf_counter()
                    for _ in range(reps):
                        jax.block_until_ready(cm(A, B, erased=[]))
                    self.step_overhead_s[rung] = (
                        time.perf_counter() - t0) / reps
                    if sub_tasks > 1:
                        jax.block_until_ready(cm(A, B, sub_tasks=sub_tasks))
                    if stages:
                        rt = (int(a_shape[-1]), int(b_shape[-1]))
                        Y = cm.worker_stage(A, B)
                        jax.block_until_ready(
                            cm.decode_stage(Y, rt, erased=[]))
                    for bucket in self._buckets:
                        Ab = jnp.zeros((bucket,) + tuple(a_shape), self.dtype)
                        jax.block_until_ready(cm(Ab, B, erased=[]))
                        if sub_tasks > 1:
                            jax.block_until_ready(
                                cm(Ab, B, sub_tasks=sub_tasks))
                        if stages:
                            rt = (int(a_shape[-1]), int(b_shape[-1]))
                            Yb = cm.worker_stage(Ab, B)
                            jax.block_until_ready(
                                cm.decode_stage(Yb, rt, erased=[]))
        info = self.cache_info()
        info["overhead_s"] = dict(self.step_overhead_s)
        info["batch_buckets"] = self._buckets
        return info

    def cache_info(self) -> dict:
        """Group-wide cache counters (builds flat after prewarm = no recompiles)."""
        info = self.group.cache_info()
        info["switches"] = self.switch_count
        return info
