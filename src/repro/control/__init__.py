"""Adaptive straggler control plane (the layer between runtime/ and launch/).

Closes the loop from observed per-worker latencies to scheme selection over
the paper's L <-> tau ladder:

    WorkerHealthMonitor   EWMA latency/variance, straggler scores, erasure
                          mask + fitted LatencyModel          (monitor.py)
    ExpectedLatencyPolicy tau-th order-statistic completion model ranking
                          bec <-> tradeoff(p') <-> polycode subject to L
                                                              (policy.py)
    PlanLadder            one CodedMatmul facade per rung over a shared
                          CacheGroup; prewarm() makes switch() recompile-
                          free                                (ladder.py)
    AdaptiveServer        the serving loop wiring the three together, with
                          CodedElasticPolicy handoff when the erasure
                          budget is exhausted                 (driver.py)

See DESIGN.md Sec. 7.
"""
from repro.control.driver import AdaptiveServer, StepReport
from repro.control.ladder import PlanLadder
from repro.control.monitor import WorkerHealthMonitor
from repro.control.policy import ExpectedLatencyPolicy, RungEstimate

__all__ = [
    "AdaptiveServer",
    "StepReport",
    "PlanLadder",
    "WorkerHealthMonitor",
    "ExpectedLatencyPolicy",
    "RungEstimate",
]
