"""Adaptive straggler control plane (the layer between runtime/ and launch/).

Closes the loop from observed per-worker latencies to scheme selection over
the paper's L <-> tau ladder:

    WorkerHealthMonitor   EWMA latency/variance, straggler scores, erasure
                          mask + fitted LatencyModel          (monitor.py)
    Policy protocol       tau-th order-statistic completion model ranking
      ExpectedLatencyPolicy   by MEAN completion              (policy.py)
      QuantileLatencyPolicy   by q-QUANTILE completion (tail SLOs)
                          over bec <-> tradeoff(p') <-> polycode, gated by L
    PlanLadder            one CodedMatmul facade per rung over a shared
                          CacheGroup; prewarm() makes switch() recompile-
                          free, incl. batched leading-dim buckets
                                                              (ladder.py)
    AdaptiveServer        the serving loop wiring the three together, with
                          an SLO-violation fallback switch and a
                          CodedElasticPolicy handoff when the erasure
                          budget is exhausted                 (driver.py)
    ViolationFeedback     sliding-window REALIZED-violation tracker that
                          tightens/loosens the prediction quantile, adapts
                          the flagging threshold, and can force the
                          tail-optimal rung                  (feedback.py)
    plan_partial_progress fractional progress plans: consume chunk
                          prefixes from flagged stragglers   (partial.py)

See DESIGN.md Sec. 7-10 and docs/architecture.md.
"""
from repro.control.driver import AdaptiveServer, StepReport
from repro.control.feedback import FeedbackConfig, ViolationFeedback
from repro.control.ladder import PlanLadder
from repro.control.monitor import WorkerHealthMonitor
from repro.control.partial import plan_partial_progress
from repro.control.policy import (
    ExpectedLatencyPolicy,
    Policy,
    QuantileLatencyPolicy,
    RungEstimate,
)

__all__ = [
    "AdaptiveServer",
    "StepReport",
    "FeedbackConfig",
    "ViolationFeedback",
    "PlanLadder",
    "WorkerHealthMonitor",
    "Policy",
    "ExpectedLatencyPolicy",
    "QuantileLatencyPolicy",
    "RungEstimate",
    "plan_partial_progress",
]
