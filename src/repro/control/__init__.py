"""Adaptive straggler control plane (the layer between runtime/ and launch/).

Closes the loop from observed per-worker latencies to scheme selection over
the paper's L <-> tau ladder:

    WorkerHealthMonitor   EWMA latency/variance, straggler scores, erasure
                          mask + fitted LatencyModel          (monitor.py)
    Policy protocol       tau-th order-statistic completion model ranking
      ExpectedLatencyPolicy   by MEAN completion              (policy.py)
      QuantileLatencyPolicy   by q-QUANTILE completion (tail SLOs)
                          over bec <-> tradeoff(p') <-> polycode, gated by L
    PlanLadder            one CodedMatmul facade per rung over a shared
                          CacheGroup; prewarm() makes switch() recompile-
                          free, incl. batched leading-dim buckets
                                                              (ladder.py)
    AdaptiveServer        the serving loop wiring the three together, with
                          an SLO-violation fallback switch and a
                          CodedElasticPolicy handoff when the erasure
                          budget is exhausted                 (driver.py)
    ViolationFeedback     sliding-window REALIZED-violation tracker that
                          tightens/loosens the prediction quantile and can
                          force the tail-optimal rung        (feedback.py)

See DESIGN.md Sec. 7-9 and docs/architecture.md.
"""
from repro.control.driver import AdaptiveServer, StepReport
from repro.control.feedback import FeedbackConfig, ViolationFeedback
from repro.control.ladder import PlanLadder
from repro.control.monitor import WorkerHealthMonitor
from repro.control.policy import (
    ExpectedLatencyPolicy,
    Policy,
    QuantileLatencyPolicy,
    RungEstimate,
)

__all__ = [
    "AdaptiveServer",
    "StepReport",
    "FeedbackConfig",
    "ViolationFeedback",
    "PlanLadder",
    "WorkerHealthMonitor",
    "Policy",
    "ExpectedLatencyPolicy",
    "QuantileLatencyPolicy",
    "RungEstimate",
]
