"""Progress planning: how much work to consume from flagged stragglers.

The binary control plane erases a flagged worker outright — the step then
never waits on it.  With sub-tasking (``runtime/partial.py``) there is a
middle ground: ask a flagged worker for a PREFIX of its chunks, paying
``q/Q`` of its (slow) finish time for ``q/Q`` of its coded rows.

Two planners share the same contract (healthy workers at full Q, every
chunk covered tau times, progress in multiples of 1/Q):

``method="lp"`` (default) solves the bottleneck LP exactly::

    minimise   W = max_k (counts_k / Q) * mean_k
    subject to coverage(counts)_c >= tau  for every chunk c,
               counts_k = Q for healthy k,  0 <= counts_k <= Q.

The objective is a min-max, so the LP collapses to a one-dimensional
parametric feasibility problem: for a wait bound T the best counts are the
caps ``counts_k = floor(T * Q / mean_k)`` (clipped to Q), coverage is
monotone non-decreasing in T, and the optimum is the smallest T in the
finite candidate set {q/Q * mean_k} U {max healthy mean} whose caps span.
A reverse-greedy trim then drops chunks the bound does not need, so the
plan also consumes as little straggler work as the optimal wait allows.
This is provably never worse than greedy: greedy's achieved wait is itself
a feasible candidate bound, and the scan returns the smallest one.

``method="greedy"`` is the legacy worst-chunk repair: start from the
binary mask and raise the flagged worker minimising the resulting wait
``(counts_k + need) / Q * mean_k`` until no chunk is undercovered.  Kept
for comparison and for the never-worse regression property.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.runtime.partial import chunk_coverage

__all__ = ["plan_partial_progress", "expected_wait"]


def expected_wait(progress, mean_s) -> float:
    """Modelled step wait of a progress plan: ``max_k progress_k * mean_k``.

    The cost model both planners optimise — worker k delivers its prefix
    after ``progress_k`` of its mean step latency, and the step waits for
    the slowest consumed prefix.
    """
    p = np.asarray(progress, dtype=np.float64)
    m = np.asarray(mean_s, dtype=np.float64)
    if p.size == 0:
        return 0.0
    return float(np.max(p * m))


def _greedy_counts(mean: np.ndarray, ids: list, Q: int, tau: int,
                   K: int) -> np.ndarray:
    """Legacy worst-chunk repair (see module docstring)."""
    counts = np.full(K, Q, dtype=np.int64)
    counts[ids] = 0
    while True:
        cov = chunk_coverage(counts, Q)
        deficient = np.flatnonzero(cov < tau)
        if not deficient.size:
            break
        # repair the worst-covered chunk first
        c = int(deficient[np.argmin(cov[deficient])])
        best_k, best_need, best_wait = -1, 0, np.inf
        for k in range(K):
            d = (c - k) % Q  # chunk c is worker k's (d+1)-th sub-task
            if counts[k] > d:
                continue  # already covers chunk c
            need = d + 1 - counts[k]
            wait = (counts[k] + need) / Q * mean[k]
            if wait < best_wait:
                best_k, best_need, best_wait = k, need, wait
        # a candidate always exists while cov[c] < tau <= K: any worker not
        # covering chunk c can be extended to it.
        counts[best_k] += best_need
    return counts


def _trim_counts(counts: np.ndarray, ids: list, mean: np.ndarray, Q: int,
                 tau: int) -> np.ndarray:
    """Drop flagged chunks the coverage constraint does not need.

    Most-expensive flagged workers first; each decrement removes exactly
    chunk ``(k + counts_k - 1) % Q`` (the last sub-task of k's cyclic
    prefix), so feasibility is maintained chunk-locally.  Never raises any
    worker's wait, so the bottleneck objective is untouched.
    """
    cov = chunk_coverage(counts, Q)
    for k in sorted(ids, key=lambda i: -mean[i]):
        while counts[k] > 0:
            c = (k + counts[k] - 1) % Q
            if cov[c] <= tau:
                break
            counts[k] -= 1
            cov[c] -= 1
    return counts


def _lp_counts(mean: np.ndarray, ids: list, Q: int, tau: int,
               K: int) -> np.ndarray:
    """Exact bottleneck-LP solve via parametric feasibility (docstring)."""
    healthy = np.ones(K, dtype=bool)
    healthy[ids] = False
    base = np.zeros(K, dtype=np.int64)
    base[healthy] = Q
    # Candidate bounds: every flagged prefix wait, plus the healthy pool's
    # own wait (the floor no plan with full healthy workers can beat).
    cands = {float(np.max(mean[healthy]))} if healthy.any() else set()
    for k in ids:
        for q in range(1, Q + 1):
            cands.add(q / Q * float(mean[k]))
    for T in sorted(cands):
        counts = base.copy()
        for k in ids:
            counts[k] = min(Q, int(np.floor(T * Q / mean[k] + 1e-9)))
        if np.all(chunk_coverage(counts, Q) >= tau):
            return _trim_counts(counts, ids, mean, Q, tau)
    # unreachable: at the largest candidate every cap is Q, so every chunk
    # has K >= tau contributors (tau <= K is validated by the caller).
    raise AssertionError("bottleneck scan found no feasible bound")


def plan_partial_progress(mean_s, flagged: Sequence[int], Q: int,
                          tau: int, method: str = "lp") -> np.ndarray:
    """Per-worker progress plan in [0, 1] covering every chunk tau times.

    Args:
        mean_s: (K,) per-worker mean step latencies (the monitor's EWMA) —
            the cost model for choosing WHICH straggler's chunks to consume.
        flagged: worker ids the monitor would erase (start at 0 chunks;
            healthy workers run all Q).
        Q: sub-task count per worker.
        tau: the active rung's recovery threshold.
        method: ``"lp"`` (default) for the exact bottleneck-LP solve,
            ``"greedy"`` for the legacy worst-chunk repair.  The LP plan's
            expected wait (:func:`expected_wait`) is never worse than
            greedy's: greedy's achieved wait is a feasible bound in the
            LP's candidate scan, which returns the smallest feasible one.

    Returns:
        (K,) progress vector, multiples of ``1/Q``.  Equals the binary
        erasure mask whenever the healthy pool alone spans the system.

    Raises:
        ValueError: on a bad shape/ids, non-positive means, an unknown
            ``method``, or ``tau > K`` (no progress assignment can cover a
            chunk tau times).
    """
    mean = np.asarray(mean_s, dtype=np.float64)
    if mean.ndim != 1 or mean.size == 0:
        raise ValueError(f"mean_s must be a (K,) vector, got {np.shape(mean_s)}")
    K = mean.shape[0]
    if not np.all(np.isfinite(mean)) or np.any(mean <= 0):
        raise ValueError(f"per-worker means must be positive, got {mean.tolist()}")
    if Q < 1:
        raise ValueError(f"need Q >= 1 sub-tasks, got {Q}")
    if tau > K:
        raise ValueError(f"tau={tau} > K={K}: no plan can span the system")
    ids = [int(i) for i in flagged]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate worker ids in flagged: {ids}")
    for i in ids:
        if not 0 <= i < K:
            raise ValueError(f"flagged id {i} out of range for K={K}")
    if method == "lp":
        counts = _lp_counts(mean, ids, Q, tau, K)
    elif method == "greedy":
        counts = _greedy_counts(mean, ids, Q, tau, K)
    else:
        raise ValueError(f"unknown method {method!r}; options: lp, greedy")
    return counts.astype(np.float64) / Q
