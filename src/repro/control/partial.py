"""Progress planning: how much work to consume from flagged stragglers.

The binary control plane erases a flagged worker outright — the step then
never waits on it.  With sub-tasking (``runtime/partial.py``) there is a
middle ground: ask a flagged worker for a PREFIX of its chunks, paying
``q/Q`` of its (slow) finish time for ``q/Q`` of its coded rows.

The planner here starts from the binary decision (flagged workers at zero
chunks — never slower than erasure) and only raises a flagged worker's
chunk count when a chunk would otherwise be UNDERCOVERED (fewer than tau
contributors).  Each repair picks the assignment minimising the resulting
wait ``(counts_k + need) / Q * mean_k``, so the refined plan degrades
gracefully: when the healthy pool spans the system the plan IS the binary
mask, and when it does not, the cheapest slices of straggler work are
consumed instead of failing over to a full synchronous wait.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.runtime.partial import chunk_coverage

__all__ = ["plan_partial_progress"]


def plan_partial_progress(mean_s, flagged: Sequence[int], Q: int,
                          tau: int) -> np.ndarray:
    """Per-worker progress plan in [0, 1] covering every chunk tau times.

    Args:
        mean_s: (K,) per-worker mean step latencies (the monitor's EWMA) —
            the cost model for choosing WHICH straggler's chunks to consume.
        flagged: worker ids the monitor would erase (start at 0 chunks;
            healthy workers run all Q).
        Q: sub-task count per worker.
        tau: the active rung's recovery threshold.

    Returns:
        (K,) progress vector, multiples of ``1/Q``.  Equals the binary
        erasure mask whenever the healthy pool alone spans the system.

    Raises:
        ValueError: on a bad shape/ids, non-positive means, or ``tau > K``
            (no progress assignment can cover a chunk tau times).
    """
    mean = np.asarray(mean_s, dtype=np.float64)
    if mean.ndim != 1 or mean.size == 0:
        raise ValueError(f"mean_s must be a (K,) vector, got {np.shape(mean_s)}")
    K = mean.shape[0]
    if not np.all(np.isfinite(mean)) or np.any(mean <= 0):
        raise ValueError(f"per-worker means must be positive, got {mean.tolist()}")
    if Q < 1:
        raise ValueError(f"need Q >= 1 sub-tasks, got {Q}")
    if tau > K:
        raise ValueError(f"tau={tau} > K={K}: no plan can span the system")
    ids = [int(i) for i in flagged]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate worker ids in flagged: {ids}")
    for i in ids:
        if not 0 <= i < K:
            raise ValueError(f"flagged id {i} out of range for K={K}")

    counts = np.full(K, Q, dtype=np.int64)
    counts[ids] = 0
    while True:
        cov = chunk_coverage(counts, Q)
        deficient = np.flatnonzero(cov < tau)
        if not deficient.size:
            break
        # repair the worst-covered chunk first
        c = int(deficient[np.argmin(cov[deficient])])
        best_k, best_need, best_wait = -1, 0, np.inf
        for k in range(K):
            d = (c - k) % Q  # chunk c is worker k's (d+1)-th sub-task
            if counts[k] > d:
                continue  # already covers chunk c
            need = d + 1 - counts[k]
            wait = (counts[k] + need) / Q * mean[k]
            if wait < best_wait:
                best_k, best_need, best_wait = k, need, wait
        # a candidate always exists while cov[c] < tau <= K: any worker not
        # covering chunk c can be extended to it.
        counts[best_k] += best_need
    return counts.astype(np.float64) / Q
