"""``AdaptiveServer``: the request loop that closes the control loop.

Each step: record per-worker finish times (real, or drawn from an injected
feed / ``LatencyModel`` for reproducible simulation) -> update the
``WorkerHealthMonitor`` -> let the policy re-rank the ``PlanLadder`` and
switch rungs -> emit the monitor's erasure mask (clamped to the active
rung's budget) -> serve the coded matmul through the active facade with the
mask as pure data.  ``CodedElasticPolicy`` consumes the same mask; when the
flagged-straggler count exhausts every rung's budget the server records a
respecialisation handoff (``plan_shrink`` target) instead of silently
waiting on known-slow machines forever.

SLO enforcement rides on top of whichever primary policy is installed:
with ``slo_quantile``/``slo_s`` set, every warm step also evaluates the
ACTIVE rung's modelled q-quantile completion, and a predicted violation
forces a switch to the tail-optimal rung immediately — off the re-rank
cadence, and even when the mean ranking disagrees.

``feedback=`` closes the loop on OBSERVED behaviour: a
``control.feedback.ViolationFeedback`` window judges each step's realized
latency (masked completion + the rung's priced overhead) against the SLO
bound and tightens/loosens the quantile the predictions are stated at —
so a fitted model that underestimates the true tail (e.g. Pareto
stragglers) gets corrected by the misses it causes, and a run of
consecutive realized violations forces the tail-optimal rung outright.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax

from repro import obs
from repro.core.api import uncoded_matmul
from repro.core.points import extend_points
from repro.core.simulator import LatencyModel, TimeFeed, WorkerTimes
from repro.distributed.elastic import CodedElasticPolicy, plan_shrink
from repro.control.feedback import FeedbackConfig, ViolationFeedback
from repro.control.ladder import PlanLadder
from repro.control.monitor import WorkerHealthMonitor
from repro.control.policy import (
    ExpectedLatencyPolicy,
    Policy,
    QuantileLatencyPolicy,
)

__all__ = ["StepReport", "StepDecision", "AdaptiveServer"]


@dataclasses.dataclass(frozen=True)
class StepReport:
    """What one adaptive serving step did and cost."""

    step: int
    rung: str
    switched: bool
    erased: Tuple[int, ...]        # workers the mask dropped this step
    sim_latency_s: float           # modelled step completion (mask-aware)
    wall_ms: float                 # measured facade-call wall time
    slack: int                     # elastic slack AFTER applying the mask
    respecialize: bool             # erasure budget exhausted ladder-wide
    shrink_target: Optional[Tuple[int, int]]  # plan_shrink mesh on handoff
    exact: Optional[bool]          # vs uncoded oracle (None = not checked)
    slo_violation: bool = False    # predicted q-quantile exceeded the SLO
    predicted_tail_s: Optional[float] = None  # SERVED rung's modelled q-quantile
    realized_s: Optional[float] = None        # realized latency the feedback judged
    realized_violation: bool = False          # realized latency exceeded the SLO
    q_effective: Optional[float] = None       # feedback-adjusted quantile this step
    progress: Optional[Tuple[float, ...]] = None  # partial plan (sub_tasks > 1)
    threshold_effective: Optional[float] = None   # adaptive monitor threshold
    span_id: Optional[str] = None  # seed-derived obs correlation ID
    pool: Optional[Tuple[int, ...]] = None  # universe ids serving (elastic)


@dataclasses.dataclass(frozen=True)
class StepDecision:
    """The CONTROL half of one serving step, before any facade call.

    ``begin_step`` runs the whole decision sequence — feed ingestion,
    monitor update, feedback restatement, policy (re)ranking, SLO
    fallback, mask/progress planning, elastic bookkeeping — and freezes
    the result here; ``complete_step`` turns it into a ``StepReport``
    once the decoded product is in hand.  The split exists so a serving
    loop can interleave the EXECUTION of one step (worker stage, decode
    stage) with other work — e.g. pipelining decode of step *t* against
    the worker stage of step *t+1* — without re-entering the control
    logic.  ``step()`` composes begin/execute/complete back-to-back and
    is bit-identical to the pre-split loop.
    """

    step: int                      # the server step this decision is for
    times: np.ndarray              # the (K,) per-worker finish times ingested
    rung: str                      # rung that will serve (already switched to)
    switched: bool                 # did the decision change the active rung
    mask: np.ndarray               # (K,) 0/1 erasure mask (derived when partial)
    progress: Optional[np.ndarray]  # (K,) fractional plan (sub_tasks > 1)
    slo_violation: bool            # predicted q-quantile exceeded the SLO
    predicted_tail_s: Optional[float]  # served rung's modelled q-quantile
    q_effective: Optional[float]   # feedback-adjusted quantile this step
    threshold_effective: Optional[float]  # feedback-adjusted flag threshold
    respecialize: bool             # erasure budget exhausted ladder-wide
    shrink_target: Optional[Tuple[int, int]]  # plan_shrink mesh on handoff
    pool: Optional[Tuple[int, ...]] = None  # universe ids serving (elastic)


class AdaptiveServer:
    """Monitor -> policy -> ladder, per request.

    Args:
        ladder: the prewarmed ``PlanLadder`` to serve through.
        monitor: worker-health state; a fresh ``WorkerHealthMonitor`` of the
            ladder's K by default.
        policy: primary rung-selection ``Policy``.  Defaults to
            ``ExpectedLatencyPolicy``, or ``QuantileLatencyPolicy`` when
            ``slo_quantile`` is given and no policy is passed explicitly.
        feed: injectable per-worker finish-time source; defaults to sampling
            ``fallback_model`` with no stragglers (a healthy cluster).  Real
            deployments pass measured per-worker step times instead.
        fallback_model: the healthy-cluster model backing the default feed.
        reevaluate_every: policy cadence in steps (1 = every step).
        score_threshold: monitor score above which a worker counts as a
            straggler.
        seed: rng seed for the default feed.
        check_exact: compare every decoded C against the uncoded oracle.
        slo_quantile: tail quantile the SLO is stated at (e.g. 0.99); turns
            on per-step tail prediction.
        slo_s: the SLO bound in seconds.  When the active rung's predicted
            ``slo_quantile``-completion exceeds it, the server immediately
            switches to the tail-optimal feasible rung (bypassing the
            cadence and the primary ranking).
        feedback: observed-violation feedback over the SLO.  ``True``
            enables it with the default ``FeedbackConfig``; a
            ``FeedbackConfig`` customises the control law.  Each step's
            REALIZED latency (masked completion + the rung's priced
            overhead) is judged against ``slo_s``; the realized violation
            rate tightens/loosens the quantile all predictions are stated
            at, and ``force_after`` consecutive misses force the
            tail-optimal rung regardless of prediction.  The same window
            also adapts the monitor's flagging threshold
            (``effective_threshold``): realized misses tighten flagging,
            calm windows relax it back to ``score_threshold``.
        sub_tasks: sub-task count Q per worker.  With ``Q > 1`` each step
            serves through the partial-straggler decode: the monitor's
            ``progress_plan`` consumes completed chunk prefixes from
            flagged stragglers instead of erasing them outright, and both
            policies rank rungs under the refined fractional law.  ``Q=1``
            is the legacy binary loop, bit for bit.
        universe: total worker-fleet size for ELASTIC pool execution.
            When set, the feed emits ``(universe,)`` per-worker times and
            the server serves on a subset of that fleet (``pool``); a
            ``must_respecialize`` step then EXECUTES the handoff — the
            ladder re-lowers onto the survivor pool's evaluation points —
            and :meth:`grow` admits joiners on Leja-extended points.
            ``None`` (default) is the fixed-pool loop, bit for bit.
        pool: initial universe member ids serving (elastic mode only);
            must have exactly ``ladder.K`` entries.  Defaults to the
            first ``ladder.K`` universe members.

    Raises:
        ValueError: if ``slo_s`` is given without ``slo_quantile``,
            ``feedback`` without both, ``sub_tasks < 1``, or an invalid
            ``universe``/``pool`` combination.
    """

    def __init__(self, ladder: PlanLadder, *,
                 monitor: Optional[WorkerHealthMonitor] = None,
                 policy: Optional[Policy] = None,
                 feed: Optional[TimeFeed] = None,
                 fallback_model: Optional[LatencyModel] = None,
                 reevaluate_every: int = 1,
                 score_threshold: float = 0.5,
                 seed: int = 0,
                 check_exact: bool = False,
                 slo_quantile: Optional[float] = None,
                 slo_s: Optional[float] = None,
                 feedback: Union[bool, FeedbackConfig, None] = None,
                 sub_tasks: int = 1,
                 universe: Optional[int] = None,
                 pool: Optional[Sequence[int]] = None):
        if slo_s is not None and slo_quantile is None:
            raise ValueError("slo_s needs slo_quantile (the quantile the "
                             "SLO is stated at)")
        if feedback and (slo_quantile is None or slo_s is None):
            raise ValueError("feedback needs slo_quantile AND slo_s (it "
                             "judges realized latencies against the bound)")
        if sub_tasks < 1:
            raise ValueError(f"need sub_tasks >= 1, got {sub_tasks}")
        self.sub_tasks = int(sub_tasks)
        self.ladder = ladder
        self.monitor = monitor or WorkerHealthMonitor(ladder.K)
        self.slo_policy: Optional[QuantileLatencyPolicy] = None
        if slo_quantile is not None:
            # inherit the primary policy's overhead override (if any) so the
            # SLO fallback and the primary ranking price rungs identically.
            self.slo_policy = QuantileLatencyPolicy(
                ladder, q=slo_quantile, score_threshold=score_threshold,
                overhead_s=getattr(policy, "overhead_s", None),
                sub_tasks=sub_tasks)
        if policy is None:
            policy = self.slo_policy or ExpectedLatencyPolicy(
                ladder, score_threshold=score_threshold, sub_tasks=sub_tasks)
        self.policy = policy
        self.slo_s = slo_s
        self.feedback: Optional[ViolationFeedback] = None
        if feedback:
            config = (feedback if isinstance(feedback, FeedbackConfig)
                      else FeedbackConfig())
            self.feedback = ViolationFeedback(slo_quantile, slo_s, config)
        self.elastic = CodedElasticPolicy(
            K=ladder.K, tau=ladder.tau(ladder.active))
        self.universe: Optional[int] = None
        self.pool: Optional[np.ndarray] = None
        if universe is not None:
            if universe < ladder.K:
                raise ValueError(
                    f"universe={universe} smaller than the pool K={ladder.K}")
            self.universe = int(universe)
            members = (np.arange(ladder.K, dtype=np.intp) if pool is None
                       else np.asarray(pool, dtype=np.intp))
            if (members.ndim != 1 or members.size != ladder.K
                    or len(set(members.tolist())) != members.size):
                raise ValueError(
                    f"pool must list {ladder.K} distinct universe members, "
                    f"got {pool!r}")
            if members.min() < 0 or members.max() >= self.universe:
                raise ValueError(
                    f"pool members outside the universe of {self.universe}")
            self.pool = members.copy()
        elif pool is not None:
            raise ValueError("pool= requires universe= (elastic mode)")
        self._feed = feed
        self._fallback = fallback_model or LatencyModel(base=1.0, jitter=0.0)
        self.reevaluate_every = max(1, reevaluate_every)
        self.score_threshold = score_threshold
        self.check_exact = check_exact
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.steps = 0
        self.reports: List[StepReport] = []
        # obs correlation scope: span IDs are span_id_for(seed, scope, step).
        # Loops running SEVERAL servers off one seed (the serve tier's
        # per-SLO-class servers) set a distinct scope per server so their
        # step IDs never collide.
        self.obs_scope = "step"

    # -- worker-time ingestion ----------------------------------------------
    def _worker_times(self) -> np.ndarray:
        """One step of per-worker finish times: (universe,) when elastic
        (the fleet keeps emitting for non-members), else (K,)."""
        width = self.universe if self.universe is not None else self.ladder.K
        if self._feed is not None:
            t = np.asarray(self._feed(self.steps, self.rng), dtype=np.float64)
            if t.shape != (width,):
                raise ValueError(
                    f"feed returned shape {t.shape}, need ({width},)")
            return t
        return self._fallback.sample(width, (), self.rng)

    def _switch_to(self, rung: str) -> bool:
        """Activate ``rung`` (carrying elastic state); True if it changed."""
        if rung == self.ladder.active:
            return False
        self.ladder.switch(rung)
        self.elastic = CodedElasticPolicy(
            K=self.ladder.K, tau=self.ladder.tau(rung),
            healthy=self.elastic.healthy.copy())
        return True

    # -- elastic pool execution ----------------------------------------------
    def _execute_shrink(self, threshold: float) -> bool:
        """Drop the flagged stragglers and re-lower onto the survivors.

        The executed half of the respecialisation handoff: survivors keep
        their evaluation points (a subset of the ladder's), the ladder
        re-lowers its rung family onto them reusing the shared cache
        group, and monitor/elastic state compacts to the survivor
        indices.  Returns False — leaving the step a flag-only handoff,
        exactly the fixed-pool behaviour — when no rung fits the survivor
        pool or nobody survives.
        """
        victims = self.monitor.stragglers(threshold)
        keep = np.setdiff1d(np.arange(self.ladder.K, dtype=np.intp), victims)
        if keep.size == 0:
            return False
        try:
            self.ladder.respecialize(self.ladder.z_points[keep])
        except ValueError:
            return False  # survivor pool below every rung's tau
        self.monitor.resize(keep=keep)
        self.elastic.shrink(keep)
        self.elastic.tau = self.ladder.tau(self.ladder.active)
        self.pool = self.pool[keep]
        obs.count("control.pool.shrink", dropped=int(victims.size))
        return True

    def grow(self, joiners: Sequence[int]) -> None:
        """Admit ``joiners`` (universe ids) onto Leja-extended points.

        The symmetric elastic path: the ladder's evaluation points extend
        by ``len(joiners)`` fresh Leja points (``core.points
        .extend_points``) and every rung re-lowers incrementally —
        surviving workers' encoded-task coefficients, cached decode
        panels, and compiled executables for the old pool are untouched,
        so only the grown pool's executables compile.  Joiners append at
        the END of the pool (they own the new points) and start cold in
        the monitor.

        Raises:
            ValueError: on a fixed-pool server, an empty/duplicate joiner
                list, ids outside the universe, or ids already serving.
        """
        if self.pool is None:
            raise ValueError("grow() needs an elastic server (universe=)")
        ids = np.asarray(joiners, dtype=np.intp)
        if ids.ndim != 1 or ids.size < 1:
            raise ValueError(f"joiners must be 1-D non-empty, got {joiners!r}")
        if len(set(ids.tolist())) != ids.size:
            raise ValueError(f"duplicate joiner ids: {joiners!r}")
        if ids.min() < 0 or ids.max() >= self.universe:
            raise ValueError(
                f"joiners outside the universe of {self.universe}")
        if np.intersect1d(ids, self.pool).size:
            raise ValueError(f"joiners already in the pool: {joiners!r}")
        g = int(ids.size)
        self.ladder.respecialize(extend_points(self.ladder.z_points, g))
        self.monitor.resize(grow=g)
        self.elastic.grow(g)
        self.elastic.tau = self.ladder.tau(self.ladder.active)
        self.pool = np.concatenate([self.pool, ids])
        obs.count("control.pool.grow", joined=g)

    # -- one serving step ----------------------------------------------------
    def begin_step(self) -> StepDecision:
        """Run the control half of one step: ingest times, decide, plan.

        Consumes exactly one feed step and mutates every piece of control
        state (monitor, feedback, ladder rung, elastic policy) exactly as
        the head of the legacy ``step()`` did.  Pair each call with exactly
        one ``complete_step`` — the step counter only advances there.
        """
        with obs.span("control.begin_step", step=self.steps,
                      scope=self.obs_scope):
            decision = self._decide()
        if decision.switched:
            obs.count("control.switch", rung=decision.rung)
        if decision.slo_violation:
            obs.count("control.slo_fallback", rung=decision.rung)
        if decision.respecialize:
            obs.count("control.respecialize")
        return decision

    def _decide(self) -> StepDecision:
        times_all = self._worker_times()
        times = times_all if self.pool is None else times_all[self.pool]
        self.monitor.record_step(times)
        scores = self.monitor.straggler_scores()

        switched = False
        slo_violation = False
        predicted_tail = None
        q_eff = None
        thr = self.score_threshold
        thr_eff = None
        if self.feedback is not None:
            # realized violations re-state the quantile every prediction
            # this step is made at (selection, tail estimate, fallback) —
            # including a user-supplied quantile PRIMARY, which would
            # otherwise keep ranking at the stale base q.
            q_eff = self.feedback.effective_q()
            self.slo_policy.q = q_eff
            if (self.policy is not self.slo_policy
                    and isinstance(self.policy, QuantileLatencyPolicy)):
                self.policy.q = q_eff
            # ...and re-state the flagging threshold the masks/plans and
            # both policies' victim sets are computed at: misses tighten
            # flagging, calm windows relax it back to the configured base.
            thr = thr_eff = self.feedback.effective_threshold(
                self.score_threshold)
            for p in (self.policy, self.slo_policy):
                if p is not None and hasattr(p, "score_threshold"):
                    p.score_threshold = thr
        # a cold monitor ranks on noise: hold the initial rung until the
        # EWMA estimates have min_history steps behind them (same gating
        # the monitor applies to its erasure mask).
        if self.monitor.steps >= self.monitor.min_history:
            model = self.monitor.fitted_model()
            best = None
            if self.steps % self.reevaluate_every == 0:
                best = self.policy.select(model, scores)
                switched = self._switch_to(best.rung)
            if self.slo_policy is not None:
                # when the quantile policy IS the primary and just ranked,
                # its winning estimate already describes the active rung —
                # reuse it instead of re-running the closed-form estimate.
                primary_is_slo = (self.policy is self.slo_policy
                                  and best is not None
                                  and best.rung == self.ladder.active)
                if primary_is_slo:
                    predicted_tail = best.quantile_latency_s
                else:
                    predicted_tail = self.slo_policy.estimate(
                        self.ladder.active, model, scores).quantile_latency_s
                if self.slo_s is not None and predicted_tail > self.slo_s:
                    # SLO fallback: the ACTIVE rung is predicted to blow the
                    # tail budget — switch to the tail-optimal rung NOW,
                    # regardless of cadence or the primary (mean) ranking.
                    slo_violation = True
                    fallback = (best if primary_is_slo
                                else self.slo_policy.select(model, scores))
                    if self._switch_to(fallback.rung):
                        switched = True
                        # report the tail of the rung that will SERVE
                        predicted_tail = fallback.quantile_latency_s
            if (self.feedback is not None and not slo_violation
                    and self.feedback.force_tail_optimal):
                # the model keeps predicting "fine" while reality keeps
                # violating: stop trusting it and take the tail-optimal
                # rung outright.
                forced = self.slo_policy.select(model, scores)
                if self._switch_to(forced.rung):
                    switched = True
                    predicted_tail = forced.quantile_latency_s

        progress = None
        if self.sub_tasks > 1:
            # fractional generalisation of the erasure mask: flagged
            # workers contribute completed chunk prefixes instead of being
            # erased outright (or waited on in full past the budget).
            progress = self.monitor.progress_plan(
                self.sub_tasks, self.ladder.tau(self.ladder.active), thr)
            mask = (progress > 0).astype(np.float64)
        else:
            budget = self.ladder.budget(self.ladder.active)
            mask = self.monitor.erasure_mask(budget, thr)
        self.elastic.observe_mask(mask)

        # ladder-wide exhaustion: more persistent stragglers than even the
        # widest-budget FEASIBLE rung can erase -> respecialisation handoff.
        flagged = self.monitor.stragglers(thr).size
        max_budget = max((self.ladder.budget(r) for r in self.ladder.rungs
                          if self.policy.feasible(r)), default=0)
        respecialize = flagged > max_budget and self.elastic.must_respecialize
        shrink_target = None
        if respecialize:
            healthy = self.ladder.K - flagged
            try:
                shrink_target = plan_shrink(healthy)
            except ValueError:
                shrink_target = None  # not even a 1x1 mesh left
            if self.pool is not None:
                # ELASTIC: execute the handoff now — this very step serves
                # on the survivor pool's re-lowered ladder.
                rung_before = self.ladder.active
                if self._execute_shrink(thr):
                    switched = switched or self.ladder.active != rung_before
                    times = times_all[self.pool]
                    if self.sub_tasks > 1:
                        progress = self.monitor.progress_plan(
                            self.sub_tasks,
                            self.ladder.tau(self.ladder.active), thr)
                        mask = (progress > 0).astype(np.float64)
                    else:
                        mask = self.monitor.erasure_mask(
                            self.ladder.budget(self.ladder.active), thr)
                    self.elastic.observe_mask(mask)

        return StepDecision(
            step=self.steps,
            times=times,
            rung=self.ladder.active,
            switched=switched,
            mask=mask,
            progress=progress,
            slo_violation=slo_violation,
            predicted_tail_s=predicted_tail,
            q_effective=q_eff,
            threshold_effective=thr_eff,
            respecialize=respecialize,
            shrink_target=shrink_target,
            pool=(None if self.pool is None
                  else tuple(int(x) for x in self.pool)),
        )

    def execute(self, decision: StepDecision, A, B) -> jax.Array:
        """The one-shot facade call ``decision`` prescribes (no pipelining).

        A serving loop wanting the two-stage overlap calls the ladder's
        ``worker_stage``/``decode_stage`` with ``decision.mask`` instead;
        either route is bit-identical.
        """
        with obs.span("control.execute", rung=decision.rung,
                      step=decision.step):
            if decision.progress is not None:
                return self.ladder(A, B, progress=decision.progress,
                                   sub_tasks=self.sub_tasks)
            return self.ladder(A, B, mask=decision.mask)

    def complete_step(self, decision: StepDecision, C, wall_ms: float,
                      A=None, B=None) -> StepReport:
        """Close out a ``begin_step`` decision once its product is decoded.

        Prices the step (masked/fractional completion of the ingested
        times), feeds the realized latency to the violation feedback, runs
        the optional exactness check (needs ``A``/``B``), and appends +
        returns the ``StepReport``.  Advances the step counter.
        """
        times, mask, progress = decision.times, decision.mask, decision.progress
        exact = None
        if self.check_exact and A is not None:
            exact = bool(np.array_equal(np.asarray(C),
                                        np.asarray(uncoded_matmul(A, B))))

        with obs.span("control.complete_step", step=decision.step,
                      scope=self.obs_scope):
            sim_latency = (
                WorkerTimes(times).completion_with_progress(progress)
                if progress is not None
                else WorkerTimes(times).completion_with_mask(mask))
            realized = None
            realized_violation = False
            if self.feedback is not None:
                # realized = what this step actually cost under the model's
                # own pricing: masked completion + the served rung's
                # overhead (the same additive cost every prediction carries).
                realized = sim_latency + self.slo_policy.overhead_for(
                    decision.rung)
                realized_violation = self.feedback.observe(realized)

        report = StepReport(
            step=decision.step,
            rung=decision.rung,
            switched=decision.switched,
            erased=tuple(int(i) for i in np.flatnonzero(mask == 0)),
            sim_latency_s=sim_latency,
            wall_ms=wall_ms,
            slack=self.elastic.slack,
            respecialize=decision.respecialize,
            shrink_target=decision.shrink_target,
            exact=exact,
            slo_violation=decision.slo_violation,
            predicted_tail_s=decision.predicted_tail_s,
            realized_s=realized,
            realized_violation=realized_violation,
            q_effective=decision.q_effective,
            progress=(None if progress is None
                      else tuple(float(x) for x in progress)),
            threshold_effective=decision.threshold_effective,
            span_id=obs.span_id_for(self.seed, self.obs_scope,
                                    decision.step),
            pool=decision.pool,
        )
        obs.observe("control.sim_latency_s", sim_latency, rung=decision.rung)
        if realized_violation:
            obs.count("control.realized_violation", rung=decision.rung)
        self.reports.append(report)
        self.steps += 1
        return report

    def step(self, A, B) -> Tuple[jax.Array, StepReport]:
        """Serve one coded matmul request through the control loop.

        ``begin_step`` (decide) -> ``execute`` (one-shot facade call) ->
        ``complete_step`` (price, feed back, report), composed
        back-to-back; bit-identical to the pre-split synchronous loop.

        Args:
            A: (v, r) or batch-leading (b, v, r) left operand.
            B: (v, t) right operand (shared across a batch).

        Returns:
            ``(C, StepReport)`` — the decoded product and what the loop did.
        """
        decision = self.begin_step()
        t0 = time.perf_counter()
        C = self.execute(decision, A, B)
        jax.block_until_ready(C)
        wall_ms = (time.perf_counter() - t0) * 1e3
        return C, self.complete_step(decision, C, wall_ms, A, B)

    def run(self, requests, make_request: Callable[[int], Tuple]) -> List[StepReport]:
        """Serve ``requests`` steps of ``make_request(step) -> (A, B)``."""
        start = len(self.reports)
        for i in range(requests):
            self.step(*make_request(i))
        return self.reports[start:]
