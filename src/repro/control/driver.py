"""``AdaptiveServer``: the request loop that closes the control loop.

Each step: record per-worker finish times (real, or drawn from an injected
feed / ``LatencyModel`` for reproducible simulation) -> update the
``WorkerHealthMonitor`` -> let the ``ExpectedLatencyPolicy`` re-rank the
``PlanLadder`` and switch rungs -> emit the monitor's erasure mask (clamped
to the active rung's budget) -> serve the coded matmul through the active
facade with the mask as pure data.  ``CodedElasticPolicy`` consumes the
same mask; when the flagged-straggler count exhausts every rung's budget
the server records a respecialisation handoff (``plan_shrink`` target)
instead of silently waiting on known-slow machines forever.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

import jax

from repro.core.api import uncoded_matmul
from repro.core.simulator import LatencyModel, TimeFeed, WorkerTimes
from repro.distributed.elastic import CodedElasticPolicy, plan_shrink
from repro.control.ladder import PlanLadder
from repro.control.monitor import WorkerHealthMonitor
from repro.control.policy import ExpectedLatencyPolicy

__all__ = ["StepReport", "AdaptiveServer"]


@dataclasses.dataclass(frozen=True)
class StepReport:
    """What one adaptive serving step did and cost."""

    step: int
    rung: str
    switched: bool
    erased: Tuple[int, ...]        # workers the mask dropped this step
    sim_latency_s: float           # modelled step completion (mask-aware)
    wall_ms: float                 # measured facade-call wall time
    slack: int                     # elastic slack AFTER applying the mask
    respecialize: bool             # erasure budget exhausted ladder-wide
    shrink_target: Optional[Tuple[int, int]]  # plan_shrink mesh on handoff
    exact: Optional[bool]          # vs uncoded oracle (None = not checked)


class AdaptiveServer:
    """Monitor -> policy -> ladder, per request.

    feed: injectable per-worker finish-time source; defaults to sampling
        ``fallback_model`` with no stragglers (a healthy cluster).  Real
        deployments pass measured per-worker step times instead.
    reevaluate_every: policy cadence in steps (1 = every step).
    check_exact: compare every decoded C against the uncoded oracle.
    """

    def __init__(self, ladder: PlanLadder, *,
                 monitor: Optional[WorkerHealthMonitor] = None,
                 policy: Optional[ExpectedLatencyPolicy] = None,
                 feed: Optional[TimeFeed] = None,
                 fallback_model: Optional[LatencyModel] = None,
                 reevaluate_every: int = 1,
                 score_threshold: float = 0.5,
                 seed: int = 0,
                 check_exact: bool = False):
        self.ladder = ladder
        self.monitor = monitor or WorkerHealthMonitor(ladder.K)
        self.policy = policy or ExpectedLatencyPolicy(
            ladder, score_threshold=score_threshold)
        self.elastic = CodedElasticPolicy(
            K=ladder.K, tau=ladder.tau(ladder.active))
        self._feed = feed
        self._fallback = fallback_model or LatencyModel(base=1.0, jitter=0.0)
        self.reevaluate_every = max(1, reevaluate_every)
        self.score_threshold = score_threshold
        self.check_exact = check_exact
        self.rng = np.random.default_rng(seed)
        self.steps = 0
        self.reports: List[StepReport] = []

    # -- worker-time ingestion ----------------------------------------------
    def _worker_times(self) -> np.ndarray:
        if self._feed is not None:
            t = np.asarray(self._feed(self.steps, self.rng), dtype=np.float64)
            if t.shape != (self.ladder.K,):
                raise ValueError(
                    f"feed returned shape {t.shape}, need ({self.ladder.K},)")
            return t
        return self._fallback.sample(self.ladder.K, (), self.rng)

    # -- one serving step ----------------------------------------------------
    def step(self, A, B) -> Tuple[jax.Array, StepReport]:
        times = self._worker_times()
        self.monitor.record_step(times)
        scores = self.monitor.straggler_scores()

        switched = False
        # a cold monitor ranks on noise: hold the initial rung until the
        # EWMA estimates have min_history steps behind them (same gating
        # the monitor applies to its erasure mask).
        if (self.monitor.steps >= self.monitor.min_history
                and self.steps % self.reevaluate_every == 0):
            model = self.monitor.fitted_model()
            best = self.policy.select(model, scores)
            if best.rung != self.ladder.active:
                self.ladder.switch(best.rung)
                self.elastic = CodedElasticPolicy(
                    K=self.ladder.K, tau=best.tau,
                    healthy=self.elastic.healthy.copy())
                switched = True

        budget = self.ladder.budget(self.ladder.active)
        mask = self.monitor.erasure_mask(budget, self.score_threshold)
        self.elastic.observe_mask(mask)

        # ladder-wide exhaustion: more persistent stragglers than even the
        # widest-budget FEASIBLE rung can erase -> respecialisation handoff.
        flagged = self.monitor.stragglers(self.score_threshold).size
        max_budget = max((self.ladder.budget(r) for r in self.ladder.rungs
                          if self.policy.feasible(r)), default=0)
        respecialize = flagged > max_budget and self.elastic.must_respecialize
        shrink_target = None
        if respecialize:
            healthy = self.ladder.K - flagged
            try:
                shrink_target = plan_shrink(healthy)
            except ValueError:
                shrink_target = None  # not even a 1x1 mesh left

        t0 = time.perf_counter()
        C = self.ladder(A, B, mask=mask)
        jax.block_until_ready(C)
        wall_ms = (time.perf_counter() - t0) * 1e3

        exact = None
        if self.check_exact:
            exact = bool(np.array_equal(np.asarray(C),
                                        np.asarray(uncoded_matmul(A, B))))

        report = StepReport(
            step=self.steps,
            rung=self.ladder.active,
            switched=switched,
            erased=tuple(int(i) for i in np.flatnonzero(mask == 0)),
            sim_latency_s=WorkerTimes(times).completion_with_mask(mask),
            wall_ms=wall_ms,
            slack=self.elastic.slack,
            respecialize=respecialize,
            shrink_target=shrink_target,
            exact=exact,
        )
        self.reports.append(report)
        self.steps += 1
        return C, report

    def run(self, requests, make_request: Callable[[int], Tuple]) -> List[StepReport]:
        """Serve ``requests`` steps of ``make_request(step) -> (A, B)``."""
        start = len(self.reports)
        for i in range(requests):
            self.step(*make_request(i))
        return self.reports[start:]
