"""Observed-violation feedback: close the SLO loop on what actually happened.

The SLO fallback (DESIGN Sec. 8.2) acts on PREDICTED quantiles of the
monitor's fitted shifted-exponential model.  When the fit is wrong — e.g.
Pareto-tailed stragglers, whose method-of-moments exponential fit
systematically underestimates the tail — predicted tails look safe while
realized violations pile up.  ``ViolationFeedback`` tracks REALIZED step
latencies against the SLO bound over a sliding window and adapts the
``QuantileLatencyPolicy``'s q:

    q_eff = clip(q_base + gain * (realized_rate - target_rate),
                 q_min, q_max)

with ``target_rate = 1 - q_base`` by default (a p99 SLO tolerates 1%
misses).  Excess realized violations TIGHTEN q (a higher quantile makes
every rung's predicted tail larger, so the predictive fallback fires
earlier and ranks more tail-protectively); a clean window LOOSENS q back
toward the base.  ``q_min`` defaults to ``q_base`` itself: with heavy
tails, "no recent misses" is weak evidence of safety — usually it means
the tightened q is WORKING — so loosening below the quantile the SLO was
stated at requires opting in with an explicit ``q_min``.  The law is
monotone non-decreasing in the realized violation rate, which is the
property tests pin down.

On top of the proportional law, ``force_after`` consecutive realized
violations assert ``force_tail_optimal``: the server then switches to the
quantile policy's pick outright, prediction be damned — the escape hatch
for a model so wrong that even the tightened-q prediction stays under the
bound.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

__all__ = ["FeedbackConfig", "ViolationFeedback"]


@dataclasses.dataclass(frozen=True)
class FeedbackConfig:
    """Knobs of the observed-violation control law.

    window:           sliding-window length (steps) the realized violation
                      rate is measured over.
    gain:             dq per unit of excess violation rate.
    q_min / q_max:    clip range of the effective quantile.  ``q_min=None``
                      (default) floors at ``q_base``: the law never
                      loosens below the quantile the SLO is stated at.
    min_observations: observations required before the law moves q off the
                      base (a near-empty window is all noise).
    force_after:      consecutive realized violations that assert
                      ``force_tail_optimal``.
    target_rate:      tolerated violation rate; None = ``1 - q_base``.
    threshold_gain:   d(threshold) per unit of excess violation rate for the
                      monitor's straggler-score threshold (see
                      :meth:`ViolationFeedback.effective_threshold`).
    threshold_min:    floor of the adaptive score threshold — the law never
                      tightens flagging below this (0 would flag everyone).
    """

    window: int = 16
    gain: float = 2.0
    q_min: Optional[float] = None
    q_max: float = 0.999
    min_observations: int = 4
    force_after: int = 3
    target_rate: Optional[float] = None
    threshold_gain: float = 1.0
    threshold_min: float = 0.1

    def __post_init__(self):
        """Validate the configuration ranges."""
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if not 0.0 < self.q_max < 1.0:
            raise ValueError(f"q_max={self.q_max} outside (0, 1)")
        if self.q_min is not None and not 0.0 < self.q_min <= self.q_max:
            raise ValueError(
                f"need 0 < q_min <= q_max, got [{self.q_min}, {self.q_max}]")
        if self.gain < 0:
            raise ValueError(f"gain must be >= 0, got {self.gain}")
        if self.force_after < 1:
            raise ValueError(f"force_after must be >= 1, got {self.force_after}")
        if self.min_observations > self.window:
            # the window can never hold that many: the law would silently
            # stay at q_base forever
            raise ValueError(
                f"min_observations={self.min_observations} exceeds "
                f"window={self.window}; the feedback law could never engage")
        if self.target_rate is not None and not 0.0 <= self.target_rate <= 1.0:
            raise ValueError(f"target_rate={self.target_rate} outside [0, 1]")
        if self.threshold_gain < 0:
            raise ValueError(
                f"threshold_gain must be >= 0, got {self.threshold_gain}")
        if not 0.0 < self.threshold_min <= 1.0:
            raise ValueError(
                f"threshold_min={self.threshold_min} outside (0, 1]")


class ViolationFeedback:
    """Sliding-window realized-violation tracker + q control law.

    Args:
        q_base: the quantile the SLO is stated at (the fallback's anchor).
        slo_s: the SLO bound in seconds realized latencies are judged by.
        config: the control-law knobs (:class:`FeedbackConfig`).

    Raises:
        ValueError: for q_base outside (0, 1) or a non-positive SLO.
    """

    def __init__(self, q_base: float, slo_s: float,
                 config: FeedbackConfig = FeedbackConfig()):
        if not 0.0 < q_base < 1.0:
            raise ValueError(f"q_base={q_base} outside (0, 1)")
        if q_base >= config.q_max:
            # clip range collapses to a point: the proportional law could
            # never tighten (same can-never-engage class as
            # min_observations > window)
            raise ValueError(
                f"q_base={q_base} >= q_max={config.q_max}; raise q_max so "
                f"the feedback law has room to tighten")
        if slo_s <= 0:
            raise ValueError(f"slo_s must be > 0, got {slo_s}")
        self.q_base = float(q_base)
        self.slo_s = float(slo_s)
        self.config = config
        self._window: collections.deque = collections.deque(
            maxlen=config.window)
        self._consecutive = 0
        self.violations = 0
        self.observations = 0

    def observe(self, realized_s: float) -> bool:
        """Fold one step's REALIZED latency in; True if it violated the SLO."""
        violated = bool(realized_s > self.slo_s)
        self._window.append(violated)
        self._consecutive = self._consecutive + 1 if violated else 0
        self.violations += violated
        self.observations += 1
        return violated

    @property
    def realized_rate(self) -> float:
        """Violation rate over the current window (0 while empty)."""
        if not self._window:
            return 0.0
        return sum(self._window) / len(self._window)

    @property
    def target_rate(self) -> float:
        """The tolerated violation rate the law regulates toward."""
        cfg = self.config.target_rate
        return (1.0 - self.q_base) if cfg is None else cfg

    @property
    def force_tail_optimal(self) -> bool:
        """True after ``force_after`` consecutive realized violations."""
        return self._consecutive >= self.config.force_after

    def effective_q(self) -> float:
        """The feedback-adjusted quantile for the NEXT step's predictions.

        Monotone non-decreasing in :attr:`realized_rate`; equals
        ``q_base`` until the window holds ``min_observations`` steps, and
        never drops below ``q_base`` unless ``q_min`` opts in.
        """
        if len(self._window) < self.config.min_observations:
            return self.q_base
        lo = self.q_base if self.config.q_min is None else self.config.q_min
        excess = self.realized_rate - self.target_rate
        return float(np.clip(self.q_base + self.config.gain * excess,
                             lo, self.config.q_max))

    def effective_threshold(self, base: float) -> float:
        """The feedback-adjusted straggler-score threshold for the monitor.

        The mirror image of :meth:`effective_q` for
        ``WorkerHealthMonitor``'s flagging threshold: excess realized
        violations LOWER the threshold (flag borderline-slow workers
        sooner, so the next mask/progress plan stops waiting on them); a
        clean window relaxes it back toward ``base``.  Monotone
        NON-INCREASING in :attr:`realized_rate`; equals ``base`` until the
        window holds ``min_observations`` steps, and never moves above
        ``base`` (relaxing beyond the configured threshold would erase
        nobody the operator asked to keep).

        Args:
            base: the configured threshold (``--monitor-threshold``).

        Returns:
            The clipped threshold in ``[min(threshold_min, base), base]``.
        """
        if len(self._window) < self.config.min_observations:
            return float(base)
        lo = min(self.config.threshold_min, float(base))
        excess = self.realized_rate - self.target_rate
        return float(np.clip(
            float(base) - self.config.threshold_gain * excess, lo, float(base)))
