"""Plan selection over the L <-> tau ladder: mean and tail-quantile policies.

The paper's Sec. IV tradeoff, run online: tighter entry bounds buy a lower
recovery threshold tau, and a lower tau buys a bigger erasure budget
``K - tau`` — more stragglers the next synchronous step can refuse to wait
for.  Both policies model the next step's completion under the monitor's
fitted per-worker ``LatencyModel``:

    step completion = max over kept workers of T_i,  T_i ~ base_i + Exp

where "kept" erases the monitor's flagged stragglers, worst first, up to
the rung's budget.  When a rung's budget covers every flagged straggler
and the budget is saturated this is exactly the tau-th order statistic of
the fitted finish times — the paper's latency model with the order
statistic now a *decision* (which mask to emit) instead of a passive
property of an async master.

``ExpectedLatencyPolicy`` ranks rungs by the MEAN of that distribution
plus the measured per-rung step cost; ``QuantileLatencyPolicy`` ranks by
its q-quantile (p99 by default) — straggler mitigation is a tail story,
and under heavy-tailed stragglers the two rankings genuinely disagree:
the mean hides the tail an SLO pays for.  Both implement the ``Policy``
protocol the ``AdaptiveServer`` drives.

Feasibility is gated by the entry bound: a rung whose digit stack
``(2L)^{p/p'}`` overflows the dtype mantissa (``core.bounds.is_safe``)
cannot decode exactly at this L and is never selected.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core.simulator import (
    LatencyModel,
    WorkerTimes,
    completion_quantile,
    masked_completion_mean,
    masked_completion_quantile,
)
from repro.control.ladder import PlanLadder
from repro.control.partial import plan_partial_progress

__all__ = [
    "RungEstimate",
    "Policy",
    "ExpectedLatencyPolicy",
    "QuantileLatencyPolicy",
]


@dataclasses.dataclass(frozen=True)
class RungEstimate:
    """One rung's ranking entry."""

    rung: str
    tau: int
    budget: int                 # erasure budget K - tau
    feasible: bool              # digit stack fits the dtype mantissa at L
    expected_latency_s: float   # E[step completion] + per-rung overhead
    erased: Tuple[int, ...]     # stragglers the mask would erase on this rung
    unmasked_stragglers: int    # flagged stragglers the budget could NOT cover
    quantile: Optional[float] = None           # q of the tail estimate, if any
    quantile_latency_s: Optional[float] = None  # q-quantile completion + overhead
    progress: Optional[Tuple[float, ...]] = None  # partial plan (sub_tasks > 1)

    @property
    def metric_s(self) -> float:
        """The latency this estimate was ranked by (quantile if present)."""
        return (self.quantile_latency_s if self.quantile_latency_s is not None
                else self.expected_latency_s)


@runtime_checkable
class Policy(Protocol):
    """What the ``AdaptiveServer`` needs from a rung-selection policy.

    Any object with these four methods can drive the control loop; the
    two implementations here share ``_LatencyPolicyBase`` but a custom
    policy (e.g. round-robin, cost-aware) only has to satisfy this shape.
    """

    ladder: PlanLadder

    def feasible(self, rung: str) -> bool:
        """Exact decode possible for ``rung`` at the ladder's entry bound L."""
        ...  # pragma: no cover - protocol

    def estimate(self, rung: str, model: LatencyModel,
                 scores: Optional[np.ndarray] = None) -> "RungEstimate":
        """Latency estimate for serving the next step on ``rung``."""
        ...  # pragma: no cover - protocol

    def rank(self, model: LatencyModel,
             scores: Optional[np.ndarray] = None) -> Sequence["RungEstimate"]:
        """All rungs' estimates, best first."""
        ...  # pragma: no cover - protocol

    def select(self, model: LatencyModel,
               scores: Optional[np.ndarray] = None) -> "RungEstimate":
        """The best feasible rung; raises if the entry bound admits none."""
        ...  # pragma: no cover - protocol


class _LatencyPolicyBase:
    """Shared machinery: victims within budget, trial sampling, ranking.

    Subclasses implement ``_masked_estimate`` to turn the rung's survivor
    mask (under the fitted model) into a ``RungEstimate`` with the
    policy's ranking metric filled in.
    """

    def __init__(self, ladder: PlanLadder, *,
                 overhead_s: Optional[Mapping[str, float]] = None,
                 trials: int = 64, seed: int = 0,
                 score_threshold: float = 0.5, sub_tasks: int = 1):
        if sub_tasks < 1:
            raise ValueError(f"need sub_tasks >= 1, got {sub_tasks}")
        self.ladder = ladder
        self.overhead_s = dict(overhead_s) if overhead_s is not None else None
        self.trials = trials
        self.seed = seed
        self.score_threshold = score_threshold
        self.sub_tasks = int(sub_tasks)

    # -- feasibility (the L gate) -------------------------------------------
    def feasible(self, rung: str) -> bool:
        """Exact decode possible for ``rung`` at the ladder's entry bound L."""
        return self.ladder.feasible(rung)

    # -- shared completion model --------------------------------------------
    def _overhead(self, rung: str) -> float:
        src = (self.overhead_s if self.overhead_s is not None
               else self.ladder.step_overhead_s)
        return float(src.get(rung, 0.0))

    def overhead_for(self, rung: str) -> float:
        """The per-rung additive step cost this policy prices rungs with.

        Public so the server's observed-violation feedback can judge
        REALIZED step latencies (completion + overhead) against the same
        pricing the predictions use.
        """
        return self._overhead(rung)

    def _all_flagged(self, scores: Optional[np.ndarray]) -> np.ndarray:
        """Every worker scoring above threshold, worst first."""
        if scores is None:
            return np.empty(0, dtype=np.int64)
        scores = np.asarray(scores, dtype=np.float64)
        flagged = np.flatnonzero(scores > self.score_threshold)
        return flagged[np.argsort(-scores[flagged], kind="stable")]

    def _victims(self, rung: str, scores: Optional[np.ndarray]) -> Tuple[np.ndarray, int]:
        """(workers the rung's mask would erase, flagged-but-unmasked count)."""
        flagged = self._all_flagged(scores)
        budget = self.ladder.budget(rung)
        return flagged[:budget], max(0, flagged.size - budget)

    def _completions(self, weights: np.ndarray, model: LatencyModel) -> np.ndarray:
        """Per-trial step completions under ``weights`` sampled from ``model``.

        ``weights`` is the 0/1 survivor mask (binary policies) or the
        fractional progress plan (``sub_tasks > 1``).  A deterministic
        model (no jitter) needs a single sample; the rng is re-seeded per
        call so every rung (and every policy sharing a seed) sees the SAME
        sample paths — rankings then compare nested survivor sets on
        identical draws, never sampling noise.
        """
        rng = np.random.default_rng(self.seed)
        trials = self.trials if model.has_jitter else 1
        K = self.ladder.K
        lat = np.empty(trials)
        for t in range(trials):
            times = WorkerTimes(model.sample(K, (), rng))
            lat[t] = (times.completion_with_progress(weights)
                      if self.sub_tasks > 1
                      else times.completion_with_mask(weights))
        return lat

    def estimate(self, rung: str, model: LatencyModel,
                 scores: Optional[np.ndarray] = None) -> RungEstimate:
        """Latency estimate for serving the next step on ``rung``.

        With ``sub_tasks > 1`` the rung is priced under the REFINED law: the
        flagged stragglers' progress plan (``plan_partial_progress``) sets
        fractional waits, so a slow worker's expected contribution is no
        longer zero and the estimate carries the plan in ``progress``.
        """
        victims, unmasked = self._victims(rung, scores)
        if self.sub_tasks > 1:
            flagged = self._all_flagged(scores)
            K = self.ladder.K
            mean_s = np.maximum(
                model.base_vector(K) * (1.0 + model.jitter_vector(K)), 1e-12)
            progress = plan_partial_progress(
                mean_s, flagged, self.sub_tasks, self.ladder.tau(rung))
            victims = np.asarray([i for i in flagged if progress[i] == 0.0],
                                 dtype=np.int64)
            est = self._masked_estimate(rung, model, progress, victims,
                                        unmasked)
            return dataclasses.replace(
                est, progress=tuple(float(x) for x in progress))
        mask = np.ones(self.ladder.K, dtype=np.float64)
        mask[victims] = 0.0
        return self._masked_estimate(rung, model, mask, victims, unmasked)

    def _masked_estimate(self, rung, model, mask, victims,
                         unmasked) -> RungEstimate:
        raise NotImplementedError

    def _base_estimate(self, rung, expected_s, victims, unmasked,
                       **extra) -> RungEstimate:
        return RungEstimate(
            rung=rung,
            tau=self.ladder.tau(rung),
            budget=self.ladder.budget(rung),
            feasible=self.feasible(rung),
            expected_latency_s=float(expected_s) + self._overhead(rung),
            erased=tuple(int(w) for w in victims),
            unmasked_stragglers=unmasked,
            **extra,
        )

    # -- ranking --------------------------------------------------------------
    def rank(self, model: LatencyModel,
             scores: Optional[np.ndarray] = None) -> Sequence[RungEstimate]:
        """All rungs, best first: feasible before infeasible, then the
        policy's latency metric, then tau (prefer the lower threshold on a
        latency tie — it keeps the bigger erasure budget in reserve)."""
        ests = [self.estimate(r, model, scores) for r in self.ladder.rungs]
        return sorted(ests, key=lambda e: (not e.feasible,
                                           round(e.metric_s, 9),
                                           e.tau))

    def select(self, model: LatencyModel,
               scores: Optional[np.ndarray] = None) -> RungEstimate:
        """The best feasible rung; raises if the entry bound admits none."""
        best = self.rank(model, scores)[0]
        if not best.feasible:
            raise ValueError(
                f"no rung of ladder {self.ladder.rungs} decodes exactly at "
                f"L={self.ladder.L} in {self.ladder.dtype}")
        return best


class ExpectedLatencyPolicy(_LatencyPolicyBase):
    """Ranks a ``PlanLadder``'s rungs by EXPECTED next-step completion.

    Args:
        ladder: the plan family to rank.
        overhead_s: per-rung additive step cost (seconds) — typically the
            ladder's ``step_overhead_s`` measured at prewarm (decode
            dominates the spread between rungs).  Missing rungs cost 0.
        trials/seed: Monte-Carlo sampling of the fitted model.  With zero
            fitted jitter one sample is exact and the loop short-circuits.
        score_threshold: monitor score above which a worker counts as a
            straggler for masking purposes.
    """

    def _masked_estimate(self, rung, model, mask, victims,
                         unmasked) -> RungEstimate:
        lat = self._completions(mask, model)
        return self._base_estimate(rung, lat.mean(), victims, unmasked)


class QuantileLatencyPolicy(_LatencyPolicyBase):
    """Ranks rungs by the q-QUANTILE of next-step completion (tail SLO).

    The ranking metric is the q-quantile of the masked completion
    distribution plus the per-rung overhead.  By default the quantile is
    CLOSED-FORM: under the fitted shifted-exponential model the masked
    completion CDF is a product of per-worker factors and
    ``core.simulator.masked_completion_quantile`` inverts it exactly —
    no sampling noise in the tail, where Monte-Carlo is weakest, and no
    sampling at all (``expected_latency_s`` comes from the analytic mean
    too).  Pass ``analytic=False`` to rank by the empirical quantile of
    the same sampled trials the expected policy uses (useful for
    apples-to-apples comparisons and for feeds that are not
    shifted-exponential).

    Args:
        ladder: the plan family to rank.
        q: the SLO quantile in [0, 1] (0.99 = "p99 completion").
        analytic: closed-form CDF inversion (True) or empirical quantile
            of the sampled trials (False).
        overhead_s / trials / seed / score_threshold: as in
            ``ExpectedLatencyPolicy``.

    Raises:
        ValueError: if ``q`` is outside [0, 1].
    """

    def __init__(self, ladder: PlanLadder, *, q: float = 0.99,
                 analytic: bool = True,
                 overhead_s: Optional[Mapping[str, float]] = None,
                 trials: int = 64, seed: int = 0,
                 score_threshold: float = 0.5, sub_tasks: int = 1):
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q={q} outside [0, 1]")
        super().__init__(ladder, overhead_s=overhead_s, trials=trials,
                         seed=seed, score_threshold=score_threshold,
                         sub_tasks=sub_tasks)
        self.q = q
        self.analytic = analytic

    def _masked_estimate(self, rung, model, mask, victims,
                         unmasked) -> RungEstimate:
        if self.analytic:
            expected = masked_completion_mean(model, mask)
            tail = masked_completion_quantile(model, mask, self.q)
        else:
            lat = self._completions(mask, model)
            expected = lat.mean()
            tail = float(completion_quantile(lat, self.q))
        return self._base_estimate(
            rung, expected, victims, unmasked,
            quantile=self.q,
            quantile_latency_s=tail + self._overhead(rung))
