"""Expected-completion-time plan selection over the L <-> tau ladder.

The paper's Sec. IV tradeoff, run online: tighter entry bounds buy a lower
recovery threshold tau, and a lower tau buys a bigger erasure budget
``K - tau`` — more stragglers the next synchronous step can refuse to wait
for.  ``ExpectedLatencyPolicy`` ranks the ladder's rungs by the expected
completion time of the next step under the monitor's fitted per-worker
``LatencyModel``:

    E[ max over kept workers of T_i ] + measured per-rung step cost

where "kept" erases the monitor's flagged stragglers, worst first, up to
the rung's budget.  When a rung's budget covers every flagged straggler
and the budget is saturated this is exactly the tau-th order statistic of
the fitted finish times — the paper's latency model with the order
statistic now a *decision* (which mask to emit) instead of a passive
property of an async master.

Feasibility is gated by the entry bound: a rung whose digit stack
``(2L)^{p/p'}`` overflows the dtype mantissa (``core.bounds.is_safe``)
cannot decode exactly at this L and is never selected.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import LatencyModel, WorkerTimes
from repro.control.ladder import PlanLadder

__all__ = ["RungEstimate", "ExpectedLatencyPolicy"]


@dataclasses.dataclass(frozen=True)
class RungEstimate:
    """One rung's ranking entry."""

    rung: str
    tau: int
    budget: int                 # erasure budget K - tau
    feasible: bool              # digit stack fits the dtype mantissa at L
    expected_latency_s: float   # E[step completion] + per-rung overhead
    erased: Tuple[int, ...]     # stragglers the mask would erase on this rung
    unmasked_stragglers: int    # flagged stragglers the budget could NOT cover


class ExpectedLatencyPolicy:
    """Ranks a ``PlanLadder``'s rungs by expected next-step completion.

    overhead_s: per-rung additive step cost (seconds) — typically the
        ladder's ``step_overhead_s`` measured at prewarm (decode dominates
        the spread between rungs).  Missing rungs cost 0.
    trials/seed: Monte-Carlo sampling of the fitted model.  With zero
        fitted jitter one sample is exact and the loop short-circuits.
    score_threshold: monitor score above which a worker counts as a
        straggler for masking purposes.
    """

    def __init__(self, ladder: PlanLadder, *,
                 overhead_s: Optional[Mapping[str, float]] = None,
                 trials: int = 64, seed: int = 0,
                 score_threshold: float = 0.5):
        self.ladder = ladder
        self.overhead_s = dict(overhead_s) if overhead_s is not None else None
        self.trials = trials
        self.seed = seed
        self.score_threshold = score_threshold

    # -- feasibility (the L gate) -------------------------------------------
    def feasible(self, rung: str) -> bool:
        """Exact decode possible at the ladder's entry bound L?"""
        return self.ladder.feasible(rung)

    # -- expected completion --------------------------------------------------
    def _overhead(self, rung: str) -> float:
        src = (self.overhead_s if self.overhead_s is not None
               else self.ladder.step_overhead_s)
        return float(src.get(rung, 0.0))

    def _victims(self, rung: str, scores: Optional[np.ndarray]) -> Tuple[np.ndarray, int]:
        """(workers the rung's mask would erase, flagged-but-unmasked count)."""
        if scores is None:
            return np.empty(0, dtype=np.int64), 0
        scores = np.asarray(scores, dtype=np.float64)
        flagged = np.flatnonzero(scores > self.score_threshold)
        flagged = flagged[np.argsort(-scores[flagged], kind="stable")]
        budget = self.ladder.budget(rung)
        return flagged[:budget], max(0, flagged.size - budget)

    def estimate(self, rung: str, model: LatencyModel,
                 scores: Optional[np.ndarray] = None) -> RungEstimate:
        """Expected completion of the next step served on ``rung``."""
        K = self.ladder.K
        victims, unmasked = self._victims(rung, scores)
        mask = np.ones(K, dtype=np.float64)
        mask[victims] = 0.0
        rng = np.random.default_rng(self.seed)
        trials = self.trials if model.jitter > 0 else 1
        lat = np.empty(trials)
        for t in range(trials):
            times = WorkerTimes(model.sample(K, (), rng))
            lat[t] = times.completion_with_mask(mask)
        return RungEstimate(
            rung=rung,
            tau=self.ladder.tau(rung),
            budget=self.ladder.budget(rung),
            feasible=self.feasible(rung),
            expected_latency_s=float(lat.mean()) + self._overhead(rung),
            erased=tuple(int(w) for w in victims),
            unmasked_stragglers=unmasked,
        )

    # -- ranking --------------------------------------------------------------
    def rank(self, model: LatencyModel,
             scores: Optional[np.ndarray] = None) -> Sequence[RungEstimate]:
        """All rungs, best first: feasible before infeasible, then expected
        latency, then tau (prefer the lower threshold on a latency tie —
        it keeps the bigger erasure budget in reserve)."""
        ests = [self.estimate(r, model, scores) for r in self.ladder.rungs]
        return sorted(ests, key=lambda e: (not e.feasible,
                                           round(e.expected_latency_s, 9),
                                           e.tau))

    def select(self, model: LatencyModel,
               scores: Optional[np.ndarray] = None) -> RungEstimate:
        """The best feasible rung; raises if the entry bound admits none."""
        best = self.rank(model, scores)[0]
        if not best.feasible:
            raise ValueError(
                f"no rung of ladder {self.ladder.rungs} decodes exactly at "
                f"L={self.ladder.L} in {self.ladder.dtype}")
        return best
