"""Live worker-health monitoring: EWMA latency tracking + straggler scoring.

``WorkerHealthMonitor`` turns per-step worker finish times into the two
artefacts the rest of the control plane consumes:

* an **erasure mask** for the next step — the highest-scoring stragglers,
  never more than the active code's erasure budget, so the synchronous
  mesh step stops waiting for machines the monitor has seen lag; and
* a fitted ``LatencyModel`` — per-worker EWMA means plus a jitter estimate —
  that the expected-latency policy samples to rank ladder rungs.

Scoring is deliberately memoryful: a worker is flagged when its step time
exceeds ``straggler_factor`` x the step's fast-quartile time, and the flag feeds an
exponentially-decayed score, so one noisy step neither erases a healthy
worker nor instantly forgives a persistent straggler.
"""
from __future__ import annotations

import numpy as np

from repro.control.partial import plan_partial_progress
from repro.core.simulator import LatencyModel

__all__ = ["WorkerHealthMonitor"]


class WorkerHealthMonitor:
    """Per-worker EWMA latency/variance + decayed straggler scores.

    alpha:            EWMA gain for the mean/variance estimates.
    score_decay:      per-step decay of the straggler score (score is a
                      convex blend: decay * old + (1 - decay) * flagged).
    straggler_factor: a worker is flagged when its step time exceeds this
                      multiple of the step's fast (25th-percentile) time.
    min_history:      steps to observe before the monitor will erase anyone
                      (a cold monitor emits the all-ones mask).
    """

    def __init__(self, K: int, *, alpha: float = 0.3, score_decay: float = 0.5,
                 straggler_factor: float = 1.5, min_history: int = 2):
        if K < 1:
            raise ValueError(f"need K >= 1 workers, got {K}")
        if not 0 < alpha <= 1:
            raise ValueError(f"alpha={alpha} outside (0, 1]")
        if not 0 <= score_decay < 1:
            raise ValueError(f"score_decay={score_decay} outside [0, 1)")
        if straggler_factor <= 1:
            raise ValueError(f"straggler_factor={straggler_factor} must be > 1")
        self.K = K
        self.alpha = alpha
        self.score_decay = score_decay
        self.straggler_factor = straggler_factor
        self.min_history = min_history
        self.steps = 0
        self._mean = np.zeros(K, dtype=np.float64)
        self._var = np.zeros(K, dtype=np.float64)
        self._score = np.zeros(K, dtype=np.float64)

    # -- ingest -------------------------------------------------------------
    def record_step(self, finish_times) -> None:
        """Fold one step's (K,) per-worker finish times into the estimates."""
        t = np.asarray(finish_times, dtype=np.float64)
        if t.shape != (self.K,):
            raise ValueError(f"finish times shape {t.shape} != ({self.K},)")
        if not np.all(np.isfinite(t)) or np.any(t < 0):
            raise ValueError("finish times must be finite and non-negative")
        if self.steps == 0:
            self._mean = t.copy()
        else:
            d = t - self._mean
            self._mean = self._mean + self.alpha * d
            self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        # flag relative to the fast quartile, not the median: stays correct
        # while up to ~3/4 of the cluster straggles simultaneously
        flagged = t > self.straggler_factor * np.quantile(t, 0.25)
        self._score = (self.score_decay * self._score
                       + (1 - self.score_decay) * flagged)
        self.steps += 1

    def resize(self, keep=None, grow: int = 0) -> None:
        """Resize the tracked pool: keep survivors' state, cold-start joiners.

        ``keep`` lists the pool-local indices that survive (in their new
        order; default all), so an elastic shrink carries each survivor's
        EWMA mean/variance and straggler score to its compacted index
        instead of restarting the monitor.  ``grow`` appends that many new
        workers with zero straggler score and the survivor-average mean as
        their initial latency estimate (a joiner has no history; the pool
        average is the least-surprising prior and keeps ``fitted_model``
        well defined).  ``steps`` is NOT reset: the monitor stays past
        ``min_history`` across a handoff, so erasure masks keep flowing.

        Raises:
            ValueError: on duplicate/out-of-range ``keep`` indices,
                negative ``grow``, or an empty resulting pool.
        """
        idx = (np.arange(self.K, dtype=np.intp) if keep is None
               else np.asarray(keep, dtype=np.intp))
        if idx.ndim != 1 or len(set(idx.tolist())) != idx.size:
            raise ValueError(f"keep must be 1-D and duplicate-free: {keep!r}")
        if idx.size and (idx.min() < 0 or idx.max() >= self.K):
            raise ValueError(f"keep indexes outside the pool of {self.K}")
        if grow < 0:
            raise ValueError(f"grow must be >= 0, got {grow}")
        if idx.size + grow < 1:
            raise ValueError("resize would leave an empty pool")
        fill = (float(np.mean(self._mean[idx]))
                if self.steps and idx.size else 0.0)
        self._mean = np.concatenate(
            [self._mean[idx], np.full(grow, fill, dtype=np.float64)])
        self._var = np.concatenate(
            [self._var[idx], np.zeros(grow, dtype=np.float64)])
        self._score = np.concatenate(
            [self._score[idx], np.zeros(grow, dtype=np.float64)])
        self.K = int(idx.size + grow)

    # -- estimates ----------------------------------------------------------
    @property
    def mean(self) -> np.ndarray:
        """(K,) EWMA per-worker step latency."""
        return self._mean.copy()

    @property
    def std(self) -> np.ndarray:
        """(K,) EWMA per-worker latency standard deviation."""
        return np.sqrt(self._var)

    def straggler_scores(self) -> np.ndarray:
        """(K,) decayed scores in [0, 1]; ~1 = persistently slow."""
        return self._score.copy()

    def stragglers(self, threshold: float = 0.5) -> np.ndarray:
        """Worker ids scoring above ``threshold``, worst first."""
        ids = np.flatnonzero(self._score > threshold)
        return ids[np.argsort(-self._score[ids], kind="stable")]

    # -- control-plane outputs ----------------------------------------------
    def erasure_mask(self, budget: int, threshold: float = 0.5) -> np.ndarray:
        """0/1 mask for the NEXT step: erase up to ``budget`` stragglers.

        Only workers scoring above ``threshold`` are erased, worst first,
        and never more than ``budget`` (the active rung's K - tau), so the
        emitted mask always leaves a decodable survivor set.  A monitor
        with fewer than ``min_history`` steps emits the all-ones mask.
        """
        if budget < 0:
            raise ValueError(f"erasure budget must be >= 0, got {budget}")
        mask = np.ones(self.K, dtype=np.float64)
        if self.steps < self.min_history:
            return mask
        victims = self.stragglers(threshold)[:budget]
        mask[victims] = 0.0
        return mask

    def progress_plan(self, Q: int, tau: int,
                      threshold: float = 0.5) -> np.ndarray:
        """(K,) fractional progress for the NEXT step's partial decode.

        The fractional generalisation of :meth:`erasure_mask`: flagged
        workers start at zero chunks, and ``plan_partial_progress`` raises
        counts only where a chunk would be undercovered — so whenever the
        binary mask leaves a decodable survivor set the plan EQUALS that
        mask, and when flagging exceeds the erasure budget the cheapest
        slices of straggler work are consumed instead of waiting on full
        straggler steps.  A cold monitor emits all-ones (wait for all).
        """
        if self.steps < self.min_history:
            return np.ones(self.K, dtype=np.float64)
        return plan_partial_progress(np.maximum(self._mean, 1e-12),
                                     self.stragglers(threshold), Q, tau)

    def fitted_model(self, fallback_base: float = 1.0) -> LatencyModel:
        """Per-worker ``LatencyModel`` from the EWMA estimates.

        Method-of-moments fit of the shifted-exponential straggler model
        ``T_i = base_i + Exp(scale_i)`` (mean = base + scale, std = scale):
        per-worker ``base_i = mean_i - std_i`` and per-worker jitter
        ``scale_i / base_i``, so a heavy-tailed worker keeps its own tail
        instead of being averaged into a cluster-wide jitter.  A shifted
        exponential cannot have std > mean, so the scale is capped at the
        mean (a transient spike can push the EWMA std past the EWMA mean;
        the cap preserves the observed mean instead of collapsing the
        base to zero).  The fitted bases already carry each worker's
        observed slowness, so ``straggler_slowdown`` is 1 (callers sample
        with ``stragglers=()``).

        Args:
            fallback_base: homogeneous base used before any step was
                recorded (a cold monitor has no estimates).

        Returns:
            A ``LatencyModel`` whose quantiles/CDF the latency policies can
            evaluate in closed form (``core.simulator``).
        """
        if self.steps == 0:
            return LatencyModel(base=fallback_base, straggler_slowdown=1.0)
        mean = np.maximum(self._mean, 1e-12)
        scale = np.minimum(self.std, mean)
        base = np.maximum(mean - scale, 1e-12)
        return LatencyModel(base=base, straggler_slowdown=1.0,
                            jitter=scale / base)
