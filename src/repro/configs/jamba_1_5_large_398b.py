"""Jamba-1.5-Large (398B total / ~94B active) [arXiv:2403.19887; hf].

72 layers of 9x (1 attention : 7 Mamba) blocks; MoE (16 experts, top-2) on
every other layer.  No explicit positional embedding (Mamba provides
position).  GQA 64H/8KV, d_head 128.
"""
import dataclasses

from repro.models import ModelConfig, MoEConfig

_PATTERN = tuple(
    ("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    pattern=_PATTERN,
    pos="none",
    moe=MoEConfig(n_experts=16, top_k=2, d_expert_ff=24576),
    mamba_d_state=16,
    mamba_expand=2,
    mamba_dconv=4,
    tie_embeddings=False,
    sub_quadratic=True,   # hybrid: eligible for long_500k
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="jamba-smoke",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert_ff=128, capacity_factor=4.0),
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
