"""Granite-3.0-8B [hf:ibm-granite/granite-3.0-2b-base family; hf].

40 layers, d_model 4096, GQA 32H/8KV (d_head 128), d_ff 12800, vocab 49155.
Note: vocab 49155 is not divisible by the 16-wide tp axis; the lm_head
shards skip vocab partitioning (see distributed.sharding.shard) and the CE
loss_chunk is reduced to bound the replicated logits tile.
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12800,
    vocab=49155,
    pattern=(("attn", "mlp"),),
    rope_theta=1e6,
    tie_embeddings=False,
    loss_chunk=128,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="granite-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=515,  # deliberately non-divisible, like the full config
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
