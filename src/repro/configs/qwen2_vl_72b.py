"""Qwen2-VL-72B [arXiv:2409.12191; hf].

80-layer backbone, d_model 8192, GQA 64H/8KV (d_head 128), d_ff 29568,
vocab 152064, M-RoPE (sections 16/24/24 over t/h/w position ids).  The
vision frontend (dynamic-resolution ViT) is a STUB per the assignment:
input_specs provides precomputed patch/text embeddings plus 3-axis
position ids.
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    pattern=(("attn", "mlp"),),
    pos="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    qkv_bias=True,
    input_mode="embeds",
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen2-vl-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    mrope_sections=(4, 2, 2),
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
