"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24 layers, d_model 2048, MHA 16H/16KV (d_head 128), QKV bias, 60 routed
experts top-4 (expert d_ff 1408) + shared expert of 4x width (5632),
vocab 151936.
"""
import dataclasses

from repro.models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=151936,
    pattern=(("attn", "moe"),),
    qkv_bias=True,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=60, top_k=4, d_expert_ff=1408, n_shared=4),
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen2-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=96,
    vocab=512,
    moe=MoEConfig(n_experts=6, top_k=2, d_expert_ff=96, n_shared=2,
                  capacity_factor=4.0),
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
