"""Architecture registry: one module per assigned arch + the paper's own."""
from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ShapeSpec,
    cells,
    get_config,
    get_smoke_config,
    list_archs,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "ShapeSpec", "cells", "get_config",
    "get_smoke_config", "list_archs", "shape_applicable",
]
