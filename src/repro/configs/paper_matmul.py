"""The paper's own experimental configuration (Sec. V).

AWS r3.large cluster, 10 workers; A, B random integer 8000x8000 matrices
with entries in {0..50}; 2x2x2 block decomposition (m=n=p=2); evaluation
points: 10 equally spaced reals in [-1, 1]; stragglers simulated by doubled
local computation.  BEC threshold tau=4 vs polynomial-code tau=9.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperMatmulConfig:
    name: str = "paper-matmul"
    v: int = 8000
    r: int = 8000
    t: int = 8000
    p: int = 2
    m: int = 2
    n: int = 2
    K: int = 10
    entry_max: int = 50
    points: str = "equispaced"
    straggler_slowdown: float = 2.0

    @property
    def L(self) -> int:
        return self.v * self.entry_max * self.entry_max + 1


CONFIG = PaperMatmulConfig()
# Reduced-size variant for CPU benches/tests (same geometry, smaller dims).
SMOKE = PaperMatmulConfig(name="paper-matmul-smoke", v=512, r=512, t=512)
