"""Qwen2-0.5B [arXiv:2407.10671; hf].

24 layers, d_model 896, GQA 14H/2KV (d_head 64), QKV bias, d_ff 4864,
vocab 151936, tied embeddings.
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_head=64,
    d_ff=4864,
    vocab=151936,
    pattern=(("attn", "mlp"),),
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen2-0.5b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
