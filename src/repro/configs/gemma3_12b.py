"""Gemma-3-12B [hf:google/gemma-3-1b-pt family; unverified].

48 layers in a 5:1 local(sliding-window 1024):global pattern, d_model 3840,
GQA 16H/8KV (d_head 256), qk-norm, d_ff 15360, vocab 262144, 128k context.
Sub-quadratic eligible: 40/48 layers are windowed; the 8 global layers use
a sequence-sharded KV cache at 500k (DESIGN.md Sec. 8).
"""
import dataclasses

from repro.models import ModelConfig

_PATTERN = tuple(
    ("attn_local" if i < 5 else "attn", "mlp") for i in range(6)
)

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab=262144,
    pattern=_PATTERN,
    window=1024,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    sub_quadratic=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="gemma3-smoke",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    window=16,
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
