"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family; hf].

28 layers, d_model 1024, GQA 16H/8KV with head_dim 128 (Qwen3 decouples
head_dim from d_model), qk-norm, d_ff 3072, vocab 151936, tied embeddings.
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=3072,
    vocab=151936,
    pattern=(("attn", "mlp"),),
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen3-0.6b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
