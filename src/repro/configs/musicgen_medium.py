"""MusicGen-medium [arXiv:2306.05284; hf].

48-layer decoder-only transformer over EnCodec tokens: d_model 1536, MHA
24H/24KV (d_head 64), GELU d_ff 6144, vocab 2048 (codebook size),
sinusoidal positions.  The EnCodec frontend is a STUB per the assignment:
input_specs provides precomputed frame embeddings (B, S, d_model).
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_head=64,
    d_ff=6144,
    vocab=2048,
    pattern=(("attn", "mlp"),),
    act="gelu",
    pos="sinusoidal",
    input_mode="embeds",
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="musicgen-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=128,
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
