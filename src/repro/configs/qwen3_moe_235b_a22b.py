"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf].

94 layers, d_model 4096, GQA 64H/4KV (d_head 128), qk-norm, 128 experts
top-8 (expert d_ff 1536), no shared expert, vocab 151936.
"""
import dataclasses

from repro.models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,
    vocab=151936,
    pattern=(("attn", "moe"),),
    qk_norm=True,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert_ff=1536),
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen3-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=96,
    vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=96, capacity_factor=4.0),
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
    tp_pad=1,
)
