"""RWKV-6 "Finch" 3B [arXiv:2404.05892; hf].

32 layers, d_model 2560 (attention-free), head_dim 64 (40 wkv heads, padded
to 48 so the 16-wide tp axis divides), channel-mix d_ff 8960, vocab 65536.
Data-dependent decay via LoRA (the Finch hallmark).
"""
import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,        # informational: wkv heads = d_model / rwkv_head_dim
    n_kv_heads=40,
    d_head=64,
    d_ff=8960,
    vocab=65536,
    pattern=(("rwkv", "rwkv_cmix"),),
    pos="none",
    rwkv_head_dim=64,
    tp_pad=16,         # pads wkv heads 40 -> 48 for tp=16
    tie_embeddings=False,
    sub_quadratic=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="rwkv6-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    rwkv_head_dim=16,
    tp_pad=1,
    q_chunk=16,
    kv_chunk=32,
    loss_chunk=32,
)
