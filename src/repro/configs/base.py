"""Config registry + the assigned input-shape sets.

Every architecture module defines CONFIG (the exact published geometry) and
SMOKE (a reduced same-family config for CPU smoke tests).  The four LM
shapes are global; ``long_500k`` applies only to sub-quadratic archs
(cfg.sub_quadratic) per the assignment rules - see DESIGN.md Sec. 8.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.models import ModelConfig

ARCH_IDS = (
    "jamba_1_5_large_398b",
    "qwen3_moe_235b_a22b",
    "qwen2_moe_a2_7b",
    "qwen3_0_6b",
    "qwen2_0_5b",
    "gemma3_12b",
    "granite_3_8b",
    "rwkv6_3b",
    "musicgen_medium",
    "qwen2_vl_72b",
    "paper_matmul",  # the paper's own experiment configuration
)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE


def list_archs() -> List[str]:
    return [a for a in ARCH_IDS if a != "paper_matmul"]


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch has no "
                       "sub-quadratic path (DESIGN.md Sec. 8)")
    return True, ""


def cells(arch: str) -> List[Tuple[str, str]]:
    cfg = get_config(arch)
    out = []
    for s in SHAPES:
        ok, _ = shape_applicable(cfg, s)
        if ok:
            out.append((arch, s))
    return out
