"""Injectable time sources for the observability layer.

Spans stamp ``(start, end)`` from whatever clock their session carries.
The default is the process monotonic clock (``time.perf_counter``), which
is right for real runs; simulated-clock runs (the serve tier, chaos
replays) install a :class:`SettableClock` instead and advance it to the
loop's own simulated ``now`` — every context-manager span then stamps
SIMULATED seconds, so two runs of the same (spec, scenario, seed) recipe
produce byte-identical span streams.
"""
from __future__ import annotations

import time
from typing import Callable

__all__ = ["Clock", "MONOTONIC", "SettableClock"]

#: A clock is any zero-argument callable returning seconds as a float.
Clock = Callable[[], float]

#: The default real-time source: monotonic, sub-microsecond, never steps.
MONOTONIC: Clock = time.perf_counter


class SettableClock:
    """A manually-advanced clock for deterministic simulated-time runs.

    Calling the instance reads the current time; :meth:`set` moves it.
    Time never goes backwards — ``set`` clamps to the maximum seen, so a
    loop that interleaves out-of-order bookkeeping cannot produce spans
    that end before they start.
    """

    def __init__(self, start_s: float = 0.0):
        self._t = float(start_s)

    def __call__(self) -> float:
        """The current simulated time in seconds."""
        return self._t

    def set(self, t_s: float) -> float:
        """Advance to ``t_s`` (monotone: never moves backwards)."""
        self._t = max(self._t, float(t_s))
        return self._t
