"""``repro.obs`` — spans, metrics, and exporters for the coded stack.

One process-wide :class:`ObsSession` holds a metrics registry, a span
recorder, and an injectable clock.  Instrumented call sites use the
module-level conveniences (:func:`count`, :func:`observe`, :func:`span`,
:func:`emit_span`) which are near-free no-ops until :func:`enable` is
called — the disabled fast path is one global ``None`` check, so the
instrumented code paths return bit-identical results with observability
off.

Enable programmatically::

    from repro import obs
    obs.enable(fresh=True)
    with obs.span("my.region", kind="demo"):
        ...
    obs.session().registry.total("runtime.executable.compile")

or via the environment: ``REPRO_OBS=1`` enables collection at import
time (used by CI to run the ordinary test suite instrumented).
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

from repro.obs.clock import MONOTONIC, Clock, SettableClock
from repro.obs.metrics import DEFAULT_BUCKETS, MetricsRegistry
from repro.obs.spans import NULL_SPAN, Span, SpanRecorder, span_id_for

__all__ = [
    "ObsSession", "SettableClock", "Span", "SpanRecorder",
    "MetricsRegistry", "DEFAULT_BUCKETS", "span_id_for",
    "enable", "disable", "enabled", "session",
    "count", "gauge", "observe", "span", "emit_span", "use_clock",
]


class ObsSession:
    """One collection session: registry + span recorder + clock."""

    def __init__(self, clock: Clock = MONOTONIC):
        self.registry = MetricsRegistry()
        self.recorder = SpanRecorder(clock)

    @property
    def clock(self) -> Clock:
        """The session's time source (spans stamp from it)."""
        return self.recorder.clock

    @clock.setter
    def clock(self, clock: Clock) -> None:
        """Swap the time source (e.g. a simulated ``SettableClock``)."""
        self.recorder.clock = clock


_session: Optional[ObsSession] = None


def enable(fresh: bool = False, clock: Clock = MONOTONIC) -> ObsSession:
    """Turn collection on, returning the active session.

    ``fresh=True`` discards any previous session (tests and benches use
    this to start from zeroed counters); otherwise an existing session
    keeps accumulating.
    """
    global _session
    if fresh or _session is None:
        _session = ObsSession(clock)
    return _session


def disable() -> None:
    """Turn collection off (instrumented sites become no-ops again)."""
    global _session
    _session = None


def enabled() -> bool:
    """Whether a collection session is active."""
    return _session is not None


def session() -> ObsSession:
    """The active session (raises if observability is disabled)."""
    if _session is None:
        raise RuntimeError(
            "observability is disabled — call repro.obs.enable() first")
    return _session


# -- instrumentation-site conveniences (no-ops while disabled) ---------------

def count(name: str, n: float = 1.0, **labels) -> None:
    """Increment counter ``name`` by ``n`` (no-op while disabled)."""
    if _session is not None:
        _session.registry.counter(name, **labels).inc(n)


def gauge(name: str, value: float, **labels) -> None:
    """Set gauge ``name`` (no-op while disabled)."""
    if _session is not None:
        _session.registry.gauge(name, **labels).set(value)


def observe(name: str, value: float,
            buckets: Optional[Sequence[float]] = None, **labels) -> None:
    """Record ``value`` into histogram ``name`` (no-op while disabled)."""
    if _session is not None:
        _session.registry.histogram(name, buckets=buckets,
                                    **labels).observe(value)


def span(name: str, track: str = "main", lane: str = "main", **attrs):
    """A context manager timing ``name`` (shared no-op while disabled)."""
    if _session is None:
        return NULL_SPAN
    return _session.recorder.span(name, track=track, lane=lane, **attrs)


def emit_span(name: str, start_s: float, end_s: float, track: str = "main",
              lane: str = "main", **attrs) -> Optional[Span]:
    """Record a pre-timed span (no-op while disabled, returning None)."""
    if _session is None:
        return None
    return _session.recorder.emit(name, start_s, end_s, track=track,
                                  lane=lane, **attrs)


def use_clock(clock: Clock) -> None:
    """Point the active session's clock at ``clock`` (no-op if disabled).

    The serve tier calls this with its :class:`SettableClock` so every
    span recorded during the run stamps simulated seconds.
    """
    if _session is not None:
        _session.clock = clock


if os.environ.get("REPRO_OBS", "").strip() not in ("", "0"):
    enable()
