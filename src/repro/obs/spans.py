"""Hierarchical spans: timed, nested regions of work.

A span is one ``(name, start_s, end_s)`` interval with a parent pointer,
an optional ``track``/``lane`` placement (Perfetto rows: one *track* per
SLO class, one *lane* per pipeline stage), and free-form string attrs.
Two ways to produce one:

* ``with recorder.span("runtime.executable.build", kind="matmul"):`` —
  the context manager stamps start/end from the session clock and
  maintains the nesting stack (exception-safe: the span is closed and
  marked ``ok=False`` if the body raises).
* ``recorder.emit("serve.worker_stage", start_s, end_s, ...)`` — for
  pre-timed intervals, e.g. the serve tier's simulated pipeline stages
  whose start/end come from the schedule, not from wall time.

Span IDs are deterministic: the recorder numbers spans in creation
order, and :func:`span_id_for` derives stable seed-keyed IDs for records
that must survive replay byte-identically (serve traces, chaos traces).
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.obs.clock import MONOTONIC, Clock

__all__ = ["Span", "SpanRecorder", "span_id_for"]


def span_id_for(seed: int, kind: str, index: int) -> str:
    """A stable 16-hex-char span ID derived from ``(seed, kind, index)``.

    This is the correlation key stamped into serve/chaos trace records:
    it depends only on the run recipe (the seed), the record kind (e.g.
    ``"step.premium"``), and the record's ordinal — never on wall time —
    so a replayed trace reproduces the IDs byte-identically.
    """
    payload = f"{int(seed)}:{kind}:{int(index)}".encode()
    return hashlib.blake2b(payload, digest_size=8).hexdigest()


@dataclass
class Span:
    """One closed interval of work on the span timeline."""

    sid: int
    name: str
    start_s: float
    end_s: float
    parent: Optional[int] = None
    track: str = "main"
    lane: str = "main"
    attrs: Dict[str, str] = field(default_factory=dict)
    ok: bool = True

    @property
    def duration_s(self) -> float:
        """Span length in seconds."""
        return self.end_s - self.start_s


class _OpenSpan:
    """Context manager for an in-progress span (returned by ``span()``)."""

    __slots__ = ("_rec", "name", "track", "lane", "attrs", "sid",
                 "start_s", "_parent")

    def __init__(self, rec: "SpanRecorder", name: str, track: str,
                 lane: str, attrs: Dict[str, str]):
        self._rec = rec
        self.name = name
        self.track = track
        self.lane = lane
        self.attrs = attrs
        self.sid = -1
        self.start_s = 0.0
        self._parent: Optional[int] = None

    def __enter__(self) -> "_OpenSpan":
        self.sid, self._parent, self.start_s = self._rec._open(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._rec._close(self, ok=exc_type is None)
        return False  # never swallow the exception


class _NullSpan:
    """The do-nothing span handed out when observability is disabled."""

    __slots__ = ()
    sid = -1
    attrs: Dict[str, str] = {}

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


#: Shared no-op context manager — allocation-free on the disabled path.
NULL_SPAN = _NullSpan()


class SpanRecorder:
    """Collects closed :class:`Span`\\ s and tracks the nesting stack.

    The stack is thread-local (each thread nests independently) but the
    closed-span list and the ID counter are shared, guarded by a lock —
    IDs are unique process-wide and reflect creation order.
    """

    def __init__(self, clock: Clock = MONOTONIC):
        self.clock: Clock = clock
        self.spans: List[Span] = []
        self._lock = threading.Lock()
        self._next_sid = 0
        self._local = threading.local()

    # -- internals -----------------------------------------------------------
    def _stack(self) -> List[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _open(self, open_span: _OpenSpan) -> Tuple[int, Optional[int], float]:
        stack = self._stack()
        parent = stack[-1] if stack else None
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
        stack.append(sid)
        return sid, parent, self.clock()

    def _close(self, open_span: _OpenSpan, ok: bool) -> None:
        stack = self._stack()
        # Exception-safety: unwind past any child left open by a raise.
        while stack and stack[-1] != open_span.sid:
            stack.pop()
        if stack:
            stack.pop()
        span = Span(sid=open_span.sid, name=open_span.name,
                    start_s=open_span.start_s, end_s=self.clock(),
                    parent=open_span._parent, track=open_span.track,
                    lane=open_span.lane, attrs=open_span.attrs, ok=ok)
        with self._lock:
            self.spans.append(span)

    # -- public API ----------------------------------------------------------
    def span(self, name: str, track: str = "main", lane: str = "main",
             **attrs) -> _OpenSpan:
        """Open a clock-timed span as a context manager."""
        return _OpenSpan(self, name, track, lane,
                         {k: str(v) for k, v in attrs.items()})

    def emit(self, name: str, start_s: float, end_s: float,
             track: str = "main", lane: str = "main",
             **attrs) -> Span:
        """Record a pre-timed span (simulated schedules, replayed traces).

        The interval is taken verbatim — the session clock is not read —
        and the span is parented to the innermost open span, if any.
        """
        stack = self._stack()
        parent = stack[-1] if stack else None
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
        span = Span(sid=sid, name=name, start_s=float(start_s),
                    end_s=float(end_s), parent=parent, track=track,
                    lane=lane, attrs={k: str(v) for k, v in attrs.items()})
        with self._lock:
            self.spans.append(span)
        return span

    def by_name(self, name: str) -> List[Span]:
        """All closed spans with ``name``, in creation order."""
        return [s for s in self.spans if s.name == name]
