"""Exporters: Chrome-trace/Perfetto JSON and Prometheus text dumps.

``write_perfetto`` renders the span list in the Chrome trace event
format (the JSON flavour Perfetto and ``chrome://tracing`` both load):
one *process* row per span ``track`` (we use tracks for SLO classes),
one *thread* row per ``lane`` (pipeline stages: workers vs decode), and
one complete event (``ph: "X"``) per span with microsecond ``ts``/
``dur``.  Pipeline overlap — decode of batch *t* running concurrently
with workers of batch *t+1* — shows up as overlapping slices on the two
lanes of one track.

``write_prometheus`` dumps the metrics registry in the Prometheus text
exposition format; ``parse_prometheus`` reads such a dump back into
plain dicts for the ``obs_report`` CLI and for tests.
"""
from __future__ import annotations

import json
import os
import re
from typing import Dict, Iterable, List, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.obs.spans import Span

__all__ = ["perfetto_events", "write_perfetto", "write_prometheus",
           "parse_prometheus"]


def _track_ids(spans: Iterable[Span]) -> Tuple[Dict[str, int],
                                               Dict[Tuple[str, str], int]]:
    """Stable (pid per track, tid per (track, lane)) assignments."""
    pids: Dict[str, int] = {}
    tids: Dict[Tuple[str, str], int] = {}
    for s in spans:
        if s.track not in pids:
            pids[s.track] = len(pids) + 1
        key = (s.track, s.lane)
        if key not in tids:
            tids[key] = sum(1 for t, _ in tids if t == s.track) + 1
    return pids, tids


def perfetto_events(spans: Iterable[Span]) -> List[dict]:
    """The Chrome trace event list for ``spans`` (metadata + slices)."""
    spans = list(spans)
    pids, tids = _track_ids(spans)
    events: List[dict] = []
    for track, pid in pids.items():
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "args": {"name": track}})
    for (track, lane), tid in tids.items():
        events.append({"ph": "M", "name": "thread_name",
                       "pid": pids[track], "tid": tid,
                       "args": {"name": lane}})
    for s in spans:
        args = dict(s.attrs)
        if not s.ok:
            args["error"] = "1"
        events.append({
            "ph": "X",
            "name": s.name,
            "pid": pids[s.track],
            "tid": tids[(s.track, s.lane)],
            "ts": round(s.start_s * 1e6, 3),
            "dur": round(max(0.0, s.end_s - s.start_s) * 1e6, 3),
            "args": args,
        })
    return events


def _mkparent(path: str) -> None:
    """Create ``path``'s parent directory if it does not exist yet."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)


def write_perfetto(path: str, spans: Iterable[Span]) -> None:
    """Write ``spans`` as a Chrome-trace JSON file at ``path``."""
    doc = {"traceEvents": perfetto_events(spans),
           "displayTimeUnit": "ms"}
    _mkparent(path)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")


def write_prometheus(path: str, registry: MetricsRegistry) -> None:
    """Write the registry's Prometheus text dump at ``path``."""
    _mkparent(path)
    with open(path, "w") as fh:
        fh.write(registry.to_prometheus())


_SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)\s*$")
_LABEL = re.compile(r'(\w+)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> Dict[str, List[Tuple[Dict[str, str],
                                                        float]]]:
    """Parse a Prometheus text dump into ``{name: [(labels, value)]}``.

    Only what the report CLI needs: sample lines with optional labels;
    ``# TYPE``/comment lines are skipped.  Histogram series keep their
    ``_bucket``/``_sum``/``_count`` suffixed names and ``le`` labels.
    """
    out: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"unparseable metrics line: {line!r}")
        labels = {k: v.replace(r"\"", '"').replace(r"\\", "\\")
                  for k, v in _LABEL.findall(m.group("labels") or "")}
        raw = m.group("value")
        value = float("inf") if raw == "+Inf" else float(raw)
        out.setdefault(m.group("name"), []).append((labels, value))
    return out
