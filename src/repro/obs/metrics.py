"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Metric names are dotted lowercase paths (``runtime.executable.compile``,
``serve.shed``); labels are keyword arguments at the call site
(``count("serve.shed", reason="rate_limited")``).  Each distinct
``(name, labels)`` pair owns one instrument, created on first touch, so
instrumentation sites never pre-register anything.

Histograms use FIXED bucket edges chosen at first touch (default:
latency-shaped seconds).  Fixed edges are what make dumps comparable
across runs — two runs of the same recipe produce the same bucket rows,
so a regression shows up as a count shift, not a re-binned axis.

``to_prometheus`` renders the whole registry in the Prometheus text
exposition format (dots become underscores; histograms emit cumulative
``_bucket{le=...}`` rows plus ``_sum``/``_count``).
"""
from __future__ import annotations

import bisect
import re
from typing import Dict, Iterable, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_BUCKETS", "prom_name"]

#: Default histogram edges (seconds): spans sub-millisecond kernel calls
#: through multi-minute simulated serving tails.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
    100.0)

LabelSet = Tuple[Tuple[str, str], ...]

_PROM_OK = re.compile(r"[^a-zA-Z0-9_:]")


def prom_name(name: str) -> str:
    """``name`` sanitised for the Prometheus exposition format."""
    return _PROM_OK.sub("_", name)


class Counter:
    """A monotonically increasing count."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0; counters never decrease)."""
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """A point-in-time value (last write wins)."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        """Record the current value."""
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with Prometheus cumulative semantics.

    ``edges`` are the finite upper bounds (ascending); an observation
    lands in the first bucket whose edge is >= the value, or the implicit
    ``+Inf`` bucket past the last edge.  ``counts`` holds the PER-BUCKET
    (non-cumulative) counts, length ``len(edges) + 1``.
    """

    kind = "histogram"
    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float] = DEFAULT_BUCKETS):
        self.edges = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError(f"bucket edges must be strictly ascending: "
                             f"{edges}")
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        """Record one observation (edge-inclusive: ``v == edge`` lands in
        that edge's bucket, matching Prometheus ``le`` semantics)."""
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1

    def cumulative(self) -> Tuple[Tuple[float, int], ...]:
        """``(le, cumulative_count)`` rows, ending with ``(inf, count)``."""
        out = []
        running = 0
        for edge, n in zip(self.edges, self.counts):
            running += n
            out.append((edge, running))
        out.append((float("inf"), self.count))
        return tuple(out)


class MetricsRegistry:
    """All instruments of one observability session, keyed (name, labels).

    A name is bound to ONE instrument kind on first touch; asking for the
    same name as a different kind raises (``serve.shed`` cannot be a
    counter in one module and a histogram in another).
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, LabelSet], object] = {}
        self._kinds: Dict[str, str] = {}

    @staticmethod
    def _label_key(labels: dict) -> LabelSet:
        return tuple(sorted((str(k), str(v)) for k, v in labels.items()))

    def _get(self, name: str, kind: str, factory, labels: dict):
        have = self._kinds.setdefault(name, kind)
        if have != kind:
            raise ValueError(
                f"metric {name!r} is already a {have}, not a {kind}")
        key = (name, self._label_key(labels))
        metric = self._metrics.get(key)
        if metric is None:
            metric = factory()
            self._metrics[key] = metric
        return metric

    def counter(self, name: str, **labels) -> Counter:
        """The counter for ``(name, labels)`` (created on first touch)."""
        return self._get(name, "counter", Counter, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        """The gauge for ``(name, labels)`` (created on first touch)."""
        return self._get(name, "gauge", Gauge, labels)

    def histogram(self, name: str, buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        """The histogram for ``(name, labels)``.

        ``buckets`` fixes the edges on FIRST touch; later calls must pass
        the same edges (or None to accept whatever was fixed).
        """
        hist = self._get(
            name, "histogram",
            lambda: Histogram(buckets if buckets is not None
                              else DEFAULT_BUCKETS), labels)
        if buckets is not None and tuple(float(b) for b in buckets) != \
                hist.edges:
            raise ValueError(
                f"histogram {name!r} already has edges {hist.edges}; "
                f"cannot re-bucket to {tuple(buckets)}")
        return hist

    # -- read side -----------------------------------------------------------
    def collect(self) -> Iterable[Tuple[str, LabelSet, object]]:
        """Every instrument as ``(name, labels, metric)``, sorted."""
        return sorted(self._metrics.items(), key=lambda kv: kv[0])

    def total(self, name: str) -> float:
        """Sum of a counter/gauge across ALL label sets (0.0 if untouched)."""
        return sum(m.value for (n, _), m in self._metrics.items()
                   if n == name and hasattr(m, "value"))

    def value(self, name: str, **labels) -> Optional[float]:
        """One counter/gauge value, or None if that label set never fired."""
        m = self._metrics.get((name, self._label_key(labels)))
        return None if m is None or not hasattr(m, "value") else m.value

    # -- Prometheus text exposition ------------------------------------------
    def to_prometheus(self) -> str:
        """The whole registry in Prometheus text format (sorted, stable)."""
        by_name: Dict[str, list] = {}
        for (name, labels), metric in self.collect():
            by_name.setdefault(name, []).append((labels, metric))
        lines = []
        for name in sorted(by_name):
            pn = prom_name(name)
            kind = self._kinds[name]
            lines.append(f"# TYPE {pn} {kind}")
            for labels, metric in by_name[name]:
                if kind == "histogram":
                    for le, cum in metric.cumulative():
                        le_s = "+Inf" if le == float("inf") else repr(le)
                        lines.append(f"{pn}_bucket"
                                     f"{_fmt_labels(labels + (('le', le_s),))}"
                                     f" {cum}")
                    lines.append(f"{pn}_sum{_fmt_labels(labels)} "
                                 f"{_fmt_value(metric.sum)}")
                    lines.append(f"{pn}_count{_fmt_labels(labels)} "
                                 f"{metric.count}")
                else:
                    lines.append(f"{pn}{_fmt_labels(labels)} "
                                 f"{_fmt_value(metric.value)}")
        return "\n".join(lines) + "\n"


def _fmt_labels(labels: LabelSet) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels)
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n")


def _fmt_value(v: float) -> str:
    # integers render without a trailing .0 (counters are usually counts)
    return str(int(v)) if float(v).is_integer() else repr(float(v))
