"""``obs_report`` — render a human-readable summary of an obs dump.

Reads the Prometheus text dump (and optionally the Perfetto JSON) that
``coded_serve --metrics-out/--perfetto-out`` writes and prints a run
summary: top spans by total time, cache-hit ratios, the shed breakdown,
and per-rung stage latency histograms.  Pure text in, pure text out —
the ``render`` function is deterministic for a given pair of dumps, so
tests golden-check it.

Usage::

    PYTHONPATH=src python -m repro.obs.report --metrics m.prom \\
        [--perfetto t.json] [--top 10]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

from repro.obs.export import parse_prometheus

__all__ = ["render", "main"]

Samples = Dict[str, List[Tuple[Dict[str, str], float]]]

#: ``(title, hit_series, miss_or_cost_series)`` ratio rows.  The second
#: series is the "other" outcome — hits / (hits + other).
_RATIO_ROWS = (
    ("runtime.executable", "runtime_executable_hit",
     "runtime_executable_compile"),
    ("decode.panel_cache", "decode_panel_cache_hit",
     "decode_panel_cache_miss"),
)


def _total(samples: Samples, name: str) -> float:
    return sum(v for _, v in samples.get(name, ()))


def _fmt_labels(labels: Dict[str, str]) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def _section_counters(samples: Samples) -> List[str]:
    lines = ["== counters =="]
    skip = ("_bucket", "_sum", "_count")
    for name in sorted(samples):
        if name.endswith(skip):
            continue
        for labels, value in samples[name]:
            label_s = f"{{{_fmt_labels(labels)}}}" if labels else ""
            lines.append(f"  {name}{label_s} = {value:g}")
    return lines


def _section_ratios(samples: Samples) -> List[str]:
    lines = ["== cache hit ratios =="]
    for title, hit_name, other_name in _RATIO_ROWS:
        hits = _total(samples, hit_name)
        other = _total(samples, other_name)
        denom = hits + other
        if denom == 0:
            continue
        lines.append(f"  {title}: {hits:g} hit / {other:g} other "
                     f"= {hits / denom:.1%}")
    if len(lines) == 1:
        lines.append("  (no cache activity recorded)")
    return lines


def _section_sheds(samples: Samples) -> List[str]:
    lines = ["== admission =="]
    admitted = _total(samples, "serve_admit")
    lines.append(f"  admitted = {admitted:g}")
    sheds = samples.get("serve_shed", [])
    if not sheds:
        lines.append("  shed = 0")
        return lines
    lines.append(f"  shed = {sum(v for _, v in sheds):g}")
    for labels, value in sorted(sheds, key=lambda lv: _fmt_labels(lv[0])):
        lines.append(f"    {_fmt_labels(labels)}: {value:g}")
    return lines


def _section_histograms(samples: Samples) -> List[str]:
    lines = ["== latency histograms =="]
    any_rows = False
    for name in sorted(samples):
        if not name.endswith("_bucket"):
            continue
        base = name[: -len("_bucket")]
        # group bucket samples by their non-le label set
        groups: Dict[Tuple[Tuple[str, str], ...],
                     List[Tuple[float, float]]] = {}
        for labels, value in samples[name]:
            le = labels.get("le", "+Inf")
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            groups.setdefault(key, []).append(
                (float("inf") if le == "+Inf" else float(le), value))
        for key in sorted(groups):
            label_s = (f"{{{_fmt_labels(dict(key))}}}" if key else "")
            total = max(v for _, v in groups[key])
            sums = [v for labels, v in samples.get(base + "_sum", ())
                    if tuple(sorted((k, x) for k, x in labels.items()))
                    == key]
            mean = (sums[0] / total) if sums and total else 0.0
            lines.append(f"  {base}{label_s}: n={total:g} mean={mean:.4g}s")
            prev = 0.0
            for le, cum in sorted(groups[key]):
                in_bucket = cum - prev
                prev = cum
                if in_bucket <= 0:
                    continue
                le_s = "+Inf" if le == float("inf") else f"{le:g}"
                lines.append(f"    le {le_s}: {in_bucket:g}")
            any_rows = True
    if not any_rows:
        lines.append("  (no histograms recorded)")
    return lines


def _section_spans(events: List[dict], top: int) -> List[str]:
    lines = [f"== top spans (by total time, top {top}) =="]
    agg: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        agg.setdefault(ev["name"], []).append(ev.get("dur", 0.0))
    rows = sorted(agg.items(), key=lambda kv: (-sum(kv[1]), kv[0]))[:top]
    if not rows:
        lines.append("  (no spans recorded)")
    for name, durs in rows:
        total_s = sum(durs) / 1e6
        lines.append(f"  {name}: n={len(durs)} total={total_s:.4g}s "
                     f"mean={total_s / len(durs):.4g}s")
    return lines


def render(metrics_text: str, perfetto_doc: Optional[dict] = None,
           top: int = 10) -> str:
    """The full report for one metrics dump (+ optional Perfetto trace)."""
    samples = parse_prometheus(metrics_text)
    blocks = []
    if perfetto_doc is not None:
        blocks.append(_section_spans(
            perfetto_doc.get("traceEvents", []), top))
    blocks.append(_section_ratios(samples))
    blocks.append(_section_sheds(samples))
    blocks.append(_section_histograms(samples))
    blocks.append(_section_counters(samples))
    return "\n".join("\n".join(b) for b in blocks) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    """CLI entry point: print the report for the given dump files."""
    ap = argparse.ArgumentParser(prog="obs_report", description=__doc__)
    ap.add_argument("--metrics", required=True,
                    help="Prometheus text dump (from --metrics-out)")
    ap.add_argument("--perfetto", default=None,
                    help="Perfetto/Chrome-trace JSON (from --perfetto-out)")
    ap.add_argument("--top", type=int, default=10,
                    help="how many span rows to show")
    args = ap.parse_args(argv)
    with open(args.metrics) as fh:
        metrics_text = fh.read()
    perfetto_doc = None
    if args.perfetto:
        with open(args.perfetto) as fh:
            perfetto_doc = json.load(fh)
    print(render(metrics_text, perfetto_doc, top=args.top), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
