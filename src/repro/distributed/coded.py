"""On-mesh coded matmul: thin delegates over the unified runtime.

The 4-stage shard_map pipeline (ENCODE -> WORKER -> ERASE -> DECODE, one
worker per device, a lost chip absorbed within the step - DESIGN.md Sec. 3)
now lives in ``repro.runtime.executors.MeshExecutor``.  This module keeps
the legacy ``coded_matmul_mesh`` signature as a deprecation shim and the
``CodedLinearPlan`` layer as a thin wrapper over the ``CodedMatmul``
facade.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.api import CodedMatmulPlan, runtime_facade
from repro.core.decoding import DecodePanelCache
from repro.runtime import CodedMatmul

__all__ = ["coded_matmul_mesh", "CodedLinearPlan"]


def coded_matmul_mesh(
    A: jnp.ndarray,
    B: jnp.ndarray,
    plan: CodedMatmulPlan,
    mesh: Mesh,
    mask: Optional[jnp.ndarray] = None,
    *,
    axis: str = "model",
    use_kernels: bool = True,
    fused: bool = True,
    panel_cache: Optional[DecodePanelCache] = None,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """DEPRECATED: use ``repro.runtime.CodedMatmul(plan, "mesh", mesh=...)``.

    C = A^T B on the mesh, tolerating up to K - tau erased workers.
    ``mask``: (K,) 0/1 survivors (default all alive); the mesh axis size
    must equal plan.K (one worker per device).  Concrete masks decode
    through a host-factored panel (no solve in the traced program); traced
    masks fall back to the in-body masked normal-equation solve.  A passed
    ``panel_cache`` is adopted by the shared facade so its ``builds``
    counter keeps tracking factorisations.
    """
    warnings.warn(
        "coded_matmul_mesh is deprecated; use repro.runtime.CodedMatmul "
        "with backend='mesh'",
        DeprecationWarning, stacklevel=2)
    cm = runtime_facade(plan, "mesh", dtype, panel_cache=panel_cache,
                        mesh=mesh, axis=axis, use_kernels=use_kernels,
                        fused=fused)
    return cm(A, B, mask=mask)


def _quant_scale(x: jnp.ndarray, qmax: int) -> jnp.ndarray:
    """Scale so round(x / scale) lands on the integer grid [-qmax, qmax].

    All-zero (or denormal-tiny) inputs get scale 1 instead of the old
    additive epsilon: with ``max|x| = 0`` the quantised tensor is exactly
    zero either way, but for ``max|x|`` below the epsilon the old formula
    collapsed every entry to zero (scale-only outputs); dividing by the
    true max keeps the full quantisation range at any magnitude.
    """
    mx = jnp.max(jnp.abs(x))
    return jnp.where(mx > 0, mx / qmax, jnp.ones_like(mx))


class CodedLinearPlan:
    """Straggler-tolerant linear layer y = x @ W via the coded pipeline.

    Maps y = x W onto the paper's C = A^T B with A = x^T (d, N), B = W
    (d, V): the contraction (d) is the coded dimension, so each worker
    holds 1/(mp) of the activations and 1/(np) of the weight - the paper's
    memory model - and any tau of K workers determine the output.

    For float inputs the layer quantises x and W onto integer grids
    (scale-and-round, the paper's footnote 1), runs the exact integer coded
    matmul, and rescales.  ``quant_bits`` bounds the grids so the digit
    stack fits the dtype (bounds.plan_p_prime is the policy).

    The layer delegates to a ``CodedMatmul`` facade on the "mesh" backend:
    the facade owns the ``DecodePanelCache`` (decode weights factored once
    per erasure pattern) and the jit-executable memo (steps after the first
    reuse one compiled program even as the mask changes).
    """

    def __init__(self, plan: CodedMatmulPlan, mesh: Mesh, *,
                 axis: str = "model", quant_bits: int = 4,
                 fused: bool = True, dtype=jnp.float32):
        self.plan = plan
        self.mesh = mesh
        self.axis = axis
        self.quant_bits = quant_bits
        self.fused = fused
        self.dtype = dtype
        self.matmul = CodedMatmul(plan, "mesh", mesh=mesh, axis=axis,
                                  fused=fused, dtype=dtype)
        self.panel_cache = self.matmul.panel_cache

    def __call__(self, x: jnp.ndarray, W: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        qmax = 2 ** (self.quant_bits - 1) - 1
        sx = _quant_scale(x, qmax)
        sw = _quant_scale(W, qmax)
        xi = jnp.round(x / sx)
        wi = jnp.round(W / sw)
        yi = self.matmul(xi.T, wi, mask=mask)
        return (yi * (sx * sw)).astype(x.dtype)
