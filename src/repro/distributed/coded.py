"""On-mesh coded matmul: the paper's pipeline as one shard_map program.

The paper's master/worker RPC becomes a single-program mesh computation over
a ``workers`` mesh axis (we reuse "model"):

  stage 1  ENCODE   - device k builds its coded blocks A~_k, B~_k from the
                      coefficient table row k;
  stage 2  WORKER   - device k computes Y_k = A~_k^T B~_k;
                      in the default FUSED mode stages 1+2 run as ONE Pallas
                      megakernel (coded_fused) that forms the coded tiles in
                      VMEM inside the matmul tiling - A~/B~ never touch HBM;
                      ``fused=False`` keeps the staged encode -> matmul_t
                      schedule for A/B comparison;
  stage 3  ERASE    - an erasure mask (data, not process death) zeroes the
                      outputs of "failed" workers - on a real pod this mask
                      comes from the health monitor / timeout watchdog;
  stage 4  DECODE   - Y is all-gathered and every device recovers the C
                      blocks it owns from ANY tau surviving outputs via the
                      mask-weighted normal equations + digit extraction.
                      With a ``panel_cache`` (concrete masks) the normal
                      equations are LU-factored ONCE on the host per erasure
                      pattern and the body receives the ready (mn, K) weight
                      panel - no linear solve runs on any device.

A lost chip's contribution is thus absorbed WITHIN the step - no restart,
no recompute - which is the paper's straggler/fault story adapted to the
synchronous-mesh world (DESIGN.md Sec. 3).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.api import CodedMatmulPlan
from repro.core.decoding import DecodePanelCache, digit_extract
from repro.core.partition import block_decompose, block_recompose, unpad
from repro.distributed.sharding import shard_map_compat
from repro.kernels import ops as kops

__all__ = ["coded_matmul_mesh", "CodedLinearPlan"]


def _decode_weights_masked(z_all: jnp.ndarray, mask: jnp.ndarray, tau: int,
                           useful: np.ndarray):
    """Rows of the pseudo-inverse Vandermonde for the useful powers only.

    W_useful (mn, K): X_useful = W_useful @ Y_all (erased rows weighted 0).
    Solved from the normal equations G X = V^T D Y with D = diag(mask);
    LU solve, not explicit inversion - for large tau the Vandermonde normal
    equations are ill-conditioned and G^{-1} squares the error."""
    K = z_all.shape[0]
    V = z_all[:, None] ** jnp.arange(tau)[None, :]          # (K, tau)
    Vw = V * mask.astype(V.dtype)[:, None]
    G = V.T @ Vw                                             # (tau, tau)
    # W_full = G^{-1} V_w^T : (tau, K); we need the useful rows.
    W_full = jnp.linalg.solve(G, Vw.T)
    return W_full[useful]                                    # (mn, K)


def _worker_body(a_blocks, b_blocks, mask, coeff_a, coeff_b, zW,
                 *, tau, s, useful, axis, use_kernels, fused, have_panel):
    """Per-device body.  a_blocks (p, m, bv, br) replicated; mask (K,).

    ``zW`` is the decode operand: the precomputed (mn, K) weight panel when
    ``have_panel`` (no solve below), else the (K,) evaluation points from
    which the masked normal equations are solved in-body (dynamic masks).
    """
    k = jax.lax.axis_index(axis)
    p, m, bv, br = a_blocks.shape
    _, n, _, bt = b_blocks.shape

    ca = jax.lax.dynamic_index_in_dim(coeff_a, k, axis=0)     # (1, p, m)
    cb = jax.lax.dynamic_index_in_dim(coeff_b, k, axis=0)
    if use_kernels and fused:
        # stages 1+2 fused: coded tiles exist only in VMEM.
        y_local = kops.fused_worker(
            ca.reshape(1, p * m), cb.reshape(1, p * n),
            a_blocks.reshape(p * m, bv, br),
            b_blocks.reshape(p * n, bv, bt))[0]               # (br, bt)
    elif use_kernels:
        a_tilde = kops.encode(ca.reshape(1, p * m),
                              a_blocks.reshape(p * m, bv * br)).reshape(bv, br)
        b_tilde = kops.encode(cb.reshape(1, p * n),
                              b_blocks.reshape(p * n, bv * bt)).reshape(bv, bt)
        y_local = kops.matmul_t(a_tilde, b_tilde)             # (br, bt)
    else:
        a_tilde = jnp.einsum("pm,pmvr->vr", ca[0], a_blocks)
        b_tilde = jnp.einsum("pn,pnvt->vt", cb[0], b_blocks)
        y_local = a_tilde.T @ b_tilde

    # stage 3: erasure - zero out "failed" workers' outputs.
    y_local = y_local * jax.lax.dynamic_index_in_dim(mask, k, 0, keepdims=False)
    # stage 4: all-gather and decode everywhere (each device keeps its C).
    Y = jax.lax.all_gather(y_local, axis)                    # (K, br, bt)
    if have_panel:
        W = zW                                               # (mn, K), ready
    else:
        W = _decode_weights_masked(zW, mask, tau, useful)    # (mn, K)
    X = jnp.einsum("uk,krt->urt", W, Y)
    C = digit_extract(X, s) if s is not None else jnp.round(X)
    return C.reshape(m, n, br, bt)


def coded_matmul_mesh(
    A: jnp.ndarray,
    B: jnp.ndarray,
    plan: CodedMatmulPlan,
    mesh: Mesh,
    mask: Optional[jnp.ndarray] = None,
    *,
    axis: str = "model",
    use_kernels: bool = True,
    fused: bool = True,
    panel_cache: Optional[DecodePanelCache] = None,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """C = A^T B on the mesh, tolerating up to K - tau erased workers.

    ``mask``: (K,) 0/1 survivors (default all alive).  The mesh axis size
    must equal plan.K (one worker per device).  Exactness is governed by the
    plan's bounds analysis (use f64 on CPU for paper-scale L).

    ``fused`` runs stages 1+2 through the coded_fused megakernel (only
    meaningful with ``use_kernels``).  ``panel_cache`` (from
    ``plan.make_panel_cache()``) precomputes/LU-caches the decode weights per
    erasure pattern on the host, so the shard-mapped body contains NO linear
    solve; it is used whenever ``mask`` is concrete (not a tracer) and falls
    back to the in-body masked solve for traced masks.
    """
    K = mesh.shape[axis]
    if K != plan.K:
        raise ValueError(f"plan built for K={plan.K}, mesh axis has {K}")
    g = plan.scheme.grid
    mask_concrete = mask is None or not isinstance(mask, jax.core.Tracer)
    if mask is None:
        mask = jnp.ones((K,), dtype)
    a_blocks = block_decompose(A.astype(dtype), g.p, g.m)
    b_blocks = block_decompose(B.astype(dtype), g.p, g.n)
    useful = np.asarray(plan.scheme.useful_z_exp().reshape(-1))
    s = plan.s if plan.scheme.needs_digit_extraction else None

    have_panel = panel_cache is not None and mask_concrete
    if have_panel:
        panel = panel_cache.get(np.asarray(mask))
        zW = jnp.asarray(np.asarray(panel.W).real, dtype)     # (mn, K)
    else:
        zW = jnp.asarray(plan.z_points, dtype)                # (K,)

    body = partial(
        _worker_body, tau=plan.tau, s=s, useful=useful, axis=axis,
        use_kernels=use_kernels, fused=fused, have_panel=have_panel)
    C_blocks = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P()),   # replicated inputs
        out_specs=P(),
    )(a_blocks, b_blocks, mask.astype(dtype),
      jnp.asarray(plan.coeff_a, dtype), jnp.asarray(plan.coeff_b, dtype),
      zW)
    C = block_recompose(C_blocks)
    return unpad(C, (A.shape[1], B.shape[1]))


class CodedLinearPlan:
    """Straggler-tolerant linear layer y = x @ W via the coded pipeline.

    Maps y = x W onto the paper's C = A^T B with A = x^T (d, N), B = W
    (d, V): the contraction (d) is the coded dimension, so each worker
    holds 1/(mp) of the activations and 1/(np) of the weight - the paper's
    memory model - and any tau of K workers determine the output.

    For float inputs the layer quantises x and W onto integer grids
    (scale-and-round, the paper's footnote 1), runs the exact integer coded
    matmul, and rescales.  ``quant_bits`` bounds the grids so the digit
    stack fits the dtype (bounds.plan_p_prime is the policy).

    The layer owns a DecodePanelCache: across steps with an unchanged
    erasure pattern the decode weights are factored once and reused (the
    per-step decode is then one einsum on-device).
    """

    def __init__(self, plan: CodedMatmulPlan, mesh: Mesh, *,
                 axis: str = "model", quant_bits: int = 4,
                 fused: bool = True, dtype=jnp.float32):
        self.plan = plan
        self.mesh = mesh
        self.axis = axis
        self.quant_bits = quant_bits
        self.fused = fused
        self.dtype = dtype
        self.panel_cache = plan.make_panel_cache()

    def __call__(self, x: jnp.ndarray, W: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        qmax = 2 ** (self.quant_bits - 1) - 1
        sx = jnp.max(jnp.abs(x)) / qmax + 1e-9
        sw = jnp.max(jnp.abs(W)) / qmax + 1e-9
        xi = jnp.round(x / sx)
        wi = jnp.round(W / sw)
        yi = coded_matmul_mesh(xi.T, wi, self.plan, self.mesh, mask,
                               axis=self.axis, fused=self.fused,
                               panel_cache=self.panel_cache, dtype=self.dtype)
        return (yi * (sx * sw)).astype(x.dtype)
