"""Elastic scaling: re-specialise the job when the healthy device set shrinks.

Large jobs lose nodes.  Two recovery tiers here:

1. IN-STEP (the paper's contribution): coded matmuls tolerate up to K - tau
   erased workers per step with NO re-lowering - the erasure mask is data.
   ``CodedElasticPolicy`` tracks the healthy mask and decides when losses
   exceed the code's slack.

2. RE-SPECIALISE: when slack is exhausted, pick the largest supported mesh
   that fits the healthy device count, re-lower the step functions, and
   restore from the latest checkpoint (parameters are resharded by jit's
   in_shardings on load).  ``plan_shrink`` chooses the target mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["CodedElasticPolicy", "plan_shrink"]


@dataclasses.dataclass
class CodedElasticPolicy:
    """Tracks worker health against the code's erasure budget."""

    K: int
    tau: int
    healthy: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.healthy is None:
            self.healthy = np.ones(self.K, dtype=bool)

    @property
    def slack(self) -> int:
        return int(self.healthy.sum()) - self.tau

    def mark_failed(self, worker: int) -> None:
        self.healthy[worker] = False

    def mark_recovered(self, worker: int) -> None:
        self.healthy[worker] = True

    def observe_mask(self, mask) -> None:
        """Adopt a health monitor's 0/1 survivor mask as the healthy set.

        Control-plane integration point: ``WorkerHealthMonitor.erasure_mask``
        feeds here each step, so ``slack``/``must_respecialize`` track the
        LIVE straggler picture instead of only explicit failure events.
        """
        m = np.asarray(mask)
        if m.shape != (self.K,):
            raise ValueError(f"mask shape {m.shape} != ({self.K},)")
        self.healthy = (m != 0).copy()

    def mask(self) -> np.ndarray:
        return self.healthy.astype(np.float64)

    def shrink(self, keep) -> None:
        """Drop every worker not in ``keep`` (pool-local indices, ordered).

        The executed-respecialisation path: after the ladder re-lowers
        onto the survivor pool, the policy's K and health state follow —
        survivors keep their health bits at their new (compacted)
        indices.

        Raises:
            ValueError: on duplicate/out-of-range indices or an empty
                survivor set.
        """
        idx = np.asarray(keep, dtype=np.intp)
        if idx.ndim != 1 or idx.size < 1:
            raise ValueError(f"keep must be 1-D and non-empty, got {keep!r}")
        if len(set(idx.tolist())) != idx.size:
            raise ValueError(f"keep has duplicate indices: {keep!r}")
        if idx.min() < 0 or idx.max() >= self.K:
            raise ValueError(f"keep indexes outside the pool of {self.K}")
        self.healthy = self.healthy[idx].copy()
        self.K = int(idx.size)

    def grow(self, g: int) -> None:
        """Admit ``g`` new workers, healthy until observed otherwise.

        New workers append at the end of the pool — matching the
        point-extension contract, where joiners take the freshly
        extended evaluation points and survivors keep theirs.
        """
        if g < 0:
            raise ValueError(f"g must be >= 0, got {g}")
        self.healthy = np.concatenate(
            [self.healthy, np.ones(g, dtype=bool)])
        self.K += g

    @property
    def must_respecialize(self) -> bool:
        """True when another failure would make steps undecodable."""
        return self.slack <= 0


_SUPPORTED_MESHES: Tuple[Tuple[int, int], ...] = (
    (16, 16), (8, 16), (8, 8), (4, 8), (4, 4), (2, 4), (2, 2), (1, 2), (1, 1),
)


def plan_shrink(healthy_devices: int,
                meshes: Sequence[Tuple[int, int]] = _SUPPORTED_MESHES
                ) -> Tuple[int, int]:
    """Largest (data, model) mesh that fits the healthy device count.

    Shrinking the data axis preserves the model-parallel layout (cheap
    reshard); the checkpoint + deterministic data stream make the restart
    exact (tests/test_substrate.py::TestTrainResume)."""
    for d, m in meshes:
        if d * m <= healthy_devices:
            return (d, m)
    raise ValueError(f"no supported mesh fits {healthy_devices} devices")
