"""Logical-axis sharding: a minimal flax-free `logical axes -> mesh axes` map.

Model code annotates tensors with LOGICAL axis names ("dp", "sp", "tp",
"fsdp", None); a context-scoped ``AxisRules`` maps those to physical mesh
axes.  Outside any rules context every annotation is a no-op, so the same
model code runs single-device (smoke tests) and on the production mesh
(dry-run / train) unchanged.

Logical names used across the codebase:
  dp    - data parallel (batch dim)                  -> ("pod", "data")
  fsdp  - fully-sharded parameter dim (ZeRO-3)       -> ("pod", "data")
  sp    - sequence parallel (activations at rest)    -> ("model",)
  tp    - tensor parallel (heads / ffn / experts)    -> ("model",)
  ep    - expert parallel                            -> ("model",)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "axis_rules", "current_rules", "shard",
           "logical_sharding", "shard_map_compat"]


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions, replication checks off.

    jax >= 0.6 exposes ``jax.shard_map`` (check_vma kwarg); 0.4/0.5 ship it
    in ``jax.experimental.shard_map`` with the older check_rep spelling.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)

AxisName = Union[str, None]


class AxisRules:
    """Maps logical axis names to physical mesh axis names (or None)."""

    def __init__(self, mesh: Mesh, table: Dict[str, Union[str, Tuple[str, ...], None]]):
        self.mesh = mesh
        self.table = dict(table)

    def physical(self, logical: AxisName):
        if logical is None:
            return None
        if logical not in self.table:
            raise KeyError(f"unknown logical axis {logical!r}; known: {list(self.table)}")
        return self.table[logical]

    def spec(self, *logical: AxisName) -> P:
        return P(*[self.physical(a) for a in logical])

    def sharding(self, *logical: AxisName) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


_local = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = current_rules()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def default_rules(mesh: Mesh, fsdp: bool = True) -> AxisRules:
    """Standard table for the production meshes.

    Single-pod  (data, model):        dp/fsdp -> data,        sp/tp/ep -> model
    Multi-pod   (pod, data, model):   dp/fsdp -> (pod, data), sp/tp/ep -> model

    ``fsdp=False`` replicates parameters over the data axes (pure TP):
    for small models the per-layer FSDP all-gathers dominate the collective
    roofline term - see EXPERIMENTS.md SecPerf.
    """
    axes = mesh.axis_names
    if "pod" in axes:
        dp: Union[str, Tuple[str, ...]] = ("pod", "data")
    else:
        dp = "data"
    return AxisRules(mesh, {
        "dp": dp,
        "fsdp": dp if fsdp else None,
        "sp": "model",
        "tp": "model",
        "ep": "model",
    })


def shard(x, *logical: AxisName):
    """Apply a sharding constraint by logical names; no-op without rules.

    An annotation whose mesh-axis product does not divide the dim size is
    silently dropped (replicated) - this keeps one set of annotations valid
    across architectures (e.g. 14-head attention on a 16-wide tp axis).
    """
    rules = current_rules()
    if rules is None:
        return x
    # Trailing unannotated dims default to replicated.
    names = list(logical) + [None] * (x.ndim - len(logical))
    resolved = []
    for dim, name in zip(x.shape, names[: x.ndim]):
        phys = rules.physical(name) if name is not None else None
        if phys is None:
            resolved.append(None)
            continue
        axes = phys if isinstance(phys, tuple) else (phys,)
        size = 1
        for a in axes:
            size *= rules.mesh.shape[a]
        resolved.append(phys if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*resolved)))


def logical_sharding(*logical: AxisName) -> Optional[NamedSharding]:
    """NamedSharding for the current rules (None outside a rules context)."""
    rules = current_rules()
    if rules is None:
        return None
    return rules.sharding(*logical)
