"""Gradient compression for cross-pod reduction (DESIGN.md Sec. 9).

The paper's digit-stack trick suggests a general principle: exact arithmetic
on scaled integer grids.  Applied to gradient all-reduce, we quantise each
gradient leaf onto an int grid (shared power-of-two scale chosen from the
global max), all-reduce int32 payloads, and dequantise - bitwise
deterministic across replicas (no float reduction-order variance) and
roughly half the bytes of f32 on the wire at bits<=15 packing, with an
error-feedback residual so the quantisation noise does not bias training.

For the dry-run path the quantise/dequantise pair lowers around the
all-reduce so the collective term shows the reduced payload.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_tree", "dequantize_tree", "compressed_psum",
           "error_feedback_update"]


def _scale_for(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    # power-of-two scale: exact multiply/divide in fp, exact across hosts
    exp = jnp.ceil(jnp.log2(jnp.maximum(amax / qmax, 1e-30)))
    return jnp.exp2(exp)


def quantize_tree(tree: Any, bits: int = 15) -> Tuple[Any, Any]:
    """tree of f32 -> (int32 tree, f32 scale tree).  bits <= 15 leaves
    headroom so summing over <= 2^16 replicas cannot overflow int32."""
    scales = jax.tree.map(lambda g: _scale_for(g, bits), tree)
    q = jax.tree.map(lambda g, s: jnp.round(g / s).astype(jnp.int32),
                     tree, scales)
    return q, scales


def dequantize_tree(q: Any, scales: Any) -> Any:
    return jax.tree.map(lambda qi, s: qi.astype(jnp.float32) * s, q, scales)


def compressed_psum(tree: Any, axis_name: str, bits: int = 15) -> Any:
    """int-grid psum: quantise -> integer psum -> dequantise.

    Exact-integer summation makes the result independent of reduction order
    (SDC-auditable); max scales are pre-synchronised with a scalar psum.
    Use inside shard_map for the cross-pod gradient reduction."""
    # synchronise scales first (max over replicas) - tiny scalar collective
    scales = jax.tree.map(
        lambda g: jax.lax.pmax(_scale_for(g, bits), axis_name), tree)
    q = jax.tree.map(lambda g, s: jnp.round(g / s).astype(jnp.int32),
                     tree, scales)
    summed = jax.tree.map(lambda qi: jax.lax.psum(qi, axis_name), q)
    return dequantize_tree(summed, scales)


def error_feedback_update(grads: Any, residual: Optional[Any],
                          bits: int = 8) -> Tuple[Any, Any]:
    """1-step error feedback: g' = Q(g + r); r' = (g + r) - g'.

    Returns (quantised-dequantised grads, new residual).  With bits=8 the
    wire payload is 4x smaller than f32 when packed; the residual keeps the
    long-run bias at zero (standard EF-SGD argument)."""
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)
    acc = jax.tree.map(jnp.add, grads, residual)
    q, s = quantize_tree(acc, bits)
    deq = dequantize_tree(q, s)
    new_res = jax.tree.map(jnp.subtract, acc, deq)
    return deq, new_res
