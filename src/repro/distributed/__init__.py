"""Distribution: mesh axes, sharding rules, coded on-mesh runtime."""
from repro.distributed.sharding import (
    AxisRules,
    axis_rules,
    current_rules,
    logical_sharding,
    shard,
)

__all__ = ["AxisRules", "axis_rules", "current_rules", "logical_sharding", "shard"]
