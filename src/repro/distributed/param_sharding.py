"""Parameter/optimizer/cache sharding rules (logical axes per leaf).

The layout implements ZeRO-3-style FSDP + Megatron TP + EP:
  * every weight matrix has one dim on "tp"/"ep" (model axis) and one on
    "fsdp" (data axes) - so params, master copies, and Adam moments are all
    fully sharded across the whole mesh;
  * scanned stacks carry a leading n_groups dim (never sharded);
  * axes that do not divide evenly are dropped (see sharding.shard).

Rules are keyed on the leaf's dict-key name, which is unique per layer kind.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import AxisRules

__all__ = ["param_logical_axes", "tree_shardings", "batch_logical_axes",
           "cache_logical_axes"]

# leaf name -> logical axes by rank (excluding any leading stack dim)
_RULES = {
    # embeddings / head
    "table": ("tp", "fsdp"),
    # attention
    "wq": ("fsdp", "tp", None),
    "wk": ("fsdp", "tp", None),
    "wv": ("fsdp", "tp", None),
    "wo": ("tp", None, "fsdp"),
    "bq": ("tp", None),
    "bk": ("tp", None),
    "bv": ("tp", None),
    "q_norm": (None,),
    "k_norm": (None,),
    # mlp
    "w_gate": ("fsdp", "tp"),      # moe (E,d,ff) handled by rank below
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # moe
    "router": (None, None),
    "sh_gate": ("fsdp", "tp"),
    "sh_up": ("fsdp", "tp"),
    "sh_down": ("tp", "fsdp"),
    # mamba
    "w_in": ("fsdp", "tp"),
    "conv_w": (None, "tp"),
    "conv_b": ("tp",),
    "w_x": ("tp", None),
    "w_dt": (None, "tp"),
    "dt_bias": ("tp",),
    "A_log": ("tp", None),
    "D": ("tp",),
    "w_out": ("tp", "fsdp"),
    # rwkv
    "mu": (None, None),
    "w_r": ("fsdp", "tp"),
    "w_k": ("fsdp", "tp"),
    # cmix w_v is (ff, d); tmix w_v is (d, d_attn) - rank-2 both;
    # see _leaf_axes
    "w_v": ("tp", "fsdp"),
    "w_g": ("fsdp", "tp"),
    "w_o": ("tp", "fsdp"),
    "w_decay_base": ("tp",),
    "w_decay_a": ("fsdp", None),
    "w_decay_b": (None, "tp"),
    "u": ("tp", None),
    "ln_scale": ("tp",),
    # norms
    "scale": (None,),
}

# MoE expert tensors are rank-3 (E, d, ff) / (E, ff, d): E on "ep".
_MOE_RANK3 = {
    "w_gate": ("ep", "fsdp", None),
    "w_up": ("ep", "fsdp", None),
    "w_down": ("ep", None, "fsdp"),
}

# rwkv name collisions resolved by shape context: tmix w_v is (d, d_attn)
# (shard out dim), cmix w_v is (d_ff, d) (shard in dim).  Both use
# ("fsdp","tp")/( "tp","fsdp") - either way one dim each; keep simple:
_RWKV_TMIX_WV = ("fsdp", "tp")


def _leaf_axes(name: str, rank: int, stacked: bool) -> Tuple[Optional[str], ...]:
    base_rank = rank - (1 if stacked else 0)
    if name in _MOE_RANK3 and base_rank == 3:
        ax = _MOE_RANK3[name]
    elif name in _RULES:
        ax = _RULES[name]
        if len(ax) != base_rank:
            ax = tuple(list(ax)[:base_rank]) + (None,) * max(0, base_rank - len(ax))
    else:
        ax = (None,) * base_rank
    if stacked:
        ax = (None,) + ax
    return ax


def param_logical_axes(params: Any) -> Any:
    """Pytree of logical-axis tuples matching the params tree.

    Leaves inside params["blocks"] are stacked (leading n_groups dim)."""

    def walk(tree, stacked: bool):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v, stacked)
            else:
                out[k] = _leaf_axes(k, len(v.shape), stacked)
        return out

    result = {}
    for k, v in params.items():
        if k == "blocks":
            result[k] = tuple(walk(b, True) for b in v)
        else:
            result[k] = walk(v, False)
    return result


def tree_shardings(rules: AxisRules, tree: Any, logical: Any) -> Any:
    """Logical-axis tuples -> NamedSharding tree (divisibility-checked)."""

    def one(leaf, axes):
        resolved = []
        for dim, name in zip(leaf.shape, axes):
            phys = rules.physical(name) if name else None
            if phys is None:
                resolved.append(None)
                continue
            ax_list = phys if isinstance(phys, tuple) else (phys,)
            size = 1
            for a in ax_list:
                size *= rules.mesh.shape[a]
            resolved.append(phys if dim % size == 0 else None)
        return NamedSharding(rules.mesh, P(*resolved))

    return jax.tree.map(one, tree, logical,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))


def batch_logical_axes(cfg, kind: str) -> Any:
    """Logical axes for the input batch pytrees."""
    if kind == "train":
        if cfg.input_mode == "tokens":
            return {"tokens": ("dp", None), "labels": ("dp", None)}
        axes = {"embeds": ("dp", "sp", None), "labels": ("dp", None)}
        if cfg.pos == "mrope":
            axes["pos_ids"] = (None, "dp", None)
        return axes
    if kind == "prefill":
        if cfg.input_mode == "tokens":
            axes = {"tokens": ("dp", None)}
        else:
            axes = {"embeds": ("dp", "sp", None)}
            if cfg.pos == "mrope":
                axes["pos_ids"] = (None, "dp", None)
        return axes
    if kind == "decode":
        if cfg.input_mode == "tokens":
            axes = {"tokens": ("dp", None)}
        else:
            axes = {"embeds": ("dp", None, None)}
            if cfg.pos == "mrope":
                axes["pos_ids"] = (None, "dp", None)
        return axes
    raise ValueError(kind)


def cache_logical_axes(cfg) -> Any:
    """Logical axes for the serve cache (matches models.lm.cache_shapes)."""
    out = []
    for mixer, _ in cfg.pattern:
        if mixer in ("attn", "attn_local"):
            one = {"k": (None, "dp", "sp", None, None),
                   "v": (None, "dp", "sp", None, None)}
        elif mixer == "mamba":
            one = {"conv": (None, "dp", None, "tp"),
                   "ssm": (None, "dp", "tp", None)}
        elif mixer == "rwkv":
            one = {"shift_t": (None, "dp", None),
                   "shift_c": (None, "dp", None),
                   "wkv": (None, "dp", "tp", None, None)}
        else:
            raise ValueError(mixer)
        out.append(one)
    return tuple(out)
