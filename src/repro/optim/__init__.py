"""Optimizer substrate (pure JAX, no optax)."""
from repro.optim.adamw import (
    OptConfig,
    adamw_init,
    adamw_init_shapes,
    adamw_update,
    cosine_lr,
    global_norm,
)

__all__ = ["OptConfig", "adamw_init", "adamw_init_shapes", "adamw_update",
           "cosine_lr", "global_norm"]
