"""AdamW with fp32 master weights, cosine schedule, global-norm clipping.

Mixed-precision discipline: model params live in bf16 for compute; the
optimizer holds an fp32 master copy plus fp32 moments (all sharded exactly
like the params - ZeRO-3).  The update runs in fp32 and re-casts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_init_shapes", "adamw_update",
           "cosine_lr", "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def _adamw_init_impl(params: Any) -> Any:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def adamw_init(params: Any) -> Any:
    # jit so every output leaf owns a distinct buffer: identical zeros would
    # otherwise alias and break buffer donation in the train step.
    return jax.jit(_adamw_init_impl)(params)


def adamw_init_shapes(param_shapes: Any) -> Any:
    """ShapeDtypeStruct mirror of adamw_init (dry-run path)."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "master": jax.tree.map(f32, param_shapes),
        "mu": jax.tree.map(f32, param_shapes),
        "nu": jax.tree.map(f32, param_shapes),
    }


def adamw_update(cfg: OptConfig, grads: Any, opt_state: Any,
                 param_dtype=jnp.bfloat16):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.betas

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    # Separate maps (XLA fuses/CSEs the recomputed clipped grad casts).
    gc = lambda g: g.astype(jnp.float32) * clip
    mu = jax.tree.map(lambda g, m: b1 * m + (1 - b1) * gc(g),
                      grads, opt_state["mu"])
    nu = jax.tree.map(lambda g, v: b2 * v + (1 - b2) * jnp.square(gc(g)),
                      grads, opt_state["nu"])
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    master = jax.tree.map(
        lambda m, v, w: w - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
                                  + cfg.weight_decay * w),
        mu, nu, opt_state["master"])
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, grads)
    new_state = {"step": step, "master": master, "mu": mu, "nu": nu}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
