"""The canonical deterministic runs behind ``tests/golden/*.jsonl``.

One fixed recipe — ladder geometry, shapes, request operands, policy
seeds, CONSTANT per-rung overheads (prewarm's MEASURED overheads carry
wall-clock noise, so golden runs must not rank by them) — applied to each
catalog entry.  ``tests/test_chaos.py`` re-runs the recipe and asserts the
recorded trace matches the checked-in golden file bit-for-bit;
``scripts/regen_golden_traces.py`` rewrites the files after an INTENDED
control-plane behaviour change (the diff then documents exactly what
changed).

Catalog: every registered scenario under its own name, plus
``pareto_feedback`` — the Pareto-tail regime served WITH observed-
violation feedback, so the feedback control law itself is pinned by a
golden trace too — ``crawler_partial`` — the crawler regime served with
``sub_tasks=4``, pinning the fractional progress plans partial decoding
emits — and the ELASTIC pair ``pool_resize_shrink`` / ``pool_resize_grow``
— the pool_resize regime served through an elastic ``AdaptiveServer``
(``universe=``), pinning the executed shrink handoff (departures exceed
the polycode-only ladder's slack, the pool re-lowers onto the survivors)
and, in the grow variant, the subsequent admission of the arriving
workers onto Leja-extended evaluation points.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.chaos.scenarios import make_scenario, scenario_names
from repro.chaos.trace import Trace, TraceRecorder

__all__ = ["GOLDEN_GRID", "GOLDEN_K", "GOLDEN_L", "GOLDEN_SHAPES",
           "GOLDEN_STEPS", "GOLDEN_SEED", "GOLDEN_OVERHEAD_S",
           "golden_names", "golden_trace", "replay_golden"]

GOLDEN_GRID = (4, 2, 1)          # rungs bec(tau=2), tradeoff p'=2(5), polycode(11)
GOLDEN_K = 12
GOLDEN_L = 257                   # every rung feasible in float64
GOLDEN_SHAPES = ((16, 8), (16, 4))
GOLDEN_STEPS = 10
GOLDEN_SEED = 7
#: deterministic per-rung step costs (units of one worker step) — the
#: depth-p digit stack prices the low-tau rungs, so the mean ranking
#: genuinely moves across regimes instead of parking on the widest budget.
GOLDEN_OVERHEAD_S = {"bec": 2.0, "tradeoff(p'=2)": 1.0, "polycode": 0.1}
_SLO_QUANTILE = 0.99
_SLO_S = 4.0                     # bound the predictive fallback is judged by
_FEEDBACK_SLO_S = 2.5            # tighter bound for the feedback variant
_PARTIAL_SUB_TASKS = 4           # Q of the crawler_partial variant

#: the elastic pool_resize pair: a polycode-only ladder (narrow budget, so
#: three departures exceed slack and force the EXECUTED handoff) on a grid
#: whose bec rung (tau=2) still fits the shrunk pool — the paper's L<->tau
#: tradeoff is what keeps the survivors decodable.
_ELASTIC_KEYS = ("pool_resize_shrink", "pool_resize_grow")
_ELASTIC_GRID = (3, 2, 1)        # bec(tau=2), polycode(tau=8)
_ELASTIC_UNIVERSE = 12           # fleet size the feed emits for
_ELASTIC_K = 10                  # initial pool: universe minus the arrivals
_ELASTIC_STEPS = 16
_ELASTIC_DEPART_STEP = 4
_ELASTIC_JOIN_STEP = 12          # grow variant only
_ELASTIC_OVERHEAD_S = {"bec": 2.0, "polycode": 0.1}


def golden_names() -> Tuple[str, ...]:
    """Catalog keys: every scenario + feedback/partial/elastic variants."""
    return scenario_names() + ("pareto_feedback",
                               "crawler_partial") + _ELASTIC_KEYS


def _elastic_scenario(key: str):
    """The pool_resize variant behind an elastic catalog ``key``."""
    return make_scenario(
        "pool_resize", num_departing=3, depart_step=_ELASTIC_DEPART_STEP,
        num_arriving=2,
        join_step=_ELASTIC_JOIN_STEP if key == "pool_resize_grow" else None)


def _request(dtype):
    """Deterministic integer operands (no rng: stable across versions)."""
    import jax.numpy as jnp

    (v, r), (_, t) = GOLDEN_SHAPES
    A = jnp.asarray(np.arange(v * r).reshape(v, r) % 5 - 2, dtype)
    B = jnp.asarray(np.arange(v * t).reshape(v, t) % 5 - 2, dtype)
    return A, B


def _serve(key: str, feed, steps: int, seed: int = GOLDEN_SEED):
    """Run the canonical server config for ``key`` over ``feed``."""
    import jax.numpy as jnp

    from repro.control import (
        AdaptiveServer,
        ExpectedLatencyPolicy,
        PlanLadder,
    )

    if key in _ELASTIC_KEYS:
        scenario = _elastic_scenario(key)
        arriving = scenario.arriving_ids(_ELASTIC_UNIVERSE, seed)
        absent = set(int(i) for i in arriving)
        pool = [i for i in range(_ELASTIC_UNIVERSE) if i not in absent]
        p, m, n = _ELASTIC_GRID
        ladder = PlanLadder(p, m, n, K=_ELASTIC_K, L=GOLDEN_L,
                            backend="reference", dtype=jnp.float64,
                            include=["polycode"])
        ladder.prewarm(*GOLDEN_SHAPES)
        policy = ExpectedLatencyPolicy(ladder,
                                       overhead_s=_ELASTIC_OVERHEAD_S)
        server = AdaptiveServer(ladder, policy=policy, feed=feed,
                                check_exact=True,
                                universe=_ELASTIC_UNIVERSE, pool=pool)
        A, B = _request(jnp.float64)
        for i in range(steps):
            if scenario.join_step is not None and i == scenario.join_step:
                server.grow(arriving)
            server.step(A, B)
        return server.reports

    feedback = key == "pareto_feedback"
    sub_tasks = _PARTIAL_SUB_TASKS if key == "crawler_partial" else 1
    p, m, n = GOLDEN_GRID
    ladder = PlanLadder(p, m, n, K=GOLDEN_K, L=GOLDEN_L,
                        backend="reference", dtype=jnp.float64)
    ladder.prewarm(*GOLDEN_SHAPES, sub_tasks=sub_tasks)
    policy = ExpectedLatencyPolicy(ladder, overhead_s=GOLDEN_OVERHEAD_S,
                                   sub_tasks=sub_tasks)
    server = AdaptiveServer(
        ladder, policy=policy, feed=feed, check_exact=True,
        slo_quantile=_SLO_QUANTILE,
        slo_s=_FEEDBACK_SLO_S if feedback else _SLO_S,
        feedback=feedback, sub_tasks=sub_tasks)
    A, B = _request(jnp.float64)
    return server.run(steps, lambda i: (A, B))


def golden_trace(key: str, steps: Optional[int] = None,
                 seed: int = GOLDEN_SEED) -> Trace:
    """Run the canonical recipe for catalog entry ``key`` and record it.

    ``steps`` defaults to ``GOLDEN_STEPS`` (``_ELASTIC_STEPS`` for the
    elastic pair, whose grow event lands at step ``_ELASTIC_JOIN_STEP``).

    Raises:
        KeyError: for a key outside :func:`golden_names`.
    """
    if key not in golden_names():
        raise KeyError(f"unknown golden key {key!r}; have {golden_names()}")
    if key in _ELASTIC_KEYS:
        if steps is None:
            steps = _ELASTIC_STEPS
        scenario = _elastic_scenario(key)
        recorder = TraceRecorder(
            scenario.compile(_ELASTIC_UNIVERSE, seed=seed), _ELASTIC_UNIVERSE,
            meta={"scenario": "pool_resize", "seed": seed, "steps": steps,
                  "grid": list(_ELASTIC_GRID), "L": GOLDEN_L,
                  "elastic": True, "universe": _ELASTIC_UNIVERSE,
                  "include": ["polycode"],
                  "join_step": scenario.join_step})
        reports = _serve(key, recorder, steps, seed=seed)
        return recorder.finish(reports)
    if steps is None:
        steps = GOLDEN_STEPS
    feedback = key == "pareto_feedback"
    scenario_name = {"pareto_feedback": "pareto",
                     "crawler_partial": "crawler"}.get(key, key)
    scenario = make_scenario(scenario_name)
    recorder = TraceRecorder(
        scenario.compile(GOLDEN_K, seed=seed), GOLDEN_K,
        meta={"scenario": scenario_name, "seed": seed, "steps": steps,
              "grid": list(GOLDEN_GRID), "L": GOLDEN_L,
              "feedback": feedback,
              "sub_tasks": (_PARTIAL_SUB_TASKS
                            if key == "crawler_partial" else 1)})
    reports = _serve(key, recorder, steps, seed=seed)
    return recorder.finish(reports)


def replay_golden(key: str, trace: Trace):
    """Re-serve ``trace`` through a FRESH canonical server; the reports
    must reproduce the trace bit-exactly (``trace.diff(...) == []``)."""
    if key not in golden_names():
        raise KeyError(f"unknown golden key {key!r}; have {golden_names()}")
    return _serve(key, trace.feed(), len(trace.steps),
                  seed=int(trace.meta.get("seed", GOLDEN_SEED)))
