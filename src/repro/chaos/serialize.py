"""One dataclass <-> JSON-safe-dict serialiser for every trace surface.

Both trace formats in the system — ``chaos.trace`` (per-step control-loop
records) and ``serve.trace`` (per-request/per-batch serving records) —
persist frozen dataclasses as JSONL and compare them field-for-field on
replay.  They share this module so a field added to ``StepReport`` (or to
the serve tier's request records) round-trips through every surface
automatically instead of each recorder hand-picking fields and silently
dropping new ones.

The contract:

* :func:`dataclass_to_dict` walks ``dataclasses.fields`` in declaration
  order, drops ``exclude``-listed fields, and passes each value through
  :func:`jsonable` (tuples/arrays -> lists, numpy scalars -> Python
  scalars, nested dataclasses -> dicts).  ``json.dumps`` serialises
  Python floats at shortest round-trip precision, so float64 values
  survive the file boundary bit-exactly.
* :func:`tuplify` is the inverse normalisation on load: nested lists
  become tuples again, so reconstructed frozen dataclasses compare equal
  to freshly built ones (``==`` is the bit-determinism contract).
* :func:`report_to_dict` is the shared ``StepReport`` serialisation:
  everything except ``wall_ms`` (measured wall time is the one field a
  bit-exact replay can never reproduce).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import numpy as np

__all__ = ["REPORT_VOLATILE_FIELDS", "jsonable", "tuplify",
           "report_field_names", "dataclass_to_dict", "report_to_dict"]

#: ``StepReport`` fields no serialiser records: wall-clock noise only.
REPORT_VOLATILE_FIELDS: Tuple[str, ...] = ("wall_ms",)


def report_field_names(report_cls: Any,
                       volatile: Tuple[str, ...] = REPORT_VOLATILE_FIELDS,
                       ) -> Tuple[str, ...]:
    """Dataclass field names minus the volatile ones, declaration order.

    The ONE place field selection happens for every trace surface:
    :func:`dataclass_to_dict` (hence :func:`report_to_dict` and both
    JSONL recorders) and ``chaos.trace.COMPARED_FIELDS`` all derive from
    it, so a field added to ``StepReport`` either flows through every
    surface at once or fails loudly — it can no longer be recorded by one
    format and silently dropped by another.

    Raises:
        TypeError: if ``report_cls`` is not a dataclass.
    """
    if not dataclasses.is_dataclass(report_cls):
        raise TypeError(f"need a dataclass, got {report_cls!r}")
    return tuple(f.name for f in dataclasses.fields(report_cls)
                 if f.name not in volatile)


def jsonable(value: Any) -> Any:
    """``value`` recursively converted to JSON-encodable Python types.

    Tuples, lists, and numpy arrays become lists; numpy scalars become the
    matching Python scalar (preserving the float64 bit pattern — ``json``
    writes shortest-round-trip decimal); nested dataclasses become dicts;
    dict values convert recursively.  Everything else passes through.
    """
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return {f.name: jsonable(getattr(value, f.name))
                for f in dataclasses.fields(value)}
    if isinstance(value, np.ndarray):
        return [jsonable(v) for v in value.tolist()]
    if isinstance(value, (tuple, list)):
        return [jsonable(v) for v in value]
    if isinstance(value, dict):
        return {k: jsonable(v) for k, v in value.items()}
    if isinstance(value, np.generic):
        return value.item()
    return value


def tuplify(value: Any) -> Any:
    """Inverse normalisation for loaded records: lists -> tuples, recursively.

    Applied to sequence-valued fields when reconstructing frozen
    dataclasses from JSON, so loaded records compare ``==`` to fresh ones.
    Dicts keep their type (values convert); scalars pass through.
    """
    if isinstance(value, (list, tuple)):
        return tuple(tuplify(v) for v in value)
    if isinstance(value, dict):
        return {k: tuplify(v) for k, v in value.items()}
    return value


def dataclass_to_dict(dc: Any, exclude: Tuple[str, ...] = ()) -> dict:
    """All of ``dc``'s fields (minus ``exclude``) as a JSON-safe dict.

    Field order follows the dataclass declaration; every value goes
    through :func:`jsonable`.  Unlike ``dataclasses.asdict`` this is
    exclusion-aware and numpy-aware, which is what the trace surfaces
    need.

    Raises:
        TypeError: if ``dc`` is not a dataclass instance.
    """
    if not dataclasses.is_dataclass(dc) or isinstance(dc, type):
        raise TypeError(f"need a dataclass instance, got {type(dc).__name__}")
    return {name: jsonable(getattr(dc, name))
            for name in report_field_names(type(dc), volatile=exclude)}


def report_to_dict(report: Any,
                   exclude: Tuple[str, ...] = REPORT_VOLATILE_FIELDS) -> dict:
    """The shared ``StepReport`` serialisation (drops wall-clock noise).

    Used by ``chaos.trace`` (step records) and ``serve.trace`` (the
    per-batch ``report`` payload) so both formats carry the SAME field
    set and a new ``StepReport`` field shows up in both.
    """
    return dataclass_to_dict(report, exclude=exclude)
