"""Scenario-driven fault injection, trace record/replay, golden runs.

The behavioural test substrate of the control plane (DESIGN Sec. 9):

    scenarios.py   declarative ``Scenario`` DSL + registry — straggler
                   regimes (iid, heavy/Pareto tails, bursts, flapping,
                   rack failure, pool resize, crawlers, degrading ramps)
                   compiled into deterministic seeded ``TimeFeed``s
    trace.py       ``TraceRecorder``/``Trace`` — capture per-step worker
                   times + ``StepReport`` streams as JSONL and replay them
                   bit-deterministically
    golden.py      the canonical recipe behind ``tests/golden/*.jsonl``

Scenario and trace handling are host-side numpy (no jax arrays touched),
though importing the package does pull jax in transitively — scenarios
build on ``repro.core.simulator`` and ``repro.core``'s package init loads
the jax-backed plan API.  Nothing COMPILES until a golden run actually
serves through a ladder.
"""
from repro.chaos.scenarios import (
    BurstySlowdown,
    CorrelatedRackFailure,
    Crawler,
    Degrading,
    FlappingWorkers,
    HeavyTailMixture,
    IIDShiftedExponential,
    ParetoTail,
    PoolResize,
    Scenario,
    make_scenario,
    register,
    scenario_names,
    trace_matrix,
)
from repro.chaos.serialize import (
    dataclass_to_dict,
    jsonable,
    report_to_dict,
    tuplify,
)
from repro.chaos.trace import (
    Trace,
    TraceRecorder,
    TraceStep,
    verify_replay,
)

__all__ = [
    "Scenario",
    "IIDShiftedExponential",
    "HeavyTailMixture",
    "ParetoTail",
    "BurstySlowdown",
    "FlappingWorkers",
    "CorrelatedRackFailure",
    "PoolResize",
    "Crawler",
    "Degrading",
    "register",
    "make_scenario",
    "scenario_names",
    "trace_matrix",
    "Trace",
    "TraceRecorder",
    "TraceStep",
    "verify_replay",
    "dataclass_to_dict",
    "jsonable",
    "report_to_dict",
    "tuplify",
]
