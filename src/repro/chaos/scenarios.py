"""Declarative straggler-regime DSL compiled to deterministic time feeds.

The control plane (PR 3/4) was validated against ONE hand-rolled
shifted-exponential feed.  Related work treats stragglers as erasures with
heterogeneous, partial, and correlated behaviour (Yu et al.; Das &
Ramamoorthy), so this module makes regimes first-class: a ``Scenario`` is
a frozen dataclass describing WHAT the cluster does (who slows down, when,
by how much), and ``compile(K, seed)`` turns it into a stateless
``core.simulator.TimeFeed`` — ``(step, rng) -> (K,) seconds`` — that any
consumer of per-worker finish times can drink from: ``simulate_completion``
(its ``feed=`` parameter), ``WorkerHealthMonitor.record_step``, and
``AdaptiveServer(feed=...)``.

Determinism contract: a compiled feed derives every random choice from
``(seed, step)`` via ``numpy.random.SeedSequence`` — it ignores the rng
argument the ``TimeFeed`` protocol passes in — and draws jitter through
``LatencyModel.sample(..., stable=True)`` (inverse-CDF over the uniform
bitstream, the only sampling path NumPy guarantees across versions).  The
same ``(scenario, K, seed)`` therefore reproduces the identical time
matrix on any machine, which is what lets ``repro.chaos.trace`` check
golden traces into the repo.

Every scenario also exposes ``calm()``: the same regime with its stressor
switched off (the "S = 0" control the bench compares against).

Registry: concrete scenarios self-register under ``Scenario.name`` via the
``@register`` decorator; ``make_scenario(name, **overrides)`` instantiates
one and ``scenario_names()`` lists the catalog.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional, Tuple, Type

import numpy as np

from repro.core.simulator import LatencyModel, TimeFeed

__all__ = [
    "Scenario",
    "IIDShiftedExponential",
    "HeavyTailMixture",
    "ParetoTail",
    "BurstySlowdown",
    "FlappingWorkers",
    "CorrelatedRackFailure",
    "PoolResize",
    "Crawler",
    "Degrading",
    "register",
    "make_scenario",
    "scenario_names",
    "trace_matrix",
]


def _rng(seed: int, *path: int) -> np.random.Generator:
    """A Generator keyed on ``(seed, *path)`` — stateless, step-addressable."""
    return np.random.default_rng(np.random.SeedSequence((int(seed),) + tuple(int(p) for p in path)))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative straggler regime.

    Subclasses define the regime's parameters as frozen dataclass fields
    and implement ``times(step, K, seed)`` (the per-step finish-time law)
    plus ``calm()`` (the stress-free control variant).  ``compile``
    wraps ``times`` into a validated ``TimeFeed``.
    """

    #: registry key; subclasses override.
    name: ClassVar[str] = "scenario"

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """The (K,) per-worker finish times of ``step`` under ``seed``."""
        raise NotImplementedError

    def calm(self) -> "Scenario":
        """The same scenario with its stressor disabled (the S=0 control)."""
        raise NotImplementedError

    def compile(self, K: int, seed: int = 0) -> TimeFeed:
        """A deterministic ``TimeFeed`` over ``K`` workers.

        The returned feed satisfies the ``core.simulator.TimeFeed``
        protocol but ignores the rng argument: all randomness is derived
        from ``(seed, step)``, so two compilations with the same arguments
        produce bit-identical streams.

        Raises:
            ValueError: if ``K < 1``.
        """
        if K < 1:
            raise ValueError(f"need K >= 1 workers, got {K}")

        def feed(step: int, rng=None) -> np.ndarray:
            t = np.asarray(self.times(int(step), K, seed), dtype=np.float64)
            if t.shape != (K,):
                raise ValueError(
                    f"{type(self).__name__}.times returned shape {t.shape}, "
                    f"need ({K},)")
            if not np.all(np.isfinite(t)) or np.any(t <= 0):
                raise ValueError(
                    f"{type(self).__name__} produced non-finite or "
                    f"non-positive times at step {step}")
            return t

        return feed

    # -- shared building blocks ---------------------------------------------
    def _pick(self, K: int, n: int, seed: int, *path: int) -> np.ndarray:
        """``n`` distinct worker ids, keyed on ``(seed, *path)``.

        Drawn by ranking K uniforms rather than ``Generator.choice``:
        NumPy guarantees only the raw uniform bitstream across versions
        (NEP 19), and the golden traces depend on these picks never
        drifting on a numpy upgrade.
        """
        n = min(int(n), K)
        if n <= 0:
            return np.empty(0, dtype=np.int64)
        ranks = np.argsort(_rng(seed, *path).random(K), kind="stable")
        return np.sort(ranks[:n])

    def _shifted_exp(self, step: int, K: int, seed: int, base: np.ndarray,
                     jitter: np.ndarray) -> np.ndarray:
        """Stable per-step shifted-exponential draw around ``base``."""
        model = LatencyModel(base=base, straggler_slowdown=1.0, jitter=jitter)
        return model.sample(K, (), _rng(seed, 9, step), stable=True)


SCENARIOS: Dict[str, Type[Scenario]] = {}


def register(cls: Type[Scenario]) -> Type[Scenario]:
    """Class decorator: add ``cls`` to the catalog under ``cls.name``."""
    if cls.name in SCENARIOS:
        raise ValueError(f"duplicate scenario name {cls.name!r}")
    SCENARIOS[cls.name] = cls
    return cls


def scenario_names() -> Tuple[str, ...]:
    """The registered scenario names, sorted."""
    return tuple(sorted(SCENARIOS))


def make_scenario(name: str, **overrides) -> Scenario:
    """Instantiate the registered scenario ``name`` with field overrides.

    Raises:
        KeyError: for an unregistered name (the message lists the catalog).
    """
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; have {scenario_names()}")
    return SCENARIOS[name](**overrides)


def trace_matrix(scenario: Scenario, K: int, steps: int,
                 seed: int = 0) -> np.ndarray:
    """The (steps, K) finish-time matrix of a compiled scenario.

    The static side of the bench (no monitor: a step waits for everyone)
    and reproducibility tests both consume this dense form.
    """
    feed = scenario.compile(K, seed=seed)
    return np.stack([feed(s, None) for s in range(steps)])


@register
@dataclasses.dataclass(frozen=True)
class IIDShiftedExponential(Scenario):
    """The paper's Fig. 1 regime: a resampled straggler set computing twice.

    ``num_stragglers`` workers (resampled every ``resample_every`` steps)
    run at ``slowdown`` x base; everyone carries light exponential jitter.
    """

    name: ClassVar[str] = "iid"
    base: float = 1.0
    slowdown: float = 2.0
    jitter: float = 0.02
    num_stragglers: int = 3
    resample_every: int = 8

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Per-worker times with the epoch's straggler set slowed down."""
        epoch = step // self.resample_every if self.resample_every else 0
        slow = self._pick(K, self.num_stragglers, seed, 0, epoch)
        base = np.full(K, self.base)
        base[slow] *= self.slowdown
        return self._shifted_exp(step, K, seed, base, np.full(K, self.jitter))

    def calm(self) -> "IIDShiftedExponential":
        """No stragglers; the iid jitter floor remains."""
        return dataclasses.replace(self, num_stragglers=0)


@register
@dataclasses.dataclass(frozen=True)
class HeavyTailMixture(Scenario):
    """A FIXED slow set with a fat exponential tail (PR 4's tail regime).

    The slow machines run at ``slowdown`` x base with ``heavy_jitter``
    exponential scale; the rest are near-deterministic.  This is the mix
    where mean and quantile rankings genuinely disagree.
    """

    name: ClassVar[str] = "heavy_tail"
    base: float = 1.0
    slowdown: float = 2.0
    healthy_jitter: float = 0.05
    heavy_jitter: float = 1.5
    num_stragglers: int = 3

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Per-worker times; the seed-fixed slow set keeps its fat tail."""
        slow = self._pick(K, self.num_stragglers, seed, 0)
        base = np.full(K, self.base)
        jitter = np.full(K, self.healthy_jitter)
        base[slow] *= self.slowdown
        jitter[slow] = self.heavy_jitter
        return self._shifted_exp(step, K, seed, base, jitter)

    def calm(self) -> "HeavyTailMixture":
        """No heavy-tailed workers; healthy jitter only."""
        return dataclasses.replace(self, num_stragglers=0)


@register
@dataclasses.dataclass(frozen=True)
class ParetoTail(Scenario):
    """Pareto-tailed stragglers: the regime the shifted-exp fit gets WRONG.

    ``num_stragglers`` seed-fixed workers finish at ``xm * U^(-1/alpha)``
    (Pareto with minimum ``xm``; ``alpha <= 2`` has infinite variance), the
    rest at base + light exponential jitter.  The monitor's method-of-
    moments shifted-exponential fit systematically underestimates this
    tail, so PREDICTED quantiles look safe while REALIZED violations pile
    up — the scenario the observed-violation feedback controller
    (``control.feedback``) exists for.
    """

    name: ClassVar[str] = "pareto"
    base: float = 1.0
    healthy_jitter: float = 0.05
    num_stragglers: int = 2
    xm: float = 2.0
    alpha: float = 1.5

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Healthy shifted-exp times with Pareto draws on the slow set."""
        slow = self._pick(K, self.num_stragglers, seed, 0)
        base = np.full(K, self.base)
        t = self._shifted_exp(step, K, seed, base,
                              np.full(K, self.healthy_jitter))
        if slow.size:
            u = _rng(seed, 8, step).random(slow.size)
            t[slow] = self.xm * np.power(1.0 - u, -1.0 / self.alpha)
        return t

    def calm(self) -> "ParetoTail":
        """No Pareto workers; healthy jitter only."""
        return dataclasses.replace(self, num_stragglers=0)


@register
@dataclasses.dataclass(frozen=True)
class BurstySlowdown(Scenario):
    """Time-correlated bursts: a fraction of the cluster slows together.

    Every ``period`` steps a burst of ``burst_len`` steps begins; during a
    burst, a per-burst resampled fraction of workers runs at ``slowdown``
    x base with ``burst_jitter`` tails.  Between bursts the cluster is
    healthy, so score decay makes the monitor's picture go stale — the
    regime that punishes purely predictive control.
    """

    name: ClassVar[str] = "bursty"
    base: float = 1.0
    healthy_jitter: float = 0.05
    period: int = 12
    burst_len: int = 4
    fraction: float = 0.25
    slowdown: float = 3.0
    burst_jitter: float = 1.0

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Healthy times, except inside a burst window."""
        base = np.full(K, self.base)
        jitter = np.full(K, self.healthy_jitter)
        if self.burst_len > 0 and (step % self.period) < self.burst_len:
            burst = step // self.period
            slow = self._pick(K, int(round(self.fraction * K)), seed, 0, burst)
            base[slow] *= self.slowdown
            jitter[slow] = self.burst_jitter
        return self._shifted_exp(step, K, seed, base, jitter)

    def calm(self) -> "BurstySlowdown":
        """Bursts disabled entirely."""
        return dataclasses.replace(self, burst_len=0)


@register
@dataclasses.dataclass(frozen=True)
class FlappingWorkers(Scenario):
    """Workers that alternate slow/healthy on a phase-shifted duty cycle.

    Each of ``num_flappers`` seed-fixed workers is slow for
    ``duty * period`` of every ``period`` steps, with a per-worker phase
    offset — persistently intermittent rather than persistently slow, so
    decayed straggler scores hover around the flagging threshold.
    """

    name: ClassVar[str] = "flapping"
    base: float = 1.0
    healthy_jitter: float = 0.05
    num_flappers: int = 2
    period: int = 6
    duty: float = 0.5
    slowdown: float = 2.5
    flap_jitter: float = 0.5

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Per-worker times with each flapper's duty window applied."""
        flappers = self._pick(K, self.num_flappers, seed, 0)
        base = np.full(K, self.base)
        jitter = np.full(K, self.healthy_jitter)
        if flappers.size:
            # floor-of-uniform, not Generator.integers: only the uniform
            # bitstream is version-stable (see _pick)
            phases = np.floor(_rng(seed, 1).random(flappers.size)
                              * max(self.period, 1)).astype(np.int64)
            on = ((step + phases) % self.period) < self.duty * self.period
            slow = flappers[on]
            base[slow] *= self.slowdown
            jitter[slow] = self.flap_jitter
        return self._shifted_exp(step, K, seed, base, jitter)

    def calm(self) -> "FlappingWorkers":
        """No flappers."""
        return dataclasses.replace(self, num_flappers=0)


@register
@dataclasses.dataclass(frozen=True)
class CorrelatedRackFailure(Scenario):
    """A whole rack degrades at once (correlated, not independent, loss).

    Workers are striped round-robin over ``racks`` racks; at ``fail_step``
    one seed-chosen rack drops to ``slowdown`` x base with ``rack_jitter``
    tails, recovering at ``recover_step`` (never, when None).  The erasure
    budget must absorb ~K/racks simultaneous stragglers.
    """

    name: ClassVar[str] = "rack"
    base: float = 1.0
    healthy_jitter: float = 0.05
    racks: int = 4
    fail_step: Optional[int] = 6
    recover_step: Optional[int] = None
    slowdown: float = 3.0
    rack_jitter: float = 1.0

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Per-worker times; the failed rack is slow inside its window."""
        base = np.full(K, self.base)
        jitter = np.full(K, self.healthy_jitter)
        failed = (self.fail_step is not None and step >= self.fail_step
                  and (self.recover_step is None or step < self.recover_step))
        if failed:
            # floor-of-uniform for version stability (see _pick)
            rack = min(int(_rng(seed, 0).random() * self.racks),
                       self.racks - 1)
            members = np.flatnonzero(np.arange(K) % self.racks == rack)
            base[members] *= self.slowdown
            jitter[members] = self.rack_jitter
        return self._shifted_exp(step, K, seed, base, jitter)

    def calm(self) -> "CorrelatedRackFailure":
        """The rack never fails."""
        return dataclasses.replace(self, fail_step=None)


@register
@dataclasses.dataclass(frozen=True)
class PoolResize(Scenario):
    """Mid-run worker pool shrink/grow.

    ``num_departing`` workers leave at ``depart_step`` (their finish times
    jump to ``down_factor`` x base — machines nobody should wait for);
    ``num_arriving`` workers are absent (same ``down_factor``) until they
    join at ``join_step``.  The two sets are disjoint.  The feed always
    emits for the full universe of K workers; a fixed-pool server sees
    departure/arrival purely through the monitor's mask, while an ELASTIC
    ``AdaptiveServer`` (``universe=``) starts its pool without the
    arriving set (:meth:`arriving_ids`), executes the shrink handoff when
    the departures exhaust slack, and ``grow()``s onto Leja-extended
    points at ``join_step``.
    """

    name: ClassVar[str] = "pool_resize"
    base: float = 1.0
    healthy_jitter: float = 0.05
    num_departing: int = 2
    depart_step: Optional[int] = 8
    num_arriving: int = 2
    join_step: Optional[int] = 4
    down_factor: float = 25.0

    def member_sets(self, K: int, seed: int) -> tuple:
        """The seed-fixed (departing, arriving) universe id arrays.

        The same ranked-uniform pick :meth:`times` applies, exposed so an
        elastic driver can start its pool without the arriving workers
        and admit exactly them at ``join_step``.
        """
        both = self._pick(K, self.num_departing + self.num_arriving, seed, 0)
        return both[: self.num_departing], both[self.num_departing:]

    def departing_ids(self, K: int, seed: int) -> np.ndarray:
        """Universe ids that go slow at ``depart_step``."""
        return self.member_sets(K, seed)[0]

    def arriving_ids(self, K: int, seed: int) -> np.ndarray:
        """Universe ids absent until ``join_step``."""
        return self.member_sets(K, seed)[1]

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Per-worker times with departures/arrivals applied at ``step``."""
        departing, arriving = self.member_sets(K, seed)
        base = np.full(K, self.base)
        if self.depart_step is not None and step >= self.depart_step:
            base[departing] *= self.down_factor
        if self.join_step is not None and step < self.join_step:
            base[arriving] *= self.down_factor
        return self._shifted_exp(step, K, seed, base,
                                 np.full(K, self.healthy_jitter))

    def calm(self) -> "PoolResize":
        """Nobody leaves, everybody already joined."""
        return dataclasses.replace(self, num_departing=0, num_arriving=0,
                                   join_step=None)


@register
@dataclasses.dataclass(frozen=True)
class Crawler(Scenario):
    """Persistently slow workers that never die — partial decoding's regime.

    ``num_crawlers`` seed-fixed workers run at a steady ``crawl_factor`` x
    base with mild ``crawl_jitter`` tails: slow enough that waiting for
    them dominates a step, but reliably PRODUCTIVE — each still completes
    a useful fraction of its block in the time the healthy pool finishes.
    Binary erasure throws that fraction away (and with more crawlers than
    the rung's budget, cannot mask them all); partial-straggler
    sub-tasking (``sub_tasks > 1``) consumes their chunk prefixes instead.
    """

    name: ClassVar[str] = "crawler"
    base: float = 1.0
    healthy_jitter: float = 0.05
    num_crawlers: int = 4
    crawl_factor: float = 1.8
    crawl_jitter: float = 0.15

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Per-worker times; the seed-fixed crawler set stays slow forever."""
        crawlers = self._pick(K, self.num_crawlers, seed, 0)
        base = np.full(K, self.base)
        jitter = np.full(K, self.healthy_jitter)
        base[crawlers] *= self.crawl_factor
        jitter[crawlers] = self.crawl_jitter
        return self._shifted_exp(step, K, seed, base, jitter)

    def calm(self) -> "Crawler":
        """No crawlers; healthy jitter only."""
        return dataclasses.replace(self, num_crawlers=0)


@register
@dataclasses.dataclass(frozen=True)
class Degrading(Scenario):
    """Workers that slow down progressively but keep producing.

    ``num_degrading`` seed-fixed workers run at
    ``min(1 + rate * step, max_factor)`` x base — a thermal-throttling /
    leaking-neighbour ramp.  Early on they are indistinguishable from
    healthy; by the time the monitor flags them they are far too slow to
    wait for yet still finish a useful prefix per step, so erasing them
    outright discards real work every step for the rest of the run.
    """

    name: ClassVar[str] = "degrading"
    base: float = 1.0
    healthy_jitter: float = 0.05
    num_degrading: int = 3
    rate: float = 0.08
    max_factor: float = 3.0
    degrade_jitter: float = 0.2

    def times(self, step: int, K: int, seed: int) -> np.ndarray:
        """Per-worker times with the ramped slowdown applied at ``step``."""
        degrading = self._pick(K, self.num_degrading, seed, 0)
        base = np.full(K, self.base)
        jitter = np.full(K, self.healthy_jitter)
        factor = min(1.0 + self.rate * step, self.max_factor)
        base[degrading] *= factor
        jitter[degrading] = self.degrade_jitter
        return self._shifted_exp(step, K, seed, base, jitter)

    def calm(self) -> "Degrading":
        """Nobody degrades; healthy jitter only."""
        return dataclasses.replace(self, num_degrading=0)
