"""Record and bit-deterministically replay adaptive serving runs.

A trace is a JSONL file: one header line, then one line per serving step
carrying BOTH sides of the control loop — the (K,) per-worker finish
times the feed produced AND the deterministic fields of the resulting
``StepReport`` (rung choice, mask, fractional progress plan, modelled
latency, predicted/realized tails, feedback quantile and threshold;
everything except wall-clock noise).  Python's
``json`` serialises floats at shortest round-trip precision, so float64
values survive the file boundary bit-exactly.

Usage — record::

    recorder = TraceRecorder(scenario.compile(K, seed=7), K,
                             meta={"scenario": "bursty", "seed": 7})
    server = AdaptiveServer(ladder, feed=recorder, ...)
    reports = server.run(steps, make_request)
    trace = recorder.finish(reports)
    trace.save("run.jsonl")

and replay::

    trace = Trace.load("run.jsonl")
    server2 = AdaptiveServer(ladder2, feed=trace.feed(), ...)  # same config
    reports2 = server2.run(len(trace.steps), make_request)
    assert trace.diff(reports2) == []

Replaying feeds the RECORDED times back through a freshly constructed,
identically configured server; because every control decision is a pure
function of the time stream (monitor EWMAs, closed-form quantiles, seeded
policy sampling), the rung choices, masks, and tails must reproduce
exactly — ``diff`` returns the field-level mismatches (empty = identical)
and ``verify_replay`` raises on any.  Golden traces under ``tests/golden/``
pin this contract in CI (regenerate via ``scripts/regen_golden_traces.py``).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

import numpy as np

from repro.chaos.serialize import (report_field_names, report_to_dict,
                                   tuplify)
from repro.core.simulator import TimeFeed

if TYPE_CHECKING:  # StepReport lives in control/, which imports jax;
    # keep repro.chaos importable (and fast) in jax-less contexts —
    # scenarios + trace handling are pure host-side numpy.
    from repro.control.driver import StepReport

__all__ = ["TRACE_VERSION", "TraceStep", "Trace", "TraceRecorder",
           "verify_replay"]

TRACE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TraceStep:
    """One recorded serving step: the feed's times + the report's decisions."""

    step: int
    times: Tuple[float, ...]
    rung: str
    switched: bool
    erased: Tuple[int, ...]
    sim_latency_s: float
    slack: int
    respecialize: bool
    shrink_target: Optional[Tuple[int, int]]
    exact: Optional[bool]
    slo_violation: bool
    predicted_tail_s: Optional[float]
    realized_s: Optional[float]
    realized_violation: bool
    q_effective: Optional[float]
    #: fractional per-worker progress plan (partial serving; None when Q=1).
    progress: Optional[Tuple[float, ...]] = None
    #: feedback-adjusted flagging threshold (None without feedback).
    threshold_effective: Optional[float] = None
    #: seed-derived obs correlation ID (span_id_for(seed, scope, step)).
    span_id: Optional[str] = None
    #: universe ids serving the step (elastic pool; None on fixed pools).
    pool: Optional[Tuple[int, ...]] = None

    @classmethod
    def from_report(cls, report: StepReport,
                    times: np.ndarray) -> "TraceStep":
        """Pair a ``StepReport`` with the times that produced it.

        Field selection goes through the shared
        :func:`repro.chaos.serialize.report_to_dict` (everything except
        wall-clock noise), so a field added to ``StepReport`` must be
        added HERE too — the resulting ``TypeError`` on the next recorded
        trace is the reminder that the trace schema (and
        ``COMPARED_FIELDS``) needs an intentional update.
        """
        rec = report_to_dict(report)
        rec["times"] = [float(t) for t in np.asarray(times)]
        return cls(**{k: tuplify(v) if isinstance(v, list) else v
                      for k, v in rec.items()})


#: StepReport fields a replay must reproduce bit-exactly — every
#: TraceStep field except the key (``step``) and the feed input
#: (``times``).  Derived from the schema itself (via the shared
#: ``report_field_names``), so a field added to StepReport + TraceStep is
#: automatically compared; forgetting the TraceStep half still fails
#: loudly in ``from_report``.
COMPARED_FIELDS = report_field_names(TraceStep, volatile=("step", "times"))


@dataclasses.dataclass(frozen=True)
class Trace:
    """A recorded run: K workers, free-form metadata, per-step records."""

    K: int
    meta: dict
    steps: Tuple[TraceStep, ...]

    def feed(self) -> TimeFeed:
        """A ``TimeFeed`` replaying the recorded per-worker times verbatim.

        Raises:
            IndexError: when asked for a step beyond the recording.
        """
        by_step = {s.step: np.asarray(s.times, dtype=np.float64)
                   for s in self.steps}

        def replay_feed(step: int, rng=None) -> np.ndarray:
            if step not in by_step:
                raise IndexError(
                    f"trace has no step {step} (recorded: {len(self.steps)})")
            return by_step[step].copy()

        return replay_feed

    def diff(self, reports: Sequence[StepReport]) -> List[str]:
        """Field-level mismatches between this trace and ``reports``.

        Every compared field must match EXACTLY (floats included — that is
        the bit-determinism contract).  Returns human-readable mismatch
        strings; an empty list means the replay reproduced the run.
        """
        out: List[str] = []
        if len(reports) != len(self.steps):
            out.append(f"step count: trace {len(self.steps)} vs "
                       f"replay {len(reports)}")
        for rec, rep in zip(self.steps, reports):
            got = TraceStep.from_report(rep, rec.times)
            for field in COMPARED_FIELDS:
                want, have = getattr(rec, field), getattr(got, field)
                if want != have:
                    out.append(f"step {rec.step} {field}: "
                               f"trace {want!r} vs replay {have!r}")
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, path) -> Path:
        """Write the trace as JSONL (header line + one line per step)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        lines = [json.dumps({"kind": "header", "version": TRACE_VERSION,
                             "K": self.K, "steps": len(self.steps),
                             "meta": self.meta}, sort_keys=True)]
        for s in self.steps:
            rec = dataclasses.asdict(s)
            rec = {"kind": "step", **{k: list(v) if isinstance(v, tuple)
                                      else v for k, v in rec.items()}}
            lines.append(json.dumps(rec, sort_keys=True))
        path.write_text("\n".join(lines) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "Trace":
        """Read a trace written by :meth:`save`.

        Raises:
            ValueError: on a missing/foreign header or version mismatch.
        """
        lines = Path(path).read_text().splitlines()
        if not lines:
            raise ValueError(f"{path}: empty trace file")
        header = json.loads(lines[0])
        if header.get("kind") != "header":
            raise ValueError(f"{path}: first line is not a trace header")
        if header.get("version") != TRACE_VERSION:
            raise ValueError(f"{path}: trace version {header.get('version')} "
                             f"!= supported {TRACE_VERSION}")
        steps = []
        for line in lines[1:]:
            rec = json.loads(line)
            if rec.pop("kind", None) != "step":
                raise ValueError(f"{path}: non-step record after header")
            rec["times"] = tuple(rec["times"])
            rec["erased"] = tuple(rec["erased"])
            if rec["shrink_target"] is not None:
                rec["shrink_target"] = tuple(rec["shrink_target"])
            if rec.get("progress") is not None:
                rec["progress"] = tuple(rec["progress"])
            if rec.get("pool") is not None:
                rec["pool"] = tuple(rec["pool"])
            steps.append(TraceStep(**rec))
        return cls(K=int(header["K"]), meta=dict(header.get("meta", {})),
                   steps=tuple(steps))


class TraceRecorder:
    """A pass-through ``TimeFeed`` that records what it emitted.

    Wrap the real feed, hand the recorder to ``AdaptiveServer(feed=...)``,
    run, then :meth:`finish` with the server's reports to obtain the
    :class:`Trace`.

    Args:
        feed: the underlying per-worker time source.
        K: worker count (recorded in the header; feeds are (K,)-shaped).
        meta: free-form provenance (scenario name/params, seed, ...).
    """

    def __init__(self, feed: TimeFeed, K: int, meta: Optional[dict] = None):
        self._feed = feed
        self.K = K
        self.meta = dict(meta or {})
        self._times: dict = {}

    def __call__(self, step: int, rng=None) -> np.ndarray:
        """Delegate to the wrapped feed, keeping a copy of the times."""
        t = np.asarray(self._feed(step, rng), dtype=np.float64)
        self._times[int(step)] = t.copy()
        return t

    def finish(self, reports: Sequence[StepReport]) -> Trace:
        """Pair the recorded times with the run's reports into a Trace.

        Raises:
            ValueError: if a report's step has no recorded times (the
                recorder was not the feed that served the run).
        """
        steps = []
        for rep in reports:
            if rep.step not in self._times:
                raise ValueError(f"no recorded times for step {rep.step}; "
                                 f"was this recorder the server's feed?")
            steps.append(TraceStep.from_report(rep, self._times[rep.step]))
        return Trace(K=self.K, meta=self.meta, steps=tuple(steps))


def verify_replay(trace: Trace, reports: Sequence[StepReport]) -> None:
    """Assert ``reports`` reproduce ``trace`` exactly.

    Raises:
        AssertionError: listing every mismatching field.
    """
    mismatches = trace.diff(reports)
    if mismatches:
        raise AssertionError(
            "replay diverged from trace:\n  " + "\n  ".join(mismatches))
