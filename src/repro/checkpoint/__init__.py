"""Checkpoint substrate: atomic npz-shard save/restore with manifest."""
from repro.checkpoint.store import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["latest_step", "restore_checkpoint", "save_checkpoint"]
