"""Fault-tolerant checkpointing: atomic, manifest-driven, resumable.

Layout:
  <dir>/step_000123/
      manifest.json       # tree structure + leaf index + data-stream step
      shard_00000.npz     # flattened leaves (chunked to ~512 MB per shard)
      .COMMIT             # written LAST; restore ignores dirs without it

Atomicity: we write into step_xxx.tmp/ and os.rename to step_xxx after the
COMMIT marker lands, so a preempted job can never observe a torn
checkpoint - the standard object-store-friendly recipe.  Restore picks the
newest committed step; torn tmp dirs are garbage-collected.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_SHARD_BYTES = 512 * 2**20

# dtypes numpy's npz roundtrips natively; everything else (bfloat16, fp8 -
# ml_dtypes extensions) is stored as a uint8 view + dtype name in the
# manifest and re-viewed on load.
_NATIVE = {"float16", "float32", "float64", "int8", "int16", "int32",
           "int64", "uint8", "uint16", "uint32", "uint64", "bool",
           "complex64", "complex128"}


def _encode(a: np.ndarray):
    name = a.dtype.name
    if name in _NATIVE:
        return a, name
    flat = np.ascontiguousarray(a).view(np.uint8)
    return flat, name


def _decode(a: np.ndarray, name: str) -> np.ndarray:
    if name in _NATIVE:
        return a
    import ml_dtypes  # noqa: F401 - registers bfloat16 & friends
    return a.view(np.dtype(name))


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str | Path, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:09d}"
    tmp = directory / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    arrays = [np.asarray(x) for x in leaves]

    shards = []
    cur: Dict[str, np.ndarray] = {}
    cur_bytes = 0
    index = []   # leaf i -> (shard, key)
    dtypes = []  # leaf i -> original dtype name
    for i, a in enumerate(arrays):
        key = f"leaf_{i}"
        enc, name = _encode(a)
        cur[key] = enc
        dtypes.append(name)
        cur_bytes += enc.nbytes
        index.append((len(shards), key))
        if cur_bytes >= _SHARD_BYTES:
            shards.append(cur)
            cur, cur_bytes = {}, 0
    shards.append(cur)

    for si, sh in enumerate(shards):
        np.savez(tmp / f"shard_{si:05d}.npz", **sh)

    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(arrays),
        "index": index,
        "dtypes": dtypes,
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / ".COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.iterdir():
        if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp"):
            if (d / ".COMMIT").exists():
                steps.append(int(d.name.split("_")[1]))
            # torn checkpoint: ignore
    return max(steps) if steps else None


def restore_checkpoint(directory: str | Path, template: Any,
                       step: Optional[int] = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``template`` (dtypes/shapes verified).

    Returns (tree, step, extra)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    d = directory / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    index = manifest["index"]

    shard_cache: Dict[int, Any] = {}
    leaves_t, treedef = jax.tree.flatten(template)
    if len(leaves_t) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, template "
            f"{len(leaves_t)}")
    dtypes = manifest.get("dtypes")
    out = []
    for i, tmpl in enumerate(leaves_t):
        si, key = index[i]
        if si not in shard_cache:
            shard_cache[si] = np.load(d / f"shard_{si:05d}.npz")
        a = shard_cache[si][key]
        if dtypes:
            a = _decode(a, dtypes[i]).reshape(np.shape(tmpl))
        if tuple(a.shape) != tuple(np.shape(tmpl)):
            raise ValueError(f"leaf {i}: shape {a.shape} != {np.shape(tmpl)}")
        out.append(a)
    tree = jax.tree.unflatten(treedef, out)
    return tree, step, manifest.get("extra", {})
