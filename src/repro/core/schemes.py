"""Coding schemes for distributed matmul C = A^T B.

Three schemes, all expressed in one algebraic frame.  Each block of A gets a
monomial  s^(s_exp) * z^(z_exp)  and likewise for B; worker k receives the
linear combinations evaluated at z_k and computes the product of its two
coded blocks.  The worker-output polynomial in z has degree tau-1, so ANY
tau workers determine all coefficients (Vandermonde interpolation).  Useful
blocks C_ij sit at known z-powers; for the bounded-entry schemes they are
superposed with interference terms at nonzero powers of the (large) base s
and are recovered by digit extraction (round + mod s).

Schemes
-------
EntangledBoundedScheme   (paper Sec. III-B) : tau = m*n           (optimal)
TradeoffScheme           (paper Sec. IV)    : tau = m*n*p' + p'-1 (p' | p)
PolynomialCodeYu         (baseline [Yu et al. 2018]): tau = p*m*n + p - 1

Notes
-----
* TradeoffScheme with p'=1 coincides with EntangledBoundedScheme up to the
  (immaterial) sign of the s exponents; with p'=p it degenerates to a pure
  polynomial code with tau = m*n*p + p - 1 and NO digit superposition.
* Paper Sec. IV states the useful z-power as m*p'*j + p'*i + p - 1; the
  derivation (and the paper's own Example 1) gives p' - 1, which is what we
  implement (verified: Example 1 useful powers z^1,z^3,z^5,z^7).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.partition import GridSpec

__all__ = [
    "Scheme",
    "EntangledBoundedScheme",
    "TradeoffScheme",
    "PolynomialCodeYu",
    "make_scheme",
]


@dataclasses.dataclass(frozen=True)
class Scheme:
    """Base: geometry + exponent tables.

    Subclasses fill in:
      a_z_exp, a_s_exp : (p, m) int arrays - monomial exponents per A block
      b_z_exp, b_s_exp : (p, n) int arrays - monomial exponents per B block
    """

    grid: GridSpec

    # ---- to be overridden -------------------------------------------------
    @property
    def tau(self) -> int:
        raise NotImplementedError

    def a_exponents(self) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def b_exponents(self) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def useful_z_exp(self) -> np.ndarray:
        """(m, n) int array: z-power carrying C_ij."""
        raise NotImplementedError

    @property
    def digit_depth(self) -> int:
        """Interference occupies s-digits -digit_depth..+digit_depth (0=C)."""
        raise NotImplementedError

    # ---- shared -----------------------------------------------------------
    @property
    def degree(self) -> int:
        return self.tau - 1

    @property
    def needs_digit_extraction(self) -> bool:
        return self.digit_depth > 0

    def max_abs_X(self, L: float, s: float) -> float:
        """Bound on |X_ij| (interpolated coefficient) given entry-product
        bound L (every C entry and every interference product < L) and base s.

        |X| <= sum_{d=-D..D} (L-1) s^d  <  L * s^D * (1 + 2/(s-1))  ~ L s^D.
        With s = 2L this is the paper's (2L)^{p/p'} / 2 up to the tiny
        negative-digit tail.
        """
        D = self.digit_depth
        return float((L - 1) * sum(float(s) ** d for d in range(-D, D + 1)))

    def encode_coeffs(self, z_points: np.ndarray, s: float):
        """Dense encoding coefficient tensors.

        Returns (coeff_a, coeff_b):
          coeff_a : (K, p, m)  with  coeff_a[k,u,i] = s^a_s[u,i] * z_k^a_z[u,i]
          coeff_b : (K, p, n)  likewise.
        Complex z yields complex coefficients.
        """
        az, asx = self.a_exponents()
        bz, bsx = self.b_exponents()
        z = np.asarray(z_points)[:, None, None]  # (K,1,1)
        sf = float(s)
        coeff_a = (sf ** asx.astype(np.float64))[None] * z ** az[None]
        coeff_b = (sf ** bsx.astype(np.float64))[None] * z ** bz[None]
        return coeff_a, coeff_b


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EntangledBoundedScheme(Scheme):
    """Paper Sec. III-B.  tau = m*n (optimal).

    A~(s,z) = sum_i z^i     sum_u A_ui s^{-u}
    B~(s,z) = sum_j z^{m j} sum_v B_vj s^{+v}
    C_ij is the s^0 digit of the z^{m j + i} coefficient.
    """

    @property
    def tau(self) -> int:
        g = self.grid
        return g.m * g.n

    def a_exponents(self):
        g = self.grid
        u = np.arange(g.p)[:, None]
        i = np.arange(g.m)[None, :]
        z_exp = np.broadcast_to(i, (g.p, g.m)).copy()
        s_exp = np.broadcast_to(-u, (g.p, g.m)).copy()
        return z_exp, s_exp

    def b_exponents(self):
        g = self.grid
        v = np.arange(g.p)[:, None]
        j = np.arange(g.n)[None, :]
        z_exp = np.broadcast_to(g.m * j, (g.p, g.n)).copy()
        s_exp = np.broadcast_to(v, (g.p, g.n)).copy()
        return z_exp, s_exp

    def useful_z_exp(self):
        g = self.grid
        i = np.arange(g.m)[:, None]
        j = np.arange(g.n)[None, :]
        return (g.m * j + i).astype(np.int64)

    @property
    def digit_depth(self) -> int:
        return self.grid.p - 1


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TradeoffScheme(Scheme):
    """Paper Sec. IV.  p' | p.  tau = m*n*p' + p' - 1, digits +-(p/p' - 1).

    A block (u_row, i):  u_row = k + (p/p') j,  j < p', k < p/p'
        -> z^{j + p' i} s^{k}
    B block (v_row, u):  v_row = w + (p/p') v,  v < p', w < p/p'
        -> z^{m p' u + (p' - 1 - v)} s^{-w}
    C_iu is the s^0 digit of z^{m p' u + p' i + p' - 1}.
    """

    p_prime: int = 1

    def __post_init__(self):
        if self.grid.p % self.p_prime != 0:
            raise ValueError(f"p'={self.p_prime} must divide p={self.grid.p}")

    @property
    def tau(self) -> int:
        g = self.grid
        return g.m * g.n * self.p_prime + self.p_prime - 1

    def a_exponents(self):
        g, pp = self.grid, self.p_prime
        q = g.p // pp  # p / p'
        u = np.arange(g.p)[:, None]
        i = np.arange(g.m)[None, :]
        j = u // q
        k = u % q
        z_exp = np.broadcast_to(j + pp * i, (g.p, g.m)).copy()
        s_exp = np.broadcast_to(k, (g.p, g.m)).copy()
        return z_exp, s_exp

    def b_exponents(self):
        g, pp = self.grid, self.p_prime
        q = g.p // pp
        vrow = np.arange(g.p)[:, None]
        u = np.arange(g.n)[None, :]
        v = vrow // q
        w = vrow % q
        z_exp = np.broadcast_to(g.m * pp * u + (pp - 1 - v), (g.p, g.n)).copy()
        s_exp = np.broadcast_to(-w, (g.p, g.n)).copy()
        return z_exp, s_exp

    def useful_z_exp(self):
        g, pp = self.grid, self.p_prime
        i = np.arange(g.m)[:, None]
        u = np.arange(g.n)[None, :]
        return (g.m * pp * u + pp * i + pp - 1).astype(np.int64)

    @property
    def digit_depth(self) -> int:
        return self.grid.p // self.p_prime - 1


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PolynomialCodeYu(Scheme):
    """Baseline of [Yu, Maddah-Ali, Avestimehr 2018].  tau = p*m*n + p - 1.

    A~(z) = sum_{u,i} A_ui z^{u + p i}
    B~(z) = sum_{v,j} B_vj z^{(p-1-v) + p m j}
    Every A_ui^T B_vj lands on a distinct z-power; C_ij (= sum over u=v) is
    the coefficient of z^{p - 1 + p i + p m j}.  No digit extraction.
    """

    @property
    def tau(self) -> int:
        g = self.grid
        return g.p * g.m * g.n + g.p - 1

    def a_exponents(self):
        g = self.grid
        u = np.arange(g.p)[:, None]
        i = np.arange(g.m)[None, :]
        z_exp = (u + g.p * i).astype(np.int64)
        s_exp = np.zeros((g.p, g.m), dtype=np.int64)
        return z_exp, s_exp

    def b_exponents(self):
        g = self.grid
        v = np.arange(g.p)[:, None]
        j = np.arange(g.n)[None, :]
        z_exp = ((g.p - 1 - v) + g.p * g.m * j).astype(np.int64)
        s_exp = np.zeros((g.p, g.n), dtype=np.int64)
        return z_exp, s_exp

    def useful_z_exp(self):
        g = self.grid
        i = np.arange(g.m)[:, None]
        j = np.arange(g.n)[None, :]
        return (g.p - 1 + g.p * i + g.p * g.m * j).astype(np.int64)

    @property
    def digit_depth(self) -> int:
        return 0


# ---------------------------------------------------------------------------
def make_scheme(kind: str, p: int, m: int, n: int, p_prime: int = 1) -> Scheme:
    grid = GridSpec(p=p, m=m, n=n)
    if kind in ("bec", "entangled", "bounded"):
        return EntangledBoundedScheme(grid)
    if kind == "tradeoff":
        return TradeoffScheme(grid, p_prime=p_prime)
    if kind in ("polycode", "yu", "baseline"):
        return PolynomialCodeYu(grid)
    raise ValueError(f"unknown scheme kind {kind!r}")
