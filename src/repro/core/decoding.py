"""Decoding: interpolation + digit extraction (paper Sec. III-C).

Given worker outputs Y_k = A~(s,z_k)^T B~(s,z_k) from any tau survivors:

1. Vandermonde-interpolate the z-polynomial coefficients X_0..X_{tau-1}.
2. Select the useful powers X_{phi(i,j)}.
3. Digit extraction (bounded-entry schemes only):
     R   = round(X)            # kills the negative s-digits (< 1/2 total)
     C^  = R mod s             # in [0, s)
     C   = C^            if C^ <= s/2
           C^ - s        otherwise       # sign recentering
   With s a power of two the mod is exact in binary floating point.

For the baseline polynomial code the useful coefficient IS C_ij (round only).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.schemes import Scheme
from repro.core.vandermonde import interpolate_solve, interpolate_masked

__all__ = [
    "digit_extract", "decode", "decode_masked",
    "DecodePanel", "DecodePanelCache", "make_decode_panel",
    "decode_with_panel", "decode_with_weights",
]


def digit_extract(X: jnp.ndarray, s: float, round_first: bool = True) -> jnp.ndarray:
    """Recover the s^0 digit of X = ... + *s^{-1} + C + *s + ... , |C| < s/2."""
    R = jnp.round(X) if round_first else X
    C_hat = jnp.mod(R, s)  # convention: result in [0, s)
    return jnp.where(C_hat <= s / 2, C_hat, C_hat - s)


def _finish_extract(scheme: Scheme, Xu: jnp.ndarray, s: float,
                    tail: tuple) -> jnp.ndarray:
    """Already-selected useful rows Xu (m*n, ...) -> (m, n, *tail) C blocks:
    real part, digit extraction (or plain rounding), block reshape."""
    g = scheme.grid
    if jnp.iscomplexobj(Xu):
        Xu = Xu.real
    if scheme.needs_digit_extraction:
        C = digit_extract(Xu, s)
    else:
        C = jnp.round(Xu)
    return C.reshape(g.m, g.n, *tail)


def _extract_useful(scheme: Scheme, X: jnp.ndarray, s: float) -> jnp.ndarray:
    """X: (tau, br, bt) coefficients -> (m, n, br, bt) decoded C blocks."""
    idx = scheme.useful_z_exp().reshape(-1)  # (m*n,)
    return _finish_extract(scheme, X[idx], s, X.shape[1:])


def decode(
    scheme: Scheme,
    z_survivors: jnp.ndarray,
    Y_survivors: jnp.ndarray,
    s: float,
) -> jnp.ndarray:
    """Decode from exactly tau survivor outputs (static survivor set).

    z_survivors: (tau,), Y_survivors: (tau, br, bt) -> C blocks (m, n, br, bt).
    """
    tau = scheme.tau
    if z_survivors.shape[0] != tau:
        raise ValueError(
            f"need exactly tau={tau} survivors, got {z_survivors.shape[0]}; "
            "slice the first tau or use decode_masked"
        )
    X = interpolate_solve(jnp.asarray(z_survivors), jnp.asarray(Y_survivors))
    return _extract_useful(scheme, X, s)


def decode_masked(
    scheme: Scheme,
    z_all: jnp.ndarray,
    Y_all: jnp.ndarray,
    mask: jnp.ndarray,
    s: float,
    ridge: float = 0.0,
) -> jnp.ndarray:
    """Decode with a dynamic 0/1 survivor mask over all K workers (jit-safe).

    Requires sum(mask) >= tau; erased rows of Y_all may hold garbage.
    """
    X = interpolate_masked(jnp.asarray(z_all), jnp.asarray(Y_all), mask, scheme.tau, ridge)
    return _extract_useful(scheme, X, s)


# ---------------------------------------------------------------------------
# Decode panels: per-survivor-mask setup factored OUT of the decode hot path.
#
# The masked normal equations G X = V_w^T Y depend only on (z, mask), not on
# the worker outputs Y.  A DecodePanel solves them ONCE on the host (LU
# factorisation of G, then the useful rows of G^{-1} V_w^T) and is reused for
# every subsequent step with the same erasure pattern: decode becomes a
# single (mn, K) @ (K, E) matmul + digit extraction, with no per-call
# factorisation on any device.  Erased workers get zero COLUMNS in W, so
# garbage rows of Y_all are annihilated without touching the mask again.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodePanel:
    """Precomputed decode weights for one (z_points, survivor-mask) pair."""

    mask: np.ndarray       # (K,) 0/1 as built
    W: np.ndarray          # (mn, K) useful rows of G^{-1} V_w^T (host const)

    @property
    def K(self) -> int:
        return self.W.shape[1]


def make_decode_panel(scheme: Scheme, z_all: np.ndarray,
                      mask: Optional[np.ndarray] = None,
                      ridge: float = 0.0) -> DecodePanel:
    """Factor the masked normal equations for a CONCRETE survivor mask.

    Pure HOST math (scipy/numpy, never jax): the panel must stay a constant
    even when built inside a trace context, so the jitted/shard-mapped decode
    body that closes over it contains no ``lu``/``triangular_solve``.
    """
    import scipy.linalg as sl

    z = np.asarray(z_all)
    K = z.shape[0]
    # Binarise: panels model 0/1 survivorship (and the cache keys by
    # support), so fractional weights would silently alias a cached panel.
    m = np.ones(K) if mask is None else (np.asarray(mask) != 0).astype(np.float64)
    if m.shape != (K,):
        raise ValueError(f"mask shape {m.shape} != ({K},)")
    if int(np.sum(m != 0)) < scheme.tau:
        raise ValueError(
            f"only {int(np.sum(m != 0))} survivors < tau={scheme.tau}")
    tau = scheme.tau
    V = z[:, None] ** np.arange(tau)[None, :]               # (K, tau)
    Vw = V * m[:, None]
    G = V.conj().T @ Vw                                      # (tau, tau)
    if ridge:
        G = G + ridge * np.eye(tau, dtype=G.dtype)
    lu_piv = sl.lu_factor(G)
    W_full = sl.lu_solve(lu_piv, Vw.conj().T)                # (tau, K)
    useful = np.asarray(scheme.useful_z_exp()).reshape(-1)
    return DecodePanel(mask=m, W=np.asarray(W_full[useful]))


def decode_with_weights(scheme: Scheme, W: jnp.ndarray, Y_all: jnp.ndarray,
                        s: float) -> jnp.ndarray:
    """Decode from a ready (mn, K) weight panel passed as an ARRAY.

    Y_all: (K, br, bt) ALL worker outputs (garbage where erased) ->
    (m, n, br, bt).  No linear solve inside; erased workers have zero
    columns in W.  Because W is an operand (not a closed-over constant),
    one compiled executable serves every concrete erasure pattern.
    """
    K = Y_all.shape[0]
    Yf = Y_all.reshape(K, -1)
    Xu = W @ Yf.astype(W.dtype)                              # (mn, E)
    return _finish_extract(scheme, Xu, s, Y_all.shape[1:])


def decode_with_panel(scheme: Scheme, panel: DecodePanel, Y_all: jnp.ndarray,
                      s: float) -> jnp.ndarray:
    """Y_all: (K, br, bt) ALL worker outputs (garbage where erased)
    -> (m, n, br, bt) via the precomputed panel.  No linear solve inside."""
    return decode_with_weights(scheme, jnp.asarray(panel.W), Y_all, s)


class DecodePanelCache:
    """Memoises DecodePanels by erasure pattern.

    The mesh runtime asks for a panel every step; for a stable mask (the
    common case - failures are rare events) this turns decode setup from
    O(tau^3) per call per device into an amortised host-side constant.
    ``builds`` counts actual factorisations (tests assert cache hits).
    """

    def __init__(self, scheme: Scheme, z_all: np.ndarray, ridge: float = 0.0):
        self.scheme = scheme
        self.z_all = np.asarray(z_all)
        self.ridge = ridge
        self.builds = 0
        self._panels: dict = {}
        self._partial_stacks: dict = {}

    def get(self, mask: Optional[np.ndarray] = None) -> DecodePanel:
        K = self.z_all.shape[0]
        m = np.ones(K) if mask is None else np.asarray(mask)
        key = tuple(int(x != 0) for x in m)
        panel = self._panels.get(key)
        if panel is None:
            with obs.span("decode.panel.build"):
                panel = make_decode_panel(self.scheme, self.z_all, m,
                                          self.ridge)
            self._panels[key] = panel
            self.builds += 1
            obs.count("decode.panel_cache.miss", cache="panel")
        else:
            obs.count("decode.panel_cache.hit", cache="panel")
        return panel

    def extended(self, z_new: np.ndarray) -> "DecodePanelCache":
        """A cache over the Leja-extended point set, seeded from this one.

        ``z_new`` must extend this cache's points (``z_new[:K] == z_all``
        bit-exact).  Every cached panel transfers: a K-pool survivor
        pattern is the (K+g)-pool pattern with all new workers erased,
        and masking the new workers zeroes their Vandermonde rows, so the
        normal-equations matrix G — hence the factored weights for the
        old workers — is IDENTICAL, and the new workers contribute zero
        columns.  Seeding therefore pads the cached ``W`` panels with
        zero columns instead of refactoring: growing the pool costs no
        host factorisations for any erasure pattern already seen
        (``builds`` starts at 0; partial stacks transfer the same way).

        Raises:
            ValueError: if ``z_new`` does not extend this cache's points.
        """
        z = np.asarray(z_new)
        K = self.z_all.shape[0]
        if z.ndim != 1 or z.shape[0] < K or not np.array_equal(z[:K],
                                                               self.z_all):
            raise ValueError("z_new must extend this cache's point set "
                             "(bit-exact prefix)")
        g = z.shape[0] - K
        cache = DecodePanelCache(self.scheme, z, self.ridge)
        if g == 0:
            cache._panels = dict(self._panels)
            cache._partial_stacks = dict(self._partial_stacks)
            return cache
        pad_mask = np.zeros(g, dtype=np.float64)
        for key, panel in self._panels.items():
            W = np.concatenate(
                [panel.W, np.zeros((panel.W.shape[0], g), panel.W.dtype)],
                axis=1)
            cache._panels[key + (0,) * g] = DecodePanel(
                mask=np.concatenate([panel.mask, pad_mask]), W=W)
        for key, stack in self._partial_stacks.items():
            new_key = ("partial",) + tuple(row + (0,) * g for row in key[1:])
            cache._partial_stacks[new_key] = np.concatenate(
                [stack, np.zeros(stack.shape[:2] + (g,), stack.dtype)],
                axis=2)
        return cache

    def get_partial(self, chunk_masks: np.ndarray) -> np.ndarray:
        """Stacked (Q, mn, K) decode weights for per-chunk survivor masks.

        ``chunk_masks`` is the (Q, K) 0/1 availability matrix of a concrete
        ``PartialPattern``: row c masks the workers whose completed prefix
        covers output-row chunk c.  Every chunk's panel has the same (mn, K)
        shape, so the stack is a single array operand for the partial decode
        executable.  Per-chunk panels come from :meth:`get`, so chunks
        sharing a survivor set — and binary patterns, where all Q rows are
        identical — share ONE factorisation; the stack itself is memoised by
        the pattern's quantized signature.
        """
        cm = np.asarray(chunk_masks)
        if cm.ndim != 2 or cm.shape[1] != self.z_all.shape[0]:
            raise ValueError(
                f"chunk_masks shape {cm.shape} != (Q, {self.z_all.shape[0]})")
        key = ("partial",) + tuple(
            tuple(int(x != 0) for x in row) for row in cm)
        stack = self._partial_stacks.get(key)
        if stack is None:
            stack = np.stack([self.get(row).W for row in cm])
            self._partial_stacks[key] = stack
            obs.count("decode.panel_cache.miss", cache="stack")
        else:
            obs.count("decode.panel_cache.hit", cache="stack")
        return stack
