"""Decoding: interpolation + digit extraction (paper Sec. III-C).

Given worker outputs Y_k = A~(s,z_k)^T B~(s,z_k) from any tau survivors:

1. Vandermonde-interpolate the z-polynomial coefficients X_0..X_{tau-1}.
2. Select the useful powers X_{phi(i,j)}.
3. Digit extraction (bounded-entry schemes only):
     R   = round(X)            # kills the negative s-digits (< 1/2 total)
     C^  = R mod s             # in [0, s)
     C   = C^            if C^ <= s/2
           C^ - s        otherwise       # sign recentering
   With s a power of two the mod is exact in binary floating point.

For the baseline polynomial code the useful coefficient IS C_ij (round only).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.schemes import Scheme
from repro.core.vandermonde import interpolate_solve, interpolate_masked

__all__ = ["digit_extract", "decode", "decode_masked"]


def digit_extract(X: jnp.ndarray, s: float, round_first: bool = True) -> jnp.ndarray:
    """Recover the s^0 digit of X = ... + *s^{-1} + C + *s + ... , |C| < s/2."""
    R = jnp.round(X) if round_first else X
    C_hat = jnp.mod(R, s)  # convention: result in [0, s)
    return jnp.where(C_hat <= s / 2, C_hat, C_hat - s)


def _extract_useful(scheme: Scheme, X: jnp.ndarray, s: float) -> jnp.ndarray:
    """X: (tau, br, bt) coefficients -> (m, n, br, bt) decoded C blocks."""
    g = scheme.grid
    idx = scheme.useful_z_exp().reshape(-1)  # (m*n,)
    Xu = X[idx]  # (m*n, br, bt)
    if jnp.iscomplexobj(Xu):
        Xu = Xu.real
    if scheme.needs_digit_extraction:
        C = digit_extract(Xu, s)
    else:
        C = jnp.round(Xu)
    return C.reshape(g.m, g.n, *X.shape[1:])


def decode(
    scheme: Scheme,
    z_survivors: jnp.ndarray,
    Y_survivors: jnp.ndarray,
    s: float,
) -> jnp.ndarray:
    """Decode from exactly tau survivor outputs (static survivor set).

    z_survivors: (tau,), Y_survivors: (tau, br, bt) -> C blocks (m, n, br, bt).
    """
    tau = scheme.tau
    if z_survivors.shape[0] != tau:
        raise ValueError(
            f"need exactly tau={tau} survivors, got {z_survivors.shape[0]}; "
            "slice the first tau or use decode_masked"
        )
    X = interpolate_solve(jnp.asarray(z_survivors), jnp.asarray(Y_survivors))
    return _extract_useful(scheme, X, s)


def decode_masked(
    scheme: Scheme,
    z_all: jnp.ndarray,
    Y_all: jnp.ndarray,
    mask: jnp.ndarray,
    s: float,
    ridge: float = 0.0,
) -> jnp.ndarray:
    """Decode with a dynamic 0/1 survivor mask over all K workers (jit-safe).

    Requires sum(mask) >= tau; erased rows of Y_all may hold garbage.
    """
    X = interpolate_masked(jnp.asarray(z_all), jnp.asarray(Y_all), mask, scheme.tau, ridge)
    return _extract_useful(scheme, X, s)
