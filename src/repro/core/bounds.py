"""Numeric-headroom analysis (paper Sec. III-D and Sec. IV).

The bounded-entry schemes require every decoded coefficient magnitude to be
exactly representable: |X| <= (2L)^{p/p'}/2 must stay within the floating
mantissa so that round() recovers the integer exactly.  This module computes
safe (L, s, p') regions per dtype and picks the smallest p' (lowest tau)
that is numerically safe - the paper's precision/threshold tradeoff as an
executable policy.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.schemes import make_scheme

__all__ = [
    "mantissa_bits",
    "conservative_L",
    "choose_s",
    "max_abs_coefficient",
    "is_safe",
    "BoundsReport",
    "plan_p_prime",
]

_MANTISSA = {
    "float64": 53,
    "float32": 24,
    "bfloat16": 8,
    "complex128": 53,
    "complex64": 24,
}


def mantissa_bits(dtype) -> int:
    name = np.dtype(dtype).name if not isinstance(dtype, str) else dtype
    try:
        return _MANTISSA[str(name)]
    except KeyError:
        raise ValueError(f"no mantissa entry for dtype {dtype!r}")


def conservative_L(v: int, max_a: float, max_b: float) -> int:
    """Paper Sec. III-D: L = v * max|A| * max|B| + 1 bounds every C entry and
    every interference product (each is an inner product of length <= v)."""
    return int(v * max_a * max_b) + 1


def choose_s(L: float, power_of_two: bool = True) -> int:
    """Smallest valid base s >= 2L; power of two preferred (exact mod by
    bit-shift, and exact fp multiplication by s)."""
    s_min = 2 * L
    if not power_of_two:
        return int(math.ceil(s_min))
    return 1 << int(math.ceil(math.log2(s_min)))


def max_abs_coefficient(L: float, s: float, digit_depth: int) -> float:
    """Bound on |X_ij|: sum over digits -D..D of (L-1) s^d."""
    return (L - 1) * sum(float(s) ** d for d in range(-digit_depth, digit_depth + 1))


def is_safe(L: float, s: float, digit_depth: int, dtype, tau: int = 1,
            conditioning_slack_bits: float = 4.0) -> bool:
    """True if decode is exact for this (L, s, digit depth, dtype).

    Exact rounding needs the interpolated X to carry absolute error < 1/2.
    We require max|Y| ~ tau * max|X| (|z| <= 1) to sit
    ``conditioning_slack_bits`` below the mantissa, leaving headroom for the
    Vandermonde solve's error amplification.  The slack is a policy knob;
    Table I reproduction uses the raw (0-slack) check.
    """
    mx = max_abs_coefficient(L, s, digit_depth) * max(tau, 1)
    if mx <= 0:
        return True
    return math.log2(mx) + conditioning_slack_bits <= mantissa_bits(dtype)


@dataclasses.dataclass(frozen=True)
class BoundsReport:
    L: int
    s: int
    p_prime: int
    tau: int
    digit_depth: int
    max_abs_X: float
    mantissa: int
    safe: bool


def plan_p_prime(
    p: int, m: int, n: int, L: int, dtype="float64",
    power_of_two_s: bool = True,
    conditioning_slack_bits: float = 4.0,
) -> BoundsReport:
    """Pick the smallest divisor p' of p whose tradeoff scheme is numerically
    safe for ``dtype``; falls back to p'=p (pure polynomial code regime,
    always safe digit-wise) if none is.

    This is the paper's Sec. IV tradeoff surfaced as an executable planner:
    small p' -> low recovery threshold but tall digit stacks; large p' ->
    shallow digits (small |X|) but high threshold.
    """
    s = choose_s(L, power_of_two_s)
    divisors = [d for d in range(1, p + 1) if p % d == 0]
    chosen = None
    for pp in divisors:
        sch = make_scheme("tradeoff", p, m, n, p_prime=pp)
        if is_safe(L, s, sch.digit_depth, dtype, tau=sch.tau,
                   conditioning_slack_bits=conditioning_slack_bits):
            chosen = (pp, sch)
            break
    if chosen is None:
        pp = p
        chosen = (pp, make_scheme("tradeoff", p, m, n, p_prime=pp))
    pp, sch = chosen
    return BoundsReport(
        L=L, s=s, p_prime=pp, tau=sch.tau, digit_depth=sch.digit_depth,
        max_abs_X=max_abs_coefficient(L, s, sch.digit_depth),
        mantissa=mantissa_bits(dtype),
        safe=is_safe(L, s, sch.digit_depth, dtype, tau=sch.tau,
                     conditioning_slack_bits=conditioning_slack_bits),
    )
