"""Core: the paper's coded-matmul schemes, decoding, bounds, and simulator."""
from repro.core.api import (
    CodedMatmulPlan,
    coded_matmul,
    encode_blocks,
    extend_plan,
    fused_worker_products,
    make_plan,
    shrink_plan,
    uncoded_matmul,
    worker_products,
)
from repro.core.bounds import BoundsReport, choose_s, conservative_L, plan_p_prime
from repro.core.decoding import (
    DecodePanel,
    DecodePanelCache,
    decode,
    decode_masked,
    decode_with_panel,
    digit_extract,
    make_decode_panel,
)
from repro.core.partition import GridSpec, block_decompose, block_recompose
from repro.core.points import extend_points, make_points
from repro.core.schemes import (
    EntangledBoundedScheme,
    PolynomialCodeYu,
    Scheme,
    TradeoffScheme,
    make_scheme,
)
from repro.core.simulator import (
    LatencyModel,
    WorkerTimes,
    completion_quantile,
    masked_completion_cdf,
    masked_completion_mean,
    masked_completion_quantile,
    simulate_completion,
)

__all__ = [
    "CodedMatmulPlan", "coded_matmul", "encode_blocks", "make_plan",
    "uncoded_matmul", "worker_products", "fused_worker_products",
    "extend_plan", "shrink_plan",
    "BoundsReport", "choose_s", "conservative_L", "plan_p_prime",
    "decode", "decode_masked", "digit_extract",
    "DecodePanel", "DecodePanelCache", "decode_with_panel",
    "make_decode_panel",
    "GridSpec", "block_decompose", "block_recompose",
    "extend_points", "make_points",
    "EntangledBoundedScheme", "PolynomialCodeYu", "Scheme", "TradeoffScheme",
    "make_scheme",
    "LatencyModel", "WorkerTimes", "simulate_completion",
    "completion_quantile", "masked_completion_cdf",
    "masked_completion_mean", "masked_completion_quantile",
]
