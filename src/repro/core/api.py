"""High-level single-host reference path for coded distributed matmul.

``coded_matmul`` runs the whole pipeline (encode -> per-worker products ->
erasure -> decode) as one JAX computation; it is the oracle against which
the Pallas kernels and the on-mesh shard_map runtime are tested, and the
engine behind the paper-reproduction benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds as bounds_mod
from repro.core.decoding import DecodePanelCache, decode, decode_masked
from repro.core.partition import GridSpec, block_decompose, block_recompose, unpad
from repro.core.points import make_points
from repro.core.schemes import Scheme, make_scheme

__all__ = ["CodedMatmulPlan", "make_plan", "coded_matmul", "encode_blocks",
           "worker_products", "fused_worker_products"]


@dataclasses.dataclass(frozen=True)
class CodedMatmulPlan:
    """Everything static about one coded matmul configuration."""

    scheme: Scheme
    K: int
    s: float
    z_points: np.ndarray          # (K,)
    coeff_a: np.ndarray           # (K, p, m) encode coefficients for A blocks
    coeff_b: np.ndarray           # (K, p, n)

    @property
    def tau(self) -> int:
        return self.scheme.tau

    @property
    def is_complex(self) -> bool:
        return np.iscomplexobj(self.z_points)

    def make_panel_cache(self, ridge: float = 0.0) -> DecodePanelCache:
        """Per-mask decode-panel cache (LU of the masked normal equations).

        Build ONE cache per plan and reuse it across steps: panels are
        factored on the host on first sight of an erasure pattern and
        amortised to a dict lookup afterwards (DESIGN.md Sec. 3.4).
        """
        return DecodePanelCache(self.scheme, self.z_points, ridge)


def make_plan(
    kind: str,
    p: int,
    m: int,
    n: int,
    K: int,
    L: int,
    *,
    p_prime: int = 1,
    points: str = "equispaced",
    s: Optional[int] = None,
) -> CodedMatmulPlan:
    scheme = make_scheme(kind, p, m, n, p_prime=p_prime)
    if K < scheme.tau:
        raise ValueError(f"K={K} below recovery threshold tau={scheme.tau}")
    z = make_points(points, K)
    s_val = s if s is not None else bounds_mod.choose_s(L)
    ca, cb = scheme.encode_coeffs(z, s_val)
    return CodedMatmulPlan(scheme=scheme, K=K, s=float(s_val), z_points=z,
                           coeff_a=ca, coeff_b=cb)


def encode_blocks(plan: CodedMatmulPlan, a_blocks: jnp.ndarray, b_blocks: jnp.ndarray):
    """a_blocks: (p, m, bv, br), b_blocks: (p, n, bv, bt)
    -> (K, bv, br), (K, bv, bt) coded matrices per worker."""
    ca = jnp.asarray(plan.coeff_a, dtype=_coeff_dtype(a_blocks, plan))
    cb = jnp.asarray(plan.coeff_b, dtype=_coeff_dtype(b_blocks, plan))
    a_tilde = jnp.einsum("kpm,pmvr->kvr", ca, a_blocks.astype(ca.dtype))
    b_tilde = jnp.einsum("kpn,pnvt->kvt", cb, b_blocks.astype(cb.dtype))
    return a_tilde, b_tilde


def worker_products(a_tilde: jnp.ndarray, b_tilde: jnp.ndarray) -> jnp.ndarray:
    """Per-worker products Y_k = A~_k^T B~_k: (K, bv, br), (K, bv, bt) -> (K, br, bt)."""
    return jnp.einsum("kvr,kvt->krt", a_tilde, b_tilde)


def fused_worker_products(plan: CodedMatmulPlan, a_blocks: jnp.ndarray,
                          b_blocks: jnp.ndarray) -> jnp.ndarray:
    """All worker products via the fused encode+product Pallas megakernel.

    a_blocks: (p, m, bv, br), b_blocks: (p, n, bv, bt) -> (K, br, bt).
    Equivalent to encode_blocks + worker_products but the coded matrices
    A~, B~ are formed only tile-wise in VMEM, never written to HBM.
    """
    from repro.kernels import ops as kops

    p, m, bv, br = a_blocks.shape
    _, n, _, bt = b_blocks.shape
    ca = jnp.asarray(plan.coeff_a.reshape(plan.K, p * m),
                     dtype=_coeff_dtype(a_blocks, plan))
    cb = jnp.asarray(plan.coeff_b.reshape(plan.K, p * n),
                     dtype=_coeff_dtype(b_blocks, plan))
    return kops.fused_worker(ca, cb,
                             a_blocks.reshape(p * m, bv, br),
                             b_blocks.reshape(p * n, bv, bt))


def _coeff_dtype(x: jnp.ndarray, plan: CodedMatmulPlan):
    if plan.is_complex:
        return jnp.complex128 if x.dtype == jnp.float64 else jnp.complex64
    return x.dtype


def coded_matmul(
    A: jnp.ndarray,
    B: jnp.ndarray,
    plan: CodedMatmulPlan,
    *,
    erased: Optional[Sequence[int]] = None,
    survivors: Optional[Sequence[int]] = None,
    dtype=jnp.float64,
    fused: bool = False,
) -> jnp.ndarray:
    """Compute C = A^T B through the coded pipeline.

    A: (v, r), B: (v, t).  ``erased`` lists worker ids treated as stragglers
    (their outputs discarded); alternatively pass an explicit ``survivors``
    order.  Uses the first tau survivors.  Exact for integer matrices within
    the plan's numeric bounds.  ``fused=True`` computes the worker products
    through the fused encode+product Pallas megakernel (coded matrices never
    materialised) instead of the staged einsum path.
    """
    if erased is not None and survivors is not None:
        raise ValueError("pass only one of erased/survivors")
    g = plan.scheme.grid
    v, r = A.shape
    v2, t = B.shape
    if v != v2:
        raise ValueError(f"contraction mismatch {A.shape} vs {B.shape}")
    A = A.astype(dtype)
    B = B.astype(dtype)
    a_blocks = block_decompose(A, g.p, g.m)
    b_blocks = block_decompose(B, g.p, g.n)
    if fused:
        Y = fused_worker_products(plan, a_blocks, b_blocks)  # (K, br, bt)
    else:
        a_tilde, b_tilde = encode_blocks(plan, a_blocks, b_blocks)
        Y = worker_products(a_tilde, b_tilde)  # (K, br, bt)

    if survivors is None:
        if erased is None:
            erased = []
        survivors = [k for k in range(plan.K) if k not in set(erased)]
    if len(survivors) < plan.tau:
        raise ValueError(
            f"only {len(survivors)} survivors < tau={plan.tau}: undecodable")
    sel = np.asarray(survivors[: plan.tau])
    z_s = jnp.asarray(plan.z_points[sel])
    C_blocks = decode(plan.scheme, z_s, Y[sel], plan.s)  # (m, n, br, bt)
    C = block_recompose(C_blocks)
    return unpad(C, (r, t)).astype(dtype)


def uncoded_matmul(A: jnp.ndarray, B: jnp.ndarray, dtype=jnp.float64) -> jnp.ndarray:
    """Direct C = A^T B reference."""
    return (A.astype(dtype).T @ B.astype(dtype))
