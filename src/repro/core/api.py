"""Plan construction + the encode/product building blocks.

``CodedMatmulPlan`` freezes everything static about one coded matmul;
``encode_blocks`` / ``worker_products`` / ``fused_worker_products`` are the
stage primitives the runtime executors are built from.

``coded_matmul`` remains as a deprecation shim over the unified runtime
(``repro.runtime.CodedMatmul``), which owns backend selection, erasure
normalisation, and jit-executable caching.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import bounds as bounds_mod
from repro.core.decoding import DecodePanelCache
from repro.core.points import make_points
from repro.core.schemes import Scheme, make_scheme

__all__ = ["CodedMatmulPlan", "make_plan", "extend_plan", "shrink_plan",
           "coded_matmul", "encode_blocks", "worker_products",
           "fused_worker_products", "runtime_facade"]


@dataclasses.dataclass(frozen=True)
class CodedMatmulPlan:
    """Everything static about one coded matmul configuration."""

    scheme: Scheme
    K: int
    s: float
    z_points: np.ndarray          # (K,)
    coeff_a: np.ndarray           # (K, p, m) encode coefficients for A blocks
    coeff_b: np.ndarray           # (K, p, n)

    @property
    def tau(self) -> int:
        return self.scheme.tau

    @property
    def is_complex(self) -> bool:
        return np.iscomplexobj(self.z_points)

    def make_panel_cache(self, ridge: float = 0.0) -> DecodePanelCache:
        """Per-mask decode-panel cache (LU of the masked normal equations).

        Build ONE cache per plan and reuse it across steps: panels are
        factored on the host on first sight of an erasure pattern and
        amortised to a dict lookup afterwards (DESIGN.md Sec. 3.4).
        """
        return DecodePanelCache(self.scheme, self.z_points, ridge)


def make_plan(
    kind: str,
    p: int,
    m: int,
    n: int,
    K: int,
    L: int,
    *,
    p_prime: int = 1,
    points: str = "equispaced",
    s: Optional[float] = None,
    z_points: Optional[np.ndarray] = None,
) -> CodedMatmulPlan:
    """Freeze one coded-matmul configuration into a plan.

    kind:    scheme family - "bec" (Sec. III-B), "tradeoff" (Sec. IV, with
             ``p_prime``), or "polycode" (the Yu et al. baseline).
    p, m, n: block grid - A is split p x m, B is split p x n.
    K:       number of workers (evaluation points); must be >= the scheme's
             recovery threshold tau.
    L:       entry-product bound (Sec. III-D): every C entry and every
             interference product must have magnitude < L.
    points:  evaluation-point family ("equispaced" / "chebyshev" /
             "unit_circle").
    s:       the digit base of the bounded-entry superposition, in the same
             units as the matrix entries (a dimensionless integer scale).
             Default ``None`` picks ``bounds.choose_s(L)`` - the smallest
             power of two >= 2L, which makes digit extraction (round +
             mod s) exact in binary floating point.  An explicit ``s`` must
             be >= 2 (bases below 2 cannot separate digits) and is only
             exact when s >= 2L; it is stored on the plan as ``float``.
    z_points: explicit (K,) evaluation points, overriding ``points``.  The
             elastic paths use this to build plans on a survivor subset or
             a Leja-extended superset of a live pool's points
             (``core.points.extend_points``).
    """
    scheme = make_scheme(kind, p, m, n, p_prime=p_prime)
    if K < scheme.tau:
        raise ValueError(f"K={K} below recovery threshold tau={scheme.tau}")
    if z_points is not None:
        z = np.asarray(z_points)
        if z.shape != (K,):
            raise ValueError(f"z_points shape {z.shape} != ({K},)")
    else:
        z = make_points(points, K)
    s_val = float(s) if s is not None else float(bounds_mod.choose_s(L))
    if s_val < 2:
        raise ValueError(f"digit base s={s_val} must be >= 2 (and >= 2L={2 * L} "
                         "for exact digit extraction)")
    ca, cb = scheme.encode_coeffs(z, s_val)
    return CodedMatmulPlan(scheme=scheme, K=K, s=s_val, z_points=z,
                           coeff_a=ca, coeff_b=cb)


def extend_plan(plan: CodedMatmulPlan, g: int,
                z_new: Optional[np.ndarray] = None) -> CodedMatmulPlan:
    """Grow a plan by ``g`` workers via incremental point extension.

    Evaluation points extend by greedy Leja selection
    (``core.points.extend_points``) and ONLY the ``g`` new coefficient
    rows are computed — the existing rows are reused by reference, so the
    first K rows of the result are bit-identical to ``plan``'s.  Encoding
    is per-point (every scheme's ``encode_coeffs`` evaluates row k from
    ``z_k`` alone), so the same plan is produced by building fresh at
    ``K + g`` with the same points; the incremental path just never
    touches the surviving workers' tasks.

    ``z_new`` optionally supplies the already-extended ``(K + g,)`` point
    set (it must extend ``plan``'s points bit-exactly) so several plans
    sharing one pool extend onto the SAME array.
    """
    if g < 0:
        raise ValueError(f"g must be >= 0, got {g}")
    if g == 0:
        return plan
    from repro.core.points import extend_points

    if z_new is not None:
        z = np.asarray(z_new)
        if z.shape != (plan.K + g,) or not np.array_equal(
                z[:plan.K], np.asarray(plan.z_points)):
            raise ValueError(
                f"z_new must extend the plan's {plan.K} points by {g}")
    else:
        z = extend_points(plan.z_points, g)
    ca_new, cb_new = plan.scheme.encode_coeffs(z[plan.K:], plan.s)
    return CodedMatmulPlan(
        scheme=plan.scheme, K=plan.K + g, s=plan.s, z_points=z,
        coeff_a=np.concatenate([plan.coeff_a, ca_new], axis=0),
        coeff_b=np.concatenate([plan.coeff_b, cb_new], axis=0))


def shrink_plan(plan: CodedMatmulPlan, keep: Sequence[int]) -> CodedMatmulPlan:
    """Shrink a plan to the ``keep`` workers (pool-local indices, in order).

    Survivors keep their evaluation points and coefficient rows (sliced,
    not re-encoded — bit-identical), so their encoded tasks and any decode
    panels for patterns inside the survivor set remain valid.

    Raises:
        ValueError: if ``keep`` has duplicates, indexes outside the pool,
            or leaves fewer than ``tau`` workers (undecodable).
    """
    idx = np.asarray(keep, dtype=np.intp)
    if idx.ndim != 1 or len(set(idx.tolist())) != idx.size:
        raise ValueError(f"keep must be 1-D and duplicate-free, got {keep!r}")
    if idx.size and (idx.min() < 0 or idx.max() >= plan.K):
        raise ValueError(f"keep indexes outside the pool of {plan.K} workers")
    if idx.size < plan.tau:
        raise ValueError(
            f"shrinking to {idx.size} workers breaks tau={plan.tau}")
    return CodedMatmulPlan(
        scheme=plan.scheme, K=int(idx.size), s=plan.s,
        z_points=plan.z_points[idx],
        coeff_a=plan.coeff_a[idx], coeff_b=plan.coeff_b[idx])


def encode_blocks(plan: CodedMatmulPlan, a_blocks: jnp.ndarray, b_blocks: jnp.ndarray):
    """a_blocks: (p, m, bv, br), b_blocks: (p, n, bv, bt)
    -> (K, bv, br), (K, bv, bt) coded matrices per worker."""
    ca = jnp.asarray(plan.coeff_a, dtype=_coeff_dtype(a_blocks, plan))
    cb = jnp.asarray(plan.coeff_b, dtype=_coeff_dtype(b_blocks, plan))
    a_tilde = jnp.einsum("kpm,pmvr->kvr", ca, a_blocks.astype(ca.dtype))
    b_tilde = jnp.einsum("kpn,pnvt->kvt", cb, b_blocks.astype(cb.dtype))
    return a_tilde, b_tilde


def worker_products(a_tilde: jnp.ndarray, b_tilde: jnp.ndarray) -> jnp.ndarray:
    """Per-worker products Y_k = A~_k^T B~_k: (K, bv, br), (K, bv, bt) -> (K, br, bt)."""
    return jnp.einsum("kvr,kvt->krt", a_tilde, b_tilde)


def fused_worker_products(plan: CodedMatmulPlan, a_blocks: jnp.ndarray,
                          b_blocks: jnp.ndarray) -> jnp.ndarray:
    """All worker products via the fused encode+product Pallas megakernel.

    a_blocks: (p, m, bv, br), b_blocks: (p, n, bv, bt) -> (K, br, bt).
    Equivalent to encode_blocks + worker_products but the coded matrices
    A~, B~ are formed only tile-wise in VMEM, never written to HBM.
    """
    from repro.kernels import ops as kops

    p, m, bv, br = a_blocks.shape
    _, n, _, bt = b_blocks.shape
    ca = jnp.asarray(plan.coeff_a.reshape(plan.K, p * m),
                     dtype=_coeff_dtype(a_blocks, plan))
    cb = jnp.asarray(plan.coeff_b.reshape(plan.K, p * n),
                     dtype=_coeff_dtype(b_blocks, plan))
    return kops.fused_worker(ca, cb,
                             a_blocks.reshape(p * m, bv, br),
                             b_blocks.reshape(p * n, bv, bt))


def _coeff_dtype(x: jnp.ndarray, plan: CodedMatmulPlan):
    if plan.is_complex:
        return jnp.complex128 if x.dtype == jnp.float64 else jnp.complex64
    return x.dtype


# ---------------------------------------------------------------------------
# Legacy entry point: deprecation shim over the unified runtime.
# ---------------------------------------------------------------------------

_RUNTIME_FACADES: dict = {}
_RUNTIME_FACADES_MAX = 64


def runtime_facade(plan: CodedMatmulPlan, backend: str = "fused",
                   dtype=jnp.float64, *, panel_cache=None, **opts):
    """Module-level memo of ``repro.runtime.CodedMatmul`` facades.

    Keyed by plan VALUE (scheme geometry + points + base), not identity, so
    equal plans share one facade - and therefore one decode-panel cache and
    one jit-executable memo - across shim calls.  A caller-supplied
    ``panel_cache`` is part of the key (by identity): callers with their
    own caches get their own facades instead of clobbering the shared one.
    The memo is FIFO-bounded so long-lived processes churning through many
    distinct plans cannot pin executables without limit.
    """
    from repro.runtime import CodedMatmul

    key = (plan.scheme, plan.K, plan.s,
           tuple(np.asarray(plan.z_points).ravel().tolist()),
           str(jnp.dtype(dtype)), backend,
           None if panel_cache is None else id(panel_cache),
           tuple(sorted(opts.items(), key=lambda kv: kv[0])))
    cm = _RUNTIME_FACADES.get(key)
    if cm is None:
        cm = CodedMatmul(plan, backend, dtype=dtype, **opts)
        if panel_cache is not None:
            # facade holds the reference, so id(panel_cache) stays valid
            # for as long as this memo entry lives
            cm.panel_cache = panel_cache
        while len(_RUNTIME_FACADES) >= _RUNTIME_FACADES_MAX:
            _RUNTIME_FACADES.pop(next(iter(_RUNTIME_FACADES)))
        _RUNTIME_FACADES[key] = cm
    return cm


def coded_matmul(
    A: jnp.ndarray,
    B: jnp.ndarray,
    plan: CodedMatmulPlan,
    *,
    erased: Optional[Sequence[int]] = None,
    survivors: Optional[Sequence[int]] = None,
    dtype=jnp.float64,
    fused: bool = False,
) -> jnp.ndarray:
    """DEPRECATED: use ``repro.runtime.CodedMatmul`` instead.

    Compute C = A^T B through the coded pipeline.  A: (v, r), B: (v, t).
    ``erased`` lists worker ids treated as stragglers; alternatively pass an
    explicit ``survivors`` set (decoding now weights ALL listed survivors,
    so order no longer matters).  Exact for integer matrices within the
    plan's numeric bounds.  ``fused=True`` selects the fused megakernel
    backend, ``fused=False`` the staged einsum reference backend.
    """
    warnings.warn(
        "coded_matmul is deprecated; use repro.runtime.CodedMatmul "
        "(plan facade with pluggable backends and jit caching)",
        DeprecationWarning, stacklevel=2)
    if erased is not None and survivors is not None:
        raise ValueError("pass only one of erased/survivors")
    cm = runtime_facade(plan, "fused" if fused else "reference", dtype)
    return cm(A, B, erased=erased, survivors=survivors)


def uncoded_matmul(A: jnp.ndarray, B: jnp.ndarray, dtype=jnp.float64) -> jnp.ndarray:
    """Direct C = A^T B reference; leading batch dims broadcast on either side."""
    return jnp.einsum("...vr,...vt->...rt", A.astype(dtype), B.astype(dtype))
