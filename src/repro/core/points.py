"""Evaluation points z_1..z_K for the coded matmul polynomials.

The paper (Sec. V) uses K equally spaced reals in [-1, 1] and notes that
real Vandermonde systems are badly conditioned; complex points on the unit
circle give error that is "identically zero" at the cost of complex
arithmetic.  Beyond the paper we also provide Chebyshev nodes, which keep
real arithmetic but improve the Vandermonde condition number exponentially
over equispaced nodes (standard approximation-theory fact).
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_points", "POINT_KINDS"]

POINT_KINDS = ("equispaced", "chebyshev", "unit_circle")


def make_points(kind: str, K: int, dtype=np.float64) -> np.ndarray:
    """Return K distinct evaluation points.

    kind:
      equispaced  - the paper's choice: K equally spaced in [-1, 1].
      chebyshev   - cos((2k+1) pi / (2K)): real, much better conditioned.
      unit_circle - exp(2 pi i k / K): complex, condition number 1 when K
                    points are used (DFT matrix); the paper's zero-error variant.
    """
    if K < 1:
        raise ValueError("K must be >= 1")
    if kind == "equispaced":
        if K == 1:
            pts = np.array([0.5])  # any nonzero point works; stay inside (-1,1)
        else:
            pts = np.linspace(-1.0, 1.0, K)
            # Avoid z=0 exactly when K is odd: 0 is a fine evaluation point for
            # positive-power polynomials, keep the paper's grid as-is.
        return pts.astype(dtype)
    if kind == "chebyshev":
        k = np.arange(K)
        pts = np.cos((2 * k + 1) * np.pi / (2 * K))
        return pts.astype(dtype)
    if kind == "unit_circle":
        k = np.arange(K)
        pts = np.exp(2j * np.pi * k / K)
        return pts.astype(np.complex128 if dtype == np.float64 else np.complex64)
    raise ValueError(f"unknown point kind {kind!r}; options: {POINT_KINDS}")
