"""Evaluation points z_1..z_K for the coded matmul polynomials.

The paper (Sec. V) uses K equally spaced reals in [-1, 1] and notes that
real Vandermonde systems are badly conditioned; complex points on the unit
circle give error that is "identically zero" at the cost of complex
arithmetic.  Beyond the paper we also provide Chebyshev nodes, which keep
real arithmetic but improve the Vandermonde condition number exponentially
over equispaced nodes (standard approximation-theory fact).

Vandermonde families also extend incrementally: appending evaluation
points leaves every existing point's polynomial evaluations (hence every
existing worker's encoded task) unchanged.  :func:`extend_points` grows a
point set by greedy Leja selection — each new point maximises the product
of distances to the points already placed — which keeps the extended
Vandermonde system well conditioned without moving the prefix.  This is
the foundation of the elastic grow path (``distributed/elastic``).
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_points", "extend_points", "POINT_KINDS"]

POINT_KINDS = ("equispaced", "chebyshev", "unit_circle")


def make_points(kind: str, K: int, dtype=np.float64) -> np.ndarray:
    """Return K distinct evaluation points.

    kind:
      equispaced  - the paper's choice: K equally spaced in [-1, 1].
      chebyshev   - cos((2k+1) pi / (2K)): real, much better conditioned.
      unit_circle - exp(2 pi i k / K): complex, condition number 1 when K
                    points are used (DFT matrix); the paper's zero-error variant.
    """
    if K < 1:
        raise ValueError("K must be >= 1")
    if kind == "equispaced":
        if K == 1:
            pts = np.array([0.5])  # any nonzero point works; stay inside (-1,1)
        else:
            pts = np.linspace(-1.0, 1.0, K)
            # Avoid z=0 exactly when K is odd: 0 is a fine evaluation point for
            # positive-power polynomials, keep the paper's grid as-is.
        return pts.astype(dtype)
    if kind == "chebyshev":
        k = np.arange(K)
        pts = np.cos((2 * k + 1) * np.pi / (2 * K))
        return pts.astype(dtype)
    if kind == "unit_circle":
        k = np.arange(K)
        pts = np.exp(2j * np.pi * k / K)
        return pts.astype(np.complex128 if dtype == np.float64 else np.complex64)
    raise ValueError(f"unknown point kind {kind!r}; options: {POINT_KINDS}")


def extend_points(z, g: int) -> np.ndarray:
    """Extend a point set by ``g`` fresh points; the prefix is untouched.

    Returns a ``(K + g,)`` array whose first K entries are bit-identical
    to ``z`` (same dtype), so every quantity derived per-point — encoding
    coefficients, worker task assignments, cached decode panels for
    old-pool erasure patterns — is unchanged by the extension.

    New points come from greedy Leja selection over a fixed deterministic
    candidate grid (a dense Chebyshev grid in [-1, 1] for real ``z``,
    dense unit-circle roots for complex ``z``): each pick maximises
    ``prod_i |c - z_i|`` over everything already placed, evaluated as a
    sum of logs.  Leja sequences keep the Vandermonde growth factor
    subexponential, so the extended system stays decodable in floating
    point; candidates within ``~100*eps`` of an existing point are
    excluded, so the result is always pairwise distinct.

    Raises:
        ValueError: on a non-1-D/empty ``z``, negative ``g``, or a
            candidate grid too coincident with ``z`` to supply ``g``
            distinct points (never happens for grids this dense unless
            ``z`` itself nearly fills the domain).
    """
    z = np.asarray(z)
    if z.ndim != 1 or z.size < 1:
        raise ValueError(f"need a 1-D non-empty point set, got shape {z.shape}")
    if g < 0:
        raise ValueError(f"g must be >= 0, got {g}")
    if g == 0:
        return z.copy()
    K = z.size
    is_complex = np.iscomplexobj(z)
    M = max(257, 8 * (K + g) + 1)
    if is_complex:
        cand = np.exp(2j * np.pi * np.arange(M) / M)
        current = z.astype(np.complex128)
    else:
        cand = np.cos((2 * np.arange(M) + 1) * np.pi / (2 * M))
        current = z.astype(np.float64)
    tol = 100 * np.finfo(np.float64).eps

    def _log_dist(d: np.ndarray) -> np.ndarray:
        # -inf marks near-coincident candidates out of the running.
        return np.where(d < tol, -np.inf, np.log(np.maximum(d, tol)))

    objective = _log_dist(np.abs(cand[:, None] - current[None, :])).sum(axis=1)
    chosen = []
    for _ in range(g):
        best = int(np.argmax(objective))
        if not np.isfinite(objective[best]):
            raise ValueError(
                f"candidate grid exhausted extending {K} points by {g}")
        chosen.append(cand[best])
        objective = objective + _log_dist(np.abs(cand - cand[best]))
    return np.concatenate([z, np.asarray(chosen).astype(z.dtype)])
