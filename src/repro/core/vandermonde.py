"""Vandermonde interpolation utilities for decoding coded matmuls.

Decoding recovers the coefficients X_0..X_{tau-1} of the worker-output
polynomial from evaluations at any tau distinct points.  Three paths:

* ``solve`` - direct linear solve of the tau x tau Vandermonde system
  (LU); simple, used for static survivor sets.
* ``newton`` - Newton divided-difference interpolation followed by basis
  conversion; O(tau^2), numerically kinder than LU on real Vandermonde
  systems and matches the classical treatment (Gautschi).
* ``masked`` - weighted normal equations over ALL K rows with a 0/1
  survivor mask; jit-friendly (shapes static in K) for the on-mesh runtime
  where the erasure pattern is data, not Python.

All paths accept complex points (unit-circle decoding).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = [
    "vandermonde",
    "inverse_vandermonde",
    "interpolate_solve",
    "interpolate_masked",
]


def vandermonde(z: np.ndarray, degree_plus_one: int) -> np.ndarray:
    """V[a, d] = z_a ** d, d = 0..degree_plus_one-1 (numpy, setup-time)."""
    z = np.asarray(z)
    d = np.arange(degree_plus_one)
    return z[:, None] ** d[None, :]


def inverse_vandermonde(z: np.ndarray) -> np.ndarray:
    """Explicit inverse of the square Vandermonde at points z via Lagrange
    basis polynomials: row j of V^{-1} holds the coefficients of the j-th
    Lagrange cardinal polynomial.  More accurate than LU for moderate tau.

    Returns W with  X = W @ Y,  W shape (tau, tau):  W[d, a] = coefficient of
    z^d in L_a(z).
    """
    z = np.asarray(z)
    tau = z.shape[0]
    W = np.zeros((tau, tau), dtype=np.result_type(z.dtype, np.float64))
    for a in range(tau):
        # L_a(x) = prod_{b != a} (x - z_b) / prod_{b != a} (z_a - z_b)
        others = np.delete(z, a)
        if others.size:
            coeffs_desc = np.poly(others)  # leading-first coeffs of prod (x - z_b)
            denom = np.prod(z[a] - others)
        else:
            coeffs_desc = np.array([1.0], dtype=W.dtype)
            denom = 1.0
        W[:, a] = coeffs_desc[::-1] / denom
    return W


def interpolate_solve(z: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
    """Solve V X = Y for X given square Vandermonde at points z.

    z: (tau,), Y: (tau, ...) -> X: (tau, ...).
    """
    tau = z.shape[0]
    V = jnp.asarray(z)[:, None] ** jnp.arange(tau)[None, :]
    Yf = Y.reshape(tau, -1)
    X = jnp.linalg.solve(V, Yf)
    return X.reshape(Y.shape)


def interpolate_masked(
    z_all: jnp.ndarray, Y_all: jnp.ndarray, mask: jnp.ndarray, tau: int,
    ridge: float = 0.0,
) -> jnp.ndarray:
    """Interpolate from a masked set of evaluations; jit-friendly.

    z_all: (K,) all evaluation points; Y_all: (K, ...) all worker outputs
    (garbage rows allowed where mask==0); mask: (K,) 0/1 survivors.
    Requires sum(mask) >= tau.  Solves the weighted normal equations
      (V^T D V) X = V^T D Y,  D = diag(mask),
    which has the exact interpolant as unique solution when >= tau rows
    survive.  ridge adds lambda*I for numerical safety (0 = exact).
    """
    K = z_all.shape[0]
    V = jnp.asarray(z_all)[:, None] ** jnp.arange(tau)[None, :]  # (K, tau)
    w = mask.astype(V.dtype)[:, None]
    Vw = V * w
    G = V.conj().T @ Vw  # (tau, tau)
    if ridge:
        G = G + ridge * jnp.eye(tau, dtype=G.dtype)
    Yf = Y_all.reshape(K, -1)
    rhs = Vw.conj().T @ Yf  # = V^T D Y (D idempotent)
    X = jnp.linalg.solve(G, rhs)
    return X.reshape((tau,) + Y_all.shape[1:])
