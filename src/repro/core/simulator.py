"""Async-cluster straggler simulator (reproduces the paper's Fig. 1 setup).

The paper runs 10 AWS workers; stragglers are simulated by making S randomly
chosen machines perform their local computation twice.  Completion latency of
a scheme with threshold tau is the tau-th smallest worker finish time plus
the decode time.  We reproduce this as a discrete-event model fed with real
measured per-worker compute times (the worker matmul run on this host) so the
comparison between schemes is apples-to-apples.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

__all__ = ["WorkerTimes", "simulate_completion", "measure_worker_time", "LatencyModel"]


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Per-worker finish-time model.

    base: seconds of useful compute per worker (measured or supplied).
    straggler_slowdown: multiplicative factor for stragglers (paper: 2.0 -
    the straggler computes twice).
    jitter: optional exponential jitter scale (fraction of base) applied to
    every worker - models cloud variance; 0 reproduces the paper's
    deterministic duplication model.
    """

    base: float
    straggler_slowdown: float = 2.0
    jitter: float = 0.0

    def sample(self, K: int, stragglers: Sequence[int], rng: np.random.Generator) -> np.ndarray:
        t = np.full(K, self.base, dtype=np.float64)
        t[list(stragglers)] *= self.straggler_slowdown
        if self.jitter > 0:
            t = t + rng.exponential(self.jitter * self.base, size=K)
        return t


@dataclasses.dataclass(frozen=True)
class WorkerTimes:
    finish: np.ndarray  # (K,) seconds

    def completion_for_threshold(self, tau: int) -> float:
        """Latency until ANY tau workers have finished."""
        return float(np.sort(self.finish)[tau - 1])

    def survivors_at_threshold(self, tau: int) -> np.ndarray:
        """Worker ids of the first tau finishers (the decode survivor set)."""
        return np.argsort(self.finish, kind="stable")[:tau]


def simulate_completion(
    K: int,
    tau: int,
    num_stragglers: int,
    model: LatencyModel,
    decode_time: float = 0.0,
    trials: int = 100,
    seed: int = 0,
) -> np.ndarray:
    """Return per-trial completion latencies (paper Fig. 1 protocol).

    Each trial picks ``num_stragglers`` distinct random workers as
    stragglers.  If fewer than tau workers can ever finish (impossible here -
    stragglers still finish, just late) the job still completes; the latency
    jump at num_stragglers > K - tau is the interesting regime.
    """
    rng = np.random.default_rng(seed)
    out = np.empty(trials)
    for t in range(trials):
        stragglers = rng.choice(K, size=num_stragglers, replace=False)
        wt = WorkerTimes(model.sample(K, stragglers, rng))
        out[t] = wt.completion_for_threshold(tau) + decode_time
    return out


def measure_worker_time(fn: Callable[[], object], repeats: int = 3) -> float:
    """Wall-time one worker's compute (median of ``repeats`` runs)."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        # Block on async JAX dispatch if applicable.
        if hasattr(result, "block_until_ready"):
            result.block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
