"""Async-cluster straggler simulator (reproduces the paper's Fig. 1 setup).

The paper runs 10 AWS workers; stragglers are simulated by making S randomly
chosen machines perform their local computation twice.  Completion latency of
a scheme with threshold tau is the tau-th smallest worker finish time plus
the decode time.  We reproduce this as a discrete-event model fed with real
measured per-worker compute times (the worker matmul run on this host) so the
comparison between schemes is apples-to-apples.

Two completion conventions coexist:

* **async master** (the paper's Fig. 1): the master decodes as soon as ANY
  tau workers finish — ``WorkerTimes.completion_for_threshold``.
* **synchronous step** (this repo's mesh runtime, DESIGN Sec. 3): a
  shard_map step waits for EVERY worker that is not declared erased; the
  0/1 mask is the only way to not wait for a straggler —
  ``WorkerTimes.completion_with_mask``.  The control plane
  (``repro.control``) exists to close that gap: an accurate mask makes the
  synchronous step complete at the tau-th order statistic.

``simulate_completion`` accepts an injectable per-worker time ``feed`` so
recorded traces (or a health monitor's fitted model) can replace the
parametric ``LatencyModel``; ``completion_cdf``/``completion_quantile``
summarise trial latencies for the control plane's expected-latency policy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

__all__ = [
    "WorkerTimes",
    "simulate_completion",
    "measure_worker_time",
    "LatencyModel",
    "TimeFeed",
    "completion_cdf",
    "completion_quantile",
]

#: Injectable per-worker finish-time source: (trial_index, rng) -> (K,) seconds.
TimeFeed = Callable[[int, np.random.Generator], np.ndarray]


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Per-worker finish-time model.

    base: seconds of useful compute — a scalar (homogeneous cluster) or a
    (K,)-vector of per-worker means (e.g. fitted by
    ``control.WorkerHealthMonitor`` from live EWMA latencies).
    straggler_slowdown: multiplicative factor for stragglers (paper: 2.0 -
    the straggler computes twice).
    jitter: optional exponential jitter scale (fraction of base) applied to
    every worker - models cloud variance; 0 reproduces the paper's
    deterministic duplication model.
    """

    base: Union[float, np.ndarray]
    straggler_slowdown: float = 2.0
    jitter: float = 0.0

    def base_vector(self, K: int) -> np.ndarray:
        """The (K,) per-worker mean compute times."""
        b = np.asarray(self.base, dtype=np.float64)
        if b.ndim == 0:
            return np.full(K, float(b), dtype=np.float64)
        if b.shape != (K,):
            raise ValueError(f"per-worker base has shape {b.shape}, need ({K},)")
        return b.copy()

    def sample(self, K: int, stragglers: Sequence[int], rng: np.random.Generator) -> np.ndarray:
        t = self.base_vector(K)
        t[list(stragglers)] *= self.straggler_slowdown
        if self.jitter > 0:
            t = t + rng.exponential(self.jitter * t)
        return t


@dataclasses.dataclass(frozen=True)
class WorkerTimes:
    finish: np.ndarray  # (K,) seconds

    def completion_for_threshold(self, tau: int) -> float:
        """Latency until ANY tau workers have finished (async master)."""
        return float(np.sort(self.finish)[tau - 1])

    def survivors_at_threshold(self, tau: int) -> np.ndarray:
        """Worker ids of the first tau finishers (the decode survivor set)."""
        return np.argsort(self.finish, kind="stable")[:tau]

    def completion_with_mask(self, mask) -> float:
        """Latency of one SYNCHRONOUS step under a 0/1 survivor mask.

        The step waits for every non-erased worker (this repo's shard_map
        runtime has no partial barrier); erased workers are never waited
        on.  With a mask that erases exactly the K - tau slowest workers
        this equals ``completion_for_threshold(tau)``.
        """
        keep = np.asarray(mask).astype(bool)
        if keep.shape != self.finish.shape:
            raise ValueError(f"mask shape {keep.shape} != {self.finish.shape}")
        if not keep.any():
            raise ValueError("mask erases every worker: nothing to wait for")
        return float(self.finish[keep].max())


def simulate_completion(
    K: int,
    tau: int,
    num_stragglers: int,
    model: Optional[LatencyModel],
    decode_time: float = 0.0,
    trials: int = 100,
    seed: int = 0,
    feed: Optional[TimeFeed] = None,
) -> np.ndarray:
    """Return per-trial completion latencies (paper Fig. 1 protocol).

    Each trial picks ``num_stragglers`` distinct random workers as
    stragglers.  If fewer than tau workers can ever finish (impossible here -
    stragglers still finish, just late) the job still completes; the latency
    jump at num_stragglers > K - tau is the interesting regime.

    ``feed`` overrides the parametric model with an injectable per-worker
    time source ``(trial, rng) -> (K,) seconds`` — recorded traces or a
    monitor-fitted model replay through the same protocol.
    """
    if model is None and feed is None:
        raise ValueError("need a LatencyModel or a time feed")
    rng = np.random.default_rng(seed)
    out = np.empty(trials)
    for t in range(trials):
        if feed is not None:
            finish = np.asarray(feed(t, rng), dtype=np.float64)
            if finish.shape != (K,):
                raise ValueError(f"feed returned shape {finish.shape}, need ({K},)")
        else:
            stragglers = rng.choice(K, size=num_stragglers, replace=False)
            finish = model.sample(K, stragglers, rng)
        out[t] = WorkerTimes(finish).completion_for_threshold(tau) + decode_time
    return out


def completion_cdf(latencies: np.ndarray, ts: np.ndarray) -> np.ndarray:
    """Empirical completion CDF: P[T <= t] for each t in ``ts``."""
    lat = np.sort(np.asarray(latencies, dtype=np.float64))
    return np.searchsorted(lat, np.asarray(ts, dtype=np.float64),
                           side="right") / max(lat.size, 1)


def completion_quantile(latencies: np.ndarray, q) -> np.ndarray:
    """Completion-latency quantile(s) (e.g. q=0.99 for a tail SLO)."""
    return np.quantile(np.asarray(latencies, dtype=np.float64), q)


def measure_worker_time(fn: Callable[[], object], repeats: int = 3) -> float:
    """Wall-time one worker's compute (median of ``repeats`` runs)."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        # Block on async JAX dispatch if applicable.
        if hasattr(result, "block_until_ready"):
            result.block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
