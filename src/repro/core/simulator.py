"""Async-cluster straggler simulator (reproduces the paper's Fig. 1 setup).

The paper runs 10 AWS workers; stragglers are simulated by making S randomly
chosen machines perform their local computation twice.  Completion latency of
a scheme with threshold tau is the tau-th smallest worker finish time plus
the decode time.  We reproduce this as a discrete-event model fed with real
measured per-worker compute times (the worker matmul run on this host) so the
comparison between schemes is apples-to-apples.

Two completion conventions coexist:

* **async master** (the paper's Fig. 1): the master decodes as soon as ANY
  tau workers finish — ``WorkerTimes.completion_for_threshold``.
* **synchronous step** (this repo's mesh runtime, DESIGN Sec. 3): a
  shard_map step waits for EVERY worker that is not declared erased; the
  0/1 mask is the only way to not wait for a straggler —
  ``WorkerTimes.completion_with_mask``.  The control plane
  (``repro.control``) exists to close that gap: an accurate mask makes the
  synchronous step complete at the tau-th order statistic.

``simulate_completion`` accepts an injectable per-worker time ``feed`` so
recorded traces (or a health monitor's fitted model) can replace the
parametric ``LatencyModel``; ``completion_cdf``/``completion_quantile``
summarise trial latencies, and ``masked_completion_quantile``/
``masked_completion_cdf`` give the per-rung step-completion distribution
under a fitted model in closed form — the tail statistics the control
plane's SLO-aware ``QuantileLatencyPolicy`` ranks rungs by.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

__all__ = [
    "WorkerTimes",
    "simulate_completion",
    "measure_worker_time",
    "LatencyModel",
    "TimeFeed",
    "completion_cdf",
    "completion_quantile",
    "masked_completion_cdf",
    "masked_completion_mean",
    "masked_completion_quantile",
]

#: Injectable per-worker finish-time source: (trial_index, rng) -> (K,) seconds.
TimeFeed = Callable[[int, np.random.Generator], np.ndarray]


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Per-worker finish-time model.

    Each worker's finish time is a shifted exponential

        T_i = base_i * slowdown_i + Exp(jitter_i * base_i * slowdown_i)

    (slowdown applies only to the trial's straggler set), the standard
    cloud straggler model the related polynomial-code analyses use.

    base: seconds of useful compute — a scalar (homogeneous cluster) or a
    (K,)-vector of per-worker means (e.g. fitted by
    ``control.WorkerHealthMonitor`` from live EWMA latencies).
    straggler_slowdown: multiplicative factor for stragglers (paper: 2.0 -
    the straggler computes twice).
    jitter: optional exponential jitter scale (fraction of base) applied to
    every worker - models cloud variance; 0 reproduces the paper's
    deterministic duplication model.  A (K,)-vector gives per-worker
    scales (heavy-tailed straggler mixes; the monitor's moment fit).
    """

    base: Union[float, np.ndarray]
    straggler_slowdown: float = 2.0
    jitter: Union[float, np.ndarray] = 0.0

    def base_vector(self, K: int) -> np.ndarray:
        """The (K,) per-worker mean compute times."""
        return self._vector(self.base, K, "base")

    def jitter_vector(self, K: int) -> np.ndarray:
        """The (K,) per-worker exponential jitter scales (fractions of base)."""
        return self._vector(self.jitter, K, "jitter")

    @property
    def has_jitter(self) -> bool:
        """True when any worker's finish time is stochastic."""
        return bool(np.any(np.asarray(self.jitter) > 0))

    @staticmethod
    def _vector(x, K: int, what: str) -> np.ndarray:
        v = np.asarray(x, dtype=np.float64)
        if v.ndim == 0:
            return np.full(K, float(v), dtype=np.float64)
        if v.shape != (K,):
            raise ValueError(f"per-worker {what} has shape {v.shape}, need ({K},)")
        return v.copy()

    def sample(self, K: int, stragglers: Sequence[int], rng: np.random.Generator,
               *, stable: bool = False) -> np.ndarray:
        """One trial's (K,) finish times with ``stragglers`` slowed down.

        ``stable=True`` draws the exponential jitter by inverse-CDF over
        ``rng.random()`` uniforms (always K of them, even for zero-scale
        workers).  NumPy guarantees the raw uniform bitstream of a seeded
        ``Generator`` across versions but NOT its distribution methods, so
        this is the path recorded golden traces (``repro.chaos``) rely on
        for bit-reproducibility.
        """
        t = self.base_vector(K)
        t[list(stragglers)] *= self.straggler_slowdown
        if stable:
            scale = self.jitter_vector(K) * t
            u = rng.random(K)
            return t + np.where(scale > 0, -scale * np.log1p(-u), 0.0)
        if self.has_jitter:
            t = t + rng.exponential(self.jitter_vector(K) * t)
        return t


@dataclasses.dataclass(frozen=True)
class WorkerTimes:
    finish: np.ndarray  # (K,) seconds

    def completion_for_threshold(self, tau: int) -> float:
        """Latency until ANY tau workers have finished (async master)."""
        return float(np.sort(self.finish)[tau - 1])

    def survivors_at_threshold(self, tau: int) -> np.ndarray:
        """Worker ids of the first tau finishers (the decode survivor set)."""
        return np.argsort(self.finish, kind="stable")[:tau]

    def completion_with_mask(self, mask) -> float:
        """Latency of one SYNCHRONOUS step under a 0/1 survivor mask.

        The step waits for every non-erased worker (this repo's shard_map
        runtime has no partial barrier); erased workers are never waited
        on.  With a mask that erases exactly the K - tau slowest workers
        this equals ``completion_for_threshold(tau)``.
        """
        keep = np.asarray(mask).astype(bool)
        if keep.shape != self.finish.shape:
            raise ValueError(f"mask shape {keep.shape} != {self.finish.shape}")
        if not keep.any():
            raise ValueError("mask erases every worker: nothing to wait for")
        return float(self.finish[keep].max())

    def completion_with_progress(self, progress) -> float:
        """Latency of one step that consumes FRACTIONS of workers' tasks.

        ``progress[k]`` in [0, 1] is the share of worker k's task the step
        waits for (the partial-straggler sub-task prefix,
        ``runtime/partial.py``); a worker's prefix lands at
        ``progress_k * finish_k`` under the proportional-work law, so the
        step completes at ``max over progress_k > 0``.  A 0/1 progress
        vector reproduces ``completion_with_mask`` exactly.
        """
        w = np.asarray(progress, dtype=np.float64)
        if w.shape != self.finish.shape:
            raise ValueError(f"progress shape {w.shape} != {self.finish.shape}")
        if np.any(w < 0) or np.any(w > 1):
            raise ValueError(f"progress must lie in [0, 1], got {w.tolist()}")
        kept = w > 0
        if not kept.any():
            raise ValueError("zero progress everywhere: nothing to wait for")
        return float((w[kept] * self.finish[kept]).max())


def simulate_completion(
    K: int,
    tau: int,
    num_stragglers: int,
    model: Optional[LatencyModel],
    decode_time: float = 0.0,
    trials: int = 100,
    seed: int = 0,
    feed: Optional[TimeFeed] = None,
) -> np.ndarray:
    """Return per-trial completion latencies (paper Fig. 1 protocol).

    Each trial picks ``num_stragglers`` distinct random workers as
    stragglers.  If fewer than tau workers can ever finish (impossible here -
    stragglers still finish, just late) the job still completes; the latency
    jump at num_stragglers > K - tau is the interesting regime.

    ``feed`` overrides the parametric model with an injectable per-worker
    time source ``(trial, rng) -> (K,) seconds`` — recorded traces or a
    monitor-fitted model replay through the same protocol.
    """
    if model is None and feed is None:
        raise ValueError("need a LatencyModel or a time feed")
    rng = np.random.default_rng(seed)
    out = np.empty(trials)
    for t in range(trials):
        if feed is not None:
            finish = np.asarray(feed(t, rng), dtype=np.float64)
            if finish.shape != (K,):
                raise ValueError(f"feed returned shape {finish.shape}, need ({K},)")
        else:
            stragglers = rng.choice(K, size=num_stragglers, replace=False)
            finish = model.sample(K, stragglers, rng)
        out[t] = WorkerTimes(finish).completion_for_threshold(tau) + decode_time
    return out


def completion_cdf(latencies: np.ndarray, ts: np.ndarray) -> np.ndarray:
    """Empirical completion CDF: P[T <= t] for each t in ``ts``."""
    lat = np.sort(np.asarray(latencies, dtype=np.float64))
    return np.searchsorted(lat, np.asarray(ts, dtype=np.float64),
                           side="right") / max(lat.size, 1)


def completion_quantile(latencies: np.ndarray, q) -> np.ndarray:
    """Completion-latency quantile(s) (e.g. q=0.99 for a tail SLO)."""
    return np.quantile(np.asarray(latencies, dtype=np.float64), q)


def _masked_shifted_exp(model: LatencyModel, mask) -> tuple:
    """(kept per-worker shifts, kept per-worker Exp scales) under a weight
    vector.

    ``mask`` generalises from 0/1 to fractional work shares in [0, 1]
    (partial-straggler sub-task prefixes): a worker waited on for share
    ``w`` contributes ``w * (base + Exp(scale)) = w*base + Exp(w*scale)``
    — the same shifted-exponential family with both parameters scaled — so
    every closed-form consumer (CDF / quantile / mean) generalises for
    free.  A 0/1 mask reproduces the binary law exactly.
    """
    w = np.asarray(mask, dtype=np.float64)
    K = w.shape[0] if w.ndim == 1 else 0
    if w.ndim != 1 or K == 0:
        raise ValueError(
            f"mask must be a (K,) weight vector, got shape {np.shape(mask)}")
    if np.any(w < 0) or np.any(w > 1):
        raise ValueError(f"weights must lie in [0, 1], got {w.tolist()}")
    kept = w > 0
    if not kept.any():
        raise ValueError("mask erases every worker: nothing to wait for")
    base = model.base_vector(K)
    scale = model.jitter_vector(K) * base
    return base[kept] * w[kept], scale[kept] * w[kept]


def _product_cdf(base: np.ndarray, scale: np.ndarray, ts) -> np.ndarray:
    """P[max_i (base_i + Exp(scale_i)) <= t] for each t (vectorised)."""
    t = np.asarray(ts, dtype=np.float64)
    tt = np.atleast_1d(t)[:, None]                       # (T, 1) vs (kept,)
    with np.errstate(divide="ignore", invalid="ignore"):
        expo = np.where(scale > 0, (tt - base) / np.where(scale > 0, scale, 1.0),
                        np.inf)
    F = np.where(tt >= base, 1.0 - np.exp(-np.where(tt >= base, expo, 0.0)), 0.0)
    # zero-scale workers: unit step at base
    F = np.where(scale > 0, F, (tt >= base).astype(np.float64))
    out = F.prod(axis=1)
    return out if t.ndim else float(out[0])


def _quantile_from_cdf(base: np.ndarray, scale: np.ndarray, q: float) -> float:
    """Invert the product CDF by bisection (base/scale precomputed)."""
    lo = float(base.max())
    if q == 0.0 or not np.any(scale > 0):
        return lo
    if q == 1.0:
        return float(np.inf)
    # upper bracket: union bound — at t with every per-worker tail mass
    # <= (1-q)/n the product CDF is >= q.
    n = base.size
    tail = (1.0 - q) / n
    with np.errstate(divide="ignore"):
        hi = float(np.max(base + scale * (-np.log(tail))))
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if _product_cdf(base, scale, mid) < q:
            lo = mid
        else:
            hi = mid
    return hi


def masked_completion_cdf(model: LatencyModel, mask, ts) -> np.ndarray:
    """Exact step-completion CDF under ``model`` with a 0/1 survivor mask.

    The synchronous step waits for every kept worker, whose finish times are
    independent shifted exponentials ``base_i + Exp(scale_i)``, so

        P[T <= t] = prod over kept i of F_i(t),
        F_i(t)    = 1 - exp(-(t - base_i) / scale_i)   for t >= base_i

    (a unit step at ``base_i`` when ``scale_i == 0``).  This is the
    tau-th-order-statistic law of the paper's latency model, specialised to
    the mask that erases the ``K - tau`` flagged stragglers.  ``mask`` may
    also carry fractional work shares in [0, 1] (partial-straggler
    prefixes): share ``w`` scales both the shift and the Exp scale by
    ``w``, staying inside the same product-of-shifted-exponentials law.
    """
    base, scale = _masked_shifted_exp(model, mask)
    return _product_cdf(base, scale, ts)


def masked_completion_quantile(model: LatencyModel, mask, q: float) -> float:
    """Closed-form q-quantile of masked step completion under ``model``.

    Inverts ``masked_completion_cdf`` by bisection (the CDF is a product of
    shifted-exponential factors — monotone, no closed inverse for
    heterogeneous workers).  Edge cases: ``q == 0`` returns the essential
    minimum ``max(kept base)``; ``q == 1`` returns ``inf`` whenever any kept
    worker has jitter (the shifted exponential is unbounded), else
    ``max(kept base)``.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile q={q} outside [0, 1]")
    base, scale = _masked_shifted_exp(model, mask)
    return _quantile_from_cdf(base, scale, q)


def masked_completion_mean(model: LatencyModel, mask) -> float:
    """Closed-form mean of masked step completion under ``model``.

    ``E[max] = lo + integral over (lo, hi) of (1 - F(t)) dt`` with ``lo``
    the essential minimum and ``hi`` the 1-1e-6 quantile (the truncated
    exponential tail beyond it contributes O(scale * 1e-6)); the integral
    is a trapezoid over the vectorised product CDF.
    """
    base, scale = _masked_shifted_exp(model, mask)
    lo = float(base.max())
    if not np.any(scale > 0):
        return lo
    hi = _quantile_from_cdf(base, scale, 1.0 - 1e-6)
    ts = np.linspace(lo, hi, 513)
    survival = 1.0 - _product_cdf(base, scale, ts)
    # np.trapz was renamed np.trapezoid in numpy 2.0; support both
    trapezoid = getattr(np, "trapezoid", None) or np.trapz
    return lo + float(trapezoid(survival, ts))


def measure_worker_time(fn: Callable[[], object], repeats: int = 3) -> float:
    """Wall-time one worker's compute (median of ``repeats`` runs)."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        # Block on async JAX dispatch if applicable.
        if hasattr(result, "block_until_ready"):
            result.block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
