"""Block decomposition of matrices for coded distributed matmul.

The paper partitions A (v x r) into a p x m grid and B (v x t) into a p x n
grid of equal-size blocks.  Workers store one (coded) block of each.  On TPU
we additionally pad block dims up to MXU-friendly multiples when requested.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

__all__ = [
    "GridSpec",
    "pad_to_multiple",
    "block_decompose",
    "block_recompose",
]


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Grid geometry for one coded matmul C = A^T B.

    A is split p x m (rows: contraction dim v, cols: output rows r).
    B is split p x n (rows: contraction dim v, cols: output cols t).
    C = A^T B is m x n blocks of (r/m x t/n).
    """

    p: int
    m: int
    n: int

    def __post_init__(self):
        if self.p < 1 or self.m < 1 or self.n < 1:
            raise ValueError(f"invalid grid {self}")

    @property
    def num_a_blocks(self) -> int:
        return self.p * self.m

    @property
    def num_b_blocks(self) -> int:
        return self.p * self.n

    @property
    def num_c_blocks(self) -> int:
        return self.m * self.n


def pad_to_multiple(x: jnp.ndarray, multiples: Tuple[int, int]) -> jnp.ndarray:
    """Zero-pad a 2-D array so each dim is a multiple of ``multiples``."""
    v, r = x.shape
    mv, mr = multiples
    pv = (-v) % mv
    pr = (-r) % mr
    if pv == 0 and pr == 0:
        return x
    return jnp.pad(x, ((0, pv), (0, pr)))


def block_decompose(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """(v, r) -> (rows, cols, v/rows, r/cols).  Pads with zeros if needed.

    Zero padding is exact for the coding schemes: zero blocks contribute zero
    useful and zero interference terms.
    """
    x = pad_to_multiple(x, (rows, cols))
    v, r = x.shape
    bv, br = v // rows, r // cols
    return x.reshape(rows, bv, cols, br).transpose(0, 2, 1, 3)


def block_recompose(blocks: jnp.ndarray) -> jnp.ndarray:
    """(rows, cols, bv, br) -> (rows*bv, cols*br)."""
    rows, cols, bv, br = blocks.shape
    return blocks.transpose(0, 2, 1, 3).reshape(rows * bv, cols * br)


def unpad(x: jnp.ndarray, shape: Tuple[int, int]) -> jnp.ndarray:
    """Crop a padded 2-D result back to ``shape``."""
    return x[: shape[0], : shape[1]]
