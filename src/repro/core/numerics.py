"""Precision helpers: x64 scoping and exact-integer dtype policy.

The paper's decode requires float64 on the master (Table I uses s up to
2^36, far beyond float32's 24-bit mantissa).  JAX disables x64 by default;
we scope it explicitly so the LM substrate stays f32/bf16 while the coded
matmul reference path runs in f64.
"""
from __future__ import annotations

import contextlib

import jax

__all__ = ["enable_x64", "x64_enabled"]


@contextlib.contextmanager
def enable_x64(enable: bool = True):
    """Context manager scoping jax_enable_x64 (uses the public config API)."""
    prev = jax.config.read("jax_enable_x64")
    try:
        jax.config.update("jax_enable_x64", enable)
        yield
    finally:
        jax.config.update("jax_enable_x64", prev)


def x64_enabled() -> bool:
    return bool(jax.config.read("jax_enable_x64"))
