"""The asynchronous multi-tenant serving loop over ``AdaptiveServer``.

``ServeTier`` composes the subsystem: per-tenant admission control
(``serve.admission``), continuous batching into prewarmed buckets
(``serve.batcher``), one ``AdaptiveServer`` PER SLO CLASS — each with its
own quantile, rung floor, and ``ViolationFeedback`` state, all sharing
ONE ``WorkerHealthMonitor`` and ONE ``PlanLadder`` — and a two-stage
pipeline that overlaps decode of step *t* with the worker stage of step
*t+1* through the facade's split entry points.

Everything advances on a SEEDED SIMULATED CLOCK: arrivals are inverse-CDF
Poisson streams keyed ``(seed, tenant index)``, worker times come from a
chaos ``TimeFeed`` consumed one step per DISPATCH (a shared counter, so
per-class servers interleave on one scenario stream), and stage latencies
are the control plane's own modelled costs (masked completion for the
worker stage, the rung's priced overhead for decode).  Real jax calls
still execute every batch — results are bit-identical to synchronous
facade answers — but TIME is simulated, so a run is a pure function of
(spec, scenario, seed) and replays bit-exactly (``serve.trace``).

Pipeline timing model (per dispatched batch)::

    compute_start = max(now, worker pool free)
    compute_done  = compute_start + masked completion      (worker stage)
    decode_start  = max(compute_done, decoder free)
    decode_done   = decode_start + rung overhead           (decode stage)

With ``pipelined=True`` the loop resumes at ``compute_done`` — the next
batch's worker stage overlaps the decoder — and a request completes at
``decode_done``.  ``pipelined=False`` serialises the stages (the
synchronous baseline ``serve_bench`` compares against).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.chaos.serialize import report_to_dict
from repro.control.driver import AdaptiveServer
from repro.control.ladder import PlanLadder
from repro.control.monitor import WorkerHealthMonitor
from repro.core.simulator import TimeFeed
from repro.serve.admission import AdmissionController, Request
from repro.serve.batcher import Batch, ContinuousBatcher
from repro.serve.tenants import RungFloorPolicy, SLOClass, TenantSpec

__all__ = ["StageTiming", "TwoStagePipeline", "RequestRecord",
           "BatchRecord", "ServeResult", "ServeTier"]


@dataclasses.dataclass(frozen=True)
class StageTiming:
    """Simulated timestamps of one batch's trip through the two stages."""

    compute_start_s: float
    compute_done_s: float
    decode_start_s: float
    decode_done_s: float


class TwoStagePipeline:
    """Simulated-clock bookkeeping for the worker/decoder stage pair.

    The worker pool and the decoder are the two exclusive resources; each
    batch occupies the workers for its masked completion, then the
    decoder for its rung overhead.  ``pipelined=False`` makes each batch
    hold BOTH resources to completion (back-to-back synchronous serving).
    """

    def __init__(self, pipelined: bool = True):
        self.pipelined = pipelined
        self.worker_free_s = 0.0
        self.decoder_free_s = 0.0

    def schedule(self, now_s: float, worker_s: float,
                 decode_s: float) -> StageTiming:
        """Book one batch through both stages starting no earlier than now."""
        start = max(now_s, self.worker_free_s)
        if not self.pipelined:
            start = max(start, self.decoder_free_s)
        compute_done = start + worker_s
        decode_start = max(compute_done, self.decoder_free_s)
        decode_done = decode_start + decode_s
        self.worker_free_s = compute_done
        self.decoder_free_s = decode_done
        return StageTiming(start, compute_done, decode_start, decode_done)

    @property
    def next_free_s(self) -> float:
        """When the loop may dispatch again (workers free; or fully drained
        when not pipelining)."""
        return self.worker_free_s if self.pipelined else self.decoder_free_s


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """Everything that happened to one request (admitted OR shed)."""

    rid: int
    tenant: str
    slo_class: str
    arrival_s: float
    admitted: bool
    slo_s: float
    reject_reason: Optional[str] = None   # "rate_limited" | "queue_full"
    batch_index: Optional[int] = None
    rung: Optional[str] = None
    dispatch_s: Optional[float] = None    # worker stage start
    completion_s: Optional[float] = None  # decode done
    queue_delay_s: Optional[float] = None
    latency_s: Optional[float] = None     # end-to-end (queueing included)
    violated: Optional[bool] = None       # latency_s > slo_s
    span_id: Optional[str] = None         # span_id_for(seed, "request", rid)


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One dispatched batch: composition, stage timings, control report."""

    index: int
    slo_class: str
    rung: str
    size: int
    bucket: int                     # prewarmed bucket the batch padded to
    request_ids: Tuple[int, ...]
    dispatch_s: float
    worker_s: float                 # modelled worker-stage latency
    decode_s: float                 # rung's priced decode overhead
    compute_start_s: float
    compute_done_s: float
    decode_start_s: float
    decode_done_s: float
    report: dict                    # shared StepReport serialisation
    span_id: Optional[str] = None   # span_id_for(seed, "batch", index)


@dataclasses.dataclass
class ServeResult:
    """A finished tier run: per-request + per-batch records and summaries."""

    requests: Tuple[RequestRecord, ...]
    batches: Tuple[BatchRecord, ...]
    meta: dict
    #: rid -> decoded (r, t) product, when the tier kept results.
    results: Optional[Dict[int, np.ndarray]] = None

    @property
    def admitted(self) -> Tuple[RequestRecord, ...]:
        """Records of requests that made it past admission."""
        return tuple(r for r in self.requests if r.admitted)

    @property
    def shed(self) -> Tuple[RequestRecord, ...]:
        """Records of shed requests (each carries its rejection reason)."""
        return tuple(r for r in self.requests if not r.admitted)

    @property
    def completed(self) -> Tuple[RequestRecord, ...]:
        """Admitted records that finished decoding."""
        return tuple(r for r in self.requests
                     if r.admitted and r.completion_s is not None)

    def throughput_rps(self) -> float:
        """Sustained completions/s: completed over first-arrival->last-done."""
        done = self.completed
        if not done:
            return 0.0
        span = (max(r.completion_s for r in done)
                - min(r.arrival_s for r in self.requests))
        return len(done) / span if span > 0 else float("inf")

    def tenant_stats(self) -> Dict[str, dict]:
        """Per-tenant counts, latency quantiles, and SLO verdicts.

        ``p_slo_s`` is the realized latency at the tenant's OWN class
        quantile; ``slo_met`` judges it against the class bound.
        """
        quantiles: Dict[str, float] = self.meta.get("class_quantiles", {})
        out: Dict[str, dict] = {}
        for rec in self.requests:
            st = out.setdefault(rec.tenant, {
                "slo_class": rec.slo_class, "slo_s": rec.slo_s,
                "generated": 0, "admitted": 0, "completed": 0,
                "shed": 0, "shed_reasons": {}, "_lat": []})
            st["generated"] += 1
            if not rec.admitted:
                st["shed"] += 1
                st["shed_reasons"][rec.reject_reason] = (
                    st["shed_reasons"].get(rec.reject_reason, 0) + 1)
                continue
            st["admitted"] += 1
            if rec.completion_s is not None:
                st["completed"] += 1
                st["_lat"].append(rec.latency_s)
        for name, st in out.items():
            lat = np.asarray(st.pop("_lat"), dtype=np.float64)
            q = quantiles.get(st["slo_class"], 0.99)
            if lat.size:
                st["p50_s"] = float(np.percentile(lat, 50.0))
                st["p99_s"] = float(np.percentile(lat, 99.0))
                st["p_slo_s"] = float(np.percentile(lat, q * 100.0))
                st["max_s"] = float(lat.max())
                st["violations"] = int(np.sum(lat > st["slo_s"]))
                st["slo_met"] = bool(st["p_slo_s"] <= st["slo_s"])
            else:
                st.update(p50_s=None, p99_s=None, p_slo_s=None, max_s=None,
                          violations=0, slo_met=None)
        return out


class ServeTier:
    """Queue -> continuous batcher -> per-class servers -> staged pipeline.

    Args:
        ladder: prewarmed ``PlanLadder`` (with ``batch_sizes`` buckets for
            batching and ideally ``stages=True`` for recompile-free
            pipelining); shared by every SLO class.
        classes: the SLO classes to serve (each gets its own
            ``AdaptiveServer`` with its own quantile/floor/feedback).
        tenants: tenant specs; every tenant must reference a known class.
        feed: chaos ``TimeFeed`` over the ladder's K workers, consumed one
            step per DISPATCH across all classes (None = all workers take
            1.0s every step).
        overhead_s: deterministic per-rung decode costs used for policy
            pricing AND the simulated decode-stage latency (prewarm's
            measured overheads carry wall-clock noise, so reproducible
            runs pass constants).
        seed: workload seed (arrival streams key off it).
        score_threshold / sub_tasks / check_exact: forwarded to each
            class's ``AdaptiveServer``.
        pipelined: overlap decode of step t with the worker stage of step
            t+1 (False = synchronous back-to-back baseline).
        max_batch: batch-size ceiling; defaults to the largest prewarmed
            bucket (1 when none — pure per-request serving).
        split_stages: serve through the facade's split worker/decode
            entry points (defaults to True exactly when ``sub_tasks == 1``;
            partial decode has no split path and uses one-shot calls with
            identical timing accounting).
        keep_results: retain every decoded per-request product on the
            result (the bench's bit-identity check reads them).

    Raises:
        ValueError: on unknown tenant classes, an empty class/tenant set,
            or ``split_stages=True`` with ``sub_tasks > 1``.
    """

    def __init__(self, ladder: PlanLadder, *,
                 classes: Sequence[SLOClass],
                 tenants: Sequence[TenantSpec],
                 feed: Optional[TimeFeed] = None,
                 overhead_s: Optional[dict] = None,
                 seed: int = 0,
                 score_threshold: float = 0.5,
                 sub_tasks: int = 1,
                 check_exact: bool = False,
                 pipelined: bool = True,
                 max_batch: Optional[int] = None,
                 split_stages: Optional[bool] = None,
                 keep_results: bool = False):
        if not classes:
            raise ValueError("need at least one SLO class")
        if not tenants:
            raise ValueError("need at least one tenant")
        self.ladder = ladder
        self.classes: Dict[str, SLOClass] = {c.name: c for c in classes}
        self.tenants: Dict[str, TenantSpec] = {t.name: t for t in tenants}
        for t in self.tenants.values():
            if t.slo_class not in self.classes:
                raise ValueError(
                    f"tenant {t.name!r} references unknown SLO class "
                    f"{t.slo_class!r}; have {sorted(self.classes)}")
        if split_stages is None:
            split_stages = sub_tasks == 1
        if split_stages and sub_tasks > 1:
            raise ValueError("split_stages requires sub_tasks == 1 (partial "
                             "decode panels are per chunk; no split path)")
        self.split_stages = split_stages
        self.pipelined = pipelined
        self.seed = int(seed)
        self.keep_results = keep_results
        self.overhead_s = overhead_s
        buckets = ladder.batch_buckets
        self.max_batch = int(max_batch if max_batch is not None
                             else (max(buckets) if buckets else 1))

        self._base_feed = feed or (
            lambda step, rng: np.full(ladder.K, 1.0, dtype=np.float64))
        self.dispatches = 0
        self.admission = AdmissionController(self.tenants)
        self.batcher = ContinuousBatcher(
            {name: t.slo_class for name, t in self.tenants.items()},
            self.max_batch)
        self.monitor = WorkerHealthMonitor(ladder.K)
        self.servers: Dict[str, AdaptiveServer] = {}
        for cls in classes:
            policy = RungFloorPolicy(
                ladder, q=cls.quantile, floor=cls.rung_floor,
                overhead_s=overhead_s, score_threshold=score_threshold,
                sub_tasks=sub_tasks)
            self.servers[cls.name] = AdaptiveServer(
                ladder, monitor=self.monitor, policy=policy,
                feed=self._shared_feed, score_threshold=score_threshold,
                seed=seed, check_exact=check_exact,
                slo_quantile=cls.quantile, slo_s=cls.slo_s,
                feedback=cls.feedback, sub_tasks=sub_tasks)
            # per-class obs scope: every class server shares the tier
            # seed, so step span IDs need the class name to stay unique.
            self.servers[cls.name].obs_scope = f"step.{cls.name}"

    # -- the shared scenario stream -----------------------------------------
    def _shared_feed(self, step: int, rng) -> np.ndarray:
        # per-class servers each count their OWN steps; the scenario
        # stream is indexed by the GLOBAL dispatch counter so the classes
        # interleave deterministically on one (seed, step)-keyed feed.
        t = np.asarray(self._base_feed(self.dispatches, rng),
                       dtype=np.float64)
        self.dispatches += 1
        return t

    # -- workload ------------------------------------------------------------
    def _arrivals(self, requests_per_tenant) -> List[Request]:
        """Seeded Poisson arrival streams, merged and id-stamped.

        Gaps are inverse-CDF exponentials over the uniform bitstream
        (the only sampling numpy keeps stable across versions), keyed
        ``(seed, tenant index)`` in sorted-tenant order.
        """
        if not isinstance(requests_per_tenant, dict):
            requests_per_tenant = {
                name: int(requests_per_tenant) for name in self.tenants}
        rows = []
        for idx, name in enumerate(sorted(self.tenants)):
            spec = self.tenants[name]
            cls = self.classes[spec.slo_class]
            rng = np.random.default_rng(
                np.random.SeedSequence((self.seed, idx)))
            t = 0.0
            for k in range(int(requests_per_tenant.get(name, 0))):
                t += float(-np.log1p(-rng.random()) / spec.arrival_rps)
                rows.append((t, idx, k, name, cls))
        rows.sort(key=lambda r: (r[0], r[1], r[2]))
        return [Request(rid=i, tenant=name, slo_class=cls.name,
                        arrival_s=float(t), deadline_s=float(t + cls.slo_s))
                for i, (t, idx, k, name, cls) in enumerate(rows)]

    # -- the event loop ------------------------------------------------------
    def run(self, make_A: Callable[[Request], np.ndarray], B,
            requests_per_tenant) -> ServeResult:
        """Generate, admit, batch, and serve the whole workload to drain.

        Args:
            make_A: per-request left operand builder ``Request -> (v, r)``
                (deterministic builders give reproducible results).
            B: the shared (v, t) right operand.
            requests_per_tenant: int (same for every tenant) or
                ``{tenant: n}`` workload sizes.

        Returns:
            A :class:`ServeResult` with every request accounted for —
            completed, or shed with an explicit reason.

        Raises:
            RuntimeError: on a second call — monitor/feedback/queue state
                is consumed by a run; build a fresh tier to rerun.
        """
        if getattr(self, "_ran", False):
            raise RuntimeError("a ServeTier serves one workload; build a "
                               "fresh tier to run again")
        self._ran = True
        B = jnp.asarray(B)
        self._pipe = TwoStagePipeline(self.pipelined)
        arrivals = self._arrivals(requests_per_tenant)
        records: Dict[int, RequestRecord] = {}
        batches: List[BatchRecord] = []
        results: Dict[int, np.ndarray] = {}
        # run the whole loop on a simulated-seconds obs clock: every span
        # recorded during the run (control decisions included) stamps the
        # loop's own deterministic `now`, so replays produce byte-identical
        # span streams.  No-op while obs is disabled.
        self._obs_clock = obs.SettableClock(0.0)
        obs.use_clock(self._obs_clock)
        i = 0
        now = 0.0
        while True:
            while i < len(arrivals) and arrivals[i].arrival_s <= now + 1e-9:
                req = arrivals[i]
                i += 1
                reason = self.admission.offer(req, req.arrival_s)
                if reason is None:
                    obs.count("serve.admit", tenant=req.tenant,
                              slo_class=req.slo_class)
                else:
                    obs.count("serve.shed", reason=reason,
                              tenant=req.tenant, slo_class=req.slo_class)
                records[req.rid] = RequestRecord(
                    rid=req.rid, tenant=req.tenant, slo_class=req.slo_class,
                    arrival_s=req.arrival_s, admitted=reason is None,
                    slo_s=self.classes[req.slo_class].slo_s,
                    reject_reason=reason,
                    span_id=obs.span_id_for(self.seed, "request", req.rid))
            batch = self.batcher.form(self.admission.queues)
            if batch is None:
                if i < len(arrivals):
                    now = max(now, arrivals[i].arrival_s)
                    continue
                break
            self._obs_clock.set(now)
            self._dispatch(batch, now, make_A, B, records, batches, results)
            now = max(now, self._pipe.next_free_s)
        meta = {
            "seed": self.seed, "pipelined": self.pipelined,
            "split_stages": self.split_stages, "max_batch": self.max_batch,
            "dispatches": self.dispatches,
            "class_quantiles": {c.name: c.quantile
                                for c in self.classes.values()},
        }
        ordered = tuple(records[rid] for rid in sorted(records))
        return ServeResult(requests=ordered, batches=tuple(batches),
                           meta=meta,
                           results=results if self.keep_results else None)

    def _dispatch(self, batch: Batch, now: float, make_A, B,
                  records: Dict[int, RequestRecord],
                  batches: List[BatchRecord],
                  results: Dict[int, np.ndarray]) -> None:
        """Serve one batch: control decision, staged execution, bookkeeping."""
        server = self.servers[batch.slo_class]
        A = jnp.stack([jnp.asarray(make_A(r)) for r in batch.requests])
        decision = server.begin_step()
        t0 = time.perf_counter()
        if self.split_stages and decision.progress is None:
            Y, ctx = self.ladder.worker_stage(A, B)
            C = self.ladder.decode_stage(Y, ctx, mask=decision.mask)
        else:
            C = server.execute(decision, A, B)
        jax.block_until_ready(C)
        wall_ms = (time.perf_counter() - t0) * 1e3
        report = server.complete_step(decision, C, wall_ms, A, B)

        worker_s = float(report.sim_latency_s)
        decode_s = float(server.slo_policy.overhead_for(report.rung))
        timing = self._pipe.schedule(now, worker_s, decode_s)
        bucket = self.ladder.bucket_for(batch.size) or batch.size
        index = len(batches)
        span_id = obs.span_id_for(self.seed, "batch", index)
        # pre-timed simulated spans: one Perfetto track per SLO class,
        # with worker/decode lanes — overlapping slices on the two lanes
        # ARE the pipeline overlap (decode of batch t under workers of
        # batch t+1).
        obs.emit_span("serve.dispatch", now, timing.decode_done_s,
                      track=batch.slo_class, lane="dispatch",
                      batch=index, rung=report.rung, span_id=span_id)
        obs.emit_span("serve.worker_stage", timing.compute_start_s,
                      timing.compute_done_s, track=batch.slo_class,
                      lane="workers", batch=index, rung=report.rung,
                      span_id=span_id)
        obs.emit_span("serve.decode_stage", timing.decode_start_s,
                      timing.decode_done_s, track=batch.slo_class,
                      lane="decode", batch=index, rung=report.rung,
                      span_id=span_id)
        obs.observe("serve.stage.worker_s", worker_s, rung=report.rung)
        obs.observe("serve.stage.decode_s", decode_s, rung=report.rung)
        obs.count("serve.batch", slo_class=batch.slo_class)
        batches.append(BatchRecord(
            index=index, slo_class=batch.slo_class, rung=report.rung,
            size=batch.size, bucket=bucket,
            request_ids=tuple(r.rid for r in batch.requests),
            dispatch_s=now, worker_s=worker_s, decode_s=decode_s,
            compute_start_s=timing.compute_start_s,
            compute_done_s=timing.compute_done_s,
            decode_start_s=timing.decode_start_s,
            decode_done_s=timing.decode_done_s,
            report=report_to_dict(report),
            span_id=span_id))
        C_np = np.asarray(C)
        for j, req in enumerate(batch.requests):
            latency = timing.decode_done_s - req.arrival_s
            obs.observe("serve.latency_s", latency,
                        slo_class=batch.slo_class)
            obs.observe("serve.queue_delay_s",
                        timing.compute_start_s - req.arrival_s,
                        slo_class=batch.slo_class)
            records[req.rid] = dataclasses.replace(
                records[req.rid],
                batch_index=index, rung=report.rung,
                dispatch_s=timing.compute_start_s,
                completion_s=timing.decode_done_s,
                queue_delay_s=timing.compute_start_s - req.arrival_s,
                latency_s=latency,
                violated=latency > records[req.rid].slo_s)
            if self.keep_results:
                results[req.rid] = C_np[j]
