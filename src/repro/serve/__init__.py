"""Async multi-tenant serving tier over the coded-matmul control plane.

The tier layers four pieces on top of :class:`repro.control.PlanLadder`
and :class:`repro.control.AdaptiveServer`, all driven by one seeded
SIMULATED clock so chaos ``TimeFeed`` scenarios and golden traces keep
working unchanged:

1. **Admission** (:mod:`repro.serve.admission`) — per-tenant token
   buckets and bounded queues; overload sheds with explicit reasons.
2. **Continuous batching** (:mod:`repro.serve.batcher`) — every step,
   waiting same-class requests coalesce up to the largest prewarmed
   batch bucket; the ladder's pad-and-slice lands each dispatch on an
   existing executable (zero recompiles).
3. **SLO classes** (:mod:`repro.serve.tenants`) — each class gets its
   own ``AdaptiveServer`` (own quantile, own ``ViolationFeedback``)
   over a SHARED worker-health monitor and ladder, with an optional
   :class:`RungFloorPolicy` erasure-budget floor; dispatch among
   classes is earliest-deadline-first.
4. **Two-stage pipeline** (:mod:`repro.serve.loop`) — decode of step t
   overlaps encode+products of step t+1 on the simulated timeline,
   using the split ``worker_stage``/``decode_stage`` entry points.

:class:`ServeTier` is the event loop tying these together;
:class:`repro.serve.trace.ServeTrace` persists a run as JSONL and backs
the golden serve trace replayed in CI.
"""
from repro.serve.admission import (
    REJECT_QUEUE_FULL,
    REJECT_RATE_LIMITED,
    AdmissionController,
    Request,
    TokenBucket,
)
from repro.serve.batcher import Batch, ContinuousBatcher
from repro.serve.loop import (
    BatchRecord,
    RequestRecord,
    ServeResult,
    ServeTier,
    StageTiming,
    TwoStagePipeline,
)
from repro.serve.tenants import (
    DEFAULT_SPEC,
    RungFloorPolicy,
    SLOClass,
    TenantSpec,
    parse_tenant_spec,
)
from repro.serve.trace import (
    GOLDEN_SERVE_OVERHEAD_S,
    GOLDEN_SERVE_REQUESTS,
    GOLDEN_SERVE_SCENARIO,
    GOLDEN_SERVE_SEED,
    ServeTrace,
    golden_serve_result,
    golden_serve_trace,
)

__all__ = [
    "REJECT_QUEUE_FULL",
    "REJECT_RATE_LIMITED",
    "AdmissionController",
    "Request",
    "TokenBucket",
    "Batch",
    "ContinuousBatcher",
    "BatchRecord",
    "RequestRecord",
    "ServeResult",
    "ServeTier",
    "StageTiming",
    "TwoStagePipeline",
    "DEFAULT_SPEC",
    "RungFloorPolicy",
    "SLOClass",
    "TenantSpec",
    "parse_tenant_spec",
    "GOLDEN_SERVE_OVERHEAD_S",
    "GOLDEN_SERVE_REQUESTS",
    "GOLDEN_SERVE_SCENARIO",
    "GOLDEN_SERVE_SEED",
    "ServeTrace",
    "golden_serve_result",
    "golden_serve_trace",
]
