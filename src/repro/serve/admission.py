"""Per-tenant admission control: token buckets + bounded queues.

Every arrival is either ADMITTED into its tenant's FIFO queue or SHED
with an explicit machine-readable reason — never silently dropped:

    ``"rate_limited"``   the tenant's token bucket was empty
    ``"queue_full"``     the tenant's bounded queue was at depth

Everything runs on the serve tier's SIMULATED clock (buckets refill from
elapsed simulated seconds), so admission decisions are a pure function of
the arrival stream and the drain schedule — deterministic under a seeded
run, which is what lets the golden serve trace replay bit-exactly.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, Optional

from repro.serve.tenants import TenantSpec

__all__ = ["Request", "TokenBucket", "AdmissionController",
           "REJECT_RATE_LIMITED", "REJECT_QUEUE_FULL"]

REJECT_RATE_LIMITED = "rate_limited"
REJECT_QUEUE_FULL = "queue_full"


@dataclasses.dataclass(frozen=True)
class Request:
    """One tenant request in the simulated workload.

    ``deadline_s`` = arrival + the tenant's class bound; the batcher's
    earliest-deadline-first ordering keys on it.
    """

    rid: int
    tenant: str
    slo_class: str
    arrival_s: float
    deadline_s: float


class TokenBucket:
    """Deterministic token bucket refilled on the simulated clock.

    Starts full (``burst`` tokens).  ``rate_rps=inf`` admits everything.
    ``take`` must be called with non-decreasing timestamps (the serve
    loop processes arrivals in arrival order per tenant).
    """

    def __init__(self, rate_rps: float, burst: int):
        self.rate = float(rate_rps)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._last_s = 0.0

    @property
    def tokens(self) -> float:
        """Tokens available at the last ``take`` timestamp."""
        return self._tokens

    def take(self, now_s: float) -> bool:
        """Refill to ``now_s`` and consume one token if one is available."""
        if math.isinf(self.rate):
            return True
        elapsed = max(0.0, now_s - self._last_s)
        self._tokens = min(self.burst, self._tokens + elapsed * self.rate)
        self._last_s = max(self._last_s, now_s)
        if self._tokens >= 1.0 - 1e-12:
            self._tokens -= 1.0
            return True
        return False


class AdmissionController:
    """Token bucket + bounded FIFO queue per tenant.

    The queues are exposed (``queues``) because the continuous batcher
    drains them directly; the controller only decides who gets IN.
    """

    def __init__(self, tenants: Dict[str, TenantSpec]):
        self.tenants = dict(tenants)
        self.buckets: Dict[str, TokenBucket] = {
            name: TokenBucket(t.rate_rps, t.burst)
            for name, t in self.tenants.items()}
        self.queues: Dict[str, Deque[Request]] = {
            name: deque() for name in self.tenants}

    def offer(self, request: Request, now_s: float) -> Optional[str]:
        """Admit ``request`` into its tenant queue, or return a shed reason.

        Returns:
            ``None`` on admission (the request is now queued), else one of
            :data:`REJECT_RATE_LIMITED` / :data:`REJECT_QUEUE_FULL`.

        Raises:
            KeyError: for a tenant the controller was not built with.
        """
        spec = self.tenants[request.tenant]
        if not self.buckets[request.tenant].take(now_s):
            return REJECT_RATE_LIMITED
        queue = self.queues[request.tenant]
        if len(queue) >= spec.max_queue:
            return REJECT_QUEUE_FULL
        queue.append(request)
        return None

    def queued(self) -> int:
        """Total requests waiting across every tenant queue."""
        return sum(len(q) for q in self.queues.values())
