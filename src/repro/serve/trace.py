"""Serve-tier metrics log: JSONL persistence + bit-exact replay contract.

A serve trace is one header line, then one ``request`` record per arrival
(admission verdict, queue delay, completion — the per-request fields
``tenant``/``queue_delay_s`` ride through the same shared serialiser the
chaos traces use, so they round-trip without hand-picking) and one
``batch`` record per dispatch (composition, stage timings, and the full
``StepReport`` payload via ``repro.chaos.serialize.report_to_dict``).

Because a ``ServeTier`` run is a pure function of (spec, scenario, seed)
on the simulated clock, re-running the recipe must reproduce the trace
EXACTLY — ``diff`` returns field-level mismatches (empty = identical).
``golden_serve_trace`` is the canonical recipe pinned by
``tests/golden/serve_heavy_tail.jsonl`` in CI (regenerate via
``scripts/regen_golden_traces.py --serve``).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.chaos.serialize import dataclass_to_dict
from repro.serve.loop import ServeResult

__all__ = ["SERVE_TRACE_VERSION", "ServeTrace",
           "GOLDEN_SERVE_SCENARIO", "GOLDEN_SERVE_SEED",
           "GOLDEN_SERVE_REQUESTS", "GOLDEN_SERVE_OVERHEAD_S",
           "golden_serve_result", "golden_serve_trace"]

SERVE_TRACE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ServeTrace:
    """A recorded tier run: meta + request/batch records as JSON-safe dicts."""

    meta: dict
    requests: Tuple[dict, ...]
    batches: Tuple[dict, ...]

    @classmethod
    def from_result(cls, result: ServeResult) -> "ServeTrace":
        """Serialise a ``ServeResult`` (records through the shared
        dataclass serialiser; decoded products are NOT recorded)."""
        return cls(
            meta=dict(result.meta),
            requests=tuple(dataclass_to_dict(r) for r in result.requests),
            batches=tuple(dataclass_to_dict(b) for b in result.batches))

    def diff(self, other: "ServeTrace") -> List[str]:
        """Field-level mismatches against another trace (empty = identical).

        Floats must match EXACTLY — the serve loop is deterministic on its
        simulated clock, so any drift is a real behaviour change.
        """
        out: List[str] = []
        for kind in ("requests", "batches"):
            mine, theirs = getattr(self, kind), getattr(other, kind)
            if len(mine) != len(theirs):
                out.append(f"{kind}: {len(mine)} vs {len(theirs)} records")
            for a, b in zip(mine, theirs):
                for field in sorted(set(a) | set(b)):
                    want, have = a.get(field), b.get(field)
                    if want != have:
                        label = a.get("rid", a.get("index", "?"))
                        out.append(f"{kind}[{label}].{field}: "
                                   f"{want!r} vs {have!r}")
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, path) -> Path:
        """Write JSONL: header, then request records, then batch records."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        lines = [json.dumps(
            {"kind": "header", "version": SERVE_TRACE_VERSION,
             "requests": len(self.requests), "batches": len(self.batches),
             "meta": self.meta}, sort_keys=True)]
        lines += [json.dumps({"kind": "request", **r}, sort_keys=True)
                  for r in self.requests]
        lines += [json.dumps({"kind": "batch", **b}, sort_keys=True)
                  for b in self.batches]
        path.write_text("\n".join(lines) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "ServeTrace":
        """Read a trace written by :meth:`save`.

        Raises:
            ValueError: on a missing/foreign header, version mismatch, or
                an unknown record kind.
        """
        lines = Path(path).read_text().splitlines()
        if not lines:
            raise ValueError(f"{path}: empty serve trace")
        header = json.loads(lines[0])
        if header.get("kind") != "header":
            raise ValueError(f"{path}: first line is not a trace header")
        if header.get("version") != SERVE_TRACE_VERSION:
            raise ValueError(
                f"{path}: serve trace version {header.get('version')} != "
                f"supported {SERVE_TRACE_VERSION}")
        requests, batches = [], []
        for line in lines[1:]:
            rec = json.loads(line)
            kind = rec.pop("kind", None)
            if kind == "request":
                requests.append(rec)
            elif kind == "batch":
                batches.append(rec)
            else:
                raise ValueError(f"{path}: unknown record kind {kind!r}")
        return cls(meta=dict(header.get("meta", {})),
                   requests=tuple(requests), batches=tuple(batches))


# ---------------------------------------------------------------------------
# The canonical golden serve run (mirrors chaos.golden's recipe style).
# ---------------------------------------------------------------------------

GOLDEN_SERVE_SCENARIO = "heavy_tail"
GOLDEN_SERVE_SEED = 11
GOLDEN_SERVE_REQUESTS = 12          # per tenant; 3 tenants -> 36 arrivals
#: constant per-rung decode costs (measured prewarm overheads carry
#: wall-clock noise; golden runs must not time anything real).
GOLDEN_SERVE_OVERHEAD_S = {"bec": 2.0, "tradeoff(p'=2)": 1.0,
                           "polycode": 0.1}
_GOLDEN_BUCKETS = (1, 2, 4, 8)


def _golden_tier():
    """The canonical tier config over the chaos golden ladder geometry."""
    import jax.numpy as jnp

    from repro.chaos.golden import GOLDEN_GRID, GOLDEN_K, GOLDEN_L, \
        GOLDEN_SHAPES
    from repro.chaos.scenarios import make_scenario
    from repro.control import PlanLadder
    from repro.serve.loop import ServeTier
    from repro.serve.tenants import DEFAULT_SPEC, parse_tenant_spec

    p, m, n = GOLDEN_GRID
    ladder = PlanLadder(p, m, n, K=GOLDEN_K, L=GOLDEN_L,
                        backend="reference", dtype=jnp.float64)
    ladder.prewarm(*GOLDEN_SHAPES, batch_sizes=_GOLDEN_BUCKETS, stages=True)
    classes, tenants = parse_tenant_spec(DEFAULT_SPEC)
    feed = make_scenario(GOLDEN_SERVE_SCENARIO).compile(
        GOLDEN_K, seed=GOLDEN_SERVE_SEED)
    tier = ServeTier(
        ladder, classes=tuple(classes.values()),
        tenants=tuple(tenants.values()), feed=feed,
        overhead_s=GOLDEN_SERVE_OVERHEAD_S, seed=GOLDEN_SERVE_SEED,
        check_exact=True, keep_results=True)
    return tier, GOLDEN_SHAPES


def _golden_request_A(shapes):
    """Deterministic per-request operand builder (no rng: version-stable)."""
    import jax.numpy as jnp

    (v, r), _ = shapes

    def make_A(request):
        base = np.arange(v * r).reshape(v, r)
        return jnp.asarray((base * (request.rid + 3)) % 11 - 5, jnp.float64)

    return make_A


def golden_serve_result() -> ServeResult:
    """Run the canonical serve recipe (heavy_tail, seeded, simulated clock)."""
    import jax.numpy as jnp

    tier, shapes = _golden_tier()
    (v, _), (_, t) = shapes
    B = jnp.asarray(np.arange(v * t).reshape(v, t) % 7 - 3, jnp.float64)
    return tier.run(_golden_request_A(shapes), B, GOLDEN_SERVE_REQUESTS)


def golden_serve_trace() -> ServeTrace:
    """The canonical run as a trace, with recipe provenance in the meta."""
    result = golden_serve_result()
    trace = ServeTrace.from_result(result)
    meta = dict(trace.meta)
    meta.update(scenario=GOLDEN_SERVE_SCENARIO, seed=GOLDEN_SERVE_SEED,
                requests_per_tenant=GOLDEN_SERVE_REQUESTS,
                version_note="regenerate via scripts/regen_golden_traces.py "
                             "--serve")
    return dataclasses.replace(trace, meta=meta)
