"""Continuous batching: coalesce queued requests into prewarmed buckets.

``form`` is called EVERY serving step (continuous batching), not once per
full batch: whatever compatible work is waiting right now is coalesced,
up to the largest prewarmed ``PlanLadder`` batch bucket — the ladder's
round-up pad-and-slice then lands every dispatch on an existing
executable, zero recompiles.

"Compatible" means SAME SLO class: requests in one batch share a rung
decision, an erasure mask, and a ``ViolationFeedback`` state, all of
which are per-class.  Among classes with waiting work, dispatch order is
earliest-deadline-first (ties break by arrival, then request id — total
and deterministic); within the winning class, waiting requests are taken
in the same EDF order across ALL of that class's tenant queues.
"""
from __future__ import annotations

import dataclasses
from typing import Deque, Dict, Optional, Tuple

from repro.serve.admission import Request

__all__ = ["Batch", "ContinuousBatcher"]


def _edf_key(request: Request) -> Tuple[float, float, int]:
    return (request.deadline_s, request.arrival_s, request.rid)


@dataclasses.dataclass(frozen=True)
class Batch:
    """One dispatchable unit: same-class requests + their earliest deadline."""

    slo_class: str
    requests: Tuple[Request, ...]
    deadline_s: float

    @property
    def size(self) -> int:
        """Number of requests coalesced into this batch."""
        return len(self.requests)


class ContinuousBatcher:
    """EDF selection over per-tenant queues, capped at the bucket ceiling.

    Args:
        class_of: tenant name -> SLO class name (batch compatibility).
        max_batch: batch-size ceiling; the largest prewarmed bucket, so
            every dispatch pads up to an existing executable.
    """

    def __init__(self, class_of: Dict[str, str], max_batch: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.class_of = dict(class_of)
        self.max_batch = int(max_batch)

    def form(self, queues: Dict[str, Deque[Request]]) -> Optional[Batch]:
        """Pop and return the next batch to dispatch (None = nothing waits).

        The winning class is the one owning the globally earliest-deadline
        waiting request; up to ``max_batch`` of that class's requests are
        REMOVED from their tenant queues (EDF order) and returned.
        """
        waiting: Dict[str, list] = {}
        for tenant, queue in queues.items():
            if queue:
                waiting.setdefault(self.class_of[tenant], []).extend(queue)
        if not waiting:
            return None
        for reqs in waiting.values():
            reqs.sort(key=_edf_key)
        winner = min(waiting, key=lambda cls: _edf_key(waiting[cls][0]))
        take = waiting[winner][: self.max_batch]
        taken = {r.rid for r in take}
        for tenant, queue in queues.items():
            if self.class_of[tenant] == winner:
                kept = [r for r in queue if r.rid not in taken]
                queue.clear()
                queue.extend(kept)
        return Batch(slo_class=winner, requests=tuple(take),
                     deadline_s=take[0].deadline_s)
