"""Tenant and SLO-class configuration for the multi-tenant serve tier.

An :class:`SLOClass` maps a latency contract onto the existing control
machinery: its quantile becomes the class's ``AdaptiveServer``
``slo_quantile`` (and its own ``ViolationFeedback`` state when enabled),
its bound becomes ``slo_s``, and its optional rung floor becomes a
:class:`RungFloorPolicy` — a ``QuantileLatencyPolicy`` that refuses to
select any rung with a SMALLER erasure budget than the floor rung, so a
premium class never gets parked on a thin-budget scheme just because the
mean ranking liked its decode cost.

A :class:`TenantSpec` binds a tenant to a class and carries its admission
knobs (token-bucket rate limit + burst, bounded queue depth) and the
simulated arrival rate its workload is generated at.

Both parse from the small JSON document ``coded_serve --serve-tier``
accepts (``{"classes": [...], "tenants": [...]}``); :data:`DEFAULT_SPEC`
is the built-in three-tenant example.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Optional, Sequence, Tuple

from repro.control.policy import QuantileLatencyPolicy

__all__ = ["SLOClass", "TenantSpec", "RungFloorPolicy",
           "parse_tenant_spec", "DEFAULT_SPEC"]


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One latency contract: quantile + bound + optional rung floor.

    Args:
        name: class identifier tenants reference.
        quantile: the tail quantile the SLO is stated at (the class
            server's ``slo_quantile``).
        slo_s: the latency bound in (simulated) seconds.  Per-request
            ``violated`` flags judge END-TO-END latency (queueing
            included) against this bound.
        rung_floor: optional rung name; the class never serves on a rung
            with a smaller erasure budget than this rung's.
        feedback: enable the class's own ``ViolationFeedback`` window
            (observed service-time violations adapt its quantile and
            flagging threshold independently of every other class).
    """

    name: str
    quantile: float = 0.99
    slo_s: float = 10.0
    rung_floor: Optional[str] = None
    feedback: bool = False

    def __post_init__(self):
        if not 0.0 < self.quantile < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {self.quantile}")
        if self.slo_s <= 0:
            raise ValueError(f"slo_s must be > 0, got {self.slo_s}")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: its SLO class, admission limits, and arrival process.

    Args:
        name: tenant identifier (queue key, metrics key).
        slo_class: name of the :class:`SLOClass` this tenant serves under.
        rate_rps: token-bucket refill rate (admitted requests/s);
            ``inf`` disables rate limiting.
        burst: token-bucket capacity (back-to-back admissions allowed).
        max_queue: bounded queue depth; arrivals beyond it are shed with
            reason ``"queue_full"``.
        arrival_rps: mean Poisson arrival rate the simulated workload
            generates for this tenant.
    """

    name: str
    slo_class: str
    rate_rps: float = math.inf
    burst: int = 8
    max_queue: int = 64
    arrival_rps: float = 1.0

    def __post_init__(self):
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.arrival_rps <= 0:
            raise ValueError(
                f"arrival_rps must be > 0, got {self.arrival_rps}")


class RungFloorPolicy(QuantileLatencyPolicy):
    """Quantile ranking with a minimum-protection rung floor.

    ``select`` first takes the base policy's winner; if that rung's
    erasure budget is SMALLER than the floor rung's (rungs order by
    ascending tau = descending budget, so "below the floor" means less
    straggler protection) and the floor itself is feasible, the floor
    rung is served instead.  With ``floor=None`` this IS
    ``QuantileLatencyPolicy`` — including its feedback hooks (``q`` and
    ``score_threshold`` restatement), which is why the serve tier uses
    this subclass rather than wrapping.
    """

    def __init__(self, ladder, *, floor: Optional[str] = None, **kwargs):
        super().__init__(ladder, **kwargs)
        if floor is not None:
            ladder.plan(floor)  # KeyError on an unknown rung, up front
        self.floor = floor

    def select(self, model, scores=None):
        """The ranked winner, clamped to the floor rung's budget."""
        best = super().select(model, scores)
        if self.floor is None:
            return best
        if (self.ladder.budget(best.rung) < self.ladder.budget(self.floor)
                and self.feasible(self.floor)):
            return self.estimate(self.floor, model, scores)
        return best


#: The built-in example spec: three tenants over two classes.  ``free``
#: arrives faster than its token bucket refills, so it demonstrably sheds.
DEFAULT_SPEC: dict = {
    "classes": [
        {"name": "premium", "quantile": 0.99, "slo_s": 15.0,
         "rung_floor": "tradeoff(p'=2)"},
        {"name": "standard", "quantile": 0.9, "slo_s": 60.0},
    ],
    "tenants": [
        {"name": "gold", "slo_class": "premium", "arrival_rps": 0.4},
        {"name": "silver", "slo_class": "standard", "arrival_rps": 0.8},
        {"name": "free", "slo_class": "standard", "arrival_rps": 2.5,
         "rate_rps": 0.5, "burst": 3, "max_queue": 8},
    ],
}


def parse_tenant_spec(
    spec,
) -> Tuple[Dict[str, SLOClass], Dict[str, TenantSpec]]:
    """``{"classes": [...], "tenants": [...]}`` -> typed, validated maps.

    Args:
        spec: a dict, a JSON string, or a sequence of per-tenant dicts
            (classes defaulting from :data:`DEFAULT_SPEC`).

    Returns:
        ``(classes, tenants)`` keyed by name, insertion-ordered.

    Raises:
        ValueError: on duplicate names, a tenant referencing an unknown
            class, or an empty section.
    """
    if isinstance(spec, str):
        spec = json.loads(spec)
    if isinstance(spec, Sequence) and not isinstance(spec, (str, bytes)):
        spec = {"classes": DEFAULT_SPEC["classes"], "tenants": list(spec)}
    class_rows = spec.get("classes") or DEFAULT_SPEC["classes"]
    tenant_rows = spec.get("tenants") or []
    if not tenant_rows:
        raise ValueError("tenant spec has no tenants")
    classes: Dict[str, SLOClass] = {}
    for row in class_rows:
        cls = SLOClass(**row)
        if cls.name in classes:
            raise ValueError(f"duplicate SLO class {cls.name!r}")
        classes[cls.name] = cls
    tenants: Dict[str, TenantSpec] = {}
    for row in tenant_rows:
        ten = TenantSpec(**row)
        if ten.name in tenants:
            raise ValueError(f"duplicate tenant {ten.name!r}")
        if ten.slo_class not in classes:
            raise ValueError(
                f"tenant {ten.name!r} references unknown SLO class "
                f"{ten.slo_class!r}; have {sorted(classes)}")
        tenants[ten.name] = ten
    return classes, tenants
