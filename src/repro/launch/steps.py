"""Step functions: train_step / prefill_step / serve_step builders.

Each builder returns a pure function suitable for jax.jit with explicit
in/out shardings; the sharding-rules context is entered inside the function
so shard() annotations resolve against the active mesh during tracing.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.distributed.sharding import AxisRules, axis_rules
from repro.models import ModelConfig, decode_step, prefill, train_loss
from repro.optim import OptConfig, adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step"]


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    rules: Optional[AxisRules] = None):
    def train_step(params, opt_state, batch):
        with axis_rules(rules):
            loss, grads = jax.value_and_grad(
                lambda p: train_loss(p, cfg, batch))(params)
            new_params, new_opt, metrics = adamw_update(opt_cfg, grads, opt_state)
            metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rules: Optional[AxisRules] = None,
                      S_max: Optional[int] = None):
    def prefill_step(params, batch):
        with axis_rules(rules):
            logits, cache = prefill(params, cfg, batch, S_max=S_max)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, rules: Optional[AxisRules] = None):
    def serve_step(params, cache, batch, pos):
        with axis_rules(rules):
            logits, new_cache = decode_step(params, cfg, cache, batch, pos)
        return logits, new_cache

    return serve_step
