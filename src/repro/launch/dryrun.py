import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: jit lowering
with abstract (ShapeDtypeStruct) params/optimizer/cache/batch - nothing is
allocated - then XLA SPMD-compiles for the production mesh.  Outputs
memory_analysis (fits-per-device), cost_analysis (FLOPs/bytes), and the
collective-bytes breakdown parsed from the partitioned HLO, cached as JSON
under results/dryrun/ for the roofline stage.

Usage:
  python -m repro.launch.dryrun --arch qwen3_0_6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.distributed.sharding import axis_rules, default_rules
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_batch,
    abstract_cache,
    abstract_state,
)
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.optim import OptConfig

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               cfg_overrides: dict | None = None, fsdp: bool = True):
    """Returns (lowered, meta) for one cell.

    ``cfg_overrides``/``fsdp`` select perf-variant lowerings for the
    hillclimb (EXPERIMENTS.md SecPerf); defaults = baseline."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        raise SystemExit(f"{arch} x {shape_name}: {why}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh, fsdp=fsdp)
    with axis_rules(rules):
        if shape.kind == "train":
            params, opt = abstract_state(cfg, rules, with_opt=True)
            batch = abstract_batch(cfg, shape, rules)
            step = make_train_step(cfg, OptConfig(), rules)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params, opt, batch)
        elif shape.kind == "prefill":
            params, _ = abstract_state(cfg, rules, with_opt=False)
            batch = abstract_batch(cfg, shape, rules)
            step = make_prefill_step(cfg, rules, S_max=shape.seq_len)
            lowered = jax.jit(step).lower(params, batch)
        else:  # decode
            params, _ = abstract_state(cfg, rules, with_opt=False)
            batch = abstract_batch(cfg, shape, rules)
            cache = abstract_cache(cfg, shape.global_batch, shape.seq_len, rules)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_serve_step(cfg, rules)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params, cache, batch, pos)
    return lowered, {"arch": arch, "shape": shape_name,
                     "multi_pod": multi_pod, "kind": shape.kind,
                     "n_devices": mesh.size}


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             hlo_out: str | None = None, save_hlo: bool = True,
             cfg_overrides: dict | None = None, fsdp: bool = True,
             tag_suffix: str = "") -> dict:
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod,
                               cfg_overrides=cfg_overrides, fsdp=fsdp)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = analyze_hlo(hlo)
    if hlo_out:
        Path(hlo_out).write_text(hlo)
    if save_hlo:
        # compressed HLO kept next to the JSON: re-analysis (new roofline
        # metrics, debugging) never needs a recompile
        import gzip
        tag = (f"{arch}__{shape_name}__"
               f"{'multipod' if multi_pod else 'singlepod'}{tag_suffix}")
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        with gzip.open(RESULTS_DIR / f"{tag}.hlo.gz", "wt") as f:
            f.write(hlo)

    result = {
        **meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed",
                                          "transcendentals", "optimal_seconds")
                 if isinstance(cost, dict) and k in cost},
        "collectives": {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
            "total_bytes": coll.total_bytes,
        },
        # true per-device dot FLOPs / HBM bytes with while-trip multipliers
        # (XLA's cost_analysis counts scan bodies once - see hlo_analysis.py)
        "dot_flops": coll.dot_flops,
        "dot_count": coll.dot_count,
        "hbm_bytes": coll.hbm_bytes,
        "hlo_lines": hlo.count("\n"),
    }
    return result


def reanalyze_all():
    """Rebuild analyzer-derived JSON fields from the stored .hlo.gz files
    (no recompilation) - run after hlo_analysis.py changes."""
    import gzip
    n = 0
    for gz in sorted(RESULTS_DIR.glob("*.hlo.gz")):
        jpath = gz.with_suffix("").with_suffix(".json")
        if not jpath.exists():
            continue
        res = json.loads(jpath.read_text())
        stats = analyze_hlo(gzip.open(gz, "rt").read())
        res["collectives"] = {
            "bytes_by_kind": stats.bytes_by_kind,
            "count_by_kind": stats.count_by_kind,
            "total_bytes": stats.total_bytes,
        }
        res["dot_flops"] = stats.dot_flops
        res["dot_count"] = stats.dot_count
        res["hbm_bytes"] = stats.hbm_bytes
        jpath.write_text(json.dumps(res, indent=2))
        n += 1
        print(f"[rean] {jpath.name}: flops={stats.dot_flops:.3e} "
              f"hbm={stats.hbm_bytes:.3e} coll={stats.total_bytes:.3e}")
    print(f"reanalyzed {n} cells")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--hlo-out")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute analyzer fields from stored .hlo.gz")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze_all()
        return

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    cells = []
    if args.all:
        for arch in list_archs():
            cfg = get_config(arch)
            for s in SHAPES:
                if shape_applicable(cfg, s)[0]:
                    cells.append((arch, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, s in cells:
        for mp in meshes:
            tag = f"{arch}__{s}__{'multipod' if mp else 'singlepod'}"
            out_path = RESULTS_DIR / f"{tag}.json"
            if args.skip_existing and out_path.exists():
                print(f"[skip] {tag}")
                continue
            print(f"[run ] {tag} ...", flush=True)
            try:
                res = run_cell(arch, s, mp, hlo_out=args.hlo_out)
                out_path.write_text(json.dumps(res, indent=2))
                mem = res["memory"]
                per_dev = (mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0)
                print(f"[ ok ] {tag}: compile={res['compile_s']}s "
                      f"flops={res['cost'].get('flops'):.3e} "
                      f"coll={res['collectives']['total_bytes']:.3e}B "
                      f"mem/dev={per_dev/2**30:.2f}GiB", flush=True)
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((tag, str(e)))
                out_path.with_suffix(".err").write_text(
                    f"{e}\n{traceback.format_exc()}")
                print(f"[FAIL] {tag}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, e in failures:
            print(f"  {tag}: {e.splitlines()[0] if e else e}")
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
