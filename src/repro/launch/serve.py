"""Serving driver: batched prefill + decode loop with a KV/state cache.

Demonstrates the inference path end-to-end: prefill a batch of prompts,
then greedy-decode N tokens per step with the jit'd serve_step.  Works
single-device with smoke configs (examples/serve_lm.py) and lowers to the
production mesh in the dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    B, S, G = args.batch, args.prompt_len, args.gen
    S_max = S + G

    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = _make_batch(cfg, prompts)

    prefill_fn = jax.jit(make_prefill_step(cfg, None, S_max=S_max))
    serve_fn = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill_fn(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    out_tokens = [jnp.argmax(logits, -1)]
    t0 = time.time()
    for i in range(G - 1):
        tok = out_tokens[-1][:, None]
        step_batch = _make_batch(cfg, tok)
        logits, cache = serve_fn(params, cache, step_batch, jnp.int32(S + i))
        out_tokens.append(jnp.argmax(logits, -1))
    jax.block_until_ready(out_tokens[-1])
    t_decode = time.time() - t0

    gen = jnp.stack(out_tokens, 1)
    tok_s = B * (G - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} batch={B} prompt={S} gen={G}")
    print(f"prefill {t_prefill*1e3:.1f} ms; decode {t_decode*1e3:.1f} ms "
          f"({tok_s:.1f} tok/s)")
    print("sample:", np.asarray(gen[0])[:12])
    return gen


def _make_batch(cfg, tokens):
    if cfg.input_mode == "tokens":
        return {"tokens": tokens}
    B, S = tokens.shape
    base = jnp.arange(cfg.d_model, dtype=jnp.float32)
    emb = (jnp.sin(tokens[..., None].astype(jnp.float32) * 0.01 + base * 0.1)
           * 0.1).astype(jnp.bfloat16)
    out = {"embeds": emb}
    if cfg.pos == "mrope":
        out["pos_ids"] = jnp.zeros((3, B, S), jnp.int32)
    return out


if __name__ == "__main__":
    main()
