"""ShapeDtypeStruct input specs for every (arch x shape) cell.

No allocation ever happens here: params, optimizer state, caches, and
batches are all abstract stand-ins (weak-type-correct, shardable), used by
jit(...).lower() in the dry-run and by eval_shape-based tooling.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.distributed.param_sharding import (
    batch_logical_axes,
    cache_logical_axes,
    param_logical_axes,
    tree_shardings,
)
from repro.distributed.sharding import AxisRules
from repro.models import ModelConfig, cache_shapes, param_shapes
from repro.optim import adamw_init_shapes

__all__ = ["input_specs", "attach_shardings", "abstract_state"]


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract batch for one shape spec."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_mode == "tokens":
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        out = {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.pos == "mrope":
            out["pos_ids"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        return out
    if shape.kind == "prefill":
        if cfg.input_mode == "tokens":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        out = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        if cfg.pos == "mrope":
            out["pos_ids"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        return out
    if shape.kind == "decode":
        if cfg.input_mode == "tokens":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        out = {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)}
        if cfg.pos == "mrope":
            out["pos_ids"] = jax.ShapeDtypeStruct((3, B, 1), jnp.int32)
        return out
    raise ValueError(shape.kind)


def attach_shardings(rules: AxisRules, tree: Any, logical: Any) -> Any:
    """Rebuild ShapeDtypeStructs with NamedShardings attached."""
    shardings = tree_shardings(rules, tree, logical)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def abstract_state(cfg: ModelConfig, rules: Optional[AxisRules],
                   with_opt: bool = True):
    """(params_abstract, opt_abstract) with shardings when rules given."""
    ps = param_shapes(cfg)
    logical = param_logical_axes(ps)
    if rules is not None:
        ps = attach_shardings(rules, ps, logical)
    opt = None
    if with_opt:
        opt = adamw_init_shapes(param_shapes(cfg))
        if rules is not None:
            opt_logical = {
                "step": (),
                "master": logical,
                "mu": logical,
                "nu": logical,
            }
            opt = attach_shardings(rules, opt, opt_logical)
    return ps, opt


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec,
                   rules: Optional[AxisRules]):
    b = input_specs(cfg, shape)
    if rules is None:
        return b
    logical = batch_logical_axes(cfg, shape.kind)
    return attach_shardings(rules, b, logical)


def abstract_cache(cfg: ModelConfig, B: int, S_max: int,
                   rules: Optional[AxisRules]):
    c = cache_shapes(cfg, B, S_max)
    if rules is None:
        return c
    logical = cache_logical_axes(cfg)
    return tuple(attach_shardings(rules, cd, ld)
                 for cd, ld in zip(c, logical))
