"""Training driver: data pipeline -> jit'd train step -> checkpoints.

Runs anywhere: single CPU device (examples, smoke configs), a debug mesh
(XLA_FLAGS=--xla_force_host_platform_device_count=N), or the production
mesh on real hardware.  Fault tolerance: periodic atomic checkpoints
(params + optimizer + data-stream step); --resume restarts from the newest
committed step and replays the exact data stream.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data import make_pipeline
from repro.distributed.sharding import default_rules
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import OptConfig, adamw_init


def build(arch: str, smoke: bool, seq: int, batch: int, lr: float,
          steps: int, mesh=None):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(2, steps // 20),
                        total_steps=steps)
    rules = default_rules(mesh) if mesh is not None else None
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, rules),
                      donate_argnums=(0, 1))
    pipe = make_pipeline(cfg.vocab, seq, batch)
    return cfg, step_fn, pipe


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, step_fn, pipe = build(args.arch, args.smoke, args.seq, args.batch,
                               args.lr, args.steps)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt_state = adamw_init(params)
    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start, extra = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    losses = []
    t0 = time.time()
    for t in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(t).items()}
        if cfg.input_mode == "embeds":
            # frontend stub: deterministic pseudo-embeddings from token ids
            tok = batch.pop("tokens")
            emb = _stub_embeds(tok, cfg.d_model)
            batch["embeds"] = emb
            if cfg.pos == "mrope":
                B, S = tok.shape
                pid = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
                batch["pos_ids"] = pid.astype(jnp.int32)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if t % args.log_every == 0 or t == args.steps - 1:
            dt = time.time() - t0
            print(f"step {t:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, t + 1, (params, opt_state),
                            extra={"data_step": t + 1})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, (params, opt_state),
                        extra={"data_step": args.steps})
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


def _stub_embeds(tokens: jnp.ndarray, d: int) -> jnp.ndarray:
    """Deterministic frontend stub: hash token ids into pseudo-embeddings
    (stands in for EnCodec frames / ViT patches per the assignment)."""
    B, S = tokens.shape
    base = jnp.arange(d, dtype=jnp.float32)
    phase = tokens[..., None].astype(jnp.float32)
    return (jnp.sin(phase * 0.01 + base * 0.1) * 0.1).astype(jnp.bfloat16)


if __name__ == "__main__":
    main()
