"""Training driver: data pipeline -> jit'd train step -> checkpoints.

Runs anywhere: single CPU device (examples, smoke configs), a debug mesh
(XLA_FLAGS=--xla_force_host_platform_device_count=N), or the production
mesh on real hardware.  Fault tolerance: periodic atomic checkpoints
(params + optimizer + data-stream step); --resume restarts from the newest
committed step and replays the exact data stream.

Elastic shrink (--elastic-shrink-at N --elastic-devices D): simulate a
mid-run device loss — checkpoint at step N, ``plan_shrink(D)`` picks the
largest supported mesh that still fits, the step function re-lowers onto
it, state restores from the checkpoint just written, and the run
continues; because the data stream is a pure function of the step index,
the handoff run is bit-exact with an uninterrupted one
(tests/test_substrate.py::TestTrainResume).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data import make_pipeline
from repro.distributed.elastic import plan_shrink
from repro.distributed.sharding import default_rules
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import OptConfig, adamw_init


def build(arch: str, smoke: bool, seq: int, batch: int, lr: float,
          steps: int, mesh=None):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(2, steps // 20),
                        total_steps=steps)
    rules = default_rules(mesh) if mesh is not None else None
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, rules),
                      donate_argnums=(0, 1))
    pipe = make_pipeline(cfg.vocab, seq, batch)
    return cfg, step_fn, pipe


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--elastic-shrink-at", type=int, default=None,
                    help="simulate losing devices BEFORE this step: "
                         "checkpoint, plan_shrink the mesh, re-lower, "
                         "restore, continue")
    ap.add_argument("--elastic-devices", type=int, default=None,
                    help="healthy device count after the simulated loss "
                         "(required with --elastic-shrink-at)")
    args = ap.parse_args(argv)
    if args.elastic_shrink_at is not None:
        if args.elastic_devices is None or args.ckpt_dir is None:
            ap.error("--elastic-shrink-at requires --elastic-devices and "
                     "--ckpt-dir (the handoff restores from checkpoint)")
        if not 0 < args.elastic_shrink_at < args.steps:
            ap.error(f"--elastic-shrink-at {args.elastic_shrink_at} outside "
                     f"(0, {args.steps})")

    cfg, step_fn, pipe = build(args.arch, args.smoke, args.seq, args.batch,
                               args.lr, args.steps)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt_state = adamw_init(params)
    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start, extra = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    losses = []
    t0 = time.time()
    for t in range(start, args.steps):
        if args.elastic_shrink_at is not None and t == args.elastic_shrink_at:
            step_fn, params, opt_state = _elastic_handoff(
                args, params, opt_state, t)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(t).items()}
        if cfg.input_mode == "embeds":
            # frontend stub: deterministic pseudo-embeddings from token ids
            tok = batch.pop("tokens")
            emb = _stub_embeds(tok, cfg.d_model)
            batch["embeds"] = emb
            if cfg.pos == "mrope":
                B, S = tok.shape
                pid = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
                batch["pos_ids"] = pid.astype(jnp.int32)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if t % args.log_every == 0 or t == args.steps - 1:
            dt = time.time() - t0
            print(f"step {t:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, t + 1, (params, opt_state),
                            extra={"data_step": t + 1})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, (params, opt_state),
                        extra={"data_step": args.steps})
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


def _elastic_handoff(args, params, opt_state, t):
    """Execute the shrink: checkpoint, re-lower, restore, continue.

    The live state is checkpointed at step ``t`` (no progress lost),
    ``plan_shrink`` picks the largest supported mesh that fits the
    surviving devices, the train step re-lowers onto it (a debug mesh
    when the host exposes enough devices, the single-device path
    otherwise), and state restores from the checkpoint just written —
    exactly the restart a real device loss would take.
    """
    save_checkpoint(args.ckpt_dir, t, (params, opt_state),
                    extra={"data_step": t})
    d, m = plan_shrink(args.elastic_devices)
    mesh = None
    if d * m > 1 and jax.device_count() >= d * m:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(d, m)
    _, step_fn, _ = build(args.arch, args.smoke, args.seq, args.batch,
                          args.lr, args.steps, mesh=mesh)
    (params, opt_state), _, _ = restore_checkpoint(
        args.ckpt_dir, (params, opt_state))
    print(f"elastic shrink at step {t}: {args.elastic_devices} healthy "
          f"devices -> mesh ({d}, {m})"
          f"{' (single-device lowering)' if mesh is None else ''}; "
          f"re-lowered and restored", flush=True)
    return step_fn, params, opt_state


def _stub_embeds(tokens: jnp.ndarray, d: int) -> jnp.ndarray:
    """Deterministic frontend stub: hash token ids into pseudo-embeddings
    (stands in for EnCodec frames / ViT patches per the assignment)."""
    B, S = tokens.shape
    base = jnp.arange(d, dtype=jnp.float32)
    phase = tokens[..., None].astype(jnp.float32)
    return (jnp.sin(phase * 0.01 + base * 0.1) * 0.1).astype(jnp.bfloat16)


if __name__ == "__main__":
    main()
