"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data x model).
Multi-pod: 2x16x16 = 512 chips (pod x data x model); the "pod" axis carries
only data parallelism + gradient reduction (cross-pod DCI traffic), matching
how multi-slice TPU jobs are actually laid out.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU multi-device tests (run under
    XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    return jax.make_mesh((data, model), ("data", "model"))
