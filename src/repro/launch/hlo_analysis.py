"""Post-partitioning HLO analysis: collective bytes + true FLOPs.

compiled.cost_analysis() visits while bodies ONCE (trip counts are not
multiplied), which under-reports scanned-layer models by n_groups x.  We
therefore parse compiled.as_text() ourselves:

* computations are split on '... -> ... {' headers;
* while ops expose backend_config={"known_trip_count":{"n":"K"}} - we build
  the call graph (while body/cond, fusion calls=, reducer to_apply=) and
  propagate multipliers from the entry (nested scans multiply);
* collective ops contribute bytes = tensor_bytes x ring_factor x multiplier
  ((G-1)/G per ring hop, 2x for all-reduce, (G-1) for reduce-scatter whose
  printed type is the scattered output);
* dot ops contribute flops = 2 x prod(result dims) x prod(contracting dims)
  x multiplier (operand shapes resolved from the definition table).

This gives per-DEVICE quantities: the roofline terms divide by per-chip
peak numbers, so no further normalisation is needed.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["HloStats", "analyze_hlo", "analyze_collectives"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(pred|bf16|[suf]\d+|c64|c128)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_WHILE_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_WHILE_REF_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"dot\(\s*%([\w\.\-]+)")


def _shapes_of(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _TYPE_RE.finditer(type_str):
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes) -> List[int]:
    out = []
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        out.append(n * _DTYPE_BYTES[dt])
    return out


def _ring_factor(kind: str, G: int) -> float:
    if G <= 1:
        return 0.0
    return {
        "all-gather": (G - 1) / G,
        "all-reduce": 2 * (G - 1) / G,
        "reduce-scatter": float(G - 1),
        "all-to-all": (G - 1) / G,
        "collective-permute": 1.0,
    }.get(kind, 1.0)


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


@dataclasses.dataclass
class HloStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    dot_flops: float
    dot_count: int
    hbm_bytes: float = 0.0
    pallas_interp_bytes: float = 0.0  # excluded interpret-mode tile traffic

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


# opcodes that move no HBM data (aliases / metadata / control flow whose
# bodies are accounted separately) + collectives (interconnect term)
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota", "partition-id", "replica-id",
               "while", "conditional", "call", "all-gather", "all-reduce",
               "reduce-scatter", "all-to-all", "collective-permute",
               "all-gather-start", "all-gather-done", "all-reduce-start",
               "all-reduce-done", "collective-permute-start",
               "collective-permute-done", "optimization-barrier"}

_INSTR_RE = re.compile(
    r"=\s*(?:\([^=]*?\)|\S+)\s+([\w\-]+)\(([^)]*)\)")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")

# Off-TPU, Pallas kernels run in interpret mode: the grid loop's per-step
# tile shuffling appears as HBM ops but is VMEM-resident on the real
# hardware target.  Such lines are tagged by the kernel's jit scope in
# metadata and EXCLUDED from the HBM term (tracked separately; the real
# kernel's HBM traffic = its operands+results, which the CALLER lines
# already account for).
_PALLAS_RE = re.compile(r"jit\(\w*pallas\w*\)")


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """name -> list of body lines.

    Headers are 'name (args...) -> result {' but the argument list WRAPS
    over multiple lines for big computations, so we latch onto the name and
    wait for the opening brace."""
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    pending: Optional[str] = None
    for raw in hlo.splitlines():
        stripped = raw.strip()
        if current is None:
            if pending is None:
                if (stripped.startswith("%") or stripped.startswith("ENTRY")) \
                        and "(" in stripped:
                    parts = stripped.split()
                    name = parts[0].lstrip("%")
                    if name == "ENTRY" and len(parts) > 1:
                        name = parts[1].lstrip("%").split("(")[0]
                    if stripped.endswith("{"):
                        comps[name] = []
                        current = name
                    else:
                        pending = name
            elif stripped.endswith("{"):
                comps[pending] = []
                current = pending
                pending = None
            continue
        if stripped.startswith("}"):
            current = None
            continue
        comps[current].append(stripped)
    return comps


def analyze_hlo(hlo: str) -> HloStats:
    comps = _split_computations(hlo)

    # op definition table: name -> (dtype, dims) of the (first) result
    defs: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
    for lines in comps.values():
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            # the RHS starts with the result type; first match is enough
            shapes = _shapes_of(dm.group(2)[:200])
            if shapes:
                defs[dm.group(1)] = shapes[0]

    # call graph with multipliers; fusion-called computations are "internal"
    # (their data traffic is accounted at the fusion call site)
    callees: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    fusion_internal = set()
    for name, lines in comps.items():
        for ln in lines:
            wm = _WHILE_REF_RE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tm = _WHILE_TRIP_RE.search(ln)
                trip = float(tm.group(1)) if tm else 1.0
                callees[name].append((body, trip))
                callees[name].append((cond, trip))
            else:
                for cm in _CALL_RE.finditer(ln):
                    callees[name].append((cm.group(1), 1.0))
                    fusion_internal.add(cm.group(1))

    called = {c for lst in callees.values() for c, _ in lst}
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if mult.get(name, 0.0) >= m:
            return
        mult[name] = m
        for child, k in callees.get(name, []):
            visit(child, m * k)

    for name in comps:
        if name not in called:
            visit(name, 1.0)

    bytes_by_kind: Dict[str, float] = defaultdict(float)
    count_by_kind: Dict[str, int] = defaultdict(int)
    dot_flops = 0.0
    dot_count = 0
    hbm_bytes = 0.0
    pallas_bytes = 0.0

    # computations that ARE an interpret-mode Pallas grid harness: a large
    # fraction of their lines carries the kernel's jit scope (measured
    # ~45 % vs <1 % for ordinary bodies that merely CALL a kernel).  Their
    # tile shuffling is VMEM-resident on the real TPU target.
    pallas_comps = set()
    for name, lines in comps.items():
        if not lines:
            continue
        tagged = sum(1 for ln in lines if _PALLAS_RE.search(ln))
        if tagged / len(lines) >= 0.2:
            pallas_comps.add(name)

    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        top_level = name not in fusion_internal
        for ln in lines:
            # ---- HBM traffic (post-fusion instruction level) -----------
            if top_level:
                im = _INSTR_RE.search(ln)
                if im and im.group(1) not in _NO_TRAFFIC:
                    dm0 = _DEF_RE.match(ln)
                    if dm0:
                        rhs = dm0.group(2)
                        pos = rhs.find(im.group(1) + "(")
                        out_b = sum(_bytes_of(_shapes_of(rhs[:pos]))) \
                            if pos > 0 else 0
                        opnd_sizes = []
                        for on in _OPERAND_NAME_RE.findall(im.group(2)):
                            sh = defs.get(on)
                            if sh:
                                opnd_sizes.append(_bytes_of([sh])[0])
                        opnd_b = sum(opnd_sizes)
                        opcode = im.group(1)
                        lhs_name = dm0.group(1)
                        if ("dynamic-update-slice" in lhs_name
                                or opcode == "dynamic-update-slice"):
                            # in-place: traffic = r/w of the UPDATE slice,
                            # not the full accumulator operand/result
                            small = (opnd_b - max(opnd_sizes)
                                     if opnd_sizes else 0)
                            nbytes = 2 * small
                        elif (opcode == "dynamic-slice"
                              or "dynamic-slice" in lhs_name):
                            # reads only the sliced window
                            nbytes = 2 * out_b
                        else:
                            nbytes = out_b + opnd_b
                        if name in pallas_comps or _PALLAS_RE.search(ln):
                            pallas_bytes += nbytes * m
                        else:
                            hbm_bytes += nbytes * m
            # ---- collectives ------------------------------------------
            matched = False
            for kind in _COLLECTIVES:
                tok_sync, tok_start = f" {kind}(", f" {kind}-start("
                is_sync = tok_sync in ln
                is_start = tok_start in ln
                if not (is_sync or is_start):
                    continue
                idx = ln.find("=")
                # position of the op INVOCATION (the lhs op NAME may also
                # contain the kind, e.g. %all-gather.209 = ... all-gather()
                op_pos = ln.find(tok_start if is_start else tok_sync)
                type_part = ln[idx + 1: op_pos] if 0 <= idx < op_pos else ln[:op_pos]
                sizes = _bytes_of(_shapes_of(type_part))
                if sizes:
                    nbytes = max(sizes) if is_start else sum(sizes)
                    G = _group_size(ln)
                    bytes_by_kind[kind] += nbytes * _ring_factor(kind, G) * m
                    count_by_kind[kind] += 1
                matched = True
                break
            if matched:
                continue
            # ---- dots --------------------------------------------------
            if " dot(" in ln:
                dm = _DEF_RE.match(ln)
                om = _OPERANDS_RE.search(ln)
                cm = _LHS_CDIMS_RE.search(ln)
                if not (dm and om and cm):
                    continue
                out_shapes = _shapes_of(dm.group(2))
                if not out_shapes:
                    continue
                out_elems = 1
                for d in out_shapes[0][1]:
                    out_elems *= d
                lhs = defs.get(om.group(1))
                if lhs is None:
                    continue
                cdims = [int(x) for x in cm.group(1).split(",") if x]
                k = 1
                for ci in cdims:
                    if ci < len(lhs[1]):
                        k *= lhs[1][ci]
                dot_flops += 2.0 * out_elems * k * m
                dot_count += 1

    return HloStats(dict(bytes_by_kind), dict(count_by_kind), dot_flops,
                    dot_count, hbm_bytes, pallas_bytes)


def analyze_collectives(hlo: str) -> HloStats:  # backwards-compat alias
    return analyze_hlo(hlo)
