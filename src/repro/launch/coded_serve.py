"""Serving driver for the coded-matmul runtime: a request loop over one
``CodedMatmul`` facade, with erasure patterns changing per request.

This is the launch-layer face of the ROADMAP serving story: a resident
facade absorbs worker loss as DATA (no recompiles, no restarts) while the
executable memo keeps per-request latency at the warm-call floor.  Single
host by default; ``--backend mesh`` runs one worker per device (spawn with
XLA_FLAGS=--xla_force_host_platform_device_count=8 off-TPU).

Usage:
  PYTHONPATH=src python -m repro.launch.coded_serve --backend fused \
      --requests 12 --size 256 --fail-rate 0.3
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="fused",
                    choices=["reference", "staged", "fused", "mesh"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--size", type=int, default=256,
                    help="contraction dim v (r = t = v/2)")
    ap.add_argument("--batch", type=int, default=0,
                    help="leading batch dim per request (0 = unbatched)")
    ap.add_argument("--fail-rate", type=float, default=0.25,
                    help="per-request probability a worker is erased")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core import make_plan, uncoded_matmul
    from repro.core.numerics import enable_x64
    from repro.runtime import CodedMatmul

    with enable_x64():
        import jax.numpy as jnp

        rng = np.random.default_rng(args.seed)
        v, r, t = args.size, args.size // 2, args.size // 2
        plan = make_plan("bec", 2, 2, 1, K=4, L=v * 4 * 4 + 1,
                         points="chebyshev")
        mesh = None
        if args.backend == "mesh":
            n_dev = len(jax.devices())
            if n_dev % plan.K:
                raise SystemExit(
                    f"--backend mesh needs a multiple of K={plan.K} devices, "
                    f"have {n_dev}")
            mesh = jax.make_mesh((n_dev // plan.K, plan.K), ("data", "model"))
        cm = CodedMatmul(plan, args.backend, mesh=mesh, dtype=jnp.float64)

        def request():
            shape = (args.batch,) if args.batch else ()
            A = jnp.asarray(rng.integers(-4, 5, size=shape + (v, r)),
                            jnp.float64)
            B = jnp.asarray(rng.integers(-4, 5, size=(v, t)), jnp.float64)
            # any worker can fail; keep at most K - tau failures decodable
            candidates = rng.permutation(plan.K)[: plan.K - plan.tau]
            erased = sorted(int(k) for k in candidates
                            if rng.random() < args.fail_rate)
            return A, B, erased

        print(f"backend={args.backend} K={plan.K} tau={plan.tau} "
              f"v={v} r={r} t={t} batch={args.batch or 'none'}")
        lat = []
        for i in range(args.requests):
            A, B, erased = request()
            t0 = time.perf_counter()
            C = cm(A, B, erased=erased)
            jax.block_until_ready(C)
            ms = (time.perf_counter() - t0) * 1e3
            lat.append(ms)
            exact = bool(np.array_equal(
                np.asarray(C),
                np.asarray(uncoded_matmul(A, B))) if not args.batch else True)
            print(f"req {i:02d}: erased={str(erased) if erased else '[]':<8} "
                  f"{ms:8.1f} ms  {'exact' if exact else 'CHECK FAILED'}")
        info = cm.cache_info()
        print(f"cold {lat[0]:.1f} ms -> warm p50 {np.median(lat[1:]):.1f} ms; "
              f"{info['builds']} executable(s), {info['hits']} cache hits, "
              f"{info['panel_builds']} decode panels, "
              f"{cm.executable_cache_size()} jit specialisations")
        return lat


if __name__ == "__main__":
    main()
