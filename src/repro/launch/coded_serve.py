"""Serving driver for the coded-matmul runtime: a request loop over one
``CodedMatmul`` facade, with erasure patterns changing per request.

This is the launch-layer face of the ROADMAP serving story: a resident
facade absorbs worker loss as DATA (no recompiles, no restarts) while the
executable memo keeps per-request latency at the warm-call floor.  Single
host by default; ``--backend mesh`` runs one worker per device (spawn with
XLA_FLAGS=--xla_force_host_platform_device_count=8 off-TPU).

``--adaptive`` swaps the single fixed plan for the control plane
(``repro.control``): a ``PlanLadder`` over the paper's bec <-> tradeoff <->
polycode family, a ``WorkerHealthMonitor`` fed with (simulated) per-worker
step times, and a latency policy that switches rungs and emits the erasure
mask — recompile-free after ``prewarm()``.  ``--policy quantile`` (or
``--slo-quantile``) ranks rungs by tail completion instead of the mean;
``--slo-ms`` adds the violation fallback that forces a switch to the
tail-optimal rung whenever the active rung's predicted quantile blows the
bound.  ``--batch`` serves vmap-batched requests of VARYING size through
prewarmed leading-dim buckets (round-up padding, zero recompiles).
``--sub-tasks Q`` turns on partial-straggler decoding: each worker's block
splits into Q ordered sub-tasks and the monitor's progress plan consumes
completed chunk prefixes from flagged stragglers instead of erasing them;
``--monitor-threshold`` sets the flagging score (the base of the adaptive
threshold law when ``--feedback`` is on).  ``--adaptive`` composes with
``--backend mesh`` (including ``--sub-tasks``): the ladder's facades run
the shard_map pipeline with progress strictly as data, so rung switches
and progress changes stay recompile-free on the mesh too.

Fault injection rides on ``repro.chaos``: ``--scenario NAME`` feeds the
loop from any registered straggler regime (deterministic under ``--seed``)
instead of the built-in resampled-straggler feed; ``--feedback`` turns on
the observed-violation controller (requires ``--slo-ms``), which
tightens/loosens the prediction quantile from realized SLO misses;
``--record PATH`` captures the run (times, decisions, and the server
config) as a JSONL trace; ``--replay PATH`` re-serves the recorded times
verbatim — decisions reproduce bit-deterministically when the server
flags match the recording, and a config drift prints a warning.

``--elastic`` (with ``--adaptive``) serves on an ELASTIC pool: the feed
emits times for a fixed worker universe, the server starts its pool
without the ``pool_resize`` scenario's arriving set, the departures
exhaust the polycode-only ladder's slack and trigger the EXECUTED shrink
handoff (the ladder re-lowers its rungs onto the survivors, on the same
executable cache), and at the scenario's join step the arrivals are
admitted onto incrementally extended Vandermonde points — the surviving
pool's executables and decode panels are reused, not recompiled.

``--serve-tier`` lifts the loop into the async multi-tenant tier
(``repro.serve``): per-tenant token-bucket admission and bounded queues,
continuous batching into the prewarmed buckets, per-SLO-class adaptive
servers with earliest-deadline-first dispatch, and a two-stage pipeline
overlapping decode of step t with the workers of step t+1 — all on a
seeded simulated clock.  ``--tenant-spec`` takes the spec as inline JSON
or ``@path/to/spec.json`` (default: the built-in three-tenant example);
``--requests`` becomes per-tenant; ``--record`` saves a replayable serve
trace; ``--no-pipeline`` serialises the stages for A/B comparison.

``--metrics-out PATH`` / ``--perfetto-out PATH`` enable the observability
layer (``repro.obs``) for the run and write its Prometheus text dump and
Chrome-trace/Perfetto span JSON (serve tier: one track per SLO class, so
the decode-of-batch-t-overlaps-workers-of-batch-t+1 pipeline is visible
on the timeline).  Render a terminal summary with
``python -m repro.obs.report --metrics PATH [--perfetto PATH]``.

Usage:
  PYTHONPATH=src python -m repro.launch.coded_serve --backend fused \
      --requests 12 --size 256 --fail-rate 0.3
  PYTHONPATH=src python -m repro.launch.coded_serve --adaptive \
      --requests 16 --size 64 --fail-rate 0.25 --batch 8 \
      --slo-quantile 0.99 --slo-ms 1800
  PYTHONPATH=src python -m repro.launch.coded_serve --adaptive \
      --scenario pareto --feedback --slo-ms 12000 --requests 32 \
      --record /tmp/pareto.jsonl
  PYTHONPATH=src python -m repro.launch.coded_serve --serve-tier \
      --scenario heavy_tail --requests 12 --seed 11 \
      --record /tmp/serve.jsonl
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax


def _oracle(A, B) -> np.ndarray:
    """Uncoded C = A^T B, batched or not (exact for integer inputs)."""
    from repro.core import uncoded_matmul

    return np.asarray(uncoded_matmul(A, B))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="fused",
                    choices=["reference", "staged", "fused", "mesh"])
    ap.add_argument("--adaptive", action="store_true",
                    help="serve through the control plane (PlanLadder + "
                         "monitor + expected-latency policy)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--size", type=int, default=256,
                    help="contraction dim v (r = t = v/2)")
    ap.add_argument("--batch", type=int, default=0,
                    help="leading batch dim per request (0 = unbatched)")
    ap.add_argument("--fail-rate", type=float, default=0.25,
                    help="per-request probability a worker is erased "
                         "(adaptive: fraction of persistently slow workers)")
    ap.add_argument("--policy", default=None, choices=["mean", "quantile"],
                    help="adaptive rung ranking: mean completion or the "
                         "--slo-quantile tail (default mean)")
    ap.add_argument("--slo-quantile", type=float, default=None,
                    help="tail quantile the SLO is stated at, e.g. 0.99; "
                         "implies --policy quantile unless --policy mean "
                         "is explicit")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="SLO bound on modelled step completion (ms); a "
                         "predicted violation forces a switch to the "
                         "tail-optimal rung")
    ap.add_argument("--scenario", default=None,
                    help="feed the adaptive loop from a registered "
                         "repro.chaos scenario (see chaos.scenario_names) "
                         "instead of the built-in straggler feed")
    ap.add_argument("--feedback", action="store_true",
                    help="observed-violation feedback: tighten/loosen the "
                         "prediction quantile from realized SLO misses "
                         "(adaptive only; requires --slo-ms)")
    ap.add_argument("--sub-tasks", type=int, default=1,
                    help="split each worker's block into Q ordered sub-tasks "
                         "(adaptive only): the decoder consumes completed "
                         "chunk prefixes from flagged stragglers instead of "
                         "erasing them outright (1 = legacy binary masking)")
    ap.add_argument("--monitor-threshold", type=float, default=0.5,
                    help="straggler-score threshold the monitor flags at; "
                         "with --feedback it becomes the BASE of the "
                         "adaptive threshold law")
    ap.add_argument("--elastic", action="store_true",
                    help="adaptive only: serve on an elastic pool driven "
                         "by the pool_resize scenario — departures trigger "
                         "the executed shrink handoff, arrivals join on "
                         "extended evaluation points")
    ap.add_argument("--serve-tier", action="store_true",
                    help="serve through the async multi-tenant tier "
                         "(admission control + continuous batching + "
                         "per-class SLOs + pipelined stages); --requests "
                         "becomes per-tenant")
    ap.add_argument("--tenant-spec", default=None, metavar="SPEC",
                    help="tenant/class spec for --serve-tier: inline JSON "
                         "or @path/to/spec.json (default: the built-in "
                         "three-tenant example)")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="serve-tier batch ceiling (0 = the largest "
                         "prewarmed bucket)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="serve-tier: serialise worker and decode stages "
                         "instead of overlapping them (A/B baseline)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable observability and write the run's metrics "
                         "as Prometheus text to PATH (see repro.obs)")
    ap.add_argument("--perfetto-out", default=None, metavar="PATH",
                    help="enable observability and write the run's spans "
                         "as Chrome-trace/Perfetto JSON to PATH (serve "
                         "tier: one track per SLO class)")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="record the adaptive run as a JSONL trace")
    ap.add_argument("--replay", default=None, metavar="PATH",
                    help="replay a recorded JSONL trace as the time feed "
                         "(bit-deterministic against the recording)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.feedback and args.slo_ms is None:
        ap.error("--feedback requires --slo-ms (the bound realized "
                 "latencies are judged by)")
    if args.scenario and args.replay:
        ap.error("--scenario and --replay are mutually exclusive feeds")
    if args.sub_tasks < 1:
        ap.error(f"--sub-tasks must be >= 1, got {args.sub_tasks}")
    if not 0.0 < args.monitor_threshold <= 1.0:
        ap.error(f"--monitor-threshold must be in (0, 1], got "
                 f"{args.monitor_threshold}")
    if args.serve_tier:
        if args.adaptive:
            ap.error("--serve-tier already runs the control plane; drop "
                     "--adaptive")
        if args.replay or args.feedback or args.slo_ms is not None:
            ap.error("--serve-tier takes SLOs and feedback from the tenant "
                     "spec, not --slo-ms/--feedback, and does not replay "
                     "adaptive traces")
        return _with_obs(run_serve_tier, args)
    if args.tenant_spec or args.no_pipeline or args.max_batch:
        ap.error("--tenant-spec/--no-pipeline/--max-batch need --serve-tier")
    if args.elastic:
        if not args.adaptive:
            ap.error("--elastic needs --adaptive (the handoff is driven by "
                     "the control plane)")
        if args.replay or args.feedback or args.slo_ms is not None \
                or args.sub_tasks != 1:
            ap.error("--elastic does not combine with --replay/--feedback/"
                     "--slo-ms/--sub-tasks")
        if args.scenario not in (None, "pool_resize"):
            ap.error("--elastic is driven by the pool_resize scenario; drop "
                     f"--scenario {args.scenario}")
        return _with_obs(run_elastic, args)
    if args.adaptive:
        return _with_obs(run_adaptive, args)
    if args.scenario or args.feedback or args.record or args.replay:
        ap.error("--scenario/--feedback/--record/--replay need --adaptive")
    if args.sub_tasks != 1:
        ap.error("--sub-tasks needs --adaptive (partial-straggler decoding "
                 "is driven by the monitor's progress plans)")
    return _with_obs(run_static, args)


def _with_obs(runner, args):
    """Run ``runner`` with observability on when an export flag asks.

    ``--metrics-out``/``--perfetto-out`` enable a FRESH obs session (so
    the dumps cover exactly this run), then write the Prometheus text
    and/or Chrome-trace JSON after the runner returns.  Without either
    flag the runner executes with observability untouched (off unless
    REPRO_OBS enabled it), keeping the default path zero-overhead.
    """
    if not (args.metrics_out or args.perfetto_out):
        return runner(args)
    from repro import obs
    from repro.obs.export import write_perfetto, write_prometheus

    obs.enable(fresh=True)
    result = runner(args)
    if args.metrics_out:
        write_prometheus(args.metrics_out, obs.session().registry)
        print(f"metrics -> {args.metrics_out}")
    if args.perfetto_out:
        write_perfetto(args.perfetto_out, obs.session().recorder.spans)
        print(f"perfetto trace -> {args.perfetto_out}")
    return result


def run_static(args):
    from repro.core import make_plan
    from repro.core.numerics import enable_x64
    from repro.runtime import CodedMatmul

    with enable_x64():
        import jax.numpy as jnp

        rng = np.random.default_rng(args.seed)
        v, r, t = args.size, args.size // 2, args.size // 2
        plan = make_plan("bec", 2, 2, 1, K=4, L=v * 4 * 4 + 1,
                         points="chebyshev")
        mesh = None
        if args.backend == "mesh":
            n_dev = len(jax.devices())
            if n_dev % plan.K:
                raise SystemExit(
                    f"--backend mesh needs a multiple of K={plan.K} devices, "
                    f"have {n_dev}")
            mesh = jax.make_mesh((n_dev // plan.K, plan.K), ("data", "model"))
        cm = CodedMatmul(plan, args.backend, mesh=mesh, dtype=jnp.float64)

        def request():
            shape = (args.batch,) if args.batch else ()
            A = jnp.asarray(rng.integers(-4, 5, size=shape + (v, r)),
                            jnp.float64)
            B = jnp.asarray(rng.integers(-4, 5, size=(v, t)), jnp.float64)
            # any worker can fail; keep at most K - tau failures decodable
            candidates = rng.permutation(plan.K)[: plan.K - plan.tau]
            erased = sorted(int(k) for k in candidates
                            if rng.random() < args.fail_rate)
            return A, B, erased

        print(f"backend={args.backend} K={plan.K} tau={plan.tau} "
              f"v={v} r={r} t={t} batch={args.batch or 'none'}")
        lat = []
        for i in range(args.requests):
            A, B, erased = request()
            t0 = time.perf_counter()
            C = cm(A, B, erased=erased)
            jax.block_until_ready(C)
            ms = (time.perf_counter() - t0) * 1e3
            lat.append(ms)
            exact = bool(np.array_equal(np.asarray(C), _oracle(A, B)))
            print(f"req {i:02d}: erased={str(erased) if erased else '[]':<8} "
                  f"{ms:8.1f} ms  {'exact' if exact else 'CHECK FAILED'}")
        info = cm.cache_info()
        print(f"cold {lat[0]:.1f} ms -> warm p50 {np.median(lat[1:]):.1f} ms; "
              f"{info['builds']} executable(s), {info['hits']} cache hits, "
              f"{info['panel_builds']} decode panels, "
              f"{cm.executable_cache_size()} jit specialisations")
        return lat


def run_adaptive(args):
    from repro.control import AdaptiveServer, ExpectedLatencyPolicy, PlanLadder
    from repro.core import conservative_L
    from repro.core.numerics import enable_x64
    from repro.core.simulator import LatencyModel

    with enable_x64():
        import jax.numpy as jnp

        rng = np.random.default_rng(args.seed)
        p, m, n, K = 4, 2, 1, 12
        v = max(args.size - args.size % p, p)
        r, t = (v // 2) - (v // 2) % m, (v // 2) - (v // 2) % n
        backend = args.backend
        mesh = None
        if backend == "mesh":
            import jax

            n_dev = len(jax.devices())
            if n_dev % K:
                raise SystemExit(
                    f"--backend mesh needs a multiple of K={K} devices, "
                    f"have {n_dev}")
            mesh = jax.make_mesh((n_dev // K, K), ("data", "model"))
        ladder = PlanLadder(p, m, n, K=K, L=conservative_L(v, 4, 4),
                            backend=backend, mesh=mesh)
        # batched requests vary in size: prewarm power-of-two buckets so
        # round-up padding keeps every size recompile-free.
        buckets = ()
        if args.batch:
            top = 1 << (args.batch - 1).bit_length()
            buckets = tuple(1 << i for i in range(top.bit_length()))
        info = ladder.prewarm((v, r), (v, t), batch_sizes=buckets,
                              sub_tasks=args.sub_tasks)
        builds_at_prewarm = info["builds"]
        print(f"adaptive ladder rungs={ladder.rungs} "
              f"taus={[ladder.tau(x) for x in ladder.rungs]} K={K} "
              f"v={v} r={r} t={t} buckets={buckets or 'none'} "
              f"sub_tasks={args.sub_tasks}; "
              f"prewarm: {builds_at_prewarm} executables, overheads "
              f"{ {k: round(1e3 * s, 2) for k, s in info['overhead_s'].items()} } ms")

        requests = args.requests
        # resolve the EFFECTIVE policy/SLO knobs up front: the recorded
        # config (and the replay drift check) must compare what the server
        # actually runs with, not raw CLI defaults.
        policy_name = args.policy or (
            "quantile" if args.slo_quantile is not None else "mean")
        slo_quantile = args.slo_quantile
        if slo_quantile is None and (policy_name == "quantile"
                                     or args.slo_ms is not None):
            slo_quantile = 0.99
        slo_s = args.slo_ms / 1e3 if args.slo_ms is not None else None
        server_config = {"policy": policy_name, "slo_quantile": slo_quantile,
                         "slo_ms": args.slo_ms, "feedback": args.feedback,
                         "backend": backend, "size": args.size,
                         "batch": args.batch, "seed": args.seed,
                         "sub_tasks": args.sub_tasks,
                         "monitor_threshold": args.monitor_threshold}
        if args.replay:
            from repro.chaos import Trace

            trace = Trace.load(args.replay)
            if trace.K != K:
                raise SystemExit(f"trace recorded K={trace.K}, ladder has "
                                 f"K={K}")
            feed = trace.feed()
            requests = min(requests, len(trace.steps))
            print(f"replaying {args.replay}: {len(trace.steps)} recorded "
                  f"steps (meta {trace.meta})")
            # replayed TIMES are always verbatim, but rung decisions only
            # reproduce under the recorded server config — say so instead
            # of letting a silently different config masquerade as replay.
            recorded = trace.meta.get("config")
            if recorded is not None:
                drift = {k: (recorded[k], server_config.get(k))
                         for k in recorded if server_config.get(k) != recorded[k]}
                if drift:
                    print("WARNING: server config differs from the recording "
                          f"(decisions will not reproduce): {drift}")
        elif args.scenario:
            from repro.chaos import make_scenario, scenario_names

            if args.scenario not in scenario_names():
                raise SystemExit(f"unknown scenario {args.scenario!r}; "
                                 f"have {scenario_names()}")
            feed = make_scenario(args.scenario).compile(K, seed=args.seed)
            print(f"scenario={args.scenario} (seed {args.seed})")
        else:
            # persistent straggler set (resampled every 6 requests): 2x
            # slowdown plus a heavy exponential tail on the slow machines
            n_slow = int(round(args.fail_rate * K))
            state = {"slow": rng.choice(K, size=n_slow, replace=False)}
            base = np.ones(K)
            jitter = np.full(K, 0.02)

            def feed(step, feed_rng):
                if step and step % 6 == 0:
                    state["slow"] = feed_rng.choice(K, size=n_slow,
                                                    replace=False)
                jit = jitter.copy()
                jit[state["slow"]] = 0.5
                model = LatencyModel(base=base, straggler_slowdown=2.0,
                                     jitter=jit)
                return model.sample(K, state["slow"], feed_rng)

        recorder = None
        if args.record:
            from repro.chaos import TraceRecorder

            recorder = TraceRecorder(
                feed, K, meta={"scenario": args.scenario, "seed": args.seed,
                               "source": "coded_serve",
                               "config": server_config})
            feed = recorder

        def make_request(i):
            shape = ()
            if args.batch:
                shape = (int(rng.integers(1, args.batch + 1)),)
            A = jnp.asarray(rng.integers(-4, 5, size=shape + (v, r)),
                            jnp.float64)
            B = jnp.asarray(rng.integers(-4, 5, size=(v, t)), jnp.float64)
            return A, B

        policy = None
        if policy_name == "mean":
            policy = ExpectedLatencyPolicy(
                ladder, score_threshold=args.monitor_threshold,
                sub_tasks=args.sub_tasks)
        print(f"policy={policy_name}"
              + (f" slo: q{slo_quantile} <= {args.slo_ms} ms"
                 if slo_s is not None else "")
              + (" feedback=on" if args.feedback else "")
              + (f" sub_tasks={args.sub_tasks}" if args.sub_tasks > 1 else "")
              + (f" threshold={args.monitor_threshold}"
                 if args.monitor_threshold != 0.5 else ""))
        server = AdaptiveServer(ladder, policy=policy, feed=feed,
                                seed=args.seed, check_exact=True,
                                score_threshold=args.monitor_threshold,
                                slo_quantile=slo_quantile, slo_s=slo_s,
                                feedback=args.feedback,
                                sub_tasks=args.sub_tasks)
        for rep in server.run(requests, make_request):
            flag = " SWITCH" if rep.switched else ""
            if rep.slo_violation:
                flag += " SLO-FALLBACK"
            if rep.realized_violation:
                flag += " REALIZED-MISS"
            tail = (f"  q-tail {rep.predicted_tail_s:6.3f} s"
                    if rep.predicted_tail_s is not None else "")
            q_eff = (f"  q_eff {rep.q_effective:.3f}"
                     if rep.q_effective is not None else "")
            partial = ""
            if rep.progress is not None:
                # show only the workers consumed at a fraction (< 1 chunk
                # budget); full workers are the quiet common case.
                frac = {k: round(x, 2) for k, x in enumerate(rep.progress)
                        if x < 1.0}
                partial = f"  partial={frac if frac else '{}'}"
            thr_eff = (f"  thr_eff {rep.threshold_effective:.3f}"
                       if rep.threshold_effective is not None else "")
            print(f"req {rep.step:02d}: rung={rep.rung:<15} "
                  f"erased={str(list(rep.erased)):<12} "
                  f"sim {rep.sim_latency_s:6.3f} s  wall {rep.wall_ms:7.1f} ms"
                  f"{tail}{q_eff}{partial}{thr_eff}  slack={rep.slack}  "
                  f"{'exact' if rep.exact else 'CHECK FAILED'}{flag}")
        info = ladder.cache_info()
        assert info["builds"] == builds_at_prewarm, (
            f"recompile after prewarm: {info}")
        print(f"{info['builds']} executables (unchanged since prewarm), "
              f"{info['hits']} cache hits, {info['panel_builds']} decode "
              f"panels, {info['switches']} rung switches")
        if server.feedback is not None:
            fb = server.feedback
            print(f"feedback: {fb.violations}/{fb.observations} realized "
                  f"violations, window rate {fb.realized_rate:.3f}, "
                  f"q_eff {fb.effective_q():.3f}")
        if recorder is not None:
            out = recorder.finish(server.reports).save(args.record)
            print(f"recorded trace -> {out}")
        return server.reports


def run_elastic(args):
    """Adaptive serving on an elastic pool: executed shrink, then grow.

    Mirrors the golden ``pool_resize_shrink``/``pool_resize_grow`` recipe:
    a polycode-only ladder (narrow erasure budget, so the departures
    exceed slack and force the handoff) on the (3, 2, 1) grid, a worker
    universe of 12 with the scenario's arriving set initially absent, and
    a grow at 3/4 of the run readmitting them on extended points.
    """
    from repro.chaos import make_scenario
    from repro.control import AdaptiveServer, ExpectedLatencyPolicy, PlanLadder
    from repro.core import conservative_L
    from repro.core.numerics import enable_x64

    with enable_x64():
        import jax.numpy as jnp

        rng = np.random.default_rng(args.seed)
        universe = 12
        join_step = (3 * args.requests) // 4 if args.requests >= 8 else None
        scenario = make_scenario("pool_resize", num_departing=3,
                                 depart_step=4, num_arriving=2,
                                 join_step=join_step)
        arriving = scenario.arriving_ids(universe, args.seed)
        absent = {int(i) for i in arriving}
        pool = [i for i in range(universe) if i not in absent]
        feed = scenario.compile(universe, seed=args.seed)

        p, m, n = 3, 2, 1
        v = max(args.size - args.size % p, p)
        r, t = (v // 2) - (v // 2) % m, v // 2
        backend = args.backend
        if backend == "mesh":
            print("--elastic does not drive the mesh backend yet; "
                  "falling back to the reference executor")
            backend = "reference"
        ladder = PlanLadder(p, m, n, K=len(pool), L=conservative_L(v, 4, 4),
                            backend=backend, include=["polycode"])
        info = ladder.prewarm((v, r), (v, t))
        builds_marker = info["builds"]
        print(f"elastic universe={universe} pool={pool} "
              f"(arriving {sorted(absent)} absent) rungs={ladder.rungs} "
              f"grid=({p},{m},{n}) v={v} r={r} t={t}; "
              f"prewarm: {builds_marker} executables")

        recorder = None
        if args.record:
            from repro.chaos import TraceRecorder

            recorder = TraceRecorder(
                feed, universe,
                meta={"scenario": "pool_resize", "seed": args.seed,
                      "source": "coded_serve", "elastic": True,
                      "universe": universe, "join_step": join_step})
            feed = recorder

        policy = ExpectedLatencyPolicy(
            ladder, score_threshold=args.monitor_threshold)
        server = AdaptiveServer(ladder, policy=policy, feed=feed,
                                seed=args.seed, check_exact=True,
                                score_threshold=args.monitor_threshold,
                                universe=universe, pool=pool)

        def make_request():
            A = jnp.asarray(rng.integers(-4, 5, size=(v, r)), jnp.float64)
            B = jnp.asarray(rng.integers(-4, 5, size=(v, t)), jnp.float64)
            return A, B

        pool_before = tuple(int(x) for x in server.pool)
        for i in range(args.requests):
            if join_step is not None and i == join_step:
                server.grow(arriving)
                builds = ladder.cache_info()["builds"]
                print(f"-- grow at step {i}: admitted {sorted(absent)} on "
                      f"extended points; pool -> "
                      f"{[int(x) for x in server.pool]} "
                      f"({builds - builds_marker} new executables, old pool's"
                      f" reused)")
                builds_marker = builds
                pool_before = tuple(int(x) for x in server.pool)
            A, B = make_request()
            _, rep = server.step(A, B)
            now = tuple(int(x) for x in server.pool)
            if now != pool_before:
                builds = ladder.cache_info()["builds"]
                print(f"-- shrink handoff at step {i}: pool "
                      f"{list(pool_before)} -> {list(now)}; re-lowered onto "
                      f"{rep.rung} ({builds - builds_marker} new "
                      f"executables, survivors' reused)")
                builds_marker = builds
                pool_before = now
            print(f"req {rep.step:02d}: pool={len(now):2d} "
                  f"rung={rep.rung:<10} erased={str(list(rep.erased)):<10} "
                  f"sim {rep.sim_latency_s:6.3f} s  "
                  f"wall {rep.wall_ms:7.1f} ms  slack={rep.slack}  "
                  f"{'exact' if rep.exact else 'CHECK FAILED'}"
                  f"{' RESPECIALIZED' if rep.respecialize else ''}")
        info = ladder.cache_info()
        assert info["builds"] == builds_marker, (
            f"recompile outside a pool transition: {info}")
        print(f"{info['builds']} executables ({builds_marker} after the "
              f"last transition — zero steady-state recompiles), "
              f"{info['hits']} cache hits, {info['panel_builds']} decode "
              f"panels, {info['switches']} rung switches")
        if recorder is not None:
            out = recorder.finish(server.reports).save(args.record)
            print(f"recorded trace -> {out}")
        return server.reports


def run_serve_tier(args):
    from repro.control import PlanLadder
    from repro.core import conservative_L
    from repro.core.numerics import enable_x64
    from repro.serve import DEFAULT_SPEC, ServeTier, ServeTrace, \
        parse_tenant_spec

    with enable_x64():
        import jax.numpy as jnp

        spec = DEFAULT_SPEC
        if args.tenant_spec:
            spec = args.tenant_spec
            if spec.startswith("@"):
                from pathlib import Path

                spec = Path(spec[1:]).read_text()
        classes, tenants = parse_tenant_spec(spec)

        p, m, n, K = 4, 2, 1, 12
        v = max(args.size - args.size % p, p)
        r, t = (v // 2) - (v // 2) % m, (v // 2) - (v // 2) % n
        backend = args.backend
        if backend == "mesh":
            print("--serve-tier does not drive the mesh backend (the split "
                  "worker/decode stages run fused on mesh); falling back to "
                  "the reference executor")
            backend = "reference"
        ladder = PlanLadder(p, m, n, K=K, L=conservative_L(v, 4, 4),
                            backend=backend)
        top = args.max_batch or 8
        buckets = tuple(1 << i for i in range((top - 1).bit_length() + 1))
        split = args.sub_tasks == 1
        info = ladder.prewarm((v, r), (v, t), batch_sizes=buckets,
                              sub_tasks=args.sub_tasks, stages=split)
        builds_at_prewarm = info["builds"]

        feed = None
        if args.scenario:
            from repro.chaos import make_scenario, scenario_names

            if args.scenario not in scenario_names():
                raise SystemExit(f"unknown scenario {args.scenario!r}; "
                                 f"have {scenario_names()}")
            feed = make_scenario(args.scenario).compile(K, seed=args.seed)

        tier = ServeTier(
            ladder, classes=tuple(classes.values()),
            tenants=tuple(tenants.values()), feed=feed,
            seed=args.seed, score_threshold=args.monitor_threshold,
            sub_tasks=args.sub_tasks, check_exact=True,
            pipelined=not args.no_pipeline)
        print(f"serve tier: rungs={ladder.rungs} K={K} v={v} r={r} t={t} "
              f"buckets={buckets} pipelined={not args.no_pipeline} "
              f"split_stages={tier.split_stages} "
              f"tenants={sorted(tenants)} classes={sorted(classes)}; "
              f"scenario={args.scenario or 'constant'} seed={args.seed}; "
              f"prewarm: {builds_at_prewarm} executables")

        rng = np.random.default_rng(args.seed)
        payload = rng.integers(-4, 5, size=(len(tenants) * 64, v, r))

        def make_A(request):
            return jnp.asarray(payload[request.rid % len(payload)],
                               jnp.float64)

        B = jnp.asarray(rng.integers(-4, 5, size=(v, t)), jnp.float64)
        result = tier.run(make_A, B, args.requests)

        stats = result.tenant_stats()
        print(f"{'tenant':<10} {'class':<10} {'gen':>4} {'adm':>4} "
              f"{'shed':>4} {'p50 s':>8} {'p_slo s':>8} {'slo s':>7} "
              f"{'viol':>5}  met")
        for name, st in stats.items():
            print(f"{name:<10} {st['slo_class']:<10} {st['generated']:>4} "
                  f"{st['admitted']:>4} {st['shed']:>4} "
                  f"{st['p50_s'] if st['p50_s'] is None else round(st['p50_s'], 3)!s:>8} "
                  f"{st['p_slo_s'] if st['p_slo_s'] is None else round(st['p_slo_s'], 3)!s:>8} "
                  f"{st['slo_s']:>7} {st['violations']:>5}  "
                  f"{'yes' if st['slo_met'] else 'NO'}"
                  + (f"  shed_reasons={st['shed_reasons']}"
                     if st['shed_reasons'] else ""))
        cache = ladder.cache_info()
        assert cache["builds"] == builds_at_prewarm, (
            f"recompile after prewarm: {cache}")
        print(f"{len(result.admitted)}/{len(result.requests)} admitted, "
              f"{len(result.shed)} shed, {len(result.batches)} batches, "
              f"sustained {result.throughput_rps():.3f} req/s (simulated); "
              f"{cache['builds']} executables (unchanged since prewarm)")
        if args.record:
            out = ServeTrace.from_result(result).save(args.record)
            print(f"recorded serve trace -> {out}")
        return result


if __name__ == "__main__":
    main()
