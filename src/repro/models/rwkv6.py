"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

The WKV recurrence per head (state S is a (dk, dv) matrix):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t            (w_t in (0,1), data-dep.)
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
Chunked evaluation: within a chunk, (decay, update) pairs run through
jax.lax.associative_scan (decays <= 1: no overflow), chunks chained by
lax.scan - same machinery as the Mamba block, state (B, H, dk, dv).

Faithfulness notes (DESIGN.md Sec. 7): the decay w_t is data-dependent
through a LoRA (the RWKV-6 hallmark); the five token-shift lerp factors are
learned per-channel constants rather than the paper's second LoRA stack - a
documented simplification that does not change the kernel structure.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import shard
from repro.distributed.sharding import shard_map_compat

__all__ = ["init_rwkv_tmix", "rwkv_tmix_shapes", "rwkv_tmix_forward",
           "init_rwkv_cmix", "rwkv_cmix_shapes", "rwkv_cmix_forward",
           "rwkv_state_shapes"]

LORA_RANK = 64


def _heads(d_model: int, head_dim: int, tp: int = 1) -> int:
    """Head count padded up so the tp axis divides it."""
    h = d_model // head_dim
    return int(math.ceil(h / tp) * tp)


def init_rwkv_tmix(key, d_model: int, *, head_dim: int = 64, tp_pad: int = 1,
                   dtype=jnp.bfloat16):
    H = _heads(d_model, head_dim, tp_pad)
    d_attn = H * head_dim  # >= d_model when padded
    ks = jax.random.split(key, 10)
    sc = 1.0 / math.sqrt(d_model)
    return {
        "mu": jnp.full((5, d_model), 0.5, dtype),  # shift-lerp for w,k,v,r,g
        "w_r": jax.random.normal(ks[0], (d_model, d_attn), dtype) * sc,
        "w_k": jax.random.normal(ks[1], (d_model, d_attn), dtype) * sc,
        "w_v": jax.random.normal(ks[2], (d_model, d_attn), dtype) * sc,
        "w_g": jax.random.normal(ks[3], (d_model, d_attn), dtype) * sc,
        "w_o": jax.random.normal(ks[4], (d_attn, d_model), dtype)
                * (1.0 / math.sqrt(d_attn)),
        "w_decay_base": jnp.full((d_attn,), -6.0, jnp.float32),
        "w_decay_a": jax.random.normal(ks[5], (d_model, LORA_RANK), dtype) * sc,
        "w_decay_b": jax.random.normal(ks[6], (LORA_RANK, d_attn), dtype)
                      * (1.0 / math.sqrt(LORA_RANK)),
        "u": jnp.zeros((H, head_dim), jnp.float32),  # bonus
        "ln_scale": jnp.ones((d_attn,), jnp.float32),  # group-norm over heads
    }


def rwkv_tmix_shapes(d_model: int, *, head_dim: int = 64, tp_pad: int = 1,
                     dtype=jnp.bfloat16):
    H = _heads(d_model, head_dim, tp_pad)
    d_attn = H * head_dim
    return {
        "mu": jax.ShapeDtypeStruct((5, d_model), dtype),
        "w_r": jax.ShapeDtypeStruct((d_model, d_attn), dtype),
        "w_k": jax.ShapeDtypeStruct((d_model, d_attn), dtype),
        "w_v": jax.ShapeDtypeStruct((d_model, d_attn), dtype),
        "w_g": jax.ShapeDtypeStruct((d_model, d_attn), dtype),
        "w_o": jax.ShapeDtypeStruct((d_attn, d_model), dtype),
        "w_decay_base": jax.ShapeDtypeStruct((d_attn,), jnp.float32),
        "w_decay_a": jax.ShapeDtypeStruct((d_model, LORA_RANK), dtype),
        "w_decay_b": jax.ShapeDtypeStruct((LORA_RANK, d_attn), dtype),
        "u": jax.ShapeDtypeStruct((H, head_dim), jnp.float32),
        "ln_scale": jax.ShapeDtypeStruct((d_attn,), jnp.float32),
    }


def rwkv_state_shapes(B: int, d_model: int, *, head_dim: int = 64,
                      tp_pad: int = 1):
    H = _heads(d_model, head_dim, tp_pad)
    return {
        "shift_t": jax.ShapeDtypeStruct((B, d_model), jnp.bfloat16),
        "shift_c": jax.ShapeDtypeStruct((B, d_model), jnp.bfloat16),
        "wkv": jax.ShapeDtypeStruct((B, H, head_dim, head_dim), jnp.float32),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]):
    """x (B, S, d) -> x shifted right one step; ``prev`` is the last token of
    the previous segment (decode/prefill chaining)."""
    if prev is None:
        first = jnp.zeros_like(x[:, :1])
    else:
        first = prev[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


# ---------------------------------------------------------------------------
# Fused Pallas WKV with custom VJP (beyond-paper; kernels/wkv_scan.py).
# Forward = VMEM-resident kernel; backward = sequential reverse chunk scan
# from the kernel's chunk-entry checkpoints.


@jax.custom_vjp
def wkv_fused(w, k, v, r, u):
    """w/k/r: (B,S,H,dk), v: (B,S,H,dv), u: (H,dk) -> (y, S_fin).
    Zero initial state (train/prefill)."""
    from repro.kernels.wkv_scan import wkv_scan_pallas
    y, s_fin, _ = wkv_scan_pallas(w, k, v, r, u,
                                  interpret=jax.default_backend() != "tpu")
    return y, s_fin


def _wkv_fwd(w, k, v, r, u):
    from repro.kernels.wkv_scan import wkv_scan_pallas
    y, s_fin, s_bounds = wkv_scan_pallas(
        w, k, v, r, u, interpret=jax.default_backend() != "tpu")
    return (y, s_fin), (w, k, v, r, u, s_bounds)


def _wkv_bwd(res, cot):
    w, k, v, r, u, s_bounds = res
    y_bar, sfin_bar = cot
    B, S, H, dk = k.shape
    dv = v.shape[-1]
    nc = s_bounds.shape[1]
    c = S // nc

    def chunked(t):
        return t.reshape(B, nc, c, H, -1).swapaxes(0, 1)  # (nc,B,c,H,*)

    w_c, k_c, v_c, r_c, yb_c = map(chunked, (w, k, v, r, y_bar))
    s0_c = s_bounds.swapaxes(0, 1)                        # (nc,B,H,dk,dv)

    def combine(l, rr):
        al, bl = l
        ar, br = rr
        return al * ar, bl * ar + br

    def chunk_bwd(gbar, inp):
        w_i, k_i, v_i, r_i, yb_i, s0 = inp                # (B,c,H,*)
        a = w_i[..., None]                                # (B,c,H,dk,1)
        b = k_i[..., None] * v_i[..., None, :]            # (B,c,H,dk,dv)
        A_cum, B_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        ones = jnp.ones_like(A_cum[:, :1])
        zeros = jnp.zeros_like(B_cum[:, :1])
        A_prev = jnp.concatenate([ones, A_cum[:, :-1]], axis=1)
        B_prev = jnp.concatenate([zeros, B_cum[:, :-1]], axis=1)
        S_prev = A_prev * s0[:, None] + B_prev            # state BEFORE t
        # P_bar = dL/d(effective state at t) = r_t (x) y_bar_t
        P_bar = r_i[..., None] * yb_i[..., None, :]       # (B,c,H,dk,dv)
        # G_t = dL/dS_t: e_t = P_bar_{t+1} (+ carry at t=c); a' = w_{t+1}
        e = jnp.concatenate([P_bar[:, 1:], jnp.zeros_like(P_bar[:, :1])],
                            axis=1)
        e = e.at[:, -1].add(gbar)
        a_sh = jnp.concatenate([a[:, 1:], jnp.ones_like(a[:, :1])], axis=1)
        af = jnp.flip(a_sh, axis=1)
        ef = jnp.flip(e, axis=1)
        _, Gf = jax.lax.associative_scan(combine, (af, ef), axis=1)
        G = jnp.flip(Gf, axis=1)                          # (B,c,H,dk,dv)

        b_bar = G + u[None, None, :, :, None] * P_bar
        w_bar = jnp.sum(G * S_prev, axis=-1)              # (B,c,H,dk)
        k_bar = jnp.sum(b_bar * v_i[..., None, :], axis=-1)
        v_bar = jnp.sum(b_bar * k_i[..., None], axis=-2)
        eff = S_prev + u[None, None, :, :, None] * b
        r_bar = jnp.sum(eff * yb_i[..., None, :], axis=-1)
        u_bar = jnp.sum(P_bar * b, axis=(0, 1, 4))        # (H, dk)
        gbar_prev = a[:, 0] * G[:, 0] + P_bar[:, 0]       # dL/dS0
        return gbar_prev, (w_bar, k_bar, v_bar, r_bar, u_bar)

    _, outs = jax.lax.scan(chunk_bwd, sfin_bar,
                           (w_c, k_c, v_c, r_c, yb_c, s0_c), reverse=True)
    w_bar, k_bar, v_bar, r_bar, u_bar_c = outs

    def unchunk(t):
        return t.swapaxes(0, 1).reshape(B, S, H, -1)

    return (unchunk(w_bar), unchunk(k_bar), unchunk(v_bar), unchunk(r_bar),
            u_bar_c.sum(0))


wkv_fused.defvjp(_wkv_fwd, _wkv_bwd)


def _wkv_kernel_call(w, k, v, r, u):
    """Route through the fused kernel, shard_mapped over (dp, tp-on-heads)
    when a mesh is active."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import current_rules

    rules = current_rules()
    if rules is None:
        return wkv_fused(w, k, v, r, u)
    mesh = rules.mesh
    tp = rules.physical("tp")
    dp = rules.physical("dp")
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    dpN = 1
    for a_ in dp_axes:
        dpN *= mesh.shape[a_]
    B, S, H, _ = k.shape
    tpN = mesh.shape[tp]
    b_spec = dp if B % dpN == 0 else None
    h_spec = tp if H % tpN == 0 else None
    return shard_map_compat(
        wkv_fused,
        mesh=mesh,
        in_specs=(P(b_spec, None, h_spec, None),) * 4
                 + (P(h_spec, None),),
        out_specs=(P(b_spec, None, h_spec, None),
                   P(b_spec, h_spec, None, None)),
    )(w, k, v, r, u)


def _wkv_chunked(w, k, v, r, u, S0, chunk: int):
    """w,k,r: (B, S, H, dk) f32 (w = per-step decay in (0,1)); v: (B,S,H,dv).
    Returns y (B, S, H, dv) and final state (B, H, dk, dv)."""
    B, S, H, dk = k.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk

    def reshape_c(x):
        return x.reshape(B, nc, chunk, H, -1).swapaxes(0, 1)

    w_c, k_c, v_c, r_c = map(reshape_c, (w, k, v, r))

    def combine(l, rr):
        al, bl = l
        ar, br = rr
        return al * ar, bl * ar + br

    def step(S_in, inp):
        wi, ki, vi, ri = inp  # (B, chunk, H, *)
        a = wi[..., None]                                   # (B,c,H,dk,1)
        b = ki[..., None] * vi[..., None, :]                # (B,c,H,dk,dv)
        A_cum, B_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        # State BEFORE step t: shift the inclusive scan right by one.
        ones = jnp.ones_like(A_cum[:, :1])
        zeros = jnp.zeros_like(B_cum[:, :1])
        A_prev = jnp.concatenate([ones, A_cum[:, :-1]], axis=1)
        B_prev = jnp.concatenate([zeros, B_cum[:, :-1]], axis=1)
        S_prev = A_prev * S_in[:, None] + B_prev            # (B,c,H,dk,dv)
        eff = S_prev + u[None, None, :, :, None] * b
        y = jnp.einsum("bchk,bchkv->bchv", ri, eff)
        S_out = A_cum[:, -1] * S_in + B_cum[:, -1]
        return S_out, y

    S_fin, y = jax.lax.scan(step, S0, (w_c, k_c, v_c, r_c))
    y = y.swapaxes(0, 1).reshape(B, S, H, dv)
    return y, S_fin


def rwkv_tmix_forward(params, x, *, head_dim: int = 64, chunk: int = 16,
                      state=None, return_state=False,
                      use_kernel: bool = False):
    """x (B, S, d_model) -> (B, S, d_model)."""
    x = shard(x, "dp", None, None)
    B, S, d = x.shape
    prev = None if state is None else state["shift_t"]
    xs = _token_shift(x, prev)
    mu = params["mu"]
    xw, xk, xv, xr, xg = [x + (xs - x) * mu[i][None, None] for i in range(5)]

    r = xr @ params["w_r"]
    k = xk @ params["w_k"]
    v = xv @ params["w_v"]
    g = xg @ params["w_g"]
    r, k, v, g = (shard(t, "dp", None, "tp") for t in (r, k, v, g))
    decay_raw = (params["w_decay_base"]
                 + (jnp.tanh((xw @ params["w_decay_a"]).astype(jnp.float32))
                    @ params["w_decay_b"].astype(jnp.float32)))
    w = jnp.exp(-jnp.exp(jnp.clip(decay_raw, -20.0, 8.0)))  # (B,S,d_attn)

    H = params["u"].shape[0]
    def to_heads(t):
        return t.reshape(B, S, H, head_dim)
    if use_kernel and state is None:
        # fused Pallas path (zero initial state: train / prefill)
        y, S_fin = _wkv_kernel_call(
            to_heads(w).astype(jnp.float32),
            to_heads(k).astype(jnp.float32),
            to_heads(v).astype(jnp.float32),
            to_heads(r).astype(jnp.float32),
            params["u"],
        )
    else:
        y, S_fin = _wkv_chunked(
            to_heads(w).astype(jnp.float32),
            to_heads(k).astype(jnp.float32),
            to_heads(v).astype(jnp.float32),
            to_heads(r).astype(jnp.float32),
            params["u"],
            jnp.zeros((B, H, head_dim, head_dim), jnp.float32) if state is None
            else state["wkv"],
            chunk,
        )
    y = y.reshape(B, S, H * head_dim)
    # Group-norm over heads (per-head standardisation).
    yh = y.reshape(B, S, H, head_dim)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, H * head_dim) * params["ln_scale"][None, None]
    y = (y.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype))
    out = y @ params["w_o"]
    out = shard(out, "dp", "sp", None)
    if return_state:
        return out, {"shift_t": x[:, -1].astype(jnp.bfloat16), "wkv": S_fin}
    return out


# ---------------------------------------------------------------------------
# channel mix


def init_rwkv_cmix(key, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    sc = 1.0 / math.sqrt(d_model)
    return {
        "mu": jnp.full((2, d_model), 0.5, dtype),  # for k and r
        "w_k": jax.random.normal(ks[0], (d_model, d_ff), dtype) * sc,
        "w_v": jax.random.normal(ks[1], (d_ff, d_model), dtype)
                * (1.0 / math.sqrt(d_ff)),
        "w_r": jax.random.normal(ks[2], (d_model, d_model), dtype) * sc,
    }


def rwkv_cmix_shapes(d_model: int, d_ff: int, dtype=jnp.bfloat16):
    return {
        "mu": jax.ShapeDtypeStruct((2, d_model), dtype),
        "w_k": jax.ShapeDtypeStruct((d_model, d_ff), dtype),
        "w_v": jax.ShapeDtypeStruct((d_ff, d_model), dtype),
        "w_r": jax.ShapeDtypeStruct((d_model, d_model), dtype),
    }


def rwkv_cmix_forward(params, x, *, state=None, return_state=False):
    x = shard(x, "dp", None, None)
    prev = None if state is None else state["shift_c"]
    xs = _token_shift(x, prev)
    mu = params["mu"]
    xk = x + (xs - x) * mu[0][None, None]
    xr = x + (xs - x) * mu[1][None, None]
    k = xk @ params["w_k"]
    k = shard(k, "dp", None, "tp")
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = k @ params["w_v"]
    out = jax.nn.sigmoid((xr @ params["w_r"]).astype(jnp.float32)).astype(x.dtype) * kv
    out = shard(out, "dp", "sp", None)
    if return_state:
        return out, {"shift_c": x[:, -1].astype(jnp.bfloat16)}
    return out
