"""Mamba-1 block (as used by Jamba) with a chunked associative-scan SSM.

Selective SSM recurrence per channel d and state s:
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D x_t
The sequence is processed in chunks: within a chunk the linear recurrence is
evaluated with jax.lax.associative_scan on (decay, update) pairs (numerically
safe: decay factors <= 1 are only ever multiplied, never inverted); chunks
are chained with lax.scan carrying the (d_inner, d_state) state.  Peak
memory is O(B * chunk * d_inner * d_state) instead of O(B * S * ...).

TP: d_inner is sharded over "tp"; the block sees the full sequence
(sequence-sharded residuals are gathered at entry like attention).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import shard
from repro.distributed.sharding import shard_map_compat

__all__ = ["init_mamba", "mamba_shapes", "mamba_forward", "mamba_decode_step",
           "mamba_state_shapes"]


def _dims(d_model: int, expand: int, d_state: int):
    d_inner = expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    return d_inner, dt_rank


def init_mamba(key, d_model: int, *, expand: int = 2, d_state: int = 16,
               dconv: int = 4, dtype=jnp.bfloat16):
    d_inner, dt_rank = _dims(d_model, expand, d_state)
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d_model)
    sci = 1.0 / math.sqrt(d_inner)
    # S4D-real initialisation for A.
    A = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                         (d_inner, d_state))
    return {
        "w_in": jax.random.normal(ks[0], (d_model, 2 * d_inner), dtype) * sc,
        "conv_w": jax.random.normal(ks[1], (dconv, d_inner), dtype) * (1 / math.sqrt(dconv)),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_x": jax.random.normal(ks[2], (d_inner, dt_rank + 2 * d_state), dtype) * sci,
        "w_dt": jax.random.normal(ks[3], (dt_rank, d_inner), dtype) * (1 / math.sqrt(dt_rank)),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": jax.random.normal(ks[4], (d_inner, d_model), dtype) * sci,
    }


def mamba_shapes(d_model: int, *, expand: int = 2, d_state: int = 16,
                 dconv: int = 4, dtype=jnp.bfloat16):
    d_inner, dt_rank = _dims(d_model, expand, d_state)
    return {
        "w_in": jax.ShapeDtypeStruct((d_model, 2 * d_inner), dtype),
        "conv_w": jax.ShapeDtypeStruct((dconv, d_inner), dtype),
        "conv_b": jax.ShapeDtypeStruct((d_inner,), dtype),
        "w_x": jax.ShapeDtypeStruct((d_inner, dt_rank + 2 * d_state), dtype),
        "w_dt": jax.ShapeDtypeStruct((dt_rank, d_inner), dtype),
        "dt_bias": jax.ShapeDtypeStruct((d_inner,), jnp.float32),
        "A_log": jax.ShapeDtypeStruct((d_inner, d_state), jnp.float32),
        "D": jax.ShapeDtypeStruct((d_inner,), jnp.float32),
        "w_out": jax.ShapeDtypeStruct((d_inner, d_model), dtype),
    }


def mamba_state_shapes(B: int, d_model: int, *, expand: int = 2,
                       d_state: int = 16, dconv: int = 4):
    d_inner, _ = _dims(d_model, expand, d_state)
    return {
        "conv": jax.ShapeDtypeStruct((B, dconv - 1, d_inner), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct((B, d_inner, d_state), jnp.float32),
    }


def _ssm_scan_chunked(params, dt_raw, Bm, Cm, x, h0, chunk: int):
    """End-to-end chunked selective scan - the (B, S, d_inner, d_state)
    decay/update/state tensors exist only PER CHUNK (peak memory
    O(B*chunk*d*s), not O(B*S*d*s)).

    dt_raw: (B, S, dt_rank); Bm, Cm: (B, S, d_state); x: (B, S, d_inner).
    Returns y: (B, S, d_inner) f32 and final state (B, d_inner, d_state)."""
    B, S, d_inner = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    A = -jnp.exp(params["A_log"])                             # (d, s) < 0

    def reshape_c(t):
        return t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(reshape_c, (dt_raw, Bm, Cm, x)))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, inp):
        dt_c, B_c, C_c, x_c = inp  # (B, chunk, ...)
        dt = jax.nn.softplus((dt_c @ params["w_dt"]).astype(jnp.float32)
                             + params["dt_bias"])             # (B,c,d)
        a = jnp.exp(dt[..., None] * A[None, None])            # (B,c,d,s)
        b = (dt * x_c.astype(jnp.float32))[..., None] \
            * B_c.astype(jnp.float32)[:, :, None, :]
        A_cum, B_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_chunk = A_cum * h[:, None] + B_cum
        y_c = jnp.einsum("bcdn,bcn->bcd", h_chunk, C_c.astype(jnp.float32))
        return h_chunk[:, -1], y_c

    h_final, y = jax.lax.scan(step, h0, xs)
    y = y.swapaxes(0, 1).reshape(B, S, d_inner)
    return y, h_final


# ---------------------------------------------------------------------------
# Pallas-kernel scan with custom VJP (beyond-paper optimization; see
# kernels/mamba_scan.py).  Forward = fused VMEM-resident kernel (HBM traffic
# ~= read inputs + write y); backward = sequential reverse scan over chunks
# from the kernel's chunk-boundary checkpoints (no full-forward remat).


@jax.custom_vjp
def mamba_scan_fused(dt, x, Bm, Cm, A_log, D):
    """dt/x: (B,S,d) f32, Bm/Cm: (B,S,s) f32 -> (y (B,S,d), h_fin (B,d,s))."""
    from repro.kernels.mamba_scan import mamba_scan_pallas
    y, h_fin, _ = mamba_scan_pallas(dt, x, Bm, Cm, A_log, D,
                                    interpret=jax.default_backend() != "tpu")
    return y, h_fin


def _fused_fwd(dt, x, Bm, Cm, A_log, D):
    from repro.kernels.mamba_scan import mamba_scan_pallas
    y, h_fin, h_bounds = mamba_scan_pallas(
        dt, x, Bm, Cm, A_log, D, interpret=jax.default_backend() != "tpu")
    return (y, h_fin), (dt, x, Bm, Cm, A_log, D, h_bounds)


def _fused_bwd(res, cot):
    dt, x, Bm, Cm, A_log, D, h_bounds = res
    y_bar, hfin_bar = cot
    B, S, d = dt.shape
    s = A_log.shape[1]
    nc = h_bounds.shape[1]
    c = S // nc
    A = -jnp.exp(A_log)

    def chunked(t):
        return t.reshape(B, nc, c, -1).swapaxes(0, 1)  # (nc, B, c, *)

    dt_c, x_c, B_c, C_c, yb_c = map(chunked, (dt, x, Bm, Cm, y_bar))
    h0_c = h_bounds.swapaxes(0, 1)                     # (nc, B, d, s)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_bwd(gbar, inp):
        # gbar: dL/dh at the END of this chunk (from later chunks)
        dt_i, x_i, B_i, C_i, yb_i, h0 = inp            # (B, c, ...)
        a = jnp.exp(dt_i[..., None] * A[None, None])   # (B,c,d,s)
        b = (dt_i * x_i)[..., None] * B_i[:, :, None, :]
        A_cum, B_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = A_cum * h0[:, None] + B_cum                # (B,c,d,s)
        h_prev = jnp.concatenate([h0[:, None], h[:, :-1]], axis=1)
        # local dL/dh from y, plus gbar injected at the last step
        e = yb_i[..., None] * C_i[:, :, None, :]       # (B,c,d,s)
        e = e.at[:, -1].add(gbar)
        # reverse first-order recurrence G_t = e_t + a_{t+1} * G_{t+1}
        a_shift = jnp.concatenate([a[:, 1:], jnp.ones_like(a[:, :1])], axis=1)
        af = jnp.flip(a_shift, axis=1)
        ef = jnp.flip(e, axis=1)
        _, Gf = jax.lax.associative_scan(combine, (af, ef), axis=1)
        G = jnp.flip(Gf, axis=1)                       # (B,c,d,s)

        a_bar = G * h_prev
        dt_bar = jnp.sum(a_bar * a * A[None, None], axis=-1) \
            + jnp.sum(G * B_i[:, :, None, :], axis=-1) * x_i
        x_bar = jnp.sum(G * B_i[:, :, None, :], axis=-1) * dt_i \
            + D[None, None] * yb_i
        B_bar = jnp.sum(G * (dt_i * x_i)[..., None], axis=2)
        C_bar = jnp.sum(yb_i[..., None] * h, axis=2)
        A_bar = jnp.sum(a_bar * a * dt_i[..., None], axis=(0, 1))
        gbar_prev = jnp.sum(a[:, 0:1] * G[:, 0:1], axis=1)  # a_1 * G_1
        return gbar_prev, (dt_bar, x_bar, B_bar, C_bar, A_bar)

    gbar0 = hfin_bar
    _, outs = jax.lax.scan(chunk_bwd, gbar0,
                           (dt_c, x_c, B_c, C_c, yb_c, h0_c), reverse=True)
    dt_bar, x_bar, B_bar, C_bar, A_bar_c = outs

    def unchunk(t):
        return t.swapaxes(0, 1).reshape(B, S, -1)

    dt_bar = unchunk(dt_bar)
    x_bar = unchunk(x_bar)
    B_bar = unchunk(B_bar)
    C_bar = unchunk(C_bar)
    # dA/dA_log = -exp(A_log) = A  ->  A_log_bar = A_bar * A
    A_log_bar = A_bar_c.sum(0) * A
    D_bar = jnp.sum(y_bar * x, axis=(0, 1))
    return dt_bar, x_bar, B_bar, C_bar, A_log_bar, D_bar


mamba_scan_fused.defvjp(_fused_fwd, _fused_bwd)


def _conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
            state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x (B, S, d); w (dconv, d).  ``state`` holds the
    trailing dconv-1 inputs from the previous segment (decode)."""
    dconv = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], dconv - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S + dconv - 1, d)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(dconv))
    new_state = xp[:, -(dconv - 1):] if dconv > 1 else pad[:, :0]
    return out + b[None, None], new_state


def _kernel_scan(params, dt_raw, Bm, Cm, x):
    """Route the scan through the fused Pallas kernel (h0 = 0 path),
    manually partitioned over (dp, tp) when a mesh is active."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import current_rules

    dt = jax.nn.softplus(
        (dt_raw @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"])
    xf = x.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    rules = current_rules()
    if rules is None:
        y, h_fin = mamba_scan_fused(dt, xf, Bf, Cf, params["A_log"],
                                    params["D"])
        return y, h_fin
    mesh = rules.mesh
    tp = rules.physical("tp")
    dp = rules.physical("dp")
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    dpN = 1
    for a in dp_axes:
        dpN *= mesh.shape[a]
    B = x.shape[0]
    d = x.shape[2]
    tpN = mesh.shape[tp]
    b_spec = dp if B % dpN == 0 else None
    d_spec = tp if d % tpN == 0 else None
    y, h_fin = shard_map_compat(
        lambda dt_, x_, b_, c_, al_, dd_: mamba_scan_fused(
            dt_, x_, b_, c_, al_, dd_),
        mesh=mesh,
        in_specs=(P(b_spec, None, d_spec), P(b_spec, None, d_spec),
                  P(b_spec, None, None), P(b_spec, None, None),
                  P(d_spec, None), P(d_spec)),
        out_specs=(P(b_spec, None, d_spec), P(b_spec, d_spec, None)),
    )(dt, xf, Bf, Cf, params["A_log"], params["D"])
    return y, h_fin


def _ssm_inner(params, xz, conv_state, h0, chunk, use_kernel=False):
    """Everything after in_proj.  xz (B, S, 2*d_inner)."""
    d_inner = params["conv_w"].shape[1]
    x, z = jnp.split(xz, 2, axis=-1)
    x, conv_state = _conv1d(x, params["conv_w"], params["conv_b"], conv_state)
    x = jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype)
    x = shard(x, "dp", None, "tp")

    proj = x @ params["w_x"]  # (B, S, dt_rank + 2*d_state)
    d_state = params["A_log"].shape[1]
    dt_rank = proj.shape[-1] - 2 * d_state
    dt_raw, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    if use_kernel:
        # fused kernel path (zero initial state; D folded in by the kernel)
        y, h_last = _kernel_scan(params, dt_raw, Bm, Cm, x)
    else:
        y, h_last = _ssm_scan_chunked(params, dt_raw, Bm, Cm, x, h0, chunk)
        y = y + params["D"][None, None] * x.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(xz.dtype), conv_state, h_last


def mamba_forward(params, x, *, chunk: int = 64, state=None,
                  return_state=False, use_kernel: bool = False):
    """x (B, S, d_model) -> (B, S, d_model).  Training/prefill path."""
    x = shard(x, "dp", None, None)
    xz = x @ params["w_in"]
    xz = shard(xz, "dp", None, "tp")
    B = x.shape[0]
    d_inner = params["conv_w"].shape[1]
    d_state = params["A_log"].shape[1]
    if state is None:
        conv_state = None
        h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
    else:
        conv_state, h0 = state["conv"], state["ssm"]
    # the fused kernel supports only zero initial state (train/prefill)
    use_kernel = use_kernel and state is None
    y, conv_state, h_last = _ssm_inner(params, xz, conv_state, h0, chunk,
                                       use_kernel=use_kernel)
    out = y @ params["w_out"]
    out = shard(out, "dp", "sp", None)
    if return_state:
        return out, {"conv": conv_state.astype(jnp.bfloat16), "ssm": h_last}
    return out


def mamba_decode_step(params, x, state):
    """x (B, 1, d_model); state {conv (B, dconv-1, d_inner), ssm (B, d, s)}."""
    out, new_state = mamba_forward(params, x, chunk=1, state=state,
                                   return_state=True)
    return out, new_state
