"""Mixture-of-Experts FFN: top-k routing with expert parallelism.

Two execution paths sharing one parameter layout:

* ``dense`` - every expert computed for every token, combined with the
  top-k gate mask.  O(E/k) FLOP waste; used single-device (smoke tests,
  correctness oracle).
* ``ep`` - expert-parallel shard_map: tokens are dispatched to the devices
  owning their experts with a capacity-bounded all_to_all over the "model"
  ("ep") mesh axis, expert FFNs run as grouped einsums on local experts,
  and a second all_to_all returns outputs to their source device (GShard /
  Switch dispatch adapted to TPU: static shapes, sort-free cumsum
  positioning, capacity drop).  Expert weights are additionally
  FSDP-sharded over the data axes and all-gathered inside the body.

Routing: softmax -> top-k -> renormalize (Qwen3/Mixtral convention).
Aux load-balance loss (Switch style) is returned as a metric.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import current_rules, shard
from repro.distributed.sharding import AxisRules, shard_map_compat

__all__ = ["MoEConfig", "init_moe", "moe_shapes", "apply_moe"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0           # shared-expert width, in units of d_expert_ff
    capacity_factor: float = 1.25
    act: str = "swiglu"

    @property
    def e_pad(self) -> int:
        """Experts padded so the EP axis divides them (dummy experts are
        never routed to: their logits are masked before top-k)."""
        return self.n_experts


def _e_padded(cfg: MoEConfig, ep_size: int) -> int:
    return int(math.ceil(cfg.n_experts / ep_size) * ep_size)


def init_moe(key, d: int, cfg: MoEConfig, ep_size: int = 1, dtype=jnp.bfloat16):
    E = _e_padded(cfg, ep_size)
    ks = jax.random.split(key, 6)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(cfg.d_expert_ff)
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * sc_in,
        "w_gate": jax.random.normal(ks[1], (E, d, cfg.d_expert_ff), dtype) * sc_in,
        "w_up": jax.random.normal(ks[2], (E, d, cfg.d_expert_ff), dtype) * sc_in,
        "w_down": jax.random.normal(ks[3], (E, cfg.d_expert_ff, d), dtype) * sc_out,
    }
    if cfg.n_shared:
        ff_sh = cfg.n_shared * cfg.d_expert_ff
        p["sh_gate"] = jax.random.normal(ks[4], (d, ff_sh), dtype) * sc_in
        p["sh_up"] = jax.random.normal(ks[5], (d, ff_sh), dtype) * sc_in
        p["sh_down"] = jax.random.normal(ks[4], (ff_sh, d), dtype) * sc_out
    return p


def moe_shapes(d: int, cfg: MoEConfig, ep_size: int = 1, dtype=jnp.bfloat16):
    E = _e_padded(cfg, ep_size)
    p = {
        "router": jax.ShapeDtypeStruct((d, E), jnp.float32),
        "w_gate": jax.ShapeDtypeStruct((E, d, cfg.d_expert_ff), dtype),
        "w_up": jax.ShapeDtypeStruct((E, d, cfg.d_expert_ff), dtype),
        "w_down": jax.ShapeDtypeStruct((E, cfg.d_expert_ff, d), dtype),
    }
    if cfg.n_shared:
        ff_sh = cfg.n_shared * cfg.d_expert_ff
        p["sh_gate"] = jax.ShapeDtypeStruct((d, ff_sh), dtype)
        p["sh_up"] = jax.ShapeDtypeStruct((d, ff_sh), dtype)
        p["sh_down"] = jax.ShapeDtypeStruct((ff_sh, d), dtype)
    return p


def _route(router_w, x_flat, cfg: MoEConfig):
    """x_flat (T, d) -> gates (T, k) f32, eids (T, k) int32, aux loss."""
    logits = (x_flat.astype(jnp.float32) @ router_w)  # (T, E_pad)
    E = router_w.shape[1]
    if E > cfg.n_experts:  # mask dummy padding experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, eids = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e (frac_tokens_e * mean_prob_e)
    onehot = jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32)
    frac = onehot.mean(0)
    aux = cfg.n_experts * jnp.sum(frac * probs.mean(0))
    return gates, eids, aux


def _expert_ffn(w_gate, w_up, w_down, xs, act: str):
    """xs (E_loc, C, d) grouped FFN."""
    up = jnp.einsum("ecd,edf->ecf", xs, w_up)
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(xs.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _shared_ffn(params, x, act: str):
    """Shared expert: computed OUTSIDE the EP shard_map so the hidden dim
    tensor-parallelises like a normal MLP."""
    up = x @ params["sh_up"]
    up = shard(up, "dp", None, "tp")
    if act == "swiglu":
        g = x @ params["sh_gate"]
        g = shard(g, "dp", None, "tp")
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return h @ params["sh_down"]


# ---------------------------------------------------------------------------
# dense path (single device / oracle)


def _moe_dense(params, x, cfg: MoEConfig):
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    gates, eids, aux = _route(params["router"], xf, cfg)
    E = params["w_gate"].shape[0]
    # (T, E) combine weights from top-k selection
    comb = jnp.zeros((xf.shape[0], E), jnp.float32)
    comb = comb.at[jnp.arange(xf.shape[0])[:, None], eids].add(gates)
    all_out = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                          jnp.broadcast_to(xf[None], (E,) + xf.shape), cfg.act)
    y = jnp.einsum("te,etd->td", comb.astype(x.dtype), all_out)
    if cfg.n_shared:
        y = y + _shared_ffn(params, xf, cfg.act)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# expert-parallel path


def _moe_ep_body(x, router_w, w_gate, w_up, w_down, cfg: MoEConfig,
                 ep_axis: str, dp_axes: Tuple[str, ...], capacity: int,
                 ep: int):
    """shard_map body.  x (B_loc, S_loc, d) local tokens; expert weights
    (E_loc, d/dp, ff) - FSDP-gathered here; returns (y, aux)."""
    # FSDP all-gather of expert weights over the data axes.
    for ax in dp_axes:
        w_gate = jax.lax.all_gather(w_gate, ax, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, ax, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, ax, axis=2, tiled=True)
    B_loc, S_loc, d = x.shape
    T = B_loc * S_loc
    xf = x.reshape(T, d)
    gates, eids, aux = _route(router_w, xf, cfg)          # (T,k)
    E = router_w.shape[1]
    E_loc = E // ep
    k = cfg.top_k

    flat_e = eids.reshape(-1)                              # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                   # position in expert
    my_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = my_pos < capacity                               # capacity drop
    tok_idx = jnp.repeat(jnp.arange(T), k)

    # Scatter tokens into the (E, C, d) send buffer.
    send = jnp.zeros((E, capacity, d), xf.dtype)
    e_idx = jnp.where(keep, flat_e, 0)
    c_idx = jnp.where(keep, my_pos, 0)
    vals = jnp.where(keep[:, None], xf[tok_idx], 0.0)
    send = send.at[e_idx, c_idx].add(vals)                 # unique (e,c) per kept tok

    # all_to_all: (ep, E_loc, C, d) -> recv[src] = tokens from src device.
    send = send.reshape(ep, E_loc, capacity, d)
    recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # recv[src, e_loc] = tokens device ``src`` sent to our expert group.
    xs = recv.swapaxes(0, 1).reshape(E_loc, ep * capacity, d)
    ys = _expert_ffn(w_gate, w_up, w_down, xs, cfg.act)
    back = jax.lax.all_to_all(ys.reshape(E_loc, ep, capacity, d).swapaxes(0, 1),
                              ep_axis, split_axis=0, concat_axis=0, tiled=False)
    # back: (ep, E_loc, C, d) -> (E, C, d), rows for OUR tokens again.
    back = back.reshape(E, capacity, d)

    gathered = back[e_idx, c_idx]                          # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = (gates.reshape(-1) * keep).astype(jnp.float32)
    y = jnp.zeros((T, d), jnp.float32).at[tok_idx].add(
        gathered.astype(jnp.float32) * w[:, None])
    y = y.astype(x.dtype)
    # aux is a local mean; average over all devices.
    for ax in (ep_axis,) + tuple(dp_axes):
        aux = jax.lax.pmean(aux, ax)
    return y.reshape(B_loc, S_loc, d), aux


def _moe_ep(params, x, cfg: MoEConfig, rules: AxisRules):
    mesh = rules.mesh
    ep_axis = rules.physical("ep")
    dp_phys = rules.physical("dp")
    dp_axes = tuple(dp_phys) if isinstance(dp_phys, tuple) else (dp_phys,)
    ep = mesh.shape[ep_axis]
    dpN = 1
    for a in dp_axes:
        dpN *= mesh.shape[a]
    B, S, d = x.shape
    seq_shard = ep if S % ep == 0 else 1   # decode: S=1 cannot seq-shard
    b_shard = dpN if B % dpN == 0 else 1   # long-context decode: B=1
    T_loc = (B // b_shard) * (S // seq_shard)
    E = params["w_gate"].shape[0]
    capacity = max(1, int(math.ceil(cfg.capacity_factor * cfg.top_k * T_loc / E)))

    batch_spec = dp_axes if b_shard > 1 else None
    seq_spec = ep_axis if seq_shard > 1 else None
    body = partial(_moe_ep_body, cfg=cfg, ep_axis=ep_axis, dp_axes=dp_axes,
                   capacity=capacity, ep=ep)
    y, aux = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_spec, seq_spec, None),         # x: (B, S, d)
            P(None, None),                         # router replicated
            P(ep_axis, dp_axes, None),             # w_gate (E, d, ff)
            P(ep_axis, dp_axes, None),             # w_up
            P(ep_axis, None, dp_axes),             # w_down (E, ff, d)
        ),
        out_specs=(P(batch_spec, seq_spec, None), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    if cfg.n_shared:
        y = y + _shared_ffn(params, x.reshape(-1, d), cfg.act).reshape(x.shape)
    return y, aux


def apply_moe(params, x, cfg: MoEConfig):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).  Chooses EP when a
    sharding-rules context is active, dense otherwise."""
    rules = current_rules()
    if rules is None:
        return _moe_dense(params, x, cfg)
    return _moe_ep(params, x, cfg, rules)
