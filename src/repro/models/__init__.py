"""Model zoo: pattern-based LM stacks (dense / MoE / SSM / hybrid)."""
from repro.models.lm import (
    ModelConfig,
    cache_shapes,
    decode_step,
    init_cache,
    init_params,
    param_shapes,
    prefill,
    train_loss,
)
from repro.models.moe import MoEConfig

__all__ = [
    "ModelConfig", "MoEConfig", "cache_shapes", "decode_step", "init_cache",
    "init_params", "param_shapes", "prefill", "train_loss",
]
