"""GQA attention: chunked-causal (train/prefill) and cached decode paths.

Training/prefill uses an online-softmax chunked attention (pure JAX "flash"
schedule): the (S x S) score matrix never materialises - q is processed in
static chunks, each attending to a statically-sliced kv range, so
* causal FLOPs are exact (no 2x masked waste), and
* sliding-window layers are automatically sub-quadratic (the kv slice per
  q-chunk is [end - window - q_chunk, end), a static band).

Tensor parallelism follows Megatron: heads sharded over "tp", activations
sequence-sharded ("sp") outside the block, gathered to full-S inside.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import shard
from repro.models.layers import apply_rope, rms_head_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter init


def init_attn(key, d: int, n_heads: int, n_kv: int, d_head: int,
              qk_norm: bool = False, qkv_bias: bool = False,
              dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, n_heads, d_head), dtype) * sc,
        "wk": jax.random.normal(ks[1], (d, n_kv, d_head), dtype) * sc,
        "wv": jax.random.normal(ks[2], (d, n_kv, d_head), dtype) * sc,
        "wo": jax.random.normal(ks[3], (n_heads, d_head, d), dtype)
               * (1.0 / math.sqrt(n_heads * d_head)),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, d_head), dtype)
        p["bk"] = jnp.zeros((n_kv, d_head), dtype)
        p["bv"] = jnp.zeros((n_kv, d_head), dtype)
    if qk_norm:
        p["q_norm"] = jnp.ones((d_head,), jnp.float32)
        p["k_norm"] = jnp.ones((d_head,), jnp.float32)
    return p


def attn_shapes(d: int, n_heads: int, n_kv: int, d_head: int,
                qk_norm: bool = False, qkv_bias: bool = False,
                dtype=jnp.bfloat16):
    p = {
        "wq": jax.ShapeDtypeStruct((d, n_heads, d_head), dtype),
        "wk": jax.ShapeDtypeStruct((d, n_kv, d_head), dtype),
        "wv": jax.ShapeDtypeStruct((d, n_kv, d_head), dtype),
        "wo": jax.ShapeDtypeStruct((n_heads, d_head, d), dtype),
    }
    if qkv_bias:
        p["bq"] = jax.ShapeDtypeStruct((n_heads, d_head), dtype)
        p["bk"] = jax.ShapeDtypeStruct((n_kv, d_head), dtype)
        p["bv"] = jax.ShapeDtypeStruct((n_kv, d_head), dtype)
    if qk_norm:
        p["q_norm"] = jax.ShapeDtypeStruct((d_head,), jnp.float32)
        p["k_norm"] = jax.ShapeDtypeStruct((d_head,), jnp.float32)
    return p


def _project_qkv(params, x):
    """x (B, S, d) -> q (B,S,H,hd), k/v (B,S,KH,hd), heads tp-sharded."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if "q_norm" in params:
        q = rms_head_norm(params["q_norm"], q)
        k = rms_head_norm(params["k_norm"], k)
    q = shard(q, "dp", None, "tp", None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked online-softmax attention (train / prefill)


def repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, KH, hd) -> (B, S, KH*groups, hd).  Train/prefill GQA layout:
    repeating kv lets the full H=KH*G head axis shard over tp even when
    KH < tp (the cache still stores only KH heads)."""
    if groups == 1:
        return k
    B, S, KH, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None], (B, S, KH, groups, hd)
                            ).reshape(B, S, KH * groups, hd)


def _attend_tile(q, k, v, mask):
    """q (B,H,qc,hd), k/v (B,H,kc,hd), mask (qc,kc) bool ->
    per-tile (scores-max, exp-sum, weighted-v) for online softmax."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32)
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                      # (B,H,qc)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask[None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def mha_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """q (B,S,H,hd), k/v (B,S,KH,hd) -> (B,S,H,hd).  Exact-FLOPs chunked
    causal attention; ``window`` enables the sliding-window band.  kv heads
    are repeated to H inside so the head axis tp-shards uniformly."""
    B, S, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    in_dtype = q.dtype
    scale = 1.0 / math.sqrt(hd)
    k = shard(repeat_kv(k, G), "dp", None, "tp", None)
    v = shard(repeat_kv(v, G), "dp", None, "tp", None)
    q = (q * scale).transpose(0, 2, 1, 3)  # B,H,S,hd
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)

    q_chunk = min(q_chunk, S)
    while S % q_chunk:
        q_chunk //= 2
    n_q = S // q_chunk

    outs = []
    for i in range(n_q):  # static unroll: exact causal/banded FLOPs
        q_start = i * q_chunk
        q_end = q_start + q_chunk
        kv_start = 0
        if window is not None:
            kv_start = max(0, q_end - window - q_chunk)
        kv_len = q_end - kv_start if causal else S - kv_start
        qi = q[:, :, q_start:q_end]
        ki = kT[:, :, kv_start:kv_start + kv_len]
        vi = vT[:, :, kv_start:kv_start + kv_len]

        n_kv = max(1, math.ceil(kv_len / kv_chunk))
        m = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((B, H, q_chunk), jnp.float32)
        acc = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        qpos = q_start + jnp.arange(q_chunk)
        for j in range(n_kv):  # static inner tiles
            ks_ = j * kv_chunk
            ke_ = min(ks_ + kv_chunk, kv_len)
            kpos = kv_start + ks_ + jnp.arange(ke_ - ks_)
            mask = jnp.ones((q_chunk, ke_ - ks_), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            mt, lt, ot = _attend_tile(qi, ki[:, :, ks_:ke_], vi[:, :, ks_:ke_], mask)
            m_new = jnp.maximum(m, mt)
            c_old = jnp.exp(m - m_new)
            c_new = jnp.exp(mt - m_new)
            l = l * c_old + lt * c_new
            acc = acc * c_old[..., None] + ot * c_new[..., None]
            m = m_new
        outs.append(acc / jnp.maximum(l[..., None], 1e-30))

    o = jnp.concatenate(outs, axis=2)  # (B,H,S,hd)
    return o.transpose(0, 2, 1, 3).astype(in_dtype)


# ---------------------------------------------------------------------------
# full blocks


def attn_forward(
    params,
    x: jnp.ndarray,
    cos_sin: Tuple[jnp.ndarray, jnp.ndarray],
    *,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    return_kv: bool = False,
    proj_first: bool = False,
):
    """Full-sequence attention (train / prefill).

    ``proj_first=False`` (baseline): gather the sequence-sharded residual to
    full S before QKV - the Megatron-SP default, moving (B,S,d) per layer.
    ``proj_first=True`` (optimized): project on the SHARDED sequence, then
    let the q/k/v sharding constraints reshard the head-sharded projections
    (all-to-all on (B,S,H/tp,hd) - 16x fewer bytes at tp=16).  See
    EXPERIMENTS.md SecPerf."""
    if not proj_first:
        x = shard(x, "dp", None, None)  # gather sequence for the block
    q, k, v = _project_qkv(params, x)
    cos, sin = cos_sin
    q = apply_rope(q, cos, sin) if cos is not None else q
    k = apply_rope(k, cos, sin) if cos is not None else k
    o = mha_chunked(q.astype(x.dtype), k.astype(x.dtype), v, window=window,
                    q_chunk=q_chunk, kv_chunk=kv_chunk)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), params["wo"])
    y = shard(y, "dp", "sp", None)  # back to sequence-sharded residual
    if return_kv:
        return y, (k.astype(x.dtype), v)
    return y


def attn_decode_step(
    params,
    x: jnp.ndarray,
    cos_sin: Tuple[jnp.ndarray, jnp.ndarray],
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,
    *,
    window: Optional[int] = None,
):
    """One-token decode.  x (B, 1, d); cache_k/v (B, S_c, KH, hd) sharded
    (dp, sp); pos () int32 - current absolute position (whole batch).

    Two cache layouts:
    * full  (S_c = S_max >= pos): k written at index ``pos``.
    * ring  (S_c = window, for sliding-window layers): slot ``pos % window``
      holds token position t_i = pos - ((pos - i) mod window); keys are
      stored post-RoPE so absolute positions are baked in.

    Decode shards the cache over SEQUENCE (sp), not heads: scores reduce
    over the sharded S_c axis (flash-decoding collective schedule).
    Returns (y (B, 1, d), cache_k, cache_v updated)."""
    B, one, d = x.shape
    q, k_new, v_new = _project_qkv(params, x)
    cos, sin = cos_sin
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    S_c = cache_k.shape[1]
    ring = window is not None and S_c == window
    write_at = jnp.mod(pos, S_c) if ring else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), write_at, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), write_at, axis=1)
    cache_k = shard(cache_k, "dp", "sp", None, None)
    cache_v = shard(cache_v, "dp", "sp", None, None)

    KH = cache_k.shape[2]
    H = q.shape[2]
    G = H // KH
    hd = q.shape[3]
    scale = 1.0 / math.sqrt(hd)
    qh = (q * scale).reshape(B, KH, G, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qh.astype(jnp.float32),
                   cache_k.astype(jnp.float32))  # (B,KH,G,S_c)
    idx = jnp.arange(S_c)
    if ring:
        tpos = pos - jnp.mod(pos - idx, S_c)  # absolute token pos per slot
        valid = tpos >= 0
    else:
        valid = idx <= pos
        if window is not None:
            valid &= (pos - idx) < window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(jnp.float32),
                   cache_v.astype(jnp.float32))
    o = o.reshape(B, 1, H, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return shard(y, "dp", None, None), cache_k, cache_v
