"""Analytic model statistics: parameter counts and MODEL_FLOPS.

MODEL_FLOPS convention (the roofline 'useful FLOPs'):
  train    6 * N_active * D            (fwd 2ND + bwd 4ND)
  prefill  2 * N_active * D
  decode   2 * N_active * B            (one token per sequence)
with N_active = non-embedding params, MoE experts counted at top_k/E.
The attention-score FLOPs (not in 6ND) are reported separately.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.models.lm import ModelConfig, param_shapes

__all__ = ["param_counts", "model_flops", "attention_score_flops"]


def _leaf_sizes(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _leaf_sizes(v, path + (k,))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _leaf_sizes(v, path + (str(i),))
    else:
        yield path, int(np.prod(tree.shape))


def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """total / embedding / active (MoE experts scaled by top_k/E)."""
    shapes = param_shapes(cfg)
    total = emb = active = 0.0
    moe_scale = 1.0
    if cfg.moe is not None:
        moe_scale = cfg.moe.top_k / cfg.moe.n_experts
    for path, size in _leaf_sizes(shapes):
        total += size
        name = path[-1]
        is_embed = "embed" in path or "lm_head" in path
        if is_embed:
            emb += size
            continue
        in_moe_experts = ("ffn" in path and name in
                          ("w_gate", "w_up", "w_down") and cfg.moe is not None
                          and "blocks" in path)
        # expert tensors are rank-3; shared/dense mlp use the same names but
        # sit outside MoE configs - distinguish via moe presence + pattern
        if in_moe_experts and _is_moe_position(cfg, path):
            active += size * moe_scale
        else:
            active += size
    return {"total": total, "embedding": emb, "non_embedding": total - emb,
            "active": active}


def _is_moe_position(cfg: ModelConfig, path: Tuple[str, ...]) -> bool:
    try:
        bi = path.index("blocks")
        pos = int(path[bi + 1])
    except (ValueError, IndexError):
        return False
    return cfg.pattern[pos][1] == "moe"


def model_flops(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    counts = param_counts(cfg)
    n = counts["active"]
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    if kind == "decode":
        return 2.0 * n * batch
    raise ValueError(kind)


def attention_score_flops(cfg: ModelConfig, kind: str, batch: int,
                          seq: int) -> float:
    """QK^T + PV flops (causal ~ S^2/2 each; windowed ~ S*W)."""
    n_attn = sum(1 for m, _ in cfg.pattern if m == "attn")
    n_local = sum(1 for m, _ in cfg.pattern if m == "attn_local")
    reps = cfg.n_groups
    d_attn = cfg.n_heads * cfg.d_head
    if kind in ("train", "prefill"):
        full = 2 * 2 * (seq * seq / 2) * d_attn * batch
        w = cfg.window or seq
        local = 2 * 2 * (seq * min(w, seq)) * d_attn * batch
        fwd = reps * (n_attn * full + n_local * local)
        return 3 * fwd if kind == "train" else fwd
    if kind == "decode":
        full = 2 * 2 * seq * d_attn * batch
        w = cfg.window or seq
        local = 2 * 2 * min(w, seq) * d_attn * batch
        return reps * (n_attn * full + n_local * local)
    raise ValueError(kind)
