"""Shared model layers: norms, rotary embeddings, MLPs, embeddings, loss.

Pure-function style: ``init_*`` returns a param dict, ``apply`` functions
take (params, x).  All layers take explicit dtypes; logical sharding
annotations come from repro.distributed.shard.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed import shard

# ---------------------------------------------------------------------------
# norms


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head RMS norm (qk-norm): x (..., hd), scale (hd,)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings


def rope_freqs(d_rot: int, theta: float, dtype=jnp.float32):
    """Inverse frequencies for RoPE: (d_rot/2,)."""
    exponents = jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot
    return (1.0 / (theta ** exponents)).astype(dtype)


def rope_cos_sin(positions: jnp.ndarray, d_rot: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, d_rot/2) in f32."""
    inv = rope_freqs(d_rot, theta)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (B, S, H, hd) with leading rotary half-pairs; cos/sin (B, S, hd/2)
    or (S, hd/2).  Rotates pairs (x1, x2) = (x[..., :hd/2], x[..., hd/2:])
    (NeoX / llama convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos_b = cos[None, :, None, :]
        sin_b = sin[None, :, None, :]
    else:  # (B, S, half)
        cos_b = cos[:, :, None, :]
        sin_b = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = xf1 * cos_b - xf2 * sin_b
    r2 = xf2 * cos_b + xf1 * sin_b
    return jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)


def mrope_cos_sin(pos_ids: jnp.ndarray, sections: Tuple[int, ...], d_rot: int,
                  theta: float):
    """Multimodal RoPE (Qwen2-VL): pos_ids (3, B, S) for (t, h, w).

    The d_rot/2 frequency slots are split into len(sections) contiguous
    groups; group g uses pos_ids[g].  Returns cos/sin (B, S, d_rot/2).
    """
    assert sum(sections) == d_rot // 2, (sections, d_rot)
    inv = rope_freqs(d_rot, theta)  # (d_rot/2,)
    ang_all = pos_ids[..., None].astype(jnp.float32) * inv  # (3, B, S, d_rot/2)
    parts = []
    start = 0
    for g, sec in enumerate(sections):
        parts.append(ang_all[g, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, d_rot/2)
    return jnp.cos(ang), jnp.sin(ang)


def sinusoidal_positions(S: int, d: int, offset=0, dtype=jnp.float32):
    """MusicGen-style fixed sinusoidal position embeddings (S, d)."""
    pos = jnp.arange(S, dtype=jnp.float32) + offset
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# MLPs


def init_mlp(key, d: int, d_ff: int, act: str, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_up": jax.random.normal(k2, (d, d_ff), dtype) * scale_in,
        "w_down": jax.random.normal(k3, (d_ff, d), dtype) * scale_out,
    }
    if act == "swiglu":
        p["w_gate"] = jax.random.normal(k1, (d, d_ff), dtype) * scale_in
    return p


def mlp_shapes(d: int, d_ff: int, act: str, dtype=jnp.bfloat16):
    p = {
        "w_up": jax.ShapeDtypeStruct((d, d_ff), dtype),
        "w_down": jax.ShapeDtypeStruct((d_ff, d), dtype),
    }
    if act == "swiglu":
        p["w_gate"] = jax.ShapeDtypeStruct((d, d_ff), dtype)
    return p


def apply_mlp(params, x, act: str):
    """x (B, S, d) -> (B, S, d); hidden sharded over tp."""
    up = x @ params["w_up"]
    up = shard(up, "dp", None, "tp")
    if act == "swiglu":
        gate = x @ params["w_gate"]
        gate = shard(gate, "dp", None, "tp")
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif act == "gelu":
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(act)
    out = h @ params["w_down"]
    return shard(out, "dp", None, None)


# ---------------------------------------------------------------------------
# embeddings / head / loss


def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(params, tokens):
    """tokens (B, S) int -> (B, S, d)."""
    out = jnp.take(params["table"], tokens, axis=0)
    return shard(out, "dp", "sp", None)


def lm_head_logits_chunk(table: jnp.ndarray, x: jnp.ndarray):
    """x (B, C, d) @ table^T (V, d) -> (B, C, V) bf16-matmul f32-accum."""
    logits = jnp.einsum("bcd,vd->bcv", x, table,
                        preferred_element_type=jnp.float32)
    return shard(logits, "dp", None, "tp")


def chunked_ce_loss(table: jnp.ndarray, x: jnp.ndarray, labels: jnp.ndarray,
                    chunk: int = 512, z_loss: float = 0.0):
    """Cross entropy fused with the lm_head matmul, scanned over sequence
    chunks so (B, S, V) logits never materialise (vocab 152k x 4k seq would
    be GiB-scale per device otherwise).

    x (B, S, d), labels (B, S) int32 -> scalar mean loss (f32).
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    assert S % chunk == 0, (S, chunk)
    xc = x.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)       # (N, B, C, d)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)     # (N, B, C)

    def body(carry, inp):
        xi, li = inp
        logits = lm_head_logits_chunk(table, xi)               # (B, C, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        loss = (lse - gold).sum()
        if z_loss:
            loss = loss + z_loss * jnp.square(lse).sum()
        return carry + loss, None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)
