"""The LM stack: pattern-based heterogeneous transformer in pure JAX.

An architecture is a repeating PATTERN of (mixer, ffn) blocks - e.g. Jamba's
1:7 attention:mamba interleave with MoE on alternate layers, or Gemma-3's
5 local : 1 global attention - scanned over ``n_groups`` repetitions with
stacked parameters (jax.lax.scan keeps the HLO small regardless of depth).

Three execution paths share the parameter layout:
  train_loss   - full-sequence fwd + chunked CE (remat per group)
  prefill      - full-sequence fwd, returns the serve cache
  decode_step  - one token, consumes/updates the cache

Mixer kinds:  attn | attn_local | mamba | rwkv
FFN   kinds:  mlp | moe | rwkv_cmix
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import shard
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.moe import MoEConfig, apply_moe, init_moe, moe_shapes

__all__ = ["ModelConfig", "init_params", "param_shapes", "train_loss",
           "prefill", "decode_step", "cache_shapes", "init_cache"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    pattern: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    window: Optional[int] = None          # sliding window for attn_local
    qk_norm: bool = False
    qkv_bias: bool = False
    act: str = "swiglu"                   # swiglu | gelu
    pos: str = "rope"                     # rope | mrope | sinusoidal
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, ...] = ()
    moe: Optional[MoEConfig] = None
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_dconv: int = 4
    mamba_kernel: bool = False   # fused Pallas scan (beyond-paper perf)
    rwkv_kernel: bool = False    # fused Pallas WKV (beyond-paper perf)
    rwkv_head_dim: int = 64
    input_mode: str = "tokens"            # tokens | embeds (stubbed frontend)
    tie_embeddings: bool = True
    eps: float = 1e-6
    dtype: str = "bfloat16"
    tp_pad: int = 16                      # pad rwkv heads to divide tp
    remat: bool = True
    remat_policy: str = "none"            # none | dots (save matmul outputs)
    proj_first: bool = False              # project-then-reshard attention
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512
    aux_coef: float = 0.01
    sub_quadratic: bool = False           # eligible for long_500k

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: n_layers={self.n_layers} vs pattern {len(self.pattern)}"

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def head_dim(self) -> int:
        return self.d_head


# ---------------------------------------------------------------------------
# parameter construction


def _block_builders(cfg: ModelConfig, mixer: str, ffn: str):
    """Returns (init_fn(key), shapes_fn()) pairs for one block position."""
    dt = cfg.param_dtype
    d = cfg.d_model

    def mixer_init(key):
        if mixer in ("attn", "attn_local"):
            return attn_mod.init_attn(key, d, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.d_head, cfg.qk_norm, cfg.qkv_bias, dt)
        if mixer == "mamba":
            return mamba_mod.init_mamba(key, d, expand=cfg.mamba_expand,
                                        d_state=cfg.mamba_d_state,
                                        dconv=cfg.mamba_dconv, dtype=dt)
        if mixer == "rwkv":
            return rwkv_mod.init_rwkv_tmix(key, d, head_dim=cfg.rwkv_head_dim,
                                           tp_pad=cfg.tp_pad, dtype=dt)
        raise ValueError(mixer)

    def mixer_shapes():
        if mixer in ("attn", "attn_local"):
            return attn_mod.attn_shapes(d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.d_head, cfg.qk_norm, cfg.qkv_bias, dt)
        if mixer == "mamba":
            return mamba_mod.mamba_shapes(d, expand=cfg.mamba_expand,
                                          d_state=cfg.mamba_d_state,
                                          dconv=cfg.mamba_dconv, dtype=dt)
        if mixer == "rwkv":
            return rwkv_mod.rwkv_tmix_shapes(d, head_dim=cfg.rwkv_head_dim,
                                             tp_pad=cfg.tp_pad, dtype=dt)
        raise ValueError(mixer)

    def ffn_init(key):
        if ffn == "mlp":
            return L.init_mlp(key, d, cfg.d_ff, cfg.act, dt)
        if ffn == "moe":
            return init_moe(key, d, cfg.moe, ep_size=cfg.tp_pad, dtype=dt)
        if ffn == "rwkv_cmix":
            return rwkv_mod.init_rwkv_cmix(key, d, cfg.d_ff, dt)
        raise ValueError(ffn)

    def ffn_shapes():
        if ffn == "mlp":
            return L.mlp_shapes(d, cfg.d_ff, cfg.act, dt)
        if ffn == "moe":
            return moe_shapes(d, cfg.moe, ep_size=cfg.tp_pad, dtype=dt)
        if ffn == "rwkv_cmix":
            return rwkv_mod.rwkv_cmix_shapes(d, cfg.d_ff, dt)
        raise ValueError(ffn)

    return mixer_init, mixer_shapes, ffn_init, ffn_shapes


def _stack_leaves(trees: Sequence):
    """List of G identical-structure pytrees -> single pytree with leading G."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = cfg.param_dtype
    keys = jax.random.split(key, 2 + cfg.n_layers)
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = L.init_embedding(keys[0], cfg.vocab, cfg.d_model, dt)
    if cfg.input_mode == "embeds" or not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(keys[1], cfg.vocab, cfg.d_model, dt)
    blocks = []
    for pos_idx, (mixer, ffn) in enumerate(cfg.pattern):
        per_group = []
        for g in range(cfg.n_groups):
            mi, _, fi, _ = _block_builders(cfg, mixer, ffn)
            lk = jax.random.fold_in(key, 100 + g * len(cfg.pattern) + pos_idx)
            k1, k2 = jax.random.split(lk)
            per_group.append({
                "norm1": L.init_rmsnorm(cfg.d_model, jnp.float32),
                "mixer": mi(k1),
                "norm2": L.init_rmsnorm(cfg.d_model, jnp.float32),
                "ffn": fi(k2),
            })
        blocks.append(_stack_leaves(per_group))
    params["blocks"] = tuple(blocks)
    params["final_norm"] = L.init_rmsnorm(cfg.d_model, jnp.float32)
    return params


def param_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree - no allocation (dry-run path)."""
    dt = cfg.param_dtype
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = {"table": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt)}
    if cfg.input_mode == "embeds" or not cfg.tie_embeddings:
        params["lm_head"] = {"table": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt)}
    blocks = []
    for mixer, ffn in cfg.pattern:
        _, ms, _, fs = _block_builders(cfg, mixer, ffn)
        one = {
            "norm1": {"scale": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32)},
            "mixer": ms(),
            "norm2": {"scale": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32)},
            "ffn": fs(),
        }
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_groups,) + s.shape, s.dtype), one)
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    params["final_norm"] = {"scale": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32)}
    return params


# ---------------------------------------------------------------------------
# position embeddings


def _cos_sin(cfg: ModelConfig, batch: Dict[str, jnp.ndarray], S: int,
             pos_offset: Optional[jnp.ndarray] = None):
    d_rot = cfg.d_head
    if cfg.pos == "rope":
        positions = jnp.arange(S)
        if pos_offset is not None:
            positions = positions + pos_offset
        return L.rope_cos_sin(positions, d_rot, cfg.rope_theta)
    if cfg.pos == "mrope":
        pos_ids = batch["pos_ids"]  # (3, B, S)
        if pos_offset is not None:
            pos_ids = pos_ids + pos_offset
        return L.mrope_cos_sin(pos_ids, cfg.mrope_sections, d_rot, cfg.rope_theta)
    return None, None  # sinusoidal handled at the embedding


# ---------------------------------------------------------------------------
# block application (train / prefill / decode)


def _apply_mixer_train(cfg, mixer, bp, x, cos_sin):
    if mixer in ("attn", "attn_local"):
        w = cfg.window if mixer == "attn_local" else None
        return attn_mod.attn_forward(bp, x, cos_sin, window=w,
                                     q_chunk=cfg.q_chunk,
                                     kv_chunk=cfg.kv_chunk,
                                     proj_first=cfg.proj_first)
    if mixer == "mamba":
        return mamba_mod.mamba_forward(bp, x, use_kernel=cfg.mamba_kernel)
    if mixer == "rwkv":
        return rwkv_mod.rwkv_tmix_forward(bp, x, head_dim=cfg.rwkv_head_dim,
                                          use_kernel=cfg.rwkv_kernel)
    raise ValueError(mixer)


def _apply_ffn(cfg, ffn, ffn_params, x):
    """Returns (y, aux)."""
    if ffn == "mlp":
        return L.apply_mlp(ffn_params, x, cfg.act), 0.0
    if ffn == "moe":
        return apply_moe(ffn_params, x, cfg.moe)
    if ffn == "rwkv_cmix":
        return rwkv_mod.rwkv_cmix_forward(ffn_params, x), 0.0
    raise ValueError(ffn)


def _group_body_train(cfg: ModelConfig, cos_sin, x, gparams):
    aux = jnp.zeros((), jnp.float32)
    for pos_idx, (mixer, ffn) in enumerate(cfg.pattern):
        bp = gparams[pos_idx]
        h = L.rmsnorm(bp["norm1"], x, cfg.eps)
        x = x + _apply_mixer_train(cfg, mixer, bp["mixer"], h, cos_sin)
        h = L.rmsnorm(bp["norm2"], x, cfg.eps)
        y, a = _apply_ffn(cfg, ffn, bp["ffn"], h)
        x = x + y
        aux = aux + a
    return x, aux


def _embed_input(cfg: ModelConfig, params, batch, S: int):
    if cfg.input_mode == "tokens":
        x = L.embed(params["embed"], batch["tokens"])
    else:
        x = batch["embeds"].astype(cfg.param_dtype)
        x = shard(x, "dp", "sp", None)
    if cfg.pos == "sinusoidal":
        x = x + L.sinusoidal_positions(S, cfg.d_model, dtype=jnp.float32
                                       ).astype(x.dtype)[None]
    return x


def forward_hidden(params, cfg: ModelConfig, batch):
    """Full-sequence forward to the final norm.  Returns (x, aux)."""
    S = (batch["tokens"].shape[1] if cfg.input_mode == "tokens"
         else batch["embeds"].shape[1])
    x = _embed_input(cfg, params, batch, S)
    cos_sin = _cos_sin(cfg, batch, S)

    body = partial(_group_body_train, cfg, cos_sin)
    if cfg.remat:
        if cfg.remat_policy == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(body)

    def scan_fn(carry, gparams):
        x, aux = carry
        x, a = body(x, gparams)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.eps)
    return x, aux


def _head_table(params, cfg):
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        return params["embed"]["table"]
    return params["lm_head"]["table"]


def train_loss(params, cfg: ModelConfig, batch):
    """Scalar LM loss (f32): chunked CE + MoE aux."""
    x, aux = forward_hidden(params, cfg, batch)
    x = shard(x, "dp", None, None)
    loss = L.chunked_ce_loss(_head_table(params, cfg), x, batch["labels"],
                             chunk=cfg.loss_chunk)
    if cfg.moe is not None:
        loss = loss + cfg.aux_coef * aux / max(1, cfg.n_layers)
    return loss


# ---------------------------------------------------------------------------
# serving: cache structure


def _mixer_cache_shapes(cfg: ModelConfig, mixer: str, B: int, S_max: int):
    dt = cfg.param_dtype
    if mixer == "attn":
        return {
            "k": jax.ShapeDtypeStruct((B, S_max, cfg.n_kv_heads, cfg.d_head), dt),
            "v": jax.ShapeDtypeStruct((B, S_max, cfg.n_kv_heads, cfg.d_head), dt),
        }
    if mixer == "attn_local":
        W = min(cfg.window, S_max)
        return {
            "k": jax.ShapeDtypeStruct((B, W, cfg.n_kv_heads, cfg.d_head), dt),
            "v": jax.ShapeDtypeStruct((B, W, cfg.n_kv_heads, cfg.d_head), dt),
        }
    if mixer == "mamba":
        return mamba_mod.mamba_state_shapes(B, cfg.d_model,
                                            expand=cfg.mamba_expand,
                                            d_state=cfg.mamba_d_state,
                                            dconv=cfg.mamba_dconv)
    if mixer == "rwkv":
        return rwkv_mod.rwkv_state_shapes(B, cfg.d_model,
                                          head_dim=cfg.rwkv_head_dim,
                                          tp_pad=cfg.tp_pad)
    raise ValueError(mixer)


def cache_shapes(cfg: ModelConfig, B: int, S_max: int):
    """ShapeDtypeStruct pytree of the serve cache (dry-run input spec)."""
    out = []
    for mixer, _ in cfg.pattern:
        one = _mixer_cache_shapes(cfg, mixer, B, S_max)
        out.append(jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_groups,) + s.shape, s.dtype),
            one))
    return tuple(out)


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, B, S_max))


# ---------------------------------------------------------------------------
# prefill


def _prime_ring(k_full: jnp.ndarray, W: int) -> jnp.ndarray:
    """(B, S, KH, hd) full keys -> (B, W, KH, hd) ring holding the last W
    tokens at slots (t mod W)."""
    B, S, KH, hd = k_full.shape
    take = min(W, S)
    last = k_full[:, S - take:]
    slots = (jnp.arange(S - take, S) % W)
    ring = jnp.zeros((B, W, KH, hd), k_full.dtype)
    return ring.at[:, slots].set(last)


def prefill(params, cfg: ModelConfig, batch, S_max: Optional[int] = None):
    """Full-sequence forward that also builds the serve cache.

    Returns (last_logits (B, vocab) f32, cache).  ``S_max`` sizes the global
    attention cache (defaults to the prompt length)."""
    S = (batch["tokens"].shape[1] if cfg.input_mode == "tokens"
         else batch["embeds"].shape[1])
    B = (batch["tokens"].shape[0] if cfg.input_mode == "tokens"
         else batch["embeds"].shape[0])
    S_max = S_max or S
    x = _embed_input(cfg, params, batch, S)
    cos_sin = _cos_sin(cfg, batch, S)

    def group_body(x, gparams):
        caches = []
        for pos_idx, (mixer, ffn) in enumerate(cfg.pattern):
            bp = gparams[pos_idx]
            h = L.rmsnorm(bp["norm1"], x, cfg.eps)
            if mixer in ("attn", "attn_local"):
                w = cfg.window if mixer == "attn_local" else None
                y, (k, v) = attn_mod.attn_forward(
                    bp["mixer"], h, cos_sin, window=w, q_chunk=cfg.q_chunk,
                    kv_chunk=cfg.kv_chunk, return_kv=True)
                if mixer == "attn_local":
                    W = min(cfg.window, S_max)
                    cache = {"k": _prime_ring(k, W), "v": _prime_ring(v, W)}
                else:
                    pad = S_max - S
                    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    cache = {"k": shard(kp, "dp", "sp", None, None),
                             "v": shard(vp, "dp", "sp", None, None)}
            elif mixer == "mamba":
                y, cache = mamba_mod.mamba_forward(
                    bp["mixer"], h, return_state=True,
                    use_kernel=cfg.mamba_kernel)
            elif mixer == "rwkv":
                y, cache = rwkv_mod.rwkv_tmix_forward(
                    bp["mixer"], h, head_dim=cfg.rwkv_head_dim,
                    return_state=True, use_kernel=cfg.rwkv_kernel)
            else:
                raise ValueError(mixer)
            x = x + y
            h = L.rmsnorm(bp["norm2"], x, cfg.eps)
            if ffn == "rwkv_cmix":
                y, cstate = rwkv_mod.rwkv_cmix_forward(bp["ffn"], h,
                                                       return_state=True)
                cache.update(cstate)
            else:
                y, _ = _apply_ffn(cfg, ffn, bp["ffn"], h)
            x = x + y
            caches.append(cache)
        return x, tuple(caches)

    x, caches = jax.lax.scan(group_body, x, params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.eps)
    last = x[:, -1]
    logits = jnp.einsum("bd,vd->bv", last, _head_table(params, cfg),
                        preferred_element_type=jnp.float32)
    return logits, caches


# ---------------------------------------------------------------------------
# decode


def decode_step(params, cfg: ModelConfig, cache, batch, pos):
    """One-token serve step.

    batch: {"tokens": (B, 1)} or {"embeds": (B, 1, d)} (+ pos_ids for mrope);
    pos: () int32 absolute position of this token.
    Returns (logits (B, vocab) f32, new_cache)."""
    x = _embed_input_decode(cfg, params, batch, pos)
    cos_sin = _cos_sin(cfg, batch, 1, pos_offset=pos)

    def group_body(x, inp):
        gparams, gcache = inp
        new_caches = []
        for pos_idx, (mixer, ffn) in enumerate(cfg.pattern):
            bp = gparams[pos_idx]
            c = gcache[pos_idx]
            h = L.rmsnorm(bp["norm1"], x, cfg.eps)
            if mixer in ("attn", "attn_local"):
                w = cfg.window if mixer == "attn_local" else None
                y, ck, cv = attn_mod.attn_decode_step(
                    bp["mixer"], h, cos_sin, c["k"], c["v"], pos, window=w)
                nc = {"k": ck, "v": cv}
            elif mixer == "mamba":
                y, nc = mamba_mod.mamba_decode_step(bp["mixer"], h, c)
            elif mixer == "rwkv":
                y, nc = rwkv_mod.rwkv_tmix_forward(
                    bp["mixer"], h, head_dim=cfg.rwkv_head_dim, state=c,
                    return_state=True)
            else:
                raise ValueError(mixer)
            x = x + y
            h = L.rmsnorm(bp["norm2"], x, cfg.eps)
            if ffn == "rwkv_cmix":
                y, cstate = rwkv_mod.rwkv_cmix_forward(bp["ffn"], h, state=c,
                                                       return_state=True)
                nc.update(cstate)
            else:
                y, _ = _apply_ffn(cfg, ffn, bp["ffn"], h)
            x = x + y
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(group_body, x, (params["blocks"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], _head_table(params, cfg),
                        preferred_element_type=jnp.float32)
    return logits, new_cache


def _embed_input_decode(cfg: ModelConfig, params, batch, pos):
    if cfg.input_mode == "tokens":
        x = L.embed(params["embed"], batch["tokens"])  # (B, 1, d)
    else:
        x = batch["embeds"].astype(cfg.param_dtype)
    if cfg.pos == "sinusoidal":
        x = x + L.sinusoidal_positions(1, cfg.d_model, offset=pos,
                                       dtype=jnp.float32).astype(x.dtype)[None]
    return shard(x, "dp", None, None)
