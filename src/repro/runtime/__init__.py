"""Runtime: the unified coded-matmul executor API.

``CodedMatmul`` is the single entry point for every backend (reference /
staged Pallas / fused megakernel / mesh shard_map); ``ErasurePattern``
normalises every erasure convention and ``PartialPattern`` its fractional
generalisation (per-worker sub-task progress); executors are pluggable via
``with_backend``.  See DESIGN.md "Runtime & Executors".
"""
from repro.runtime.erasure import ErasurePattern
from repro.runtime.partial import (
    PartialPattern,
    chunk_bounds,
    chunk_coverage,
    chunk_masks_for,
)
from repro.runtime.executors import (
    BACKENDS,
    Executor,
    FusedKernelExecutor,
    LocalExecutor,
    MeshExecutor,
    ReferenceExecutor,
    StagedKernelExecutor,
    resolve_executor,
)
from repro.runtime.facade import CacheGroup, CodedMatmul, plan_token

__all__ = [
    "CodedMatmul",
    "CacheGroup",
    "plan_token",
    "ErasurePattern",
    "PartialPattern",
    "chunk_bounds",
    "chunk_coverage",
    "chunk_masks_for",
    "Executor",
    "LocalExecutor",
    "ReferenceExecutor",
    "StagedKernelExecutor",
    "FusedKernelExecutor",
    "MeshExecutor",
    "resolve_executor",
    "BACKENDS",
]
