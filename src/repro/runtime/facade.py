"""``CodedMatmul``: one executor-agnostic entry point for coded matmuls.

The facade owns everything the three legacy entry points used to own
separately:

* the ``DecodePanelCache`` (host-LU decode weights per erasure pattern);
* erasure normalisation (``erased=`` / ``survivors=`` / 0/1 ``mask``,
  concrete or traced) into one ``ErasurePattern``;
* batching: leading batch dimensions on A and/or B are lifted with vmap;
* a jit-executable memo keyed by (backend, A.shape, B.shape, dtype,
  erasure-kind), so repeated serving calls - including calls with NEW
  erasure patterns of the same kind - reuse one compiled executable.

Usage::

    cm = CodedMatmul(plan)                      # fused Pallas backend
    C  = cm(A, B, erased=[3])                   # or survivors=/mask=
    C2 = cm.with_backend("reference")(A, B)     # same caches, new backend
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

import numpy as np

from repro import obs
from repro.core.api import CodedMatmulPlan
from repro.runtime.erasure import ErasurePattern
from repro.runtime.executors import (
    Executor,
    local_backend_names,
    resolve_executor,
)
from repro.runtime.partial import PartialPattern

__all__ = ["CodedMatmul", "CacheGroup", "plan_token"]


def _kind_label(kind) -> str:
    """Bounded-cardinality metric label for an executable kind."""
    return kind if isinstance(kind, str) else str(kind[0])


def plan_token(plan: CodedMatmulPlan):
    """Hashable identity of a plan's static configuration.

    Folds in everything a compiled executable or decode panel depends on:
    the scheme (frozen geometry dataclass), worker count, digit base, and
    evaluation points.  Equal-valued plans share a token even when they are
    distinct objects.
    """
    return (plan.scheme, plan.K, plan.s,
            tuple(np.asarray(plan.z_points).ravel().tolist()))


class CacheGroup:
    """Cross-facade shared caches for a FAMILY of plans.

    ``CodedMatmul.with_backend`` already shares caches between sibling
    facades of ONE plan; a ``CacheGroup`` extends that to many plans (the
    control plane's ``PlanLadder`` holds one per ladder).  Executable keys
    fold in each facade's plan token, so distinct rungs never alias a
    compiled program, while the build/hit counters span the whole group —
    ``stats["builds"]`` staying flat across rung switches is the proof that
    switching is recompile-free.  Decode-panel caches remain per-plan
    (panels depend on the scheme and evaluation points) but live here so
    every facade of the same plan shares one.
    """

    def __init__(self):
        self.executables: dict = {}
        self.stats = {"builds": 0, "hits": 0}
        self._panel_caches: dict = {}

    def panel_cache_for(self, plan: CodedMatmulPlan, ridge: float = 0.0):
        """The group's shared ``DecodePanelCache`` for ``plan`` (built once
        per distinct plan token + ridge)."""
        key = (plan_token(plan), ridge)
        pc = self._panel_caches.get(key)
        if pc is None:
            pc = plan.make_panel_cache(ridge)
            self._panel_caches[key] = pc
        return pc

    def seed_extended_panels(self, old_plan: CodedMatmulPlan,
                             new_plan: CodedMatmulPlan,
                             ridge: float = 0.0) -> bool:
        """Seed ``new_plan``'s panel cache from ``old_plan``'s by extension.

        The elastic grow path: when ``new_plan``'s evaluation points
        extend ``old_plan``'s (bit-exact prefix), every decode panel
        cached for the old pool transfers to the grown pool with zero
        columns appended for the new workers
        (``DecodePanelCache.extended``) — no refactorisation, and the old
        plan's cache is untouched.  Returns True when seeding happened;
        False when there was nothing to seed from, the new cache already
        exists, or the points do not extend.
        """
        old = self._panel_caches.get((plan_token(old_plan), ridge))
        new_key = (plan_token(new_plan), ridge)
        if old is None or new_key in self._panel_caches:
            return False
        try:
            self._panel_caches[new_key] = old.extended(
                np.asarray(new_plan.z_points))
        except ValueError:
            return False
        return True

    @property
    def panel_builds(self) -> int:
        """Total decode panels built across every member plan."""
        return sum(pc.builds for pc in self._panel_caches.values())

    def cache_info(self) -> dict:
        """Group-wide executable and decode-panel cache counters."""
        return {
            "builds": self.stats["builds"],
            "hits": self.stats["hits"],
            "entries": len(self.executables),
            "panel_builds": self.panel_builds,
            "plans": len(self._panel_caches),
        }


class CodedMatmul:
    """Coded C = A^T B with a pluggable execution backend.

    A: (*batch, v, r), B: (*batch, v, t) -> C: (*batch, r, t).  Leading
    batch dimensions must match on A and B, or be present on only one of
    them.  The erasure pattern applies to the whole batch (one survivor
    set per serving step).

    Backends: "reference" | "staged" | "fused" (default) | "mesh" (pass
    ``mesh=``, one worker per device along ``axis``).  All backends are
    bit-identical for integer inputs within the plan's bounds.
    """

    def __init__(self, plan: CodedMatmulPlan, backend="fused", *,
                 dtype=jnp.float64, mesh=None, axis: str = "model",
                 use_kernels: bool = True, fused: bool = True,
                 panel_ridge: float = 0.0, cache_group: "CacheGroup" = None,
                 sub_tasks: int = 1, _shared=None):
        if sub_tasks < 1:
            raise ValueError(f"need sub_tasks >= 1, got {sub_tasks}")
        self.sub_tasks = int(sub_tasks)
        self.plan = plan
        self.dtype = jnp.dtype(dtype)
        self._mesh = mesh
        self._axis = axis
        self._use_kernels = use_kernels
        self._fused = fused
        self._plan_token = plan_token(plan)
        self._executor: Executor = resolve_executor(
            backend, mesh=mesh, axis=axis, use_kernels=use_kernels,
            fused=fused)
        if cache_group is not None and _shared is not None:
            raise ValueError("pass cache_group or _shared, not both")
        if cache_group is not None:
            # cross-facade sharing hook: many plans, one executable memo
            # (keys fold in the plan token) + one stats block.
            self.panel_cache = cache_group.panel_cache_for(plan, panel_ridge)
            self._executables = cache_group.executables
            self._stats = cache_group.stats
        elif _shared is not None:
            self.panel_cache, self._executables, self._stats = _shared
        else:
            self.panel_cache = plan.make_panel_cache(panel_ridge)
            self._executables = {}
            self._stats = {"builds": 0, "hits": 0}

    # -- backend plumbing ---------------------------------------------------
    @property
    def backend(self) -> str:
        """Name of the executor serving this facade's calls."""
        return self._executor.name

    def with_backend(self, backend, *, mesh=None, axis: Optional[str] = None,
                     use_kernels: Optional[bool] = None,
                     fused: Optional[bool] = None) -> "CodedMatmul":
        """A sibling facade on another backend, SHARING panel + jit caches."""
        return CodedMatmul(
            self.plan, backend, dtype=self.dtype,
            mesh=self._mesh if mesh is None else mesh,
            axis=self._axis if axis is None else axis,
            use_kernels=self._use_kernels if use_kernels is None else use_kernels,
            fused=self._fused if fused is None else fused,
            sub_tasks=self.sub_tasks,
            _shared=(self.panel_cache, self._executables, self._stats))

    def cache_info(self) -> dict:
        """Executable-memo and panel-cache counters (tests assert on these)."""
        return {
            "builds": self._stats["builds"],
            "hits": self._stats["hits"],
            "entries": len(self._executables),
            "panel_builds": self.panel_cache.builds,
        }

    def executable_cache_size(self) -> int:
        """Total jit-compiled specialisations across memoised executables."""
        total = 0
        for fn in self._executables.values():
            size = getattr(fn, "_cache_size", None)
            total += int(size()) if callable(size) else 1
        return total

    # -- the call -----------------------------------------------------------
    def __call__(self, A, B, erasure: Any = None, *,
                 erased: Optional[Sequence[int]] = None,
                 survivors: Optional[Sequence[int]] = None,
                 mask: Any = None, progress: Any = None,
                 sub_tasks: Optional[int] = None) -> jnp.ndarray:
        """Coded C = A^T B under at most one erasure spec (none = all alive).

        Args:
            A: (*batch, v, r) left operand.
            B: (*batch, v, t) right operand.
            erasure: positional spec — an ``ErasurePattern``, a
                ``PartialPattern``, a (K,) 0/1 mask, or a list of erased
                worker ids.
            erased / survivors / mask: keyword alternatives.
            progress: (K,) fractional progress in [0, 1] — routes through
                the partial-straggler decode (``runtime/partial.py``).
            sub_tasks: per-call override of the facade's sub-task count Q.
                ``Q > 1`` (or an explicit ``progress``/``PartialPattern``)
                selects the partial path; ``Q = 1`` with binary specs is the
                legacy path, bit for bit.

        Returns:
            (*batch, r, t) decoded product.

        Raises:
            ValueError: on conflicting erasure specs, rank-<2 operands,
                contraction mismatch, fewer than tau survivors, or a partial
                progress vector that does not span the decoding system.
        """
        Q = self.sub_tasks if sub_tasks is None else int(sub_tasks)
        if Q < 1:
            raise ValueError(f"need sub_tasks >= 1, got {Q}")
        if Q > 1 or progress is not None or isinstance(erasure, PartialPattern):
            pattern = PartialPattern.normalize(
                self.plan.K, Q, erasure, progress=progress, erased=erased,
                survivors=survivors, mask=mask)
            return self._call_partial(A, B, pattern)
        pattern = ErasurePattern.normalize(
            self.plan.K, erasure, erased=erased, survivors=survivors,
            mask=mask)
        A = jnp.asarray(A)
        B = jnp.asarray(B)
        self._check_operands(A, B)
        fn = self._get_executable(A, B, pattern.kind)
        mask_arr = pattern.mask_array(self._mask_dtype())
        if pattern.kind == "concrete":
            if pattern.n_survivors < self.plan.tau:
                raise ValueError(
                    f"only {pattern.n_survivors} survivors < "
                    f"tau={self.plan.tau}: undecodable")
            panel = self.panel_cache.get(pattern.mask)
            W = jnp.asarray(panel.W, self._decode_dtype())
            return fn(A, B, mask_arr, W)
        return fn(A, B, mask_arr)

    # -- split-stage serving -------------------------------------------------
    def worker_stage(self, A, B) -> jnp.ndarray:
        """Stages 1+2 only: encode + ALL-K worker products (no erase/decode).

        The returned (*batch, K, br, bt) padded block products are what the
        workers hand back before any erasure is applied; feed them to
        :meth:`decode_stage` (with the erasure pattern observed MEANWHILE)
        to finish the step.  Splitting the call lets a serving loop overlap
        decode of step ``t`` with the worker stage of step ``t+1``; the
        composition is bit-identical to the one-shot ``__call__``.

        Raises:
            NotImplementedError: on backends whose pipeline has no
                worker/decode seam (mesh).
        """
        A = jnp.asarray(A)
        B = jnp.asarray(B)
        self._check_operands(A, B)
        fn = self._get_executable(A, B, "products")
        return fn(A, B)

    def decode_stage(self, Y, rt, erasure: Any = None, *,
                     erased: Optional[Sequence[int]] = None,
                     survivors: Optional[Sequence[int]] = None,
                     mask: Any = None, progress: Any = None,
                     sub_tasks: Optional[int] = None) -> jnp.ndarray:
        """Stages 3+4: erase + decode a :meth:`worker_stage` result.

        Args:
            Y: (*batch, K, br, bt) worker products from THIS facade's
                :meth:`worker_stage` (same plan, same operand shapes).
            rt: the original trailing dims ``(r, t)`` =
                ``(A.shape[-1], B.shape[-1])`` — static per executable,
                because slicing the block padding off the recomposed
                product needs concrete sizes the stage input no longer
                carries.
            erasure / erased / survivors / mask: binary erasure spec, as
                for ``__call__`` (concrete or traced).
            progress / sub_tasks: rejected here — partial-straggler specs
                have no split-stage path (see the raise below).

        Returns:
            (*batch, r, t) decoded product, bit-identical to the one-shot
            call under the same pattern.

        Raises:
            ValueError: on conflicting specs or fewer than tau survivors.
            NotImplementedError: on backends with no worker/decode seam,
                and for partial/progress specs: split-stage decode has no
                per-chunk panel path, because the (Q, mn, K) panel stack is
                keyed by the chunk-availability matrix, which the staged
                (K, br, bt) products no longer determine — serve partial
                patterns one-shot via ``cm(A, B, progress=..., sub_tasks=Q)``
                instead (any backend).
        """
        if (progress is not None
                or (sub_tasks is not None and int(sub_tasks) != 1)
                or isinstance(erasure, PartialPattern)):
            raise NotImplementedError(
                "split-stage decode has no per-chunk panel path: "
                "decode_stage accepts only binary erasure specs "
                "(erasure= / erased= / survivors= / mask=). Serve partial "
                "patterns one-shot via cm(A, B, progress=..., sub_tasks=Q) "
                "— supported on every backend, including mesh (the "
                f"worker/decode seam itself exists only on the local "
                f"backends: {local_backend_names()}).")
        Y = jnp.asarray(Y)
        r, t = int(rt[0]), int(rt[1])
        pattern = ErasurePattern.normalize(
            self.plan.K, erasure, erased=erased, survivors=survivors,
            mask=mask)
        kind = (("decode", r, t) if pattern.kind == "concrete"
                else ("decode-traced", r, t))
        fn = self._get_decode_executable(Y, kind)
        mask_arr = pattern.mask_array(self._mask_dtype())
        if pattern.kind == "concrete":
            if pattern.n_survivors < self.plan.tau:
                raise ValueError(
                    f"only {pattern.n_survivors} survivors < "
                    f"tau={self.plan.tau}: undecodable")
            panel = self.panel_cache.get(pattern.mask)
            W = jnp.asarray(panel.W, self._decode_dtype())
            return fn(Y, mask_arr, W)
        return fn(Y, mask_arr)

    def _call_partial(self, A, B, pattern: PartialPattern) -> jnp.ndarray:
        """Partial-straggler decode path: per-chunk masks + panel stack."""
        A = jnp.asarray(A)
        B = jnp.asarray(B)
        self._check_operands(A, B)
        if pattern.is_concrete:
            pattern.require_decodable(self.plan.tau)
            fn = self._get_executable(A, B, ("partial", pattern.Q))
            cm = pattern.chunk_masks
            W_stack = self.panel_cache.get_partial(cm)
            return fn(A, B, jnp.asarray(cm, self._mask_dtype()),
                      jnp.asarray(W_stack, self._decode_dtype()))
        fn = self._get_executable(A, B, ("partial-traced", pattern.Q))
        return fn(A, B, pattern.progress_array(self._mask_dtype()))

    def _check_operands(self, A, B) -> None:
        if A.ndim < 2 or B.ndim < 2:
            raise ValueError(f"need >= 2-D operands, got {A.shape} / {B.shape}")
        if A.shape[-2] != B.shape[-2]:
            raise ValueError(f"contraction mismatch {A.shape} vs {B.shape}")

    # -- executable construction -------------------------------------------
    def _get_executable(self, A, B, kind):
        # the token folds in executor CONFIG (mesh/axis/kernel flags) and
        # the PLAN identity, so with_backend siblings that share a backend
        # name but differ in config — and CacheGroup members on different
        # plans — never alias each other's compiled executables.
        key = (self._plan_token, self._executor.cache_token(), A.shape,
               B.shape, str(self.dtype), kind)
        fn = self._executables.get(key)
        if fn is not None:
            self._stats["hits"] += 1
            obs.count("runtime.executable.hit", kind=_kind_label(kind))
            return fn
        with obs.span("runtime.executable.build", kind=_kind_label(kind), backend=self.backend):
            fn = self._build(A.ndim - 2, B.ndim - 2, kind)
        self._executables[key] = fn
        self._stats["builds"] += 1
        obs.count("runtime.executable.compile", kind=_kind_label(kind))
        return fn

    def _get_decode_executable(self, Y, kind):
        # decode-stage memo: keyed on the PRODUCTS shape plus the static
        # (r, t) folded into the kind — leading dims beyond (K, br, bt)
        # are batch dims, vmapped over Y only (mask/W stay per-step data).
        key = (self._plan_token, self._executor.cache_token(), Y.shape,
               str(self.dtype), kind)
        fn = self._executables.get(key)
        if fn is not None:
            self._stats["hits"] += 1
            obs.count("runtime.executable.hit", kind=_kind_label(kind))
            return fn
        with obs.span("runtime.executable.build", kind=_kind_label(kind), backend=self.backend):
            base = self._executor.make_pipeline(self.plan, kind, self.dtype)
            n_data = 2 if kind[0] == "decode" else 1
            for _ in range(Y.ndim - 3):
                base = jax.vmap(base, in_axes=(0, *([None] * n_data)))
            fn = jax.jit(base)
        self._executables[key] = fn
        self._stats["builds"] += 1
        obs.count("runtime.executable.compile", kind=_kind_label(kind))
        return fn

    def _build(self, a_batch: int, b_batch: int, kind):
        base = self._executor.make_pipeline(self.plan, kind, self.dtype)
        # data operands after (A, B): (mask, W) / (chunk_masks, W_stack) for
        # panel-carrying kinds, (mask,) / (progress,) for traced ones, and
        # none at all for the split worker stage ("products").
        if kind == "products":
            n_data = 0
        else:
            n_data = 2 if kind == "concrete" or (
                isinstance(kind, tuple) and kind[0] == "partial") else 1
        if (a_batch or b_batch) and not self._executor.supports_batching:
            raise NotImplementedError(
                f"backend {self.backend!r} does not support batched operands")
        if a_batch and b_batch and a_batch != b_batch:
            raise ValueError(
                f"batch rank mismatch: A has {a_batch} leading dims, "
                f"B has {b_batch}; batch one operand or both equally")
        fn = base
        for _ in range(max(a_batch, b_batch)):
            in_axes = (0 if a_batch else None, 0 if b_batch else None,
                       *([None] * n_data))
            fn = jax.vmap(fn, in_axes=in_axes)
        return jax.jit(fn)

    # -- dtype policy -------------------------------------------------------
    def _mask_dtype(self):
        return jnp.float64 if self.dtype == jnp.float64 else jnp.float32

    def _decode_dtype(self):
        if self.plan.is_complex:
            return (jnp.complex128 if self.dtype == jnp.float64
                    else jnp.complex64)
        return self.dtype
