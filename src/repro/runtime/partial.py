"""Fractional straggler progress: ``PartialPattern`` and the chunk schedule.

``ErasurePattern`` models a worker as binary — alive or erased.  The
partial-straggler line of work (Das & Ramamoorthy, arXiv 2012.06065,
2109.12070) shows a slow worker that completed an ordered PREFIX of its
task still contributes to decoding.  This module is the runtime face of
that idea: each worker's coded block product ``A~_k^T B~_k`` is split into
``Q`` ordered sub-tasks (row chunks of the output), and a worker reporting
progress ``q/Q`` has completed ``q`` of them.

Chunk schedule
--------------
A naive schedule (every worker processes chunk 0 first, then 1, ...) is
useless: chunk ``Q-1`` would only ever be covered by workers that finished
EVERYTHING, so recovery would still need tau full finishers.  Workers
therefore process chunks in a CYCLIC order — worker ``k`` runs chunk
``(k + j) % Q`` as its ``j``-th sub-task — so each prefix length spreads
its coverage evenly over the chunks:

    worker k has chunk c  <=>  ((c - k) mod Q) < q_k

Decodability is then PER CHUNK: chunk ``c`` decodes iff at least tau
workers completed it, and the whole product decodes iff every chunk does.
A binary pattern is the special case ``q_k in {0, Q}``; ``Q = 1`` is
exactly ``ErasurePattern``.

Like ``ErasurePattern``, a pattern is *concrete* (host-known progress:
the decode path looks up a per-chunk panel stack keyed on the quantized
signature) or *traced* (progress is a jax tracer: per-chunk masked
normal-equation solves in-body).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.runtime.erasure import ErasurePattern

__all__ = ["PartialPattern", "chunk_bounds", "chunk_masks_for",
           "chunk_coverage"]


def _is_traced(x: Any) -> bool:
    return isinstance(x, jax.core.Tracer)


def chunk_bounds(rows: int, Q: int) -> tuple:
    """Row offsets splitting ``rows`` output rows into ``Q`` ordered chunks.

    Chunks differ in size by at most one row (the first ``rows % Q`` chunks
    get the extra row).  Returns ``Q + 1`` offsets.

    Raises:
        ValueError: when ``rows < Q`` (a chunk would be empty).
    """
    if Q < 1:
        raise ValueError(f"need Q >= 1 sub-tasks, got {Q}")
    if rows < Q:
        raise ValueError(
            f"cannot split {rows} output rows into Q={Q} non-empty chunks; "
            f"lower --sub-tasks or grow the block size")
    sizes = np.full(Q, rows // Q, dtype=np.int64)
    sizes[: rows % Q] += 1
    return tuple(int(x) for x in np.concatenate([[0], np.cumsum(sizes)]))


def chunk_masks_for(counts: np.ndarray, Q: int) -> np.ndarray:
    """(Q, K) 0/1 chunk-availability masks from per-worker chunk counts.

    ``counts[k]`` is the number of sub-tasks worker ``k`` completed under
    the cyclic schedule; row ``c`` of the result masks the workers whose
    prefix covers chunk ``c``.
    """
    counts = np.asarray(counts, dtype=np.int64)
    c = np.arange(Q)[:, None]
    k = np.arange(counts.shape[0])[None, :]
    return (((c - k) % Q) < counts[None, :]).astype(np.float64)


def chunk_coverage(counts: np.ndarray, Q: int) -> np.ndarray:
    """(Q,) number of workers covering each chunk under the cyclic schedule."""
    return chunk_masks_for(counts, Q).sum(axis=1).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class PartialPattern:
    """Per-worker fractional progress over K workers and Q sub-tasks.

    ``progress`` is a (K,) float array in [0, 1] for ``kind == "concrete"``
    (quantized to multiples of ``1/Q`` via ``chunk_counts``) and the
    original jax value for ``kind == "traced"``.
    """

    K: int
    Q: int
    kind: str  # "concrete" | "traced"
    progress: Any

    # -- constructors -------------------------------------------------------
    @classmethod
    def full(cls, K: int, Q: int) -> "PartialPattern":
        """Every worker completed all ``Q`` sub-tasks."""
        cls._check_q(Q)
        return cls(K=K, Q=Q, kind="concrete",
                   progress=np.ones(K, dtype=np.float64))

    @classmethod
    def from_progress(cls, K: int, Q: int, progress: Any) -> "PartialPattern":
        """Pattern from a (K,) progress vector — concrete array or tracer.

        Raises:
            ValueError: on a bad shape, or concrete values outside [0, 1].
        """
        cls._check_q(Q)
        if _is_traced(progress):
            if getattr(progress, "shape", None) != (K,):
                raise ValueError(
                    f"traced progress shape "
                    f"{getattr(progress, 'shape', None)} != ({K},)")
            return cls(K=K, Q=Q, kind="traced", progress=progress)
        prog = np.asarray(progress, dtype=np.float64)
        if prog.shape != (K,):
            raise ValueError(f"progress shape {prog.shape} != ({K},)")
        if not np.all(np.isfinite(prog)) or np.any(prog < 0) or np.any(prog > 1):
            raise ValueError(
                f"progress must lie in [0, 1], got {prog.tolist()}")
        return cls(K=K, Q=Q, kind="concrete", progress=prog)

    @classmethod
    def from_erasure(cls, pattern: ErasurePattern, Q: int) -> "PartialPattern":
        """Lift a binary ``ErasurePattern`` (0/1 progress) to ``Q`` sub-tasks."""
        cls._check_q(Q)
        if pattern.is_concrete:
            return cls(K=pattern.K, Q=Q, kind="concrete",
                       progress=np.asarray(pattern.mask, dtype=np.float64))
        return cls(K=pattern.K, Q=Q, kind="traced",
                   progress=pattern.mask)

    @classmethod
    def normalize(
        cls,
        K: int,
        Q: int,
        spec: Any = None,
        *,
        progress: Any = None,
        erased: Optional[Sequence[int]] = None,
        survivors: Optional[Sequence[int]] = None,
        mask: Any = None,
    ) -> "PartialPattern":
        """Accept one spec (pattern / progress / binary forms; none = full).

        A ``PartialPattern`` spec must agree with ``K`` (and with ``Q``
        unless it carries its own); binary specs become 0/1 progress.
        """
        if spec is not None and progress is not None:
            raise ValueError("pass only one of partial spec / progress")
        if isinstance(spec, PartialPattern):
            if spec.K != K:
                raise ValueError(
                    f"pattern built for K={spec.K}, plan has K={K}")
            return spec
        if isinstance(spec, ErasurePattern):
            return cls.from_erasure(spec, Q)
        if spec is not None:
            return cls.from_progress(K, Q, spec)
        if progress is not None:
            return cls.from_progress(K, Q, progress)
        if erased is not None or survivors is not None or mask is not None:
            return cls.from_erasure(
                ErasurePattern.normalize(K, erased=erased,
                                         survivors=survivors, mask=mask), Q)
        return cls.full(K, Q)

    # -- views --------------------------------------------------------------
    @property
    def is_concrete(self) -> bool:
        """True when the progress vector is host-known (not a jax tracer)."""
        return self.kind == "concrete"

    @property
    def chunk_counts(self) -> np.ndarray:
        """(K,) completed sub-task counts: ``floor(progress * Q)`` (concrete)."""
        self._require_concrete("chunk_counts")
        return np.floor(np.asarray(self.progress) * self.Q
                        + 1e-9).astype(np.int64)

    @property
    def chunk_masks(self) -> np.ndarray:
        """(Q, K) per-chunk worker-availability masks (concrete patterns)."""
        return chunk_masks_for(self.chunk_counts, self.Q)

    @property
    def coverage(self) -> np.ndarray:
        """(Q,) workers covering each chunk (concrete patterns)."""
        return chunk_coverage(self.chunk_counts, self.Q)

    @property
    def key(self) -> tuple:
        """Hashable identity: (Q, quantized signature) for concrete patterns."""
        if self.is_concrete:
            return (self.Q,) + tuple(int(c) for c in self.chunk_counts)
        return (self.Q, "traced")

    def decodable(self, tau: int) -> bool:
        """True when every chunk has at least ``tau`` contributors."""
        return bool(np.all(self.coverage >= tau))

    def require_decodable(self, tau: int) -> None:
        """Raise loudly (not garbage output) when a chunk is undercovered.

        Raises:
            ValueError: naming every chunk whose coverage is below ``tau``.
        """
        cov = self.coverage
        bad = np.flatnonzero(cov < tau)
        if bad.size:
            detail = ", ".join(f"chunk {int(c)}: {int(cov[c])}" for c in bad)
            raise ValueError(
                f"partial progress does not span the decoding system: "
                f"need >= tau={tau} contributors per chunk, got {detail} "
                f"(counts {self.chunk_counts.tolist()}, Q={self.Q})")

    def progress_array(self, dtype):
        """The progress vector as a jax array of ``dtype`` (traced passthrough)."""
        import jax.numpy as jnp

        if self.is_concrete:
            return jnp.asarray(self.progress, dtype)
        return self.progress.astype(dtype)

    # -- helpers ------------------------------------------------------------
    def _require_concrete(self, what: str) -> None:
        if not self.is_concrete:
            raise ValueError(f"{what} is undefined for a traced partial pattern")

    @staticmethod
    def _check_q(Q: int) -> None:
        if Q < 1:
            raise ValueError(f"need Q >= 1 sub-tasks, got {Q}")
