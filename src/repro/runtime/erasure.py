"""One erasure-pattern type for every entry point.

Historically each entry point had its own convention: ``coded_matmul`` took
``erased=`` / ``survivors=`` lists, ``coded_matmul_mesh`` took a 0/1 ``mask``
array (concrete or traced), and ``CodedLinearPlan`` forwarded a mask.
``ErasurePattern`` normalises all of them into one value with two *kinds*:

* ``concrete`` - the survivor set is host-known (a Python list, a numpy
  array, or a committed jax array).  The runtime can build/look up a
  ``DecodePanel`` for it and the erasure pattern never enters the traced
  program as a shape or branch - repeated calls with different concrete
  patterns reuse ONE compiled executable.
* ``traced``   - the mask is a jax tracer (the pattern is data inside an
  enclosing jit/vmap).  Decode falls back to the in-body masked
  normal-equation solve.

Positional normalisation rule: an array-like of length K is a 0/1 mask;
anything else sequence-like is a list of erased worker ids.  Use the
keyword forms when in doubt.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import numpy as np

__all__ = ["ErasurePattern"]


def _is_traced(x: Any) -> bool:
    return isinstance(x, jax.core.Tracer)


@dataclasses.dataclass(frozen=True)
class ErasurePattern:
    """Normalised survivor/erasure description for K workers.

    ``mask`` is a (K,) 0/1 numpy array for ``kind == "concrete"`` and the
    original jax value for ``kind == "traced"``.
    """

    K: int
    kind: str  # "concrete" | "traced"
    mask: Any

    # -- constructors -------------------------------------------------------
    @classmethod
    def all_alive(cls, K: int) -> "ErasurePattern":
        """The no-failure pattern: every one of the K workers survives."""
        return cls(K=K, kind="concrete", mask=np.ones(K, dtype=np.float64))

    @classmethod
    def from_erased(cls, K: int, erased: Sequence[int]) -> "ErasurePattern":
        """Concrete pattern from a list of ERASED worker ids.

        Raises:
            ValueError: on duplicate or out-of-range ids.
        """
        ids = cls._check_ids(K, erased, "erased")
        mask = np.ones(K, dtype=np.float64)
        mask[list(ids)] = 0.0
        return cls(K=K, kind="concrete", mask=mask)

    @classmethod
    def from_survivors(cls, K: int, survivors: Sequence[int]) -> "ErasurePattern":
        """Concrete pattern from a list of SURVIVING worker ids.

        Raises:
            ValueError: on duplicate or out-of-range ids.
        """
        ids = cls._check_ids(K, survivors, "survivors")
        mask = np.zeros(K, dtype=np.float64)
        mask[list(ids)] = 1.0
        return cls(K=K, kind="concrete", mask=mask)

    @classmethod
    def from_mask(cls, K: int, mask: Any) -> "ErasurePattern":
        """Pattern from a (K,) 0/1 mask — concrete array or jax tracer.

        Raises:
            ValueError: if the mask's shape is not (K,), or a concrete mask
                holds values outside {0, 1} (a fractional progress vector
                passed as a binary mask would otherwise silently decode as
                if every straggler were fully alive).
        """
        if _is_traced(mask):
            if getattr(mask, "shape", None) != (K,):
                raise ValueError(
                    f"traced mask shape {getattr(mask, 'shape', None)} != ({K},)")
            return cls(K=K, kind="traced", mask=mask)
        m = np.asarray(mask)
        if m.shape != (K,):
            raise ValueError(f"mask shape {m.shape} != ({K},)")
        if not bool(np.all((m == 0) | (m == 1))):
            raise ValueError(
                f"binary erasure mask entries must be 0 or 1, got "
                f"{m.tolist()}: a fractional per-worker completion vector "
                f"is NOT an erasure mask — pass it as progress= with "
                f"sub_tasks=Q (or a PartialPattern) so the finished prefix "
                f"of each straggler is decoded instead of discarded")
        return cls(K=K, kind="concrete", mask=(m != 0).astype(np.float64))

    @classmethod
    def normalize(
        cls,
        K: int,
        spec: Any = None,
        *,
        erased: Optional[Sequence[int]] = None,
        survivors: Optional[Sequence[int]] = None,
        mask: Any = None,
    ) -> "ErasurePattern":
        """Accept exactly one of spec/erased/survivors/mask (or none)."""
        given = [x is not None for x in (spec, erased, survivors, mask)]
        if sum(given) > 1:
            raise ValueError(
                "pass only one of erasure spec / erased / survivors / mask")
        if spec is not None:
            if isinstance(spec, ErasurePattern):
                if spec.K != K:
                    raise ValueError(f"pattern built for K={spec.K}, plan has K={K}")
                return spec
            if _is_traced(spec) or (
                hasattr(spec, "shape") and getattr(spec, "shape") == (K,)
            ):
                return cls.from_mask(K, spec)
            if isinstance(spec, (list, tuple, np.ndarray)):
                arr = np.asarray(spec)
                if arr.shape == (K,):
                    return cls.from_mask(K, arr)
                return cls.from_erased(K, [int(i) for i in arr.reshape(-1)])
            raise TypeError(f"cannot interpret erasure spec {type(spec).__name__}")
        if erased is not None:
            return cls.from_erased(K, erased)
        if survivors is not None:
            return cls.from_survivors(K, survivors)
        if mask is not None:
            return cls.from_mask(K, mask)
        return cls.all_alive(K)

    # -- views --------------------------------------------------------------
    @property
    def is_concrete(self) -> bool:
        """True when the survivor set is host-known (not a jax tracer)."""
        return self.kind == "concrete"

    @property
    def survivors(self) -> tuple:
        """Surviving worker ids, ascending (concrete patterns only)."""
        self._require_concrete("survivors")
        return tuple(int(i) for i in np.flatnonzero(self.mask))

    @property
    def erased(self) -> tuple:
        """Erased worker ids, ascending (concrete patterns only)."""
        self._require_concrete("erased")
        return tuple(int(i) for i in np.flatnonzero(self.mask == 0))

    @property
    def n_survivors(self) -> int:
        """Number of surviving workers (concrete patterns only)."""
        self._require_concrete("n_survivors")
        return int(np.sum(self.mask != 0))

    @property
    def key(self) -> tuple:
        """Hashable identity: the support for concrete, the kind for traced."""
        if self.is_concrete:
            return tuple(int(x != 0) for x in self.mask)
        return ("traced",)

    def mask_array(self, dtype):
        """The mask as a jax-consumable array of ``dtype`` (traced passthrough)."""
        import jax.numpy as jnp

        if self.is_concrete:
            return jnp.asarray(self.mask, dtype)
        return self.mask.astype(dtype)

    # -- helpers ------------------------------------------------------------
    def _require_concrete(self, what: str) -> None:
        if not self.is_concrete:
            raise ValueError(f"{what} is undefined for a traced erasure pattern")

    @staticmethod
    def _check_ids(K: int, ids: Sequence[int], what: str) -> Sequence[int]:
        ids = [int(i) for i in ids]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate worker ids in {what}: {ids}")
        for i in ids:
            if not 0 <= i < K:
                raise ValueError(f"{what} id {i} out of range for K={K}")
        return ids
