"""Pluggable backends for the coded-matmul pipeline.

Every executor turns (A, B, erasure) into the decoded product C through the
same four stages (encode -> worker products -> erase -> decode); what varies
is WHERE and HOW the worker products are computed:

  reference  pure-jnp einsum oracle (ground truth, any backend, complex ok)
  staged     Pallas encode kernel -> HBM -> Pallas block matmul per worker
  fused      one Pallas megakernel per call; coded tiles live only in VMEM
  mesh       shard_map over a worker axis: one device per worker, erasure
             (binary or per-chunk partial) as a runtime mask, all-gather +
             replicated decode

Executors expose ``make_pipeline(plan, kind, dtype)`` returning a pure
function the ``CodedMatmul`` facade jit-compiles and memoises:

  kind == "concrete":  fn(A, B, mask, W)  with W the (mn, K) decode panel
  kind == "traced":    fn(A, B, mask)     in-body masked solve

Partial-straggler kinds are tuples carrying the sub-task count Q
(``runtime/partial.py``): each worker's output rows split into Q cyclic
chunks and decode consumes whatever prefix each worker finished:

  kind == ("partial", Q):         fn(A, B, chunk_masks, W_stack)
                                  chunk_masks (Q, K), W_stack (Q, mn, K)
  kind == ("partial-traced", Q):  fn(A, B, progress)  with progress (K,)

All signatures take the erasure/progress pattern strictly as DATA, so one
compiled executable serves every pattern of that kind.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.api import (
    CodedMatmulPlan,
    _coeff_dtype,
    encode_blocks,
    fused_worker_products,
    worker_products,
)
from repro.core.decoding import decode_masked, decode_with_weights, digit_extract
from repro.core.partition import block_decompose, block_recompose, unpad
from repro.runtime.partial import chunk_bounds
from repro.distributed.sharding import shard_map_compat
from repro.kernels import ops as kops

__all__ = [
    "Executor",
    "LocalExecutor",
    "ReferenceExecutor",
    "StagedKernelExecutor",
    "FusedKernelExecutor",
    "MeshExecutor",
    "resolve_executor",
    "BACKENDS",
    "local_backend_names",
]


@runtime_checkable
class Executor(Protocol):
    """Backend protocol: a name plus a pipeline builder per erasure kind."""

    name: str
    supports_batching: bool

    def make_pipeline(
        self, plan: CodedMatmulPlan, kind: str, dtype
    ) -> Callable:  # pragma: no cover - protocol
        """A pure (A, B, mask[, W]) -> C pipeline for one erasure kind."""
        ...

    def cache_token(self):  # pragma: no cover - protocol
        """Hashable identity for the executable memo: name + any config
        that changes the compiled pipeline (mesh, axis, kernel flags)."""
        ...


class LocalExecutor:
    """Shared single-host pipeline; subclasses provide the worker stage."""

    name = "local"
    supports_batching = True

    def cache_token(self):
        """Executable-memo identity (the name: local executors are config-free)."""
        return self.name

    def worker_products(
        self, plan: CodedMatmulPlan, a_blocks: jnp.ndarray, b_blocks: jnp.ndarray
    ) -> jnp.ndarray:
        """(p, m, bv, br), (p, n, bv, bt) -> all-K worker outputs (K, br, bt)."""
        raise NotImplementedError

    def make_pipeline(self, plan: CodedMatmulPlan, kind, dtype) -> Callable:
        """The single-host 4-stage pipeline for one erasure ``kind``."""
        g = plan.scheme.grid

        def products(A, B):
            a_blocks = block_decompose(A.astype(dtype), g.p, g.m)
            b_blocks = block_decompose(B.astype(dtype), g.p, g.n)
            return self.worker_products(plan, a_blocks, b_blocks)  # (K, br, bt)

        def stages(A, B, mask):
            Y = products(A, B)
            # stage 3 ERASE: zero failed workers' outputs (decode weights
            # also annihilate them; the multiply keeps parity with the mesh
            # pipeline where erased devices genuinely emit garbage).
            return Y * mask.astype(Y.dtype)[:, None, None]

        def finish(C_blocks, r, t):
            return unpad(block_recompose(C_blocks), (r, t)).astype(dtype)

        if kind == "products":
            # stage 1+2 only (encode + worker products), for split-stage
            # serving: the (K, br, bt) output feeds a ("decode", r, t)
            # executable later, possibly while the NEXT step's products run.
            return products

        if isinstance(kind, tuple):
            if kind[0] in ("decode", "decode-traced"):
                return self._make_decode_pipeline(plan, kind, finish)
            return self._make_partial_pipeline(plan, kind, dtype, products,
                                               finish)

        if kind == "concrete":

            def fn(A, B, mask, W):
                Y = stages(A, B, mask)
                C_blocks = decode_with_weights(plan.scheme, W, Y, plan.s)
                return finish(C_blocks, A.shape[1], B.shape[1])

            return fn

        z_all = jnp.asarray(plan.z_points)

        def fn(A, B, mask):
            Y = stages(A, B, mask)
            C_blocks = decode_masked(plan.scheme, z_all, Y,
                                     mask.astype(Y.real.dtype), plan.s)
            return finish(C_blocks, A.shape[1], B.shape[1])

        return fn

    def _make_decode_pipeline(self, plan: CodedMatmulPlan, kind: tuple,
                              finish: Callable) -> Callable:
        """Stage 3+4 only: erase + decode precomputed worker products.

        The kind tuple carries the ORIGINAL unpadded operand trailing dims
        ``(r, t)`` statically — the products array has padded block shape,
        so the slice that strips the padding cannot be recovered from the
        stage input alone.  Signatures mirror the full pipeline's:

          ("decode", r, t):         fn(Y, mask, W)   with W the (mn, K) panel
          ("decode-traced", r, t):  fn(Y, mask)      in-body masked solve
        """
        style, r, t = kind

        if style == "decode":

            def fn(Y, mask, W):
                Ym = Y * mask.astype(Y.dtype)[:, None, None]
                C_blocks = decode_with_weights(plan.scheme, W, Ym, plan.s)
                return finish(C_blocks, r, t)

            return fn

        z_all = jnp.asarray(plan.z_points)

        def fn(Y, mask):
            Ym = Y * mask.astype(Y.dtype)[:, None, None]
            C_blocks = decode_masked(plan.scheme, z_all, Ym,
                                     mask.astype(Y.real.dtype), plan.s)
            return finish(C_blocks, r, t)

        return fn

    def _make_partial_pipeline(self, plan: CodedMatmulPlan, kind: tuple,
                               dtype, products: Callable,
                               finish: Callable) -> Callable:
        """Prefix-aware pipeline: per-chunk erase + decode, kind carries Q.

        The Q row chunks have static bounds (from the padded block row count),
        so the per-chunk loop is a plain Python loop inside one jitted body —
        chunk c erases with its own (K,) availability row and decodes with
        its own (mn, K) panel, then the chunks concatenate back into the
        full C block rows.  ``Q = 1`` reproduces the binary pipeline exactly
        (one chunk, one mask, one panel).
        """
        style, Q = kind

        if style == "partial":

            def fn(A, B, chunk_masks, W_stack):
                Y = products(A, B)                       # (K, br, bt)
                bounds = chunk_bounds(Y.shape[1], Q)
                parts = []
                for c in range(Q):
                    Yc = Y[:, bounds[c]:bounds[c + 1], :]
                    Yc = Yc * chunk_masks[c].astype(Yc.dtype)[:, None, None]
                    parts.append(decode_with_weights(
                        plan.scheme, W_stack[c], Yc, plan.s))
                return finish(jnp.concatenate(parts, axis=2),
                              A.shape[1], B.shape[1])

            return fn

        if style != "partial-traced":
            raise ValueError(f"unknown partial pipeline kind {kind!r}")

        z_all = jnp.asarray(plan.z_points)
        k_idx = jnp.arange(plan.K)

        def fn(A, B, progress):
            Y = products(A, B)                           # (K, br, bt)
            bounds = chunk_bounds(Y.shape[1], Q)
            counts = jnp.floor(progress * Q + 1e-9)
            parts = []
            for c in range(Q):
                mask_c = ((c - k_idx) % Q < counts).astype(Y.real.dtype)
                Yc = Y[:, bounds[c]:bounds[c + 1], :]
                Yc = Yc * mask_c.astype(Yc.dtype)[:, None, None]
                parts.append(decode_masked(
                    plan.scheme, z_all, Yc, mask_c, plan.s))
            return finish(jnp.concatenate(parts, axis=2),
                          A.shape[1], B.shape[1])

        return fn


class ReferenceExecutor(LocalExecutor):
    """Pure-jnp staged einsums: the oracle every other backend must match."""

    name = "reference"

    def worker_products(self, plan, a_blocks, b_blocks):
        """Encode + per-worker products as plain einsums (the oracle path)."""
        a_tilde, b_tilde = encode_blocks(plan, a_blocks, b_blocks)
        return worker_products(a_tilde, b_tilde)


class StagedKernelExecutor(LocalExecutor):
    """Pallas encode kernel -> HBM -> Pallas block matmul per worker."""

    name = "staged"

    def worker_products(self, plan, a_blocks, b_blocks):
        """Pallas encode into HBM, then one Pallas block matmul per worker."""
        p, m, bv, br = a_blocks.shape
        _, n, _, bt = b_blocks.shape
        ca = jnp.asarray(plan.coeff_a.reshape(plan.K, p * m),
                         dtype=_coeff_dtype(a_blocks, plan))
        cb = jnp.asarray(plan.coeff_b.reshape(plan.K, p * n),
                         dtype=_coeff_dtype(b_blocks, plan))
        a_tilde = kops.encode(ca, a_blocks.reshape(p * m, bv * br))
        b_tilde = kops.encode(cb, b_blocks.reshape(p * n, bv * bt))
        a_tilde = a_tilde.reshape(plan.K, bv, br)
        b_tilde = b_tilde.reshape(plan.K, bv, bt)
        return jnp.stack(
            [kops.matmul_t(a_tilde[k], b_tilde[k]) for k in range(plan.K)])


class FusedKernelExecutor(LocalExecutor):
    """Fused encode+product megakernel: coded matrices never touch HBM."""

    name = "fused"

    def worker_products(self, plan, a_blocks, b_blocks):
        """One fused encode+product megakernel call for all K workers."""
        return fused_worker_products(plan, a_blocks, b_blocks)


# ---------------------------------------------------------------------------
# Mesh backend: the pipeline as one shard_map program, one device per worker.
# ---------------------------------------------------------------------------


def _decode_weights_masked(z_all: jnp.ndarray, mask: jnp.ndarray, tau: int,
                           useful: np.ndarray):
    """Useful rows of the masked pseudo-inverse Vandermonde (in-body solve).

    W_useful (mn, K): X_useful = W_useful @ Y_all (erased rows weighted 0).
    Solved from the normal equations G X = V^T D Y with D = diag(mask);
    LU solve, not explicit inversion - for large tau the Vandermonde normal
    equations are ill-conditioned and G^{-1} squares the error."""
    K = z_all.shape[0]
    V = z_all[:, None] ** jnp.arange(tau)[None, :]          # (K, tau)
    Vw = V * mask.astype(V.dtype)[:, None]
    G = V.T @ Vw                                             # (tau, tau)
    W_full = jnp.linalg.solve(G, Vw.T)
    return W_full[useful]                                    # (mn, K)


def _mesh_local_product(a_blocks, b_blocks, coeff_a, coeff_b, k,
                        *, use_kernels, fused):
    """Stages 1+2 on ONE device: encode worker ``k``'s share, multiply.

    a_blocks (p, m, bv, br) / b_blocks (p, n, bv, bt) replicated; returns
    the (br, bt) block product this device contributes to the all-gather.
    """
    p, m, bv, br = a_blocks.shape
    _, n, _, bt = b_blocks.shape
    ca = jax.lax.dynamic_index_in_dim(coeff_a, k, axis=0)     # (1, p, m)
    cb = jax.lax.dynamic_index_in_dim(coeff_b, k, axis=0)
    if use_kernels and fused:
        # stages 1+2 fused: coded tiles exist only in VMEM.
        return kops.fused_worker(
            ca.reshape(1, p * m), cb.reshape(1, p * n),
            a_blocks.reshape(p * m, bv, br),
            b_blocks.reshape(p * n, bv, bt))[0]               # (br, bt)
    if use_kernels:
        a_tilde = kops.encode(ca.reshape(1, p * m),
                              a_blocks.reshape(p * m, bv * br)).reshape(bv, br)
        b_tilde = kops.encode(cb.reshape(1, p * n),
                              b_blocks.reshape(p * n, bv * bt)).reshape(bv, bt)
        return kops.matmul_t(a_tilde, b_tilde)                # (br, bt)
    a_tilde = jnp.einsum("pm,pmvr->vr", ca[0], a_blocks)
    b_tilde = jnp.einsum("pn,pnvt->vt", cb[0], b_blocks)
    return a_tilde.T @ b_tilde


def _mesh_worker_body(a_blocks, b_blocks, mask, coeff_a, coeff_b, zW,
                      *, tau, s, useful, axis, use_kernels, fused, have_panel):
    """Per-device body.  a_blocks (p, m, bv, br) replicated; mask (K,).

    ``zW`` is the decode operand: the ready (mn, K) weight panel when
    ``have_panel`` (no solve below), else the (K,) evaluation points from
    which the masked normal equations are solved in-body (dynamic masks).
    """
    k = jax.lax.axis_index(axis)
    p, m, bv, br = a_blocks.shape
    _, n, _, bt = b_blocks.shape
    y_local = _mesh_local_product(a_blocks, b_blocks, coeff_a, coeff_b, k,
                                  use_kernels=use_kernels, fused=fused)

    # stage 3: erasure - zero out "failed" workers' outputs.
    y_local = y_local * jax.lax.dynamic_index_in_dim(mask, k, 0, keepdims=False)
    # stage 4: all-gather and decode everywhere (each device keeps its C).
    Y = jax.lax.all_gather(y_local, axis)                    # (K, br, bt)
    if have_panel:
        W = zW                                               # (mn, K), ready
    else:
        W = _decode_weights_masked(zW, mask, tau, useful)    # (mn, K)
    X = jnp.einsum("uk,krt->urt", W, Y)
    C = digit_extract(X, s) if s is not None else jnp.round(X)
    return C.reshape(m, n, br, bt)


def _mesh_partial_body(a_blocks, b_blocks, cm, coeff_a, coeff_b, zW,
                       *, Q, tau, s, useful, axis, use_kernels, fused,
                       have_panel):
    """Per-device partial-straggler body: ONE block product, Q chunk decodes.

    Each device emits its block product once; after the all-gather every
    device decodes chunk-by-chunk.  ``cm`` is the (Q, K) chunk-availability
    matrix and ``zW`` the stacked (Q, mn, K) decode panels when
    ``have_panel`` (concrete progress); for traced progress ``cm`` is the
    (K,) progress vector, ``zW`` the (K,) evaluation points, and chunk c's
    mask + masked normal equations are derived in-body.  The chunk bounds
    are static (from the padded block row count), so the per-chunk loop is
    a plain Python loop inside the one shard_map program — progress stays
    strictly DATA and one executable serves every progress vector.
    """
    k = jax.lax.axis_index(axis)
    p, m, bv, br = a_blocks.shape
    _, n, _, bt = b_blocks.shape
    y_local = _mesh_local_product(a_blocks, b_blocks, coeff_a, coeff_b, k,
                                  use_kernels=use_kernels, fused=fused)

    # stage 4: all-gather the UNMASKED products; stage 3 erasure happens
    # per chunk below (a slow worker's finished prefix still contributes).
    Y = jax.lax.all_gather(y_local, axis)                    # (K, br, bt)
    bounds = chunk_bounds(br, Q)
    if not have_panel:
        counts = jnp.floor(cm * Q + 1e-9)                    # (K,)
        k_idx = jnp.arange(Y.shape[0])
    parts = []
    for c in range(Q):
        if have_panel:
            mask_c = cm[c]                                   # (K,)
            W_c = zW[c]                                      # (mn, K)
        else:
            # worker k runs chunk (k + j) % Q as its j-th sub-task, so it
            # holds chunk c iff ((c - k) mod Q) < its finished count.
            mask_c = ((c - k_idx) % Q < counts).astype(Y.real.dtype)
            W_c = _decode_weights_masked(zW, mask_c, tau, useful)
        Yc = Y[:, bounds[c]:bounds[c + 1], :]
        Yc = Yc * mask_c.astype(Yc.dtype)[:, None, None]
        parts.append(jnp.einsum("uk,krt->urt", W_c, Yc))
    X = jnp.concatenate(parts, axis=1)                       # (mn, br, bt)
    C = digit_extract(X, s) if s is not None else jnp.round(X)
    return C.reshape(m, n, br, bt)


class MeshExecutor:
    """One worker per device along a mesh axis; erasure is a runtime mask."""

    name = "mesh"
    supports_batching = True  # vmap lifts through shard_map

    def __init__(self, mesh, *, axis: str = "model", use_kernels: bool = True,
                 fused: bool = True):
        if mesh is None:
            raise ValueError("MeshExecutor requires a mesh (backend='mesh')")
        self.mesh = mesh
        self.axis = axis
        self.use_kernels = use_kernels
        self.fused = fused

    def cache_token(self):
        """Executable-memo identity: name + mesh + axis + kernel flags."""
        return (self.name, self.mesh, self.axis, self.use_kernels, self.fused)

    def make_pipeline(self, plan: CodedMatmulPlan, kind, dtype) -> Callable:
        """The shard_map pipeline (one device per worker) for ``kind``.

        Binary kinds ("concrete"/"traced") and partial-straggler kinds
        (("partial", Q) / ("partial-traced", Q)) are supported; partial
        replicates the stacked (Q, mn, K) decode panels (or solves chunk
        masks in-body when traced) so each device decodes chunk-by-chunk
        after a single all-gather — same signatures as the local pipelines.

        Raises:
            NotImplementedError: for split-stage kinds ("products" /
                ("decode", r, t)), whose stages run fused inside one
                shard_map program, leaving no seam to pipeline across.
            ValueError: if the mesh axis size differs from the plan's K,
                the plan uses complex (unit-circle) evaluation points, or
                the tuple kind is not a known partial style.
        """
        is_stage = (kind == "products"
                    or (isinstance(kind, tuple) and kind
                        and kind[0] in ("decode", "decode-traced")))
        if is_stage:
            raise NotImplementedError(
                f"mesh backend does not support split-stage serving (kind "
                f"{kind!r}): encode, worker products, and decode run fused "
                f"inside one shard_map program, so there is no seam to "
                f"pipeline across. Split worker/decode stages are supported "
                f"by the local backends: {local_backend_names()}.")
        if not isinstance(kind, str) and (
                not isinstance(kind, tuple) or len(kind) != 2
                or kind[0] not in ("partial", "partial-traced")):
            raise ValueError(f"unknown mesh pipeline kind {kind!r}")
        K = self.mesh.shape[self.axis]
        if K != plan.K:
            raise ValueError(
                f"plan built for K={plan.K}, mesh axis {self.axis!r} has {K}")
        if plan.is_complex:
            # the legacy mesh path silently cast the complex encode
            # coefficients to real (discarding imaginary parts -> corrupt
            # decode); an explicit error replaces that silent corruption.
            raise ValueError(
                "mesh backend does not support complex (unit-circle) plans; "
                "use chebyshev/equispaced points or a local backend")
        g = plan.scheme.grid
        useful = np.asarray(plan.scheme.useful_z_exp().reshape(-1))
        s = plan.s if plan.scheme.needs_digit_extraction else None
        coeff_a = jnp.asarray(plan.coeff_a, dtype)
        coeff_b = jnp.asarray(plan.coeff_b, dtype)
        is_partial = isinstance(kind, tuple)
        if is_partial:
            style, Q = kind
            body = partial(
                _mesh_partial_body, Q=Q, tau=plan.tau, s=s, useful=useful,
                axis=self.axis, use_kernels=self.use_kernels,
                fused=self.fused, have_panel=(style == "partial"))
        else:
            body = partial(
                _mesh_worker_body, tau=plan.tau, s=s, useful=useful,
                axis=self.axis, use_kernels=self.use_kernels,
                fused=self.fused, have_panel=(kind == "concrete"))
        mapped = shard_map_compat(
            body,
            mesh=self.mesh,
            in_specs=(P(), P(), P(), P(), P(), P()),   # replicated operands
            out_specs=P(),
        )

        def run(A, B, mask, zW):
            a_blocks = block_decompose(A.astype(dtype), g.p, g.m)
            b_blocks = block_decompose(B.astype(dtype), g.p, g.n)
            C_blocks = mapped(a_blocks, b_blocks, mask.astype(dtype),
                              coeff_a, coeff_b, zW)
            return unpad(block_recompose(C_blocks),
                         (A.shape[1], B.shape[1])).astype(dtype)

        if is_partial and style == "partial":

            def fn(A, B, chunk_masks, W_stack):
                return run(A, B, chunk_masks, W_stack.astype(dtype))

            return fn

        if is_partial:
            z_all_pt = jnp.asarray(plan.z_points, dtype)

            def fn(A, B, progress):
                return run(A, B, progress, z_all_pt)

            return fn

        if kind == "concrete":

            def fn(A, B, mask, W):
                return run(A, B, mask, W.astype(dtype))

            return fn

        z_all = jnp.asarray(plan.z_points, dtype)

        def fn(A, B, mask):
            return run(A, B, mask, z_all)

        return fn


BACKENDS = {
    "reference": ReferenceExecutor,
    "staged": StagedKernelExecutor,
    "fused": FusedKernelExecutor,
    "mesh": MeshExecutor,
}

# The split-stage (products / decode) seam only exists on local backends;
# computed ONCE from the registry so error messages cannot drift from it.
_LOCAL_BACKEND_NAMES = ", ".join(sorted(
    name for name, cls in BACKENDS.items()
    if isinstance(cls, type) and issubclass(cls, LocalExecutor)))


def local_backend_names() -> str:
    """Comma-joined names of the local (split-stage capable) backends."""
    return _LOCAL_BACKEND_NAMES


def resolve_executor(backend, *, mesh=None, axis: str = "model",
                     use_kernels: bool = True, fused: bool = True) -> Executor:
    """Executor instance from a backend name (or passthrough instance)."""
    if not isinstance(backend, str):
        if not isinstance(backend, Executor):
            raise TypeError(f"not an Executor: {type(backend).__name__}")
        return backend
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; options: {sorted(BACKENDS)}")
    if backend == "mesh":
        return MeshExecutor(mesh, axis=axis, use_kernels=use_kernels,
                            fused=fused)
    return BACKENDS[backend]()
