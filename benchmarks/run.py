"""Benchmark harness: one section per paper table/figure + roofline readout.

Prints ``name,value,derived`` CSV blocks.  Sizes are scaled to this CPU
host (documented per bench); EXPERIMENTS.md maps each section back to the
paper's corresponding table/figure.
"""
from __future__ import annotations

import time


def main() -> None:
    t0 = time.time()
    print("== fig1_latency (paper Fig. 1: latency vs stragglers) ==")
    from benchmarks import fig1_latency
    fig1_latency.main()

    print("\n== table1_error (paper Table I: decode error vs bound L) ==")
    from benchmarks import table1_error
    table1_error.main()

    print("\n== tradeoff_sweep (paper Sec. IV: tau vs headroom) ==")
    from benchmarks import tradeoff_sweep
    tradeoff_sweep.main()

    print("\n== kernels_micro (Pallas stages, interpret mode) ==")
    from benchmarks import kernels_micro
    kernels_micro.main(save="BENCH_kernels.json")

    print("\n== runtime_bench (executor cold-compile vs cached serving) ==")
    from benchmarks import runtime_bench
    runtime_bench.main(save="BENCH_runtime.json")

    print("\n== roofline (from dry-run artifacts) ==")
    from benchmarks import roofline
    roofline.main()

    print(f"\ntotal bench time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
