"""Paper Fig. 1 reproduction: completion latency vs straggler count.

Protocol (scaled to this host, CPU): 10 workers, m=n=p=2 block split,
integer matrices with entries in {0..50}.  Per-worker compute time is
MEASURED (one coded block product on this machine); stragglers compute
twice (2x slowdown, the paper's model); completion = tau-th finisher +
measured decode time.  BEC (tau=4) vs polynomial code (tau=9).

Expected shape (paper Sec. V): BEC flat for S in 0..6, jump at S=7;
polycode degrades from S >= 2.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.configs.paper_matmul import SMOKE as PCFG
from repro.core import (
    LatencyModel,
    make_plan,
    simulate_completion,
    uncoded_matmul,
)
from repro.core.numerics import enable_x64
from repro.runtime import CodedMatmul, ReferenceExecutor


def run(size: int = 0, trials: int = 20):
    cfg = PCFG if size == 0 else PCFG.__class__(v=size, r=size, t=size)
    rng = np.random.default_rng(0)
    rows = []
    with enable_x64():
        import jax.numpy as jnp
        A = jnp.asarray(rng.integers(0, cfg.entry_max + 1,
                                     size=(cfg.v, cfg.r)), jnp.float64)
        B = jnp.asarray(rng.integers(0, cfg.entry_max + 1,
                                     size=(cfg.v, cfg.t)), jnp.float64)
        plans = {
            "bec": make_plan("bec", cfg.p, cfg.m, cfg.n, K=cfg.K, L=cfg.L,
                             points=cfg.points),
            "polycode": make_plan("polycode", cfg.p, cfg.m, cfg.n, K=cfg.K,
                                  L=cfg.L, points=cfg.points),
        }

        # measure ONE worker's compute: a coded block product (the paper's
        # per-machine task) - NOT the serialized all-workers run
        bv, br = cfg.v // cfg.p, cfg.r // cfg.m
        bt = cfg.t // cfg.n
        a_t = jnp.asarray(rng.normal(size=(bv, br)))
        b_t = jnp.asarray(rng.normal(size=(bv, bt)))
        f = jax.jit(lambda a, b: a.T @ b)
        f(a_t, b_t).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            f(a_t, b_t).block_until_ready()
        t_worker = (time.perf_counter() - t0) / 5

        C_ref = uncoded_matmul(A, B)
        for name, plan in plans.items():
            # measure the MASTER's decode separately on precomputed Y
            from repro.core.decoding import decode as decode_fn
            from repro.core.partition import block_decompose
            ab = block_decompose(A, cfg.p, cfg.m)
            bb = block_decompose(B, cfg.p, cfg.n)
            Y = ReferenceExecutor().worker_products(plan, ab, bb)
            zs = jnp.asarray(plan.z_points[: plan.tau])
            dec = jax.jit(lambda z, y: decode_fn(plan.scheme, z, y, plan.s))
            dec(zs, Y[: plan.tau])  # warm up
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(dec(zs, Y[: plan.tau]))
            t_decode = (time.perf_counter() - t0) / 3

            C = CodedMatmul(plan, "reference")(A, B)
            err = float(np.linalg.norm(np.asarray(C - C_ref)) /
                        np.linalg.norm(np.asarray(C_ref)))
            model = LatencyModel(base=t_worker,
                                 straggler_slowdown=cfg.straggler_slowdown)
            for S in range(0, 9):
                lat = simulate_completion(cfg.K, plan.tau, S, model,
                                          decode_time=t_decode,
                                          trials=trials, seed=S)
                rows.append({
                    "scheme": name, "tau": plan.tau, "stragglers": S,
                    "latency_s": float(np.mean(lat)),
                    "worker_s": t_worker, "decode_s": t_decode,
                    "rel_err": err,
                })
    return rows


def main():
    rows = run()
    print("scheme,tau,stragglers,latency_s,rel_err")
    for r in rows:
        print(f"{r['scheme']},{r['tau']},{r['stragglers']},"
              f"{r['latency_s']:.4f},{r['rel_err']:.2e}")
    return rows


if __name__ == "__main__":
    main()
