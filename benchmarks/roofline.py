"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md Sec. Roofline).

Per (arch x shape x mesh) JSON under results/dryrun/:
  compute term    = HLO_dot_FLOPs_per_device / peak_FLOPs      [s]
  memory term     = HLO_bytes_per_device / HBM_bw              [s]
  collective term = collective_bytes_per_device / link_bw      [s]
plus MODEL_FLOPS / HLO_FLOPs (useful-compute ratio, catches remat and
padding waste) and the dominant-term verdict.

Hardware: TPU v5e - 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
NOTE: HLO dot flops are parsed with while-trip multipliers (hlo_analysis);
XLA's own cost_analysis undercounts scan bodies.  'bytes accessed' comes
from cost_analysis and is normalised per device.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.models.stats import attention_score_flops, model_flops

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh: str = "singlepod"):
    cells = []
    for f in sorted(RESULTS_DIR.glob(f"*__{mesh}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def roofline_row(cell: dict) -> dict:
    arch, shape_name = cell["arch"], cell["shape"]
    n_dev = cell["n_devices"]
    shape = SHAPES[shape_name]
    cfg = get_config(arch)

    compute_s = cell["dot_flops"] / PEAK_FLOPS
    if cell.get("hbm_bytes"):
        # instruction-level bytes with while-trip multipliers (hlo_analysis)
        memory_s = cell["hbm_bytes"] / HBM_BW
    else:
        # legacy cells: scale cost_analysis bytes by the flop undercount
        raw_bytes = cell["cost"].get("bytes accessed") or 0.0
        raw_flops = cell["cost"].get("flops") or 1.0
        scale = max(1.0, cell["dot_flops"] / max(raw_flops, 1.0))
        memory_s = raw_bytes * scale / HBM_BW
    coll_s = cell["collectives"]["total_bytes"] / LINK_BW

    mf = model_flops(cfg, shape.kind, shape.global_batch, shape.seq_len)
    attn_f = attention_score_flops(cfg, shape.kind, shape.global_batch,
                                   shape.seq_len)
    useful = (mf + attn_f) / n_dev
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    return {
        "arch": arch, "shape": shape_name, "mesh": cell["multi_pod"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_per_dev": useful,
        "hlo_flops_per_dev": cell["dot_flops"],
        "useful_ratio": useful / max(cell["dot_flops"], 1.0),
        "roofline_fraction": (useful / PEAK_FLOPS) / max(total, 1e-12),
        "mem_gib_per_dev": ((cell["memory"]["argument_bytes"] or 0)
                            + (cell["memory"]["temp_bytes"] or 0)) / 2 ** 30,
        "compile_s": cell["compile_s"],
    }


def main():
    cells = load_cells()
    if not cells:
        print("no dry-run artifacts yet (run repro.launch.dryrun)")
        return []
    rows = [roofline_row(c) for c in cells]
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_fraction,mem_gib_per_dev")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.4f},"
              f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
              f"{r['mem_gib_per_dev']:.1f}")
    return rows


if __name__ == "__main__":
    main()
