"""Control-plane bench: static best rung vs adaptive, swept over stragglers.

The serving model is the repo's SYNCHRONOUS mesh step (DESIGN Sec. 3): a
step waits for every worker that is not declared erased — the 0/1 mask is
the only way to not wait for a straggler.  A *static* deployment fixes one
rung at ``make_plan`` time and has no health monitor, so its step
completion is the max over ALL workers.  The *adaptive* control plane
(``repro.control``) learns the straggler set from observed step times and
erases it, within the active rung's budget ``K - tau``, approaching the
tau-th order statistic — the paper's async-master latency, recovered as a
control decision.

Per (L, straggler-count) regime both sides replay the SAME per-worker time
traces.  Every adaptive step also executes a real coded matmul through the
``PlanLadder`` facades and is checked exact against the uncoded oracle;
the runtime's own ``runtime.executable.compile`` counter (read through
``benchmarks.obs_util.CompileWatch``) proves rung switches after
``prewarm()`` compile nothing.

The p50-vs-p99 POLICY sweep plays the same game at the tail: under a
heavy-tailed straggler mix (2x slowdown plus a fat exponential tail on the
slow machines) the MEAN ranking and the QUANTILE ranking genuinely
disagree — the per-rung step costs (synthetic, in units of one worker
step: the depth-p digit stack makes the low-tau rungs the expensive ones,
exactly the paper's L <-> tau price) outweigh the mean cost of leaving a
straggler unmasked, but not its p99 cost.  The mean policy therefore
serves the cheap narrow-budget rung and eats the tail; the
``QuantileLatencyPolicy`` pays the digit tax for tail protection — worse
p50, strictly better p99.  Both sides serve vmap-BATCHED requests of
varying size through prewarmed leading-dim buckets, and the zero-recompile
contract is asserted across every batched rung switch.

The SCENARIO sweep replays every regime registered in ``repro.chaos``
(iid, heavy/Pareto tails, bursts, flapping, rack failure, pool resize) —
each in its stressed form AND its ``calm()`` control — through the same
static-vs-adaptive comparison, so a control-plane regression against any
archetype fails CI, not just the one hand-rolled mix the earlier benches
used.  The FEEDBACK sweep compares the static-q SLO fallback against the
observed-violation feedback controller (``control.feedback``) under the
heavy-tailed mix with a deliberately understated base quantile: the
static policy's predictions look safe, the cheap narrow-budget rung
serves, and realized p99 misses pile up; feedback tightens q off the
misses and pins the wide-budget rung while its window remembers.

The PARTIAL sweep (``partial_sweep``) benches the tentpole of the
partial-straggler work: binary erasure vs ``sub_tasks=Q`` fractional
consumption over IDENTICAL scenario traces, priced under the same
synthetic per-rung overheads.  The overheads drive selection to the
narrow-budget polycode rung (budget 1), so with more flagged stragglers
than the budget the binary server must WAIT IN FULL on the uncovered
slow machines while the partial server consumes their completed chunk
prefixes — fractional waits ``w * finish`` instead of ``finish``.
``partial_sweep --backend mesh`` replays the strict-win regimes through
``MeshExecutor`` facades (one worker per forced host device): the same
gates — partial beats binary, zero recompiles across progress changes,
Q=1 bit-parity — proven on the shard_map pipeline, landing under the
``partial_sweep_mesh`` key next to the reference rows.

The ELASTIC sweep (``elastic_sweep``) drives the executed pool handoff:
a polycode-only ladder on a 12-worker universe loses 3 workers (past its
budget of 2), the server executes the shrink respecialisation — the
ladder re-lowers onto the 7 survivors, where only bec fits — then the 2
initially-absent workers join on incrementally extended Vandermonde
points and the policy re-ranks back to polycode.  Gates: the run
survives the over-slack shrink exactly, priced latency recovers after
the grow, and the grow recompiles NOTHING for pre-existing rungs (the
old pool's executables all survive; post-grow serving adds zero
compiles).

Rows land in BENCH_control.json (a sweep run merge-appends into the
existing file).  ``--check`` asserts the acceptance criteria (CI smoke):
adaptive matches the best static rung at zero stragglers, beats every
static rung in at least one nonzero regime, zero recompiles after prewarm
(batched and partial sweeps included), the quantile policy strictly beats
the mean policy on p99 under the heavy-tailed mix while matching it at
S=0, the budget-exhaustion scenario hands off to
``CodedElasticPolicy``/``plan_shrink``, every registered scenario's calm
control shows zero spurious erasures (forcing adaptive == static exactly
— the S=0 gate stated so it can fail) while its stressed regime shows
adaptive beating static by a real margin, the feedback controller
strictly reduces realized SLO violations vs. the static-q policy, and the
partial server never loses to binary erasure on realized p99 — strictly
beating it under ``heavy_tail`` and ``pareto`` — while a ``Q=1`` server
reproduces the binary report stream field for field.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.obs_util import CompileWatch, assert_no_recompiles

# geometry shared by every rung of the ladder (paper Sec. IV family)
P, M, N, K = 4, 2, 1, 12
V, R, T = 16, 8, 4
STEPS = 24
RESAMPLE_EVERY = 8
BASE_S = 1.0
SLOWDOWN = 2.0
JITTER = 0.02
L_SMALL = V * 4 * 4 + 1     # conservative_L(V, 4, 4): every rung feasible
L_LARGE = 1 << 14           # bec's depth-3 digit stack overflows f64 here
STRAGGLER_COUNTS = (0, 1, 3, 5)

# -- p50-vs-p99 policy sweep ------------------------------------------------
Q_STEPS = 48
Q_WARMUP = 6                # cold-monitor steps excluded from the stats
Q_SLO = 0.99
HEAVY_JITTER = 1.5          # stragglers: 2x slowdown + Exp(1.5 x base) tail
HEALTHY_JITTER = 0.05
# synthetic per-rung step cost (units of BASE_S): the depth-p digit stack
# prices the low-tau rungs, the paper's L <-> tau tradeoff as overhead
Q_OVERHEAD = {"bec": 10.0, "tradeoff(p'=2)": 9.0, "polycode": 0.5}
Q_STRAGGLERS = (0, 3, 5)
Q_BATCHES = (5, 3, 8, 2)    # per-request batch sizes, cycled
Q_BUCKETS = (4, 8)          # prewarmed leading-dim buckets (round-up pad)


def _traces(S: int, seed: int) -> np.ndarray:
    """(STEPS, K) per-worker finish times: persistent straggler set of size
    S, resampled every RESAMPLE_EVERY steps (the paper's 2x duplication
    model plus light exponential jitter)."""
    from repro.core.simulator import LatencyModel

    rng = np.random.default_rng(seed)
    model = LatencyModel(base=BASE_S, straggler_slowdown=SLOWDOWN,
                         jitter=JITTER)
    out = np.empty((STEPS, K))
    slow = rng.choice(K, size=S, replace=False)
    for step in range(STEPS):
        if step and step % RESAMPLE_EVERY == 0:
            slow = rng.choice(K, size=S, replace=False)
        out[step] = model.sample(K, slow, rng)
    return out


def _run_regime(L: int, S: int, seed: int) -> dict:
    import jax.numpy as jnp

    from repro.control import AdaptiveServer, ExpectedLatencyPolicy, PlanLadder

    traces = _traces(S, seed)
    watch = CompileWatch()
    ladder = PlanLadder(P, M, N, K=K, L=L, backend="reference")
    ladder.prewarm((V, R), (V, T))
    watch.mark()
    # uniform zero overhead: rungs differ only through masking/feasibility,
    # so the sweep is deterministic given the seeds (measured per-rung step
    # costs are reported by `prewarm` and exercised in coded_serve).
    policy = ExpectedLatencyPolicy(
        ladder, overhead_s={r: 0.0 for r in ladder.rungs})
    server = AdaptiveServer(ladder, policy=policy,
                            feed=lambda step, rng: traces[step],
                            seed=seed, check_exact=True)

    rng = np.random.default_rng(seed + 1)
    A = jnp.asarray(rng.integers(-4, 5, size=(V, R)), jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)
    reports = server.run(STEPS, lambda i: (A, B))

    static_s = {r: float(traces.max(axis=1).mean()) for r in ladder.rungs}
    rung_counts: dict = {}
    for rep in reports:
        rung_counts[rep.rung] = rung_counts.get(rep.rung, 0) + 1
    info = ladder.cache_info()
    return {
        "L": L,
        "stragglers": S,
        "static_s": static_s,
        "static_feasible": {r: policy.feasible(r) for r in ladder.rungs},
        "adaptive_s": float(np.mean([rep.sim_latency_s for rep in reports])),
        "adaptive_rungs": rung_counts,
        "switches": info["switches"],
        "recompiles": watch.delta(),
        "panel_builds": info["panel_builds"],
        "respecializations": sum(rep.respecialize for rep in reports),
        "all_exact": all(rep.exact for rep in reports),
    }


def _heavy_traces(S: int, steps: int, seed: int) -> np.ndarray:
    """(steps, K) finish times under the heavy-tailed straggler mix: a FIXED
    set of S machines at 2x slowdown with an Exp(HEAVY_JITTER x base) tail,
    everyone else near-deterministic."""
    from repro.core.simulator import LatencyModel

    rng = np.random.default_rng(seed)
    slow = rng.choice(K, size=S, replace=False)
    jitter = np.full(K, HEALTHY_JITTER)
    jitter[slow] = HEAVY_JITTER
    model = LatencyModel(base=BASE_S, straggler_slowdown=SLOWDOWN,
                         jitter=jitter)
    return np.stack([model.sample(K, slow, rng) for _ in range(steps)])


def _run_policy(policy_name: str, traces: np.ndarray, seed: int) -> dict:
    """One policy serving batched requests over ``traces``; realized step
    latency = masked completion + the rung's synthetic overhead."""
    import jax.numpy as jnp

    from repro.control import (
        AdaptiveServer,
        ExpectedLatencyPolicy,
        PlanLadder,
        QuantileLatencyPolicy,
    )

    watch = CompileWatch()
    ladder = PlanLadder(P, M, N, K=K, L=L_SMALL, backend="reference")
    ladder.prewarm((V, R), (V, T), batch_sizes=Q_BUCKETS)
    watch.mark()
    if policy_name == "mean":
        policy = ExpectedLatencyPolicy(ladder, overhead_s=Q_OVERHEAD)
    else:
        policy = QuantileLatencyPolicy(ladder, q=Q_SLO, overhead_s=Q_OVERHEAD)
    server = AdaptiveServer(ladder, policy=policy,
                            feed=lambda step, rng: traces[step],
                            seed=seed, check_exact=True)

    rng = np.random.default_rng(seed + 1)
    A_pool = jnp.asarray(rng.integers(-4, 5, size=(max(Q_BATCHES), V, R)),
                         jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)
    reports = server.run(Q_STEPS,
                         lambda i: (A_pool[: Q_BATCHES[i % len(Q_BATCHES)]], B))

    realized = np.array([rep.sim_latency_s + Q_OVERHEAD[rep.rung]
                         for rep in reports])[Q_WARMUP:]
    rung_counts: dict = {}
    for rep in reports[Q_WARMUP:]:
        rung_counts[rep.rung] = rung_counts.get(rep.rung, 0) + 1
    info = ladder.cache_info()
    return {
        "policy": policy_name,
        "p50_s": float(np.quantile(realized, 0.5)),
        "p99_s": float(np.quantile(realized, Q_SLO)),
        "rungs": rung_counts,
        "switches": info["switches"],
        "recompiles": watch.delta(),
        "all_exact": all(rep.exact for rep in reports),
    }


def _run_quantile_sweep() -> list:
    """Mean vs quantile policy over identical heavy-tailed batched traces."""
    rows = []
    for S in Q_STRAGGLERS:
        traces = _heavy_traces(S, Q_STEPS, seed=101 + S)
        for policy_name in ("mean", "quantile"):
            row = _run_policy(policy_name, traces, seed=101 + S)
            row["stragglers"] = S
            rows.append(row)
    return rows


# -- registered-scenario sweep ------------------------------------------------
SC_STEPS = 24
SC_SEED = 5

# -- partial-straggler sweep (binary erasure vs sub-task consumption) ---------
PARTIAL_SCENARIOS = ("heavy_tail", "pareto", "crawler", "degrading")
PARTIAL_SUB_TASKS = 4
PARTIAL_STEPS = 48
PARTIAL_WARMUP = 6
PARTIAL_SEED = 11
# the mesh gate replays only the strict-win regimes (a shard_map program
# per step over K forced host devices is CI-expensive; the gates it proves
# — partial beats binary ON MESH, zero recompiles across progress changes
# — need exactly these rows)
PARTIAL_MESH_SCENARIOS = ("heavy_tail", "pareto")

# -- elastic shrink/grow sweep ------------------------------------------------
EL_GRID = (3, 2, 1)         # bec(tau=2) + polycode(tau=8); 3 prime, no tradeoff
EL_UNIVERSE = 12
EL_STEPS = 24
EL_DEPART = 4               # 3 departures > the polycode-only budget of 2
EL_JOIN = 14                # the 2 absent workers join here
EL_SEED = 7
#: constant per-rung step costs: the grow gate is that readmitting the
#: joiners wins back polycode's cheap digit stack (0.1 vs bec's 2.0).
EL_OVERHEAD = {"bec": 2.0, "polycode": 0.1}

# -- observed-violation feedback sweep ---------------------------------------
FB_STEPS = 96
FB_WARMUP = 8
FB_Q_BASE = 0.8             # deliberately understated: predictions look safe
FB_SLO_S = 12.0
FB_SEEDS = (37, 51)
FB_CONFIG = dict(gain=8.0, window=32, force_after=2, target_rate=0.01)


def _run_scenario(name: str, seed: int) -> dict:
    """Static vs adaptive under one registered chaos scenario.

    Both the stressed regime and its ``calm()`` control replay the SAME
    deterministic trace matrix on both sides; the static side has no
    monitor, so its step completion is the max over all workers.
    """
    import jax.numpy as jnp

    from repro.chaos import make_scenario, trace_matrix
    from repro.control import AdaptiveServer, ExpectedLatencyPolicy, PlanLadder

    row: dict = {"scenario": name, "seed": seed}
    for variant in ("stressed", "calm"):
        scenario = make_scenario(name)
        if variant == "calm":
            scenario = scenario.calm()
        traces = trace_matrix(scenario, K, SC_STEPS, seed=seed)
        watch = CompileWatch()
        ladder = PlanLadder(P, M, N, K=K, L=L_SMALL, backend="reference")
        ladder.prewarm((V, R), (V, T))
        watch.mark()
        policy = ExpectedLatencyPolicy(
            ladder, overhead_s={r: 0.0 for r in ladder.rungs})
        server = AdaptiveServer(ladder, policy=policy,
                                feed=lambda step, rng: traces[step],
                                seed=seed, check_exact=True)
        rng = np.random.default_rng(seed + 1)
        A = jnp.asarray(rng.integers(-4, 5, size=(V, R)), jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)
        reports = server.run(SC_STEPS, lambda i: (A, B))
        row[variant] = {
            "static_s": float(traces.max(axis=1).mean()),
            "adaptive_s": float(np.mean([r.sim_latency_s for r in reports])),
            "erasures": int(sum(len(r.erased) for r in reports)),
            "respecializations": int(sum(r.respecialize for r in reports)),
            "recompiles": watch.delta(),
            "all_exact": all(r.exact for r in reports),
        }
    return row


def _run_scenario_sweep() -> list:
    """Every registered scenario, stressed + calm control."""
    from repro.chaos import scenario_names

    return [_run_scenario(name, seed=SC_SEED) for name in scenario_names()]


def _partial_backend(backend: str):
    """Ladder ``backend=`` argument for a partial-sweep server.

    ``"mesh"`` builds a K-device mesh executor (pure-jnp worker products:
    Pallas kernels run interpret-mode off-TPU, far too slow for a CI
    sweep) — spawn with ``XLA_FLAGS=--xla_force_host_platform_device_count
    =<K>`` so the devices exist.
    """
    if backend == "reference":
        return "reference"
    if backend != "mesh":
        raise ValueError(f"unknown partial-sweep backend {backend!r}")
    import jax

    from repro.runtime import MeshExecutor

    if len(jax.devices()) < K:
        raise RuntimeError(
            f"--backend mesh needs >= K={K} devices, have "
            f"{len(jax.devices())}; spawn with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={K}")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:K]), ("model",))
    return MeshExecutor(mesh, use_kernels=False)


def _serve_partial(traces: np.ndarray, sub_tasks: int, seed: int,
                   backend: str = "reference"):
    """One server (binary when ``sub_tasks=1``) over a fixed trace matrix.

    Returns ``(row, reports, ladder, (A, B))`` so the caller can run the
    Q=1 bit-parity check against the same compiled facades and operands.
    """
    import jax.numpy as jnp

    from repro.control import AdaptiveServer, ExpectedLatencyPolicy, PlanLadder

    watch = CompileWatch()
    ladder = PlanLadder(P, M, N, K=K, L=L_SMALL,
                        backend=_partial_backend(backend))
    ladder.prewarm((V, R), (V, T), sub_tasks=sub_tasks)
    watch.mark()
    policy = ExpectedLatencyPolicy(ladder, overhead_s=Q_OVERHEAD,
                                   sub_tasks=sub_tasks)
    server = AdaptiveServer(ladder, policy=policy,
                            feed=lambda step, rng: traces[step],
                            seed=seed, check_exact=True, sub_tasks=sub_tasks)
    rng = np.random.default_rng(seed + 1)
    A = jnp.asarray(rng.integers(-4, 5, size=(V, R)), jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)
    reports = server.run(len(traces), lambda i: (A, B))

    realized = np.array([r.sim_latency_s + Q_OVERHEAD[r.rung]
                         for r in reports])[PARTIAL_WARMUP:]
    rung_counts: dict = {}
    fractions = 0
    for r in reports[PARTIAL_WARMUP:]:
        rung_counts[r.rung] = rung_counts.get(r.rung, 0) + 1
        if r.progress is not None:
            fractions += sum(1 for x in r.progress if 0.0 < x < 1.0)
    row = {
        "sub_tasks": sub_tasks,
        "p50_s": float(np.quantile(realized, 0.5)),
        "p99_s": float(np.quantile(realized, Q_SLO)),
        "mean_s": float(realized.mean()),
        "fractional_consumptions": fractions,
        "rungs": rung_counts,
        "recompiles": watch.delta(),
        "all_exact": all(r.exact for r in reports),
    }
    return row, reports, ladder, (A, B)


def _q1_parity(ladder, A, B, binary_reports) -> bool:
    """Every mask the binary run emitted, replayed through the Q=1 partial
    path (``progress`` vector + ``sub_tasks=1``): the decoded products must
    be BIT-IDENTICAL to the legacy mask path — the fractional code is a
    strict generalisation, not a parallel implementation."""
    for rung, erased in sorted({(r.rung, r.erased) for r in binary_reports}):
        ladder.switch(rung)  # a mask is only decodable on the rung that cut it
        progress = np.ones(K)
        progress[list(erased)] = 0.0
        legacy = np.asarray(ladder(A, B, erased=list(erased)))
        partial = np.asarray(ladder(A, B, progress=progress, sub_tasks=1))
        if not np.array_equal(legacy, partial):
            return False
    return True


def _run_partial(name: str, seed: int, backend: str = "reference") -> dict:
    """Binary erasure vs partial consumption under one chaos scenario.

    Both servers replay the SAME deterministic trace matrix; the binary
    run's masks additionally replay through the Q=1 partial decode path
    and must reproduce the legacy products bit for bit.
    """
    from repro.chaos import make_scenario, trace_matrix

    traces = trace_matrix(make_scenario(name), K, PARTIAL_STEPS, seed=seed)
    binary, binary_reports, ladder, (A, B) = _serve_partial(
        traces, 1, seed, backend)
    partial, _, _, _ = _serve_partial(traces, PARTIAL_SUB_TASKS, seed,
                                      backend)
    return {"scenario": name, "seed": seed, "backend": backend,
            "binary": binary, "partial": partial,
            "q1_bit_identical": _q1_parity(ladder, A, B, binary_reports)}


def _run_partial_sweep(backend: str = "reference") -> list:
    """Binary vs partial over the backend's partial-regime scenarios."""
    names = (PARTIAL_MESH_SCENARIOS if backend == "mesh"
             else PARTIAL_SCENARIOS)
    return [_run_partial(name, seed=PARTIAL_SEED, backend=backend)
            for name in names]


def _run_feedback(enabled: bool, seed: int) -> dict:
    """Static-q SLO fallback vs observed-violation feedback (heavy tails).

    Realized step latency = masked completion + the served rung's priced
    overhead — exactly what the feedback window judges against the SLO.
    """
    import jax.numpy as jnp

    from repro.chaos import make_scenario
    from repro.control import (
        AdaptiveServer,
        ExpectedLatencyPolicy,
        FeedbackConfig,
        PlanLadder,
    )

    feed = make_scenario("heavy_tail").compile(K, seed=seed)
    ladder = PlanLadder(P, M, N, K=K, L=L_SMALL, backend="reference")
    ladder.prewarm((V, R), (V, T))
    policy = ExpectedLatencyPolicy(ladder, overhead_s=Q_OVERHEAD)
    server = AdaptiveServer(
        ladder, policy=policy, feed=feed, seed=seed,
        slo_quantile=FB_Q_BASE, slo_s=FB_SLO_S,
        feedback=FeedbackConfig(**FB_CONFIG) if enabled else None)
    A = jnp.zeros((V, R), jnp.float64)
    B = jnp.zeros((V, T), jnp.float64)
    reports = server.run(FB_STEPS, lambda i: (A, B))[FB_WARMUP:]
    realized = np.array([r.sim_latency_s + Q_OVERHEAD[r.rung]
                         for r in reports])
    rung_counts: dict = {}
    for r in reports:
        rung_counts[r.rung] = rung_counts.get(r.rung, 0) + 1
    return {
        "policy": "feedback" if enabled else "static_q",
        "seed": seed,
        "violations": int((realized > FB_SLO_S).sum()),
        "steps": len(reports),
        "p50_s": float(np.quantile(realized, 0.5)),
        "p99_s": float(np.quantile(realized, 0.99)),
        "rungs": rung_counts,
    }


def _run_feedback_sweep() -> list:
    """static-q vs feedback over identical heavy-tailed feeds per seed."""
    return [_run_feedback(enabled, seed)
            for seed in FB_SEEDS for enabled in (False, True)]


def _run_elastic(seed: int) -> dict:
    """Elastic shrink-then-grow through the adaptive server (EXECUTED).

    A polycode-only ladder (budget 2) on a worker universe of 12 serves on
    an initial pool of 10; three departures exceed slack and trigger the
    executed shrink handoff (the ladder re-lowers onto the survivors —
    only bec fits the shrunk pool), then the two absent workers join at
    ``EL_JOIN`` on incrementally extended evaluation points and the policy
    re-ranks back to polycode.  The run measures priced step latency per
    phase and proves the grow compiles NOTHING for pre-existing rungs:
    every executable cached for the old pool survives the grow, and
    serving after the grow's own prewarm adds zero compiles.
    """
    import jax.numpy as jnp

    from repro.chaos import make_scenario
    from repro.control import AdaptiveServer, ExpectedLatencyPolicy, PlanLadder

    scenario = make_scenario("pool_resize", num_departing=3,
                             depart_step=EL_DEPART, num_arriving=2,
                             join_step=EL_JOIN)
    feed = scenario.compile(EL_UNIVERSE, seed=seed)
    arriving = scenario.arriving_ids(EL_UNIVERSE, seed)
    absent = {int(i) for i in arriving}
    pool = [i for i in range(EL_UNIVERSE) if i not in absent]

    watch = CompileWatch()
    p, m, n = EL_GRID
    ladder = PlanLadder(p, m, n, K=len(pool), L=L_SMALL,
                        backend="reference", include=["polycode"])
    ladder.prewarm((V, R), (V, T))
    policy = ExpectedLatencyPolicy(ladder, overhead_s=EL_OVERHEAD)
    server = AdaptiveServer(ladder, policy=policy, feed=feed, seed=seed,
                            check_exact=True,
                            universe=EL_UNIVERSE, pool=pool)
    rng = np.random.default_rng(seed + 1)
    A = jnp.asarray(rng.integers(-4, 5, size=(V, R)), jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)

    shrink_step = None
    exec_keys_pre_grow: set = set()
    for i in range(EL_STEPS):
        if i == EL_JOIN:
            exec_keys_pre_grow = set(ladder.group.executables)
            server.grow(arriving)
            watch.mark()  # grow's own prewarm compiled the grown pool;
            # everything SERVED after it must hit the cache.
        server.step(A, B)
        if shrink_step is None and len(server.pool) < len(pool):
            shrink_step = i
    reports = server.reports
    priced = np.array([r.sim_latency_s + EL_OVERHEAD[r.rung]
                       for r in reports])
    return {
        "seed": seed,
        "universe": EL_UNIVERSE,
        "pool_initial": len(pool),
        "pool_shrunk": (len(reports[shrink_step].pool)
                        if shrink_step is not None else None),
        "pool_final": len(reports[-1].pool),
        "shrink_step": shrink_step,
        "join_step": EL_JOIN,
        "respecializations": int(sum(r.respecialize for r in reports)),
        "rung_first": reports[0].rung,
        "rung_shrunk": (reports[shrink_step].rung
                        if shrink_step is not None else None),
        "rung_final": reports[-1].rung,
        "pre_depart_mean_s": float(priced[:EL_DEPART].mean()),
        "shrunk_mean_s": (float(priced[shrink_step:EL_JOIN].mean())
                          if shrink_step is not None else None),
        "post_grow_mean_s": float(priced[EL_JOIN:].mean()),
        "post_grow_recompiles": watch.delta(),
        "old_executables_survived": exec_keys_pre_grow
        <= set(ladder.group.executables),
        "all_exact": all(r.exact for r in reports),
    }


def check_elastic(row: dict) -> None:
    """Acceptance gates of the elastic sweep (also run under ``--check``).

    The run must SURVIVE a shrink that exceeds the active rung's slack
    (the handoff executes: pool shrank, a respecialisation fired, every
    step decoded exactly), must RECOVER throughput after the grow (the
    readmitted pool serves the cheap wide rung again, beating the shrunk
    phase and landing back at the pre-departure price), and the grow must
    compile NOTHING for pre-existing rungs — the old pool's executables
    all survive and post-grow serving adds zero compiles.
    """
    assert row["all_exact"], f"inexact decode in the elastic sweep: {row}"
    assert row["shrink_step"] is not None, (
        f"the shrink handoff never executed: {row}")
    assert row["respecializations"] > 0, (
        f"no respecialisation event recorded: {row}")
    assert row["pool_shrunk"] < row["pool_initial"], (
        f"pool did not shrink: {row}")
    assert row["rung_shrunk"] != row["rung_first"], (
        f"shrink did not re-lower the rung: {row}")
    assert row["pool_final"] > row["pool_shrunk"], (
        f"pool did not grow back: {row}")
    assert row["rung_final"] == row["rung_first"], (
        f"grow did not recover the wide rung: {row}")
    assert row["post_grow_mean_s"] < 0.8 * row["shrunk_mean_s"], (
        f"no throughput recovery after grow: {row}")
    assert row["post_grow_mean_s"] <= 1.25 * row["pre_depart_mean_s"], (
        f"post-grow price did not return to the pre-departure level: {row}")
    assert_no_recompiles(row["post_grow_recompiles"],
                         "serving after the elastic grow")
    assert row["old_executables_survived"], (
        f"grow evicted pre-existing executables: {row}")


def _run_exhausted(seed: int) -> dict:
    """Budget-exhaustion handoff: a polycode-only ladder (budget 1) facing 3
    persistent stragglers must flag a respecialisation (plan_shrink)."""
    import jax.numpy as jnp

    from repro.control import AdaptiveServer, PlanLadder

    S = 3
    traces = _traces(S, seed)
    ladder = PlanLadder(P, M, N, K=K, L=L_SMALL, backend="reference",
                        include=["polycode"])
    ladder.prewarm((V, R), (V, T))
    server = AdaptiveServer(ladder, feed=lambda step, rng: traces[step],
                            seed=seed, check_exact=True)
    rng = np.random.default_rng(seed + 1)
    A = jnp.asarray(rng.integers(-4, 5, size=(V, R)), jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)
    reports = server.run(STEPS, lambda i: (A, B))
    events = [rep for rep in reports if rep.respecialize]
    return {
        "ladder": list(ladder.rungs),
        "stragglers": S,
        "budget": ladder.budget("polycode"),
        "respecializations": len(events),
        "shrink_target": list(events[0].shrink_target) if events else None,
        "all_exact": all(rep.exact for rep in reports),
    }


def run(sweep: str = "all", backend: str = "reference") -> dict:
    from repro.core.numerics import enable_x64

    partial_config = {
        "scenarios": list(PARTIAL_SCENARIOS), "sub_tasks": PARTIAL_SUB_TASKS,
        "steps": PARTIAL_STEPS, "warmup": PARTIAL_WARMUP,
        "seed": PARTIAL_SEED, "overhead_s": Q_OVERHEAD,
    }
    elastic_config = {
        "grid": list(EL_GRID), "universe": EL_UNIVERSE, "steps": EL_STEPS,
        "depart_step": EL_DEPART, "join_step": EL_JOIN, "seed": EL_SEED,
        "overhead_s": EL_OVERHEAD, "include": ["polycode"],
    }
    if sweep == "partial_sweep":
        # the mesh gate lands under its OWN key, so a mesh run appends to
        # BENCH_control.json next to the reference rows instead of
        # replacing them.
        key = "partial_sweep" if backend == "reference" else (
            f"partial_sweep_{backend}")
        cfg = dict(partial_config, backend=backend)
        if backend == "mesh":
            cfg["scenarios"] = list(PARTIAL_MESH_SCENARIOS)
        with enable_x64():
            partial_sweep = _run_partial_sweep(backend)
        return {"config": {key: cfg}, key: partial_sweep}
    if sweep == "elastic_sweep":
        with enable_x64():
            elastic_sweep = _run_elastic(EL_SEED)
        return {"config": {"elastic_sweep": elastic_config},
                "elastic_sweep": elastic_sweep}
    with enable_x64():
        regimes = [_run_regime(L, S, seed=17 + S)
                   for L in (L_SMALL, L_LARGE)
                   for S in STRAGGLER_COUNTS]
        quantile_sweep = _run_quantile_sweep()
        scenario_sweep = _run_scenario_sweep()
        feedback_sweep = _run_feedback_sweep()
        partial_sweep = _run_partial_sweep()
        elastic_sweep = _run_elastic(EL_SEED)
        exhausted = _run_exhausted(seed=29)
    return {
        "config": {
            "grid": [P, M, N], "K": K, "shape": [V, R, T], "steps": STEPS,
            "resample_every": RESAMPLE_EVERY, "base_s": BASE_S,
            "slowdown": SLOWDOWN, "jitter": JITTER,
            "L": {"small": L_SMALL, "large": L_LARGE},
            "quantile_sweep": {
                "steps": Q_STEPS, "warmup": Q_WARMUP, "slo_quantile": Q_SLO,
                "heavy_jitter": HEAVY_JITTER, "healthy_jitter": HEALTHY_JITTER,
                "overhead_s": Q_OVERHEAD, "batches": list(Q_BATCHES),
                "buckets": list(Q_BUCKETS),
            },
            "scenario_sweep": {"steps": SC_STEPS, "seed": SC_SEED},
            "feedback_sweep": {
                "steps": FB_STEPS, "warmup": FB_WARMUP,
                "q_base": FB_Q_BASE, "slo_s": FB_SLO_S,
                "seeds": list(FB_SEEDS), "scenario": "heavy_tail",
                "overhead_s": Q_OVERHEAD, "config": FB_CONFIG,
            },
            "partial_sweep": partial_config,
            "elastic_sweep": elastic_config,
        },
        "regimes": regimes,
        "quantile_sweep": quantile_sweep,
        "scenario_sweep": scenario_sweep,
        "feedback_sweep": feedback_sweep,
        "partial_sweep": partial_sweep,
        "elastic_sweep": elastic_sweep,
        "exhausted": exhausted,
    }


def check_partial(rows: list) -> None:
    """Acceptance gates of the partial sweep (also run under ``--check``).

    Partial must never lose to binary erasure on realized p99 (the plan
    construction guarantees it is never slower), must beat it STRICTLY
    under ``heavy_tail`` and ``pareto`` (more flagged stragglers than the
    narrow budget — the regime sub-tasking exists for), must actually
    consume fractions, keep every decode exact and recompile-free, and
    the Q=1 path must be bit-identical to the legacy mask path.
    """
    by_name = {row["scenario"]: row for row in rows}
    assert {"heavy_tail", "pareto"} <= set(by_name), (
        f"partial sweep missing its win regimes: {sorted(by_name)}")
    for row in rows:
        binary, partial = row["binary"], row["partial"]
        for side in (binary, partial):
            assert side["all_exact"], f"inexact partial-sweep decode: {row}"
            assert_no_recompiles(
                side["recompiles"],
                f"the partial sweep ({row['scenario']}, "
                f"Q={side['sub_tasks']})")
        assert row["q1_bit_identical"], (
            f"Q=1 partial decode diverged from the legacy mask path: {row}")
        assert partial["p99_s"] <= binary["p99_s"] * 1.001, (
            f"partial LOST to binary erasure on p99 at "
            f"{row['scenario']}: {row}")
        assert partial["fractional_consumptions"] > 0, (
            f"partial server never consumed a fraction at "
            f"{row['scenario']}: {row}")
    for name in ("heavy_tail", "pareto"):
        row = by_name[name]
        assert row["partial"]["p99_s"] < 0.95 * row["binary"]["p99_s"], (
            f"partial did not STRICTLY beat binary p99 under {name}: {row}")


def check(result: dict) -> None:
    for row in result["regimes"]:
        assert row["all_exact"], f"inexact decode: {row}"
        assert_no_recompiles(
            row["recompiles"],
            f"regime L={row['L']} S={row['stragglers']}")
        feasible = [r for r, ok in row["static_feasible"].items() if ok]
        assert set(row["adaptive_rungs"]) <= set(feasible), (
            f"adaptive served an invalid rung: {row}")
        best_static = min(row["static_s"][r] for r in feasible)
        if row["stragglers"] == 0:
            assert row["adaptive_s"] <= best_static * 1.05, (
                f"adaptive worse than best static at S=0: {row}")
    beats = [row for row in result["regimes"]
             if row["stragglers"] > 0
             and row["adaptive_s"] < min(row["static_s"].values()) * 0.95]
    assert beats, "adaptive never beat every static rung in a straggler regime"
    large = [row for row in result["regimes"] if row["L"] == L_LARGE]
    assert all("bec" not in row["adaptive_rungs"] for row in large), (
        "policy served bec past its entry-bound feasibility")
    by_s: dict = {}
    for row in result["quantile_sweep"]:
        assert row["all_exact"], f"inexact batched decode: {row}"
        assert_no_recompiles(
            row["recompiles"],
            f"batched rung switches (policy {row['policy']}, "
            f"S={row['stragglers']})")
        by_s.setdefault(row["stragglers"], {})[row["policy"]] = row
    for S, pair in by_s.items():
        mean, quant = pair["mean"], pair["quantile"]
        if S == 0:
            assert abs(quant["p99_s"] - mean["p99_s"]) <= 0.05 * mean["p99_s"], (
                f"policies diverge with no stragglers (S=0): {pair}")
        else:
            assert quant["p99_s"] < 0.95 * mean["p99_s"], (
                f"quantile policy did not beat mean policy on p99 at "
                f"S={S}: {pair}")
    ex = result["exhausted"]
    assert ex["respecializations"] > 0 and ex["shrink_target"], (
        f"no respecialisation handoff under exhausted budget: {ex}")
    for row in result["scenario_sweep"]:
        for variant in ("stressed", "calm"):
            v = row[variant]
            assert v["all_exact"], f"inexact decode ({variant}): {row}"
            assert_no_recompiles(
                v["recompiles"], f"{variant} {row['scenario']}")
        # the S=0 criterion, stated so it CAN fail (a masked mean is <= the
        # all-worker max by construction, so a one-sided bound is vacuous):
        # at the calm control the monitor must erase NOBODY and never flag a
        # respecialisation, which forces adaptive_s == static_s exactly.
        calm = row["calm"]
        assert calm["erasures"] == 0, (
            f"monitor erased healthy workers at calm "
            f"{row['scenario']}: {calm}")
        assert calm["respecializations"] == 0, (
            f"spurious respecialisation at calm {row['scenario']}: {calm}")
        assert calm["adaptive_s"] == calm["static_s"], (
            f"adaptive diverged from best static at calm "
            f"{row['scenario']}: {calm}")
        # under stress the masks must actually shed waits: a real margin,
        # not the by-construction <= bound.
        stressed = row["stressed"]
        assert stressed["adaptive_s"] <= stressed["static_s"] * 0.9, (
            f"adaptive failed to beat static under stressed "
            f"{row['scenario']}: {stressed}")
        assert stressed["erasures"] > 0, (
            f"no erasures under stressed {row['scenario']}: {stressed}")
    by_seed: dict = {}
    for row in result["feedback_sweep"]:
        by_seed.setdefault(row["seed"], {})[row["policy"]] = row
    reduced = 0
    for seed, pair in by_seed.items():
        static, fb = pair["static_q"], pair["feedback"]
        assert fb["violations"] <= static["violations"], (
            f"feedback INCREASED realized violations at seed {seed}: {pair}")
        assert fb["p99_s"] <= static["p99_s"] * 1.02, (
            f"feedback worsened realized p99 at seed {seed}: {pair}")
        reduced += fb["violations"] < static["violations"]
    assert reduced > 0, (
        "feedback never strictly reduced realized SLO violations vs the "
        f"static-q policy: {result['feedback_sweep']}")
    check_partial(result["partial_sweep"])
    check_elastic(result["elastic_sweep"])


def _print_elastic(row: dict) -> None:
    print(f"elastic: pool {row['pool_initial']} -> {row['pool_shrunk']} "
          f"(shrink step {row['shrink_step']}, {row['rung_first']} -> "
          f"{row['rung_shrunk']}) -> {row['pool_final']} "
          f"(join step {row['join_step']}, back to {row['rung_final']}); "
          f"priced mean {row['pre_depart_mean_s']:.2f} -> "
          f"{row['shrunk_mean_s']:.2f} -> {row['post_grow_mean_s']:.2f} s, "
          f"{row['post_grow_recompiles']} post-grow recompiles, old "
          f"executables survived: {row['old_executables_survived']}")


def _print_partial(rows: list) -> None:
    for row in rows:
        b, p = row["binary"], row["partial"]
        backend = row.get("backend", "reference")
        print(f"partial [{backend}] {row['scenario']:<12} "
              f"binary p99 {b['p99_s']:6.2f} s "
              f"vs Q={p['sub_tasks']} p99 {p['p99_s']:6.2f} s "
              f"(p50 {b['p50_s']:5.2f} -> {p['p50_s']:5.2f} s, "
              f"{p['fractional_consumptions']} fractional consumptions, "
              f"q1 parity {row['q1_bit_identical']})")


def main(argv=None, save: str = "BENCH_control.json"):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("sweep", nargs="?", default="all",
                    choices=["all", "partial_sweep", "elastic_sweep"],
                    help="which sweep to run: the full bench (default), "
                         "only the binary-vs-partial comparison, or only "
                         "the elastic shrink/grow handoff")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "mesh"],
                    help="executor the partial sweep serves through: "
                         "reference (default) or mesh (one worker per "
                         "device; needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=12)")
    ap.add_argument("--check", action="store_true",
                    help="assert the acceptance criteria (CI smoke)")
    args = ap.parse_args(argv)
    if args.backend != "reference" and args.sweep != "partial_sweep":
        ap.error("--backend mesh only applies to the partial_sweep sweep")

    result = run(args.sweep, args.backend)
    out = Path(__file__).resolve().parents[1] / save
    # merge-append: a single-sweep run updates its keys in the existing
    # file instead of discarding the other sweeps' rows.
    merged = result
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except ValueError:
            merged = {}
        merged.setdefault("config", {}).update(result["config"])
        merged.update({k: v for k, v in result.items() if k != "config"})
    out.write_text(json.dumps(merged, indent=2) + "\n")
    print(f"wrote {out}")
    if args.sweep == "partial_sweep":
        key = ("partial_sweep" if args.backend == "reference"
               else f"partial_sweep_{args.backend}")
        _print_partial(result[key])
        if args.check:
            check_partial(result[key])
            print(f"control bench partial check ({args.backend}): OK")
        return result
    if args.sweep == "elastic_sweep":
        _print_elastic(result["elastic_sweep"])
        if args.check:
            check_elastic(result["elastic_sweep"])
            print("control bench elastic check: OK")
        return result
    for row in result["regimes"]:
        static = {r: round(s, 3) for r, s in row["static_s"].items()}
        print(f"L={row['L']:>6} S={row['stragglers']}: "
              f"static {static} vs adaptive {row['adaptive_s']:.3f} s "
              f"(rungs {row['adaptive_rungs']}, switches {row['switches']}, "
              f"recompiles {row['recompiles']})")
    for row in result["quantile_sweep"]:
        print(f"S={row['stragglers']} policy={row['policy']:<8} "
              f"p50 {row['p50_s']:6.2f} s  p99 {row['p99_s']:6.2f} s "
              f"(rungs {row['rungs']}, recompiles {row['recompiles']})")
    for row in result["scenario_sweep"]:
        s, c = row["stressed"], row["calm"]
        print(f"scenario {row['scenario']:<12} stressed: static {s['static_s']:6.2f} "
              f"vs adaptive {s['adaptive_s']:6.2f} s | calm: static "
              f"{c['static_s']:5.2f} vs adaptive {c['adaptive_s']:5.2f} s")
    for row in result["feedback_sweep"]:
        print(f"feedback seed={row['seed']} policy={row['policy']:<9} "
              f"violations {row['violations']:2d}/{row['steps']} "
              f"p50 {row['p50_s']:5.2f} s  p99 {row['p99_s']:5.2f} s "
              f"(rungs {row['rungs']})")
    _print_partial(result["partial_sweep"])
    _print_elastic(result["elastic_sweep"])
    ex = result["exhausted"]
    print(f"exhausted-budget handoff: {ex['respecializations']} "
          f"respecialisations -> shrink {ex['shrink_target']}")
    if args.check:
        check(result)
        print("control bench check: OK")
    return result


if __name__ == "__main__":
    main()
