"""Control-plane bench: static best rung vs adaptive, swept over stragglers.

The serving model is the repo's SYNCHRONOUS mesh step (DESIGN Sec. 3): a
step waits for every worker that is not declared erased — the 0/1 mask is
the only way to not wait for a straggler.  A *static* deployment fixes one
rung at ``make_plan`` time and has no health monitor, so its step
completion is the max over ALL workers.  The *adaptive* control plane
(``repro.control``) learns the straggler set from observed step times and
erases it, within the active rung's budget ``K - tau``, approaching the
tau-th order statistic — the paper's async-master latency, recovered as a
control decision.

Per (L, straggler-count) regime both sides replay the SAME per-worker time
traces.  Every adaptive step also executes a real coded matmul through the
``PlanLadder`` facades and is checked exact against the uncoded oracle;
the ladder's shared ``CacheGroup`` counters prove rung switches after
``prewarm()`` compile nothing.

The p50-vs-p99 POLICY sweep plays the same game at the tail: under a
heavy-tailed straggler mix (2x slowdown plus a fat exponential tail on the
slow machines) the MEAN ranking and the QUANTILE ranking genuinely
disagree — the per-rung step costs (synthetic, in units of one worker
step: the depth-p digit stack makes the low-tau rungs the expensive ones,
exactly the paper's L <-> tau price) outweigh the mean cost of leaving a
straggler unmasked, but not its p99 cost.  The mean policy therefore
serves the cheap narrow-budget rung and eats the tail; the
``QuantileLatencyPolicy`` pays the digit tax for tail protection — worse
p50, strictly better p99.  Both sides serve vmap-BATCHED requests of
varying size through prewarmed leading-dim buckets, and the zero-recompile
contract is asserted across every batched rung switch.

Rows land in BENCH_control.json.  ``--check`` asserts the acceptance
criteria (CI smoke): adaptive matches the best static rung at zero
stragglers, beats every static rung in at least one nonzero regime, zero
recompiles after prewarm (batched sweeps included), the quantile policy
strictly beats the mean policy on p99 under the heavy-tailed mix while
matching it at S=0, and the budget-exhaustion scenario hands off to
``CodedElasticPolicy``/``plan_shrink``.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

# geometry shared by every rung of the ladder (paper Sec. IV family)
P, M, N, K = 4, 2, 1, 12
V, R, T = 16, 8, 4
STEPS = 24
RESAMPLE_EVERY = 8
BASE_S = 1.0
SLOWDOWN = 2.0
JITTER = 0.02
L_SMALL = V * 4 * 4 + 1     # conservative_L(V, 4, 4): every rung feasible
L_LARGE = 1 << 14           # bec's depth-3 digit stack overflows f64 here
STRAGGLER_COUNTS = (0, 1, 3, 5)

# -- p50-vs-p99 policy sweep ------------------------------------------------
Q_STEPS = 48
Q_WARMUP = 6                # cold-monitor steps excluded from the stats
Q_SLO = 0.99
HEAVY_JITTER = 1.5          # stragglers: 2x slowdown + Exp(1.5 x base) tail
HEALTHY_JITTER = 0.05
# synthetic per-rung step cost (units of BASE_S): the depth-p digit stack
# prices the low-tau rungs, the paper's L <-> tau tradeoff as overhead
Q_OVERHEAD = {"bec": 10.0, "tradeoff(p'=2)": 9.0, "polycode": 0.5}
Q_STRAGGLERS = (0, 3, 5)
Q_BATCHES = (5, 3, 8, 2)    # per-request batch sizes, cycled
Q_BUCKETS = (4, 8)          # prewarmed leading-dim buckets (round-up pad)


def _traces(S: int, seed: int) -> np.ndarray:
    """(STEPS, K) per-worker finish times: persistent straggler set of size
    S, resampled every RESAMPLE_EVERY steps (the paper's 2x duplication
    model plus light exponential jitter)."""
    from repro.core.simulator import LatencyModel

    rng = np.random.default_rng(seed)
    model = LatencyModel(base=BASE_S, straggler_slowdown=SLOWDOWN,
                         jitter=JITTER)
    out = np.empty((STEPS, K))
    slow = rng.choice(K, size=S, replace=False)
    for step in range(STEPS):
        if step and step % RESAMPLE_EVERY == 0:
            slow = rng.choice(K, size=S, replace=False)
        out[step] = model.sample(K, slow, rng)
    return out


def _run_regime(L: int, S: int, seed: int) -> dict:
    import jax.numpy as jnp

    from repro.control import AdaptiveServer, ExpectedLatencyPolicy, PlanLadder

    traces = _traces(S, seed)
    ladder = PlanLadder(P, M, N, K=K, L=L, backend="reference")
    prewarm = ladder.prewarm((V, R), (V, T))
    builds_prewarm = prewarm["builds"]
    # uniform zero overhead: rungs differ only through masking/feasibility,
    # so the sweep is deterministic given the seeds (measured per-rung step
    # costs are reported by `prewarm` and exercised in coded_serve).
    policy = ExpectedLatencyPolicy(
        ladder, overhead_s={r: 0.0 for r in ladder.rungs})
    server = AdaptiveServer(ladder, policy=policy,
                            feed=lambda step, rng: traces[step],
                            seed=seed, check_exact=True)

    rng = np.random.default_rng(seed + 1)
    A = jnp.asarray(rng.integers(-4, 5, size=(V, R)), jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)
    reports = server.run(STEPS, lambda i: (A, B))

    static_s = {r: float(traces.max(axis=1).mean()) for r in ladder.rungs}
    rung_counts: dict = {}
    for rep in reports:
        rung_counts[rep.rung] = rung_counts.get(rep.rung, 0) + 1
    info = ladder.cache_info()
    return {
        "L": L,
        "stragglers": S,
        "static_s": static_s,
        "static_feasible": {r: policy.feasible(r) for r in ladder.rungs},
        "adaptive_s": float(np.mean([rep.sim_latency_s for rep in reports])),
        "adaptive_rungs": rung_counts,
        "switches": info["switches"],
        "builds_prewarm": builds_prewarm,
        "builds_final": info["builds"],
        "panel_builds": info["panel_builds"],
        "respecializations": sum(rep.respecialize for rep in reports),
        "all_exact": all(rep.exact for rep in reports),
    }


def _heavy_traces(S: int, steps: int, seed: int) -> np.ndarray:
    """(steps, K) finish times under the heavy-tailed straggler mix: a FIXED
    set of S machines at 2x slowdown with an Exp(HEAVY_JITTER x base) tail,
    everyone else near-deterministic."""
    from repro.core.simulator import LatencyModel

    rng = np.random.default_rng(seed)
    slow = rng.choice(K, size=S, replace=False)
    jitter = np.full(K, HEALTHY_JITTER)
    jitter[slow] = HEAVY_JITTER
    model = LatencyModel(base=BASE_S, straggler_slowdown=SLOWDOWN,
                         jitter=jitter)
    return np.stack([model.sample(K, slow, rng) for _ in range(steps)])


def _run_policy(policy_name: str, traces: np.ndarray, seed: int) -> dict:
    """One policy serving batched requests over ``traces``; realized step
    latency = masked completion + the rung's synthetic overhead."""
    import jax.numpy as jnp

    from repro.control import (
        AdaptiveServer,
        ExpectedLatencyPolicy,
        PlanLadder,
        QuantileLatencyPolicy,
    )

    ladder = PlanLadder(P, M, N, K=K, L=L_SMALL, backend="reference")
    prewarm = ladder.prewarm((V, R), (V, T), batch_sizes=Q_BUCKETS)
    builds_prewarm = prewarm["builds"]
    if policy_name == "mean":
        policy = ExpectedLatencyPolicy(ladder, overhead_s=Q_OVERHEAD)
    else:
        policy = QuantileLatencyPolicy(ladder, q=Q_SLO, overhead_s=Q_OVERHEAD)
    server = AdaptiveServer(ladder, policy=policy,
                            feed=lambda step, rng: traces[step],
                            seed=seed, check_exact=True)

    rng = np.random.default_rng(seed + 1)
    A_pool = jnp.asarray(rng.integers(-4, 5, size=(max(Q_BATCHES), V, R)),
                         jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)
    reports = server.run(Q_STEPS,
                         lambda i: (A_pool[: Q_BATCHES[i % len(Q_BATCHES)]], B))

    realized = np.array([rep.sim_latency_s + Q_OVERHEAD[rep.rung]
                         for rep in reports])[Q_WARMUP:]
    rung_counts: dict = {}
    for rep in reports[Q_WARMUP:]:
        rung_counts[rep.rung] = rung_counts.get(rep.rung, 0) + 1
    info = ladder.cache_info()
    return {
        "policy": policy_name,
        "p50_s": float(np.quantile(realized, 0.5)),
        "p99_s": float(np.quantile(realized, Q_SLO)),
        "rungs": rung_counts,
        "switches": info["switches"],
        "builds_prewarm": builds_prewarm,
        "builds_final": info["builds"],
        "all_exact": all(rep.exact for rep in reports),
    }


def _run_quantile_sweep() -> list:
    """Mean vs quantile policy over identical heavy-tailed batched traces."""
    rows = []
    for S in Q_STRAGGLERS:
        traces = _heavy_traces(S, Q_STEPS, seed=101 + S)
        for policy_name in ("mean", "quantile"):
            row = _run_policy(policy_name, traces, seed=101 + S)
            row["stragglers"] = S
            rows.append(row)
    return rows


def _run_exhausted(seed: int) -> dict:
    """Budget-exhaustion handoff: a polycode-only ladder (budget 1) facing 3
    persistent stragglers must flag a respecialisation (plan_shrink)."""
    import jax.numpy as jnp

    from repro.control import AdaptiveServer, PlanLadder

    S = 3
    traces = _traces(S, seed)
    ladder = PlanLadder(P, M, N, K=K, L=L_SMALL, backend="reference",
                        include=["polycode"])
    ladder.prewarm((V, R), (V, T))
    server = AdaptiveServer(ladder, feed=lambda step, rng: traces[step],
                            seed=seed, check_exact=True)
    rng = np.random.default_rng(seed + 1)
    A = jnp.asarray(rng.integers(-4, 5, size=(V, R)), jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(V, T)), jnp.float64)
    reports = server.run(STEPS, lambda i: (A, B))
    events = [rep for rep in reports if rep.respecialize]
    return {
        "ladder": list(ladder.rungs),
        "stragglers": S,
        "budget": ladder.budget("polycode"),
        "respecializations": len(events),
        "shrink_target": list(events[0].shrink_target) if events else None,
        "all_exact": all(rep.exact for rep in reports),
    }


def run() -> dict:
    from repro.core.numerics import enable_x64

    with enable_x64():
        regimes = [_run_regime(L, S, seed=17 + S)
                   for L in (L_SMALL, L_LARGE)
                   for S in STRAGGLER_COUNTS]
        quantile_sweep = _run_quantile_sweep()
        exhausted = _run_exhausted(seed=29)
    return {
        "config": {
            "grid": [P, M, N], "K": K, "shape": [V, R, T], "steps": STEPS,
            "resample_every": RESAMPLE_EVERY, "base_s": BASE_S,
            "slowdown": SLOWDOWN, "jitter": JITTER,
            "L": {"small": L_SMALL, "large": L_LARGE},
            "quantile_sweep": {
                "steps": Q_STEPS, "warmup": Q_WARMUP, "slo_quantile": Q_SLO,
                "heavy_jitter": HEAVY_JITTER, "healthy_jitter": HEALTHY_JITTER,
                "overhead_s": Q_OVERHEAD, "batches": list(Q_BATCHES),
                "buckets": list(Q_BUCKETS),
            },
        },
        "regimes": regimes,
        "quantile_sweep": quantile_sweep,
        "exhausted": exhausted,
    }


def check(result: dict) -> None:
    for row in result["regimes"]:
        assert row["all_exact"], f"inexact decode: {row}"
        assert row["builds_final"] == row["builds_prewarm"], (
            f"recompile after prewarm: {row}")
        feasible = [r for r, ok in row["static_feasible"].items() if ok]
        assert set(row["adaptive_rungs"]) <= set(feasible), (
            f"adaptive served an invalid rung: {row}")
        best_static = min(row["static_s"][r] for r in feasible)
        if row["stragglers"] == 0:
            assert row["adaptive_s"] <= best_static * 1.05, (
                f"adaptive worse than best static at S=0: {row}")
    beats = [row for row in result["regimes"]
             if row["stragglers"] > 0
             and row["adaptive_s"] < min(row["static_s"].values()) * 0.95]
    assert beats, "adaptive never beat every static rung in a straggler regime"
    large = [row for row in result["regimes"] if row["L"] == L_LARGE]
    assert all("bec" not in row["adaptive_rungs"] for row in large), (
        "policy served bec past its entry-bound feasibility")
    by_s: dict = {}
    for row in result["quantile_sweep"]:
        assert row["all_exact"], f"inexact batched decode: {row}"
        assert row["builds_final"] == row["builds_prewarm"], (
            f"recompile across batched rung switches: {row}")
        by_s.setdefault(row["stragglers"], {})[row["policy"]] = row
    for S, pair in by_s.items():
        mean, quant = pair["mean"], pair["quantile"]
        if S == 0:
            assert abs(quant["p99_s"] - mean["p99_s"]) <= 0.05 * mean["p99_s"], (
                f"policies diverge with no stragglers (S=0): {pair}")
        else:
            assert quant["p99_s"] < 0.95 * mean["p99_s"], (
                f"quantile policy did not beat mean policy on p99 at "
                f"S={S}: {pair}")
    ex = result["exhausted"]
    assert ex["respecializations"] > 0 and ex["shrink_target"], (
        f"no respecialisation handoff under exhausted budget: {ex}")


def main(argv=None, save: str = "BENCH_control.json"):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the acceptance criteria (CI smoke)")
    args = ap.parse_args(argv)

    result = run()
    out = Path(__file__).resolve().parents[1] / save
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {out}")
    for row in result["regimes"]:
        static = {r: round(s, 3) for r, s in row["static_s"].items()}
        print(f"L={row['L']:>6} S={row['stragglers']}: "
              f"static {static} vs adaptive {row['adaptive_s']:.3f} s "
              f"(rungs {row['adaptive_rungs']}, switches {row['switches']}, "
              f"builds {row['builds_prewarm']}->{row['builds_final']})")
    for row in result["quantile_sweep"]:
        print(f"S={row['stragglers']} policy={row['policy']:<8} "
              f"p50 {row['p50_s']:6.2f} s  p99 {row['p99_s']:6.2f} s "
              f"(rungs {row['rungs']}, builds "
              f"{row['builds_prewarm']}->{row['builds_final']})")
    ex = result["exhausted"]
    print(f"exhausted-budget handoff: {ex['respecializations']} "
          f"respecialisations -> shrink {ex['shrink_target']}")
    if args.check:
        check(result)
        print("control bench check: OK")
    return result


if __name__ == "__main__":
    main()
