"""Microbenchmarks for the Pallas kernel stages (+ XLA reference).

On this CPU container the kernels run in interpret mode, so absolute times
are NOT TPU-indicative; the value here is (a) regression tracking of the
wrapper overhead, (b) fused-vs-staged pipeline comparison at matched sizes,
and (c) the FLOP/byte accounting printed per stage, which feeds the
kernel-level roofline discussion in EXPERIMENTS.md.

``main(save=path)`` persists the rows as JSON (name, us, derived) so later
PRs have a regression baseline (run.py writes BENCH_kernels.json).
``python -m benchmarks.kernels_micro --check`` runs a correctness smoke:
the fused megakernel must match the XLA reference (CI gate).
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import make_plan
from repro.core.partition import block_decompose
from repro.kernels import ops, ref
from repro.runtime import FusedKernelExecutor, ReferenceExecutor, StagedKernelExecutor


def _time(f, *args, reps=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _fused_inputs(rng, K=4, P=4, Q=4, v=256, r=256, t=256):
    ca = jnp.asarray(rng.normal(size=(K, P)), jnp.float32)
    cb = jnp.asarray(rng.normal(size=(K, Q)), jnp.float32)
    a_blocks = jnp.asarray(rng.normal(size=(P, v, r)), jnp.float32)
    b_blocks = jnp.asarray(rng.normal(size=(Q, v, t)), jnp.float32)
    return ca, cb, a_blocks, b_blocks


def run():
    rng = np.random.default_rng(0)
    rows = []
    # encode: K=10 workers, P=4 blocks, E = 512x512 block
    K, P, E = 10, 4, 512 * 512
    coeff = jnp.asarray(rng.normal(size=(K, P)), jnp.float32)
    blocks = jnp.asarray(rng.normal(size=(P, E)), jnp.float32)
    us_ref = _time(jax.jit(ref.encode_ref), coeff, blocks)
    us_k = _time(lambda c, b: ops.encode(c, b), coeff, blocks)
    flops = 2 * K * P * E
    rows.append(("encode_pallas_interp", us_k, f"flops={flops:.2e}"))
    rows.append(("encode_xla_ref", us_ref, f"flops={flops:.2e}"))

    # worker block matmul 512^3
    v = r = t = 512
    A = jnp.asarray(rng.normal(size=(v, r)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(v, t)), jnp.float32)
    us_ref = _time(jax.jit(ref.matmul_t_ref), A, B)
    us_k = _time(lambda a, b: ops.matmul_t(a, b), A, B)
    rows.append(("block_matmul_pallas_interp", us_k, f"flops={2*v*r*t:.2e}"))
    rows.append(("block_matmul_xla_ref", us_ref, f"flops={2*v*r*t:.2e}"))

    # fused encode+product megakernel vs the staged schedule, via the
    # runtime executors at matched sizes (K=4 workers, bec 2x2x2 plan).
    # HBM traffic saved by fusion: the coded operands A~/B~ (2*v*(r+t)
    # floats per worker written then re-read) never materialise.
    vf = rf = tf = 256
    plan = make_plan("bec", 2, 2, 2, K=4, L=2 * vf * 9 + 1, points="chebyshev")
    Af = jnp.asarray(rng.normal(size=(2 * vf, 2 * rf)), jnp.float32)
    Bf = jnp.asarray(rng.normal(size=(2 * vf, 2 * tf)), jnp.float32)
    ab = block_decompose(Af, 2, 2)                       # (2, 2, vf, rf)
    bb = block_decompose(Bf, 2, 2)
    Kf, Pf = plan.K, 4
    flops_f = Kf * (2 * Pf * vf * rf + 2 * Pf * vf * tf + 2 * vf * rf * tf)
    saved = Kf * 2 * vf * (rf + tf) * 4  # bytes of A~/B~ HBM round-trip
    fused_x, staged_x, ref_x = (FusedKernelExecutor(), StagedKernelExecutor(),
                                ReferenceExecutor())
    us_fused = _time(lambda a, b: fused_x.worker_products(plan, a, b), ab, bb)
    us_staged = _time(lambda a, b: staged_x.worker_products(plan, a, b), ab, bb)
    us_ref = _time(jax.jit(lambda a, b: ref_x.worker_products(plan, a, b)),
                   ab, bb)
    rows.append(("fused_worker_pallas_interp", us_fused,
                 f"flops={flops_f:.2e};hbm_saved_bytes={saved:.2e}"))
    rows.append(("staged_encode_matmul_interp", us_staged,
                 f"flops={flops_f:.2e}"))
    rows.append(("fused_worker_xla_ref", us_ref, f"flops={flops_f:.2e}"))

    # decode: mn=4 from tau=4, E block
    W = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    Y = jnp.asarray(rng.integers(-100, 100, size=(4, E)), jnp.float32)
    us_ref = _time(jax.jit(lambda w, y: ref.decode_ref(w, y, 1024.0)), W, Y)
    us_k = _time(lambda w, y: ops.decode(w, y, 1024.0), W, Y)
    rows.append(("decode_pallas_interp", us_k, f"bytes={Y.nbytes:.2e}"))
    rows.append(("decode_xla_ref", us_ref, f"bytes={Y.nbytes:.2e}"))
    return rows


def check() -> None:
    """CI smoke: the fused megakernel must match the XLA reference."""
    rng = np.random.default_rng(1)
    ca, cb, a_blocks, b_blocks = _fused_inputs(rng, K=3, P=4, Q=2,
                                               v=192, r=160, t=96)
    out = ops.fused_worker(ca, cb, a_blocks, b_blocks)
    exp = ref.fused_worker_ref(ca, cb, a_blocks, b_blocks)
    err = float(jnp.max(jnp.abs(out - exp)))
    scale = float(jnp.max(jnp.abs(exp))) + 1e-9
    assert err / scale < 1e-4, f"fused kernel mismatch: rel err {err/scale:.3e}"
    print(f"fused kernel check OK (rel err {err/scale:.3e})")


def save_json(rows, path: str) -> None:
    records = []
    for name, us, derived in rows:
        rec = {"name": name, "us": round(us, 1)}
        for item in derived.split(";"):
            k, _, val = item.partition("=")
            rec[k] = float(val)
        records.append(rec)
    with open(path, "w") as f:
        json.dump(records, f, indent=2)
        f.write("\n")


def main(save: str | None = None):
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if save:
        save_json(rows, save)
        print(f"saved {save}")
    return rows


if __name__ == "__main__":
    if "--check" in sys.argv:
        check()
    else:
        save = None
        if "--save" in sys.argv:
            i = sys.argv.index("--save")
            save = sys.argv[i + 1] if i + 1 < len(sys.argv) else "BENCH_kernels.json"
        main(save=save)
