"""Microbenchmarks for the three Pallas kernel stages (+ XLA reference).

On this CPU container the kernels run in interpret mode, so absolute times
are NOT TPU-indicative; the value here is (a) regression tracking of the
wrapper overhead and (b) the FLOP/byte accounting printed per stage, which
feeds the kernel-level roofline discussion in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *args, reps=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    rng = np.random.default_rng(0)
    rows = []
    # encode: K=10 workers, P=4 blocks, E = 512x512 block
    K, P, E = 10, 4, 512 * 512
    coeff = jnp.asarray(rng.normal(size=(K, P)), jnp.float32)
    blocks = jnp.asarray(rng.normal(size=(P, E)), jnp.float32)
    us_ref = _time(jax.jit(ref.encode_ref), coeff, blocks)
    us_k = _time(lambda c, b: ops.encode(c, b), coeff, blocks)
    flops = 2 * K * P * E
    rows.append(("encode_pallas_interp", us_k, f"flops={flops:.2e}"))
    rows.append(("encode_xla_ref", us_ref, f"flops={flops:.2e}"))

    # worker block matmul 512^3
    v = r = t = 512
    A = jnp.asarray(rng.normal(size=(v, r)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(v, t)), jnp.float32)
    us_ref = _time(jax.jit(ref.matmul_t_ref), A, B)
    us_k = _time(lambda a, b: ops.matmul_t(a, b), A, B)
    rows.append(("block_matmul_pallas_interp", us_k, f"flops={2*v*r*t:.2e}"))
    rows.append(("block_matmul_xla_ref", us_ref, f"flops={2*v*r*t:.2e}"))

    # decode: mn=4 from tau=4, E block
    W = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    Y = jnp.asarray(rng.integers(-100, 100, size=(4, E)), jnp.float32)
    us_ref = _time(jax.jit(lambda w, y: ref.decode_ref(w, y, 1024.0)), W, Y)
    us_k = _time(lambda w, y: ops.decode(w, y, 1024.0), W, Y)
    rows.append(("decode_pallas_interp", us_k, f"bytes={Y.nbytes:.2e}"))
    rows.append(("decode_xla_ref", us_ref, f"bytes={Y.nbytes:.2e}"))
    return rows


def main():
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
