"""Shared counter-based recompile gate for the benches.

Before the obs layer, each bench proved its zero-recompile contract by
hand: thread a ``CacheGroup`` builds count out of every helper, snapshot
it after prewarm, and compare at the end.  ``CompileWatch`` replaces
that bookkeeping with the ``runtime.executable.compile`` counter the
facade itself increments — one watch per bench, ``mark()`` after
prewarm, ``assert_no_recompiles`` at the end — so the gate measures the
same signal production observability exports, and a bench cannot drift
from what the runtime actually did.

The watch ENABLES observability (the counter is dead while obs is off —
an assertion against a dead counter would pass vacuously) and reads
totals across all label sets, so per-kind splits don't hide a recompile.
"""
from __future__ import annotations

from repro import obs

__all__ = ["CompileWatch", "assert_no_recompiles"]


class CompileWatch:
    """Delta-reader over the ``runtime.executable.compile`` counter.

    Construction turns observability on (accumulating into the existing
    session unless ``fresh=True``) and marks the current count; ``mark``
    re-baselines (call it right after a prewarm) and ``delta`` is the
    compiles since the last mark.
    """

    COUNTER = "runtime.executable.compile"

    def __init__(self, fresh: bool = False):
        obs.enable(fresh=fresh)
        self._mark = self.compiles()

    def compiles(self) -> int:
        """Total executable compiles so far (all kinds)."""
        return int(obs.session().registry.total(self.COUNTER))

    def mark(self) -> int:
        """Re-baseline: subsequent ``delta`` counts from this point."""
        self._mark = self.compiles()
        return self._mark

    def delta(self) -> int:
        """Executable compiles since the last ``mark``."""
        return self.compiles() - self._mark


def assert_no_recompiles(count: int, label: str = "") -> None:
    """Assert a recorded post-prewarm compile delta is zero.

    Takes the plain count (``watch.delta()`` at run time, or the
    ``"recompiles"`` field of a bench row at ``--check`` time) so the
    gate works on persisted results too.  Keeps the benches' ``--check``
    semantics: a violation raises ``AssertionError`` naming the label
    and the count.
    """
    where = f" during {label}" if label else ""
    assert count == 0, (
        f"{count} executable recompile(s){where} — serving after prewarm "
        f"must be recompile-free ({CompileWatch.COUNTER})")
