"""Generate EXPERIMENTS.md sections from bench + dry-run artifacts.

Fills the <!-- PLACEHOLDER --> markers: Fig1/Table1/Tradeoff results, the
dry-run table, the roofline table, and the Perf variant comparison.

Run after benches + sweeps:  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"
PEAK, HBM, LINK = 197e12, 819e9, 50e9


def _md_table(header, rows):
    out = ["| " + " | ".join(header) + " |",
           "|" + "|".join("---" for _ in header) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def fig1_section():
    from benchmarks.fig1_latency import run
    rows = run(size=1024, trials=20)
    by = {}
    for r in rows:
        by.setdefault((r["scheme"], r["tau"]), []).append(
            (r["stragglers"], r["latency_s"]))
    lines = []
    for (scheme, tau), pts in by.items():
        lat = " ".join(f"S{s}={l*1e3:.1f}ms" for s, l in pts)
        lines.append(f"- **{scheme}** (τ={tau}): {lat}")
    lines.append("- shape matches paper Fig. 1: BEC flat through S=6 "
                 "(erasure budget K−τ=6), jump at S=7; polycode degrades "
                 "from S=2. (v=1024 CPU scale; worker/decode times measured.)")
    return "\n".join(lines)


def table1_section():
    from benchmarks.table1_error import run
    rows = run()
    t = _md_table(
        ["bound", "s", "log2 max|X|", "rel err (measured)", "analytic safe"],
        [(r["bound"], f"2^{int(__import__('math').log2(r['s']))}",
          f"{r['log2_maxX']:.1f}", f"{r['rel_err']:.2e}",
          r["analytic_safe"]) for r in rows])
    return t + ("\n\nError climbs 4+ orders of magnitude once log₂|X| crosses "
                "the f64 mantissa (53b) and collapses to ~1 when interpolation "
                "error crosses s/2 (mod-s wraps) - the paper's 'useless at "
                "bound 2000' row, shifted by the v=8000→2000 headroom delta.")


def tradeoff_section():
    from benchmarks.tradeoff_sweep import run
    rows = run()
    return _md_table(
        ["p'", "τ", "digit depth", "log2 analytic max|X|",
         "log2 measured max|Y|", "f64-safe"],
        [(r["p_prime"], r["tau"], r["digit_depth"],
          f"{r['log2_analytic_maxX']:.1f}", f"{r['log2_measured_maxY']:.1f}",
          r["f64_safe"]) for r in rows])


def _cells(mesh):
    out = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        out.append(json.loads(f.read_text()))
    return out


def dryrun_section():
    rows = []
    for mesh in ("singlepod", "multipod"):
        for c in _cells(mesh):
            mem = c["memory"]
            per_dev = ((mem["argument_bytes"] or 0)
                       + (mem["temp_bytes"] or 0)) / 2 ** 30
            rows.append((c["arch"], c["shape"],
                         "2x16x16" if c["multi_pod"] else "16x16",
                         f"{c['compile_s']:.0f}s",
                         f"{c['dot_flops']:.2e}",
                         f"{c['collectives']['total_bytes']:.2e}",
                         f"{per_dev:.1f}"))
    return _md_table(
        ["arch", "shape", "mesh", "compile", "dot FLOPs/dev",
         "coll B/dev", "GiB/dev (args+temp)"], rows)


def roofline_section():
    from benchmarks.roofline import roofline_row
    rows = []
    for c in _cells("singlepod"):
        r = roofline_row(c)
        rows.append((r["arch"], r["shape"],
                     f"{r['compute_s']:.3f}", f"{r['memory_s']:.3f}",
                     f"{r['collective_s']:.3f}", r["dominant"],
                     f"{r['useful_ratio']:.2f}",
                     f"{r['roofline_fraction']:.3f}",
                     f"{r['mem_gib_per_dev']:.0f}"))
    return _md_table(
        ["arch", "shape", "compute s", "memory s", "collective s",
         "dominant", "useful ratio", "roofline frac", "GiB/dev"], rows)


def perf_section():
    rows = []
    for f in sorted(RESULTS.glob("*__singlepod__*.json")):
        c = json.loads(f.read_text())
        variant = f.stem.split("__singlepod__")[1]
        base_f = RESULTS / (f.stem.split("__singlepod__")[0]
                            + "__singlepod.json")
        if not base_f.exists():
            continue
        b = json.loads(base_f.read_text())

        def t(cell):
            return (cell["dot_flops"] / PEAK,
                    cell.get("hbm_bytes", 0) / HBM,
                    cell["collectives"]["total_bytes"] / LINK)

        bc, bm, bl = t(b)
        vc, vm, vl = t(c)
        rows.append((c["arch"], c["shape"], variant,
                     f"{bc:.2f}→{vc:.2f}", f"{bm:.2f}→{vm:.2f}",
                     f"{bl:.2f}→{vl:.2f}",
                     f"{max(bc,bm,bl)/max(vc,vm,vl):.2f}x"))
    if not rows:
        return "(run benchmarks/hillclimb.py first)"
    return _md_table(
        ["arch", "shape", "variant", "compute s", "memory s",
         "collective s", "bottleneck speedup"], rows)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    sections = {
        "FIG1_RESULTS": fig1_section,
        "TABLE1_RESULTS": table1_section,
        "TRADEOFF_RESULTS": tradeoff_section,
        "DRYRUN_TABLE": dryrun_section,
        "ROOFLINE_TABLE": roofline_section,
        "PERF_LOG": perf_section,
    }
    for marker, fn in sections.items():
        token = f"<!-- {marker} -->"
        if token not in md:
            print(f"marker {marker} missing; skipped")
            continue
        try:
            content = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{marker}: {e}")
            continue
        # idempotent: replace marker..(next heading or EOF) with fresh content
        pattern = re.compile(re.escape(token) + r".*?(?=\n#{2,3} |\Z)",
                             re.DOTALL)
        md = pattern.sub(token + "\n" + content + "\n", md)
        print(f"filled {marker}")
    (ROOT / "EXPERIMENTS.md").write_text(md)


if __name__ == "__main__":
    main()
