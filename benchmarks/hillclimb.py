"""Perf hillclimb driver (EXPERIMENTS.md SecPerf).

Each experiment = (cell, variant): lowers + compiles with the variant's
config/sharding overrides, reruns the HLO analysis, and prints the three
roofline terms next to the baseline.  Results land in results/dryrun/ with
a __<variant> suffix so the JSON trail shows the whole path.

Run one:   PYTHONPATH=src python -m benchmarks.hillclimb --cell jamba_train --variant mamba_kernel
Run plan:  PYTHONPATH=src python -m benchmarks.hillclimb --plan
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

# cell id -> (arch, shape)
CELLS = {
    "jamba_train": ("jamba_1_5_large_398b", "train_4k"),
    "jamba_prefill": ("jamba_1_5_large_398b", "prefill_32k"),
    "qwen3_0_6b_train": ("qwen3_0_6b", "train_4k"),
    "qwen2_vl_train": ("qwen2_vl_72b", "train_4k"),
    "qwen3_moe_train": ("qwen3_moe_235b_a22b", "train_4k"),
    "rwkv6_train": ("rwkv6_3b", "train_4k"),
    "rwkv6_prefill": ("rwkv6_3b", "prefill_32k"),
}

# variant -> (cfg_overrides, fsdp)
VARIANTS = {
    "baseline": ({}, True),
    "mamba_kernel": ({"mamba_kernel": True}, True),
    "no_fsdp": ({}, False),
    "remat_dots": ({"remat_policy": "dots"}, True),
    "no_fsdp_remat_dots": ({"remat_policy": "dots"}, False),
    "mamba_kernel_chunk128": ({"mamba_kernel": True}, True),
    "loss_chunk_2k": ({"loss_chunk": 2048}, True),
    "mamba_kernel_remat_dots": (
        {"mamba_kernel": True, "remat_policy": "dots"}, True),
    "proj_first": ({"proj_first": True}, True),
    "rwkv_kernel": ({"rwkv_kernel": True}, True),
    "mamba_kernel_proj_first": (
        {"mamba_kernel": True, "proj_first": True}, True),
}

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def terms(res):
    c = res["dot_flops"] / PEAK
    m = res.get("hbm_bytes", 0) / HBM
    l = res["collectives"]["total_bytes"] / LINK
    dom = max((("compute", c), ("memory", m), ("collective", l)),
              key=lambda kv: kv[1])[0]
    return c, m, l, dom


def run(cell: str, variant: str):
    from repro.launch.dryrun import RESULTS_DIR, run_cell
    arch, shape = CELLS[cell]
    overrides, fsdp = VARIANTS[variant]
    suffix = "" if variant == "baseline" else f"__{variant}"
    res = run_cell(arch, shape, multi_pod=False,
                   cfg_overrides=overrides, fsdp=fsdp, tag_suffix=suffix)
    out = RESULTS_DIR / f"{arch}__{shape}__singlepod{suffix}.json"
    out.write_text(json.dumps(res, indent=2))
    c, m, l, dom = terms(res)
    mem_bytes = ((res["memory"]["argument_bytes"] or 0)
                 + (res["memory"]["temp_bytes"] or 0))
    print(f"{cell} [{variant}]: compute={c:.3f}s memory={m:.3f}s "
          f"collective={l:.3f}s dominant={dom} "
          f"mem/dev={mem_bytes / 2**30:.1f}GiB "
          f"compile={res['compile_s']}s")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=CELLS)
    ap.add_argument("--variant", choices=VARIANTS, default="baseline")
    ap.add_argument("--plan", action="store_true",
                    help="run the full 3-cell hillclimb plan")
    args = ap.parse_args()
    if args.plan:
        plan = [
            ("jamba_train", "mamba_kernel"),
            ("jamba_train", "mamba_kernel_remat_dots"),
            ("qwen3_0_6b_train", "no_fsdp"),
            ("qwen3_0_6b_train", "no_fsdp_remat_dots"),
            ("qwen2_vl_train", "remat_dots"),
            ("jamba_prefill", "mamba_kernel"),
        ]
        for cell, variant in plan:
            try:
                run(cell, variant)
            except Exception as e:  # noqa: BLE001
                print(f"[FAIL] {cell} {variant}: {e}")
        return
    run(args.cell, args.variant)


if __name__ == "__main__":
    main()
