"""Serve-tier bench: pipelined multi-tenant serving vs back-to-back steps.

Both sides run the SAME ``ServeTier`` event loop over the same tenants,
the same seeded arrival processes, and the same chaos scenario feed, on
the same worker pool (one ``PlanLadder``, shared across every run so the
zero-recompile contract is asserted across the WHOLE bench):

* the **tier** runs as designed — continuous batching into prewarmed
  buckets plus the two-stage pipeline (decode of step t overlaps the
  workers of step t+1);
* the **baseline** is the synchronous serving model the repo had before
  the tier: ``max_batch=1`` and ``pipelined=False`` reduce the loop to
  back-to-back ``AdaptiveServer`` steps (each request dispatched alone,
  decode serialised behind its own workers).

Per scenario the bench reports sustained req/s, per-tenant realized
latency quantiles at each tenant's own SLO quantile, and the shed
accounting (every generated request is admitted or shed WITH a reason —
never silently dropped).  Every admitted request's decoded product is
compared bit-for-bit against a fresh synchronous facade call on the same
operands — integer payloads make the answer rung-independent, so the
assert is exact equality, not a tolerance.

Rows land in BENCH_serve.json (merge-append).  ``--check`` runs only the
two heavy-tailed regimes (``heavy_tail``, ``pareto``) and asserts the
acceptance criteria: the tier sustains STRICTLY higher req/s than the
baseline, the premium tenant's realized tail meets its SLO class while
the baseline misses it, shed requests are reported not dropped, every
admitted result is bit-identical, and nothing recompiled after prewarm.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.obs_util import CompileWatch, assert_no_recompiles

# ladder geometry shared with control_bench (paper Sec. IV family)
P, M, N, K = 4, 2, 1, 12
V, R, T = 16, 8, 4
BUCKETS = (1, 2, 4, 8)
SEED = 11
REQUESTS = 16               # per tenant per run
#: synthetic per-rung decode cost (simulated seconds): deterministic
#: pricing, and a decode stage thick enough that pipelining has work to
#: overlap.
OVERHEAD_S = {"bec": 2.0, "tradeoff(p'=2)": 1.0, "polycode": 0.1}
CHECK_SCENARIOS = ("heavy_tail", "pareto")

#: the bench workload: a premium tenant with a tight bound and a rung
#: floor, a well-behaved standard tenant, and an overloaded free tier
#: that demonstrably sheds.  The premium tenant ALONE arrives faster
#: than the serial service rate, so even with its EDF priority the
#: baseline queues past the 12 s bound (realized tail ~20 s) while the
#: batched+pipelined tier stays under it with ~35% headroom.
SPEC = {
    "classes": [
        {"name": "premium", "quantile": 0.99, "slo_s": 12.0,
         "rung_floor": "tradeoff(p'=2)"},
        {"name": "standard", "quantile": 0.9, "slo_s": 120.0},
    ],
    "tenants": [
        {"name": "gold", "slo_class": "premium", "arrival_rps": 1.5},
        {"name": "silver", "slo_class": "standard", "arrival_rps": 1.0},
        {"name": "free", "slo_class": "standard", "arrival_rps": 2.5,
         "rate_rps": 0.5, "burst": 3, "max_queue": 6},
    ],
}


def _payloads():
    """Deterministic integer operands keyed by request id (rng-free)."""
    import jax.numpy as jnp

    base = np.arange(V * R).reshape(V, R)

    def make_A(request):
        return jnp.asarray((base * (request.rid + 3)) % 11 - 5, jnp.float64)

    B = jnp.asarray(np.arange(V * T).reshape(V, T) % 7 - 3, jnp.float64)
    return make_A, B


def _ladder():
    from repro.control import PlanLadder

    ladder = PlanLadder(P, M, N, K=K, L=V * 4 * 4 + 1, backend="reference")
    ladder.prewarm((V, R), (V, T), batch_sizes=BUCKETS, stages=True)
    return ladder


def _run_side(ladder, scenario: str, *, pipelined: bool,
              max_batch) -> "tuple":
    """One ServeTier run (tier or baseline) over a fresh scenario feed."""
    from repro.chaos import make_scenario
    from repro.serve import ServeTier, parse_tenant_spec

    classes, tenants = parse_tenant_spec(SPEC)
    # the ladder is shared across every run of the bench (zero-recompile
    # contract); reset its switch state so each row is independent of
    # which scenarios ran before it.
    ladder.switch(ladder.rungs[0])
    feed = make_scenario(scenario).compile(K, seed=SEED)
    tier = ServeTier(
        ladder, classes=tuple(classes.values()),
        tenants=tuple(tenants.values()), feed=feed,
        overhead_s=OVERHEAD_S, seed=SEED, check_exact=True,
        pipelined=pipelined, max_batch=max_batch, keep_results=True)
    make_A, B = _payloads()
    result = tier.run(make_A, B, REQUESTS)
    return result, make_A, B


def _bit_identity(ladder, result, make_A, B) -> bool:
    """Every admitted result vs a fresh synchronous facade call, exactly."""
    cm = ladder.facade(ladder.rungs[0])
    for rec in result.completed:
        A = make_A(rec)
        if not np.array_equal(np.asarray(cm(A, B)), result.results[rec.rid]):
            return False
    return True


def _summarize(result) -> dict:
    stats = result.tenant_stats()
    shed_reasons: dict = {}
    for rec in result.shed:
        shed_reasons[rec.reject_reason] = \
            shed_reasons.get(rec.reject_reason, 0) + 1
    return {
        "rps": result.throughput_rps(),
        "generated": len(result.requests),
        "admitted": len(result.admitted),
        "completed": len(result.completed),
        "shed": len(result.shed),
        "shed_reasons": shed_reasons,
        "batches": len(result.batches),
        "max_batch_used": max((b.size for b in result.batches), default=0),
        "tenants": stats,
    }


def _run_scenario(ladder, scenario: str) -> dict:
    """Tier vs baseline under one scenario; both sides fully accounted."""
    tier_result, make_A, B = _run_side(ladder, scenario,
                                       pipelined=True, max_batch=None)
    base_result, _, _ = _run_side(ladder, scenario,
                                  pipelined=False, max_batch=1)
    row = {"scenario": scenario, "seed": SEED,
           "tier": _summarize(tier_result),
           "baseline": _summarize(base_result)}
    for side, result in (("tier", tier_result), ("baseline", base_result)):
        summary = row[side]
        summary["accounting_ok"] = (
            summary["generated"] == summary["admitted"] + summary["shed"]
            and all(rec.reject_reason for rec in result.shed)
            and summary["completed"] == summary["admitted"])
        summary["bit_identical"] = _bit_identity(ladder, result, make_A, B)
        summary["all_exact"] = all(
            b.report.get("exact") for b in result.batches)
    return row


def run(scenarios=None) -> dict:
    from repro.chaos import scenario_names
    from repro.core.numerics import enable_x64

    names = tuple(scenarios) if scenarios else scenario_names()
    with enable_x64():
        # the watch reads the runtime's own compile counter; mark() after
        # prewarm makes every later build a recorded recompile.
        watch = CompileWatch()
        ladder = _ladder()
        watch.mark()
        rows = [_run_scenario(ladder, name) for name in names]
        recompiles = watch.delta()
    return {
        "config": {
            "grid": [P, M, N], "K": K, "shape": [V, R, T],
            "buckets": list(BUCKETS), "seed": SEED,
            "requests_per_tenant": REQUESTS, "overhead_s": OVERHEAD_S,
            "spec": SPEC,
        },
        "recompiles": recompiles,
        "scenarios": rows,
    }


def check(result: dict) -> None:
    """The serve-tier acceptance gates (CI smoke under ``--check``).

    Stated so each can FAIL: strict req/s win, premium SLO met by the
    tier AND missed by the baseline (the bound sits between them, so a
    tier regression or a baseline speedup both trip it), explicit shed
    accounting on both sides, per-request bit-identity, zero recompiles.
    """
    assert_no_recompiles(result["recompiles"], "the serve sweep")
    by_name = {row["scenario"]: row for row in result["scenarios"]}
    missing = set(CHECK_SCENARIOS) - set(by_name)
    assert not missing, f"check scenarios missing from the run: {missing}"
    for name in CHECK_SCENARIOS:
        row = by_name[name]
        tier, base = row["tier"], row["baseline"]
        for side_name, side in (("tier", tier), ("baseline", base)):
            assert side["accounting_ok"], (
                f"{name}/{side_name}: shed requests dropped without a "
                f"reason or counts do not balance: {side}")
            assert side["bit_identical"], (
                f"{name}/{side_name}: a served product diverged from the "
                f"synchronous facade answer")
            assert side["all_exact"], (
                f"{name}/{side_name}: an in-loop exactness check failed")
        assert tier["rps"] > base["rps"], (
            f"{name}: tier did not sustain strictly higher req/s "
            f"({tier['rps']:.3f} vs baseline {base['rps']:.3f})")
        gold_tier = tier["tenants"]["gold"]
        gold_base = base["tenants"]["gold"]
        assert gold_tier["slo_met"], (
            f"{name}: premium tenant missed its SLO under the tier: "
            f"{gold_tier}")
        assert gold_base["p_slo_s"] is not None \
            and gold_base["p_slo_s"] > gold_base["slo_s"], (
                f"{name}: the synchronous baseline MET the premium SLO "
                f"(p{100 * 0.99:.0f} {gold_base['p_slo_s']} <= "
                f"{gold_base['slo_s']} s) — the comparison shows nothing")
        assert tier["shed"] > 0 and tier["shed_reasons"], (
            f"{name}: the overloaded free tier never shed — admission "
            f"control untested: {tier}")


def main(argv=None, save: str = "BENCH_serve.json"):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="run only the heavy-tailed regimes and assert "
                         "the acceptance criteria (CI smoke)")
    ap.add_argument("--scenario", action="append", default=None,
                    help="run only these scenarios (repeatable; default: "
                         "the full chaos catalog)")
    args = ap.parse_args(argv)

    scenarios = args.scenario
    if args.check and scenarios is None:
        scenarios = list(CHECK_SCENARIOS)
    result = run(scenarios)

    out = Path(__file__).resolve().parents[1] / save
    merged = result
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except ValueError:
            merged = {}
        merged.setdefault("config", {}).update(result["config"])
        have = {row["scenario"]: row for row in merged.get("scenarios", [])}
        have.update({row["scenario"]: row for row in result["scenarios"]})
        merged["scenarios"] = list(have.values())
        merged.pop("builds_prewarm", None)  # pre-obs schema
        merged.pop("builds_final", None)
        merged["recompiles"] = result["recompiles"]
    out.write_text(json.dumps(merged, indent=2) + "\n")
    print(f"wrote {out}")

    for row in result["scenarios"]:
        tier, base = row["tier"], row["baseline"]
        gold_t = tier["tenants"]["gold"]
        gold_b = base["tenants"]["gold"]
        print(f"{row['scenario']:<14} tier {tier['rps']:6.3f} req/s "
              f"({tier['batches']} batches, shed {tier['shed']}) vs "
              f"baseline {base['rps']:6.3f} req/s "
              f"({base['batches']} steps, shed {base['shed']}); "
              f"premium tail "
              f"{gold_t['p_slo_s'] and round(gold_t['p_slo_s'], 2)} s "
              f"(met {gold_t['slo_met']}) vs baseline "
              f"{gold_b['p_slo_s'] and round(gold_b['p_slo_s'], 2)} s "
              f"(met {gold_b['slo_met']})")
    if args.check:
        check(result)
        print("serve bench check: OK")
    return result


if __name__ == "__main__":
    main()
