"""Paper Sec. IV quantified: threshold tau vs numeric headroom, p' sweep.

For p=8, m=n=2 and the paper-scale L, sweep p' over divisors of p and
report (tau, analytic max|X|, measured max|X| on random data, f64-safe?).
This is the tradeoff curve the paper describes qualitatively; plan_p_prime
uses it as an executable policy (smallest safe tau per dtype).
"""
from __future__ import annotations

import numpy as np

from repro.core import bounds as bounds_mod
from repro.core import make_plan
from repro.core.numerics import enable_x64
from repro.core.partition import block_decompose
from repro.runtime import ReferenceExecutor


def run(p: int = 8, m: int = 2, n: int = 2, v: int = 256, bound: int = 20):
    rng = np.random.default_rng(0)
    L = bounds_mod.conservative_L(v, bound, bound)
    s = bounds_mod.choose_s(L)
    rows = []
    with enable_x64():
        import jax.numpy as jnp
        A = jnp.asarray(rng.integers(-bound, bound + 1, size=(v, 64)),
                        jnp.float64)
        B = jnp.asarray(rng.integers(-bound, bound + 1, size=(v, 64)),
                        jnp.float64)
        for pp in [d for d in range(1, p + 1) if p % d == 0]:
            plan = make_plan("tradeoff", p, m, n, K=None or
                             (m * n * pp + pp - 1 + 2), L=L, p_prime=pp,
                             points="chebyshev")
            ab = block_decompose(A, p, m)
            bb = block_decompose(B, p, n)
            Y = ReferenceExecutor().worker_products(plan, ab, bb)
            analytic = bounds_mod.max_abs_coefficient(
                L, s, plan.scheme.digit_depth)
            rows.append({
                "p_prime": pp, "tau": plan.tau,
                "digit_depth": plan.scheme.digit_depth,
                "log2_analytic_maxX": float(np.log2(analytic)),
                "log2_measured_maxY": float(np.log2(
                    np.max(np.abs(np.asarray(Y))) + 1)),
                "f64_safe": bounds_mod.is_safe(
                    L, s, plan.scheme.digit_depth, "float64", tau=plan.tau),
            })
    return rows


def main():
    rows = run()
    print("p_prime,tau,digit_depth,log2_analytic_maxX,log2_measured_maxY,f64_safe")
    for r in rows:
        print(f"{r['p_prime']},{r['tau']},{r['digit_depth']},"
              f"{r['log2_analytic_maxX']:.1f},{r['log2_measured_maxY']:.1f},"
              f"{r['f64_safe']}")
    return rows


if __name__ == "__main__":
    main()
