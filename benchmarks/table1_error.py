"""Paper Table I reproduction: decoding error vs entry bound L.

The paper (v=8000): bounds {100,200,500,1000,2000} -> s = 2^28..2^36; error
stays <= ~1e-5 through bound=1000 and the computation is 'useless' at 2000
(|X| ~ (2L)^p/2 overflows float64's 53-bit mantissa).

We run the same sweep at v=2000 (CPU budget), where the SAME mechanism
produces the same curve shifted by log2(8000/2000)=2 bits: the breakdown
appears at the bound where log2(max|X|) crosses 53.  Both the measured
error and the analytic safe/unsafe verdict (core.bounds) are reported.
"""
from __future__ import annotations

import numpy as np

from repro.core import bounds as bounds_mod
from repro.core import coded_matmul, make_plan, uncoded_matmul
from repro.core.numerics import enable_x64


def run(v: int = 2000,
        bounds_list=(100, 200, 500, 1000, 2000, 5000, 10000, 100000)):
    """At v=2000 the wrap-around cliff (paper: 'useless' at bound 2000 with
    v=8000) lands ~2 octaves later - bounds 5000/10000 exhibit it; the
    mechanism (interpolation error crossing s/2 -> mod-s wraps) is identical,
    shifted by log2(8000/2000) bits of |X| headroom."""
    rng = np.random.default_rng(0)
    rows = []
    with enable_x64():
        import jax.numpy as jnp
        for bound in bounds_list:
            A = jnp.asarray(rng.integers(0, bound + 1, size=(v, v // 2)),
                            jnp.float64)
            B = jnp.asarray(rng.integers(0, bound + 1, size=(v, v // 2)),
                            jnp.float64)
            L = bounds_mod.conservative_L(v, bound, bound)
            s = bounds_mod.choose_s(L)
            plan = make_plan("bec", 2, 2, 2, K=10, L=L, points="equispaced")
            C = coded_matmul(A, B, plan, erased=[0])  # one straggler
            C_ref = uncoded_matmul(A, B)
            err = float(np.linalg.norm(np.asarray(C - C_ref)) /
                        np.linalg.norm(np.asarray(C_ref)))
            safe = bounds_mod.is_safe(L, s, plan.scheme.digit_depth,
                                      "float64", tau=plan.tau,
                                      conditioning_slack_bits=0.0)
            rows.append({"bound": bound, "L": L, "s": s,
                         "log2_maxX": float(np.log2(
                             bounds_mod.max_abs_coefficient(L, s, 1))),
                         "rel_err": err, "analytic_safe": safe})
    return rows


def main():
    rows = run()
    print("bound,s,log2_maxX,rel_err,analytic_safe")
    for r in rows:
        print(f"{r['bound']},2^{int(np.log2(r['s']))},{r['log2_maxX']:.1f},"
              f"{r['rel_err']:.3e},{r['analytic_safe']}")
    return rows


if __name__ == "__main__":
    main()
