"""Serving-path bench: cold-compile vs cached-call latency per executor.

The unified runtime memoises jit-compiled executables by (backend, shape,
dtype, erasure-kind) and passes the erasure pattern strictly as data, so a
serving loop that sees a NEW erasure pattern every step still reuses one
compiled program.  This bench measures, per backend:

  cold_ms     first call: pipeline build + jit trace + XLA compile
  warm_ms     mean over repeated calls, each with a DIFFERENT mask
  executables jit specialisations after the loop (must stay at 1 - the
              proof that the cache removes recompiles from serving)

Rows are saved to BENCH_runtime.json (``main(save=...)`` / run.py).  The
mesh backend needs one device per worker, so its rows come from a child
interpreter with 8 fake CPU devices; absolute times are CPU-interpret
numbers, the cold/warm RATIO is the signal.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

import jax

LOCAL_BACKENDS = ("reference", "staged", "fused")
_MESH_FLAG = "--mesh-json"


def _problem():
    import jax.numpy as jnp

    from repro.core import make_plan

    rng = np.random.default_rng(0)
    v, r, t = 512, 256, 256
    A = jnp.asarray(rng.integers(-4, 5, size=(v, r)), jnp.float64)
    B = jnp.asarray(rng.integers(-4, 5, size=(v, t)), jnp.float64)
    plan = make_plan("bec", 2, 2, 1, K=4, L=v * 4 * 4 + 1, points="chebyshev")
    return plan, A, B


def _masks(K: int, n: int):
    """n distinct single-erasure patterns, cycled."""
    return [[k % K] for k in range(n)]


def bench_backend(cm, A, B, reps: int = 8) -> dict:
    t0 = time.perf_counter()
    jax.block_until_ready(cm(A, B, erased=[0]))
    cold_ms = (time.perf_counter() - t0) * 1e3

    masks = _masks(cm.plan.K, reps)
    for erased in masks:  # warm the panels so warm_ms times the call path
        jax.block_until_ready(cm(A, B, erased=erased))
    t0 = time.perf_counter()
    for erased in masks:
        jax.block_until_ready(cm(A, B, erased=erased))
    warm_ms = (time.perf_counter() - t0) * 1e3 / reps

    info = cm.cache_info()
    return {
        "backend": cm.backend,
        "cold_ms": round(cold_ms, 2),
        "warm_ms": round(warm_ms, 3),
        "cold_over_warm": round(cold_ms / max(warm_ms, 1e-9), 1),
        "warm_patterns": len({tuple(m) for m in masks}),
        "builds": info["builds"],
        "executables": cm.executable_cache_size(),
    }


def run_local() -> list:
    from repro.core.numerics import enable_x64
    from repro.runtime import CodedMatmul

    with enable_x64():
        plan, A, B = _problem()
        rows = []
        for backend in LOCAL_BACKENDS:
            # independent facade per backend: per-row counters start at zero
            row = bench_backend(CodedMatmul(plan, backend), A, B)
            assert row["executables"] == row["builds"] == 1, row
            rows.append(row)
        return rows


def run_mesh_child() -> list:
    """Executed inside the child (8 fake devices): mesh-backend rows."""
    from repro.core.numerics import enable_x64
    from repro.runtime import CodedMatmul

    with enable_x64():
        plan, A, B = _problem()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cm = CodedMatmul(plan, "mesh", mesh=mesh, dtype=jax.numpy.float64)
        row = bench_backend(cm, A, B)
        assert row["executables"] == row["builds"], row
        return [row]


def run() -> list:
    rows = run_local()
    rows.extend(_mesh_rows_via_subprocess())
    return rows


def _mesh_rows_via_subprocess() -> list:
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.runtime_bench", _MESH_FLAG],
        env=env, cwd=root, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        print(f"mesh rows skipped (child failed):\n{proc.stderr[-500:]}")
        return []
    return json.loads(proc.stdout.strip().splitlines()[-1])


def save_json(rows, path: str) -> None:
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
        f.write("\n")


def main(save: str | None = None):
    rows = run()
    print("backend,cold_ms,warm_ms,cold_over_warm,executables")
    for r in rows:
        print(f"{r['backend']},{r['cold_ms']},{r['warm_ms']},"
              f"{r['cold_over_warm']},{r['executables']}")
    if save:
        save_json(rows, save)
        print(f"saved {save}")
    return rows


if __name__ == "__main__":
    if _MESH_FLAG in sys.argv:
        print(json.dumps(run_mesh_child()))
    else:
        save = None if "--no-save" in sys.argv else "BENCH_runtime.json"
        if "--save" in sys.argv:
            i = sys.argv.index("--save")
            save = (sys.argv[i + 1] if i + 1 < len(sys.argv)
                    else "BENCH_runtime.json")
        main(save=save)
