"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the framework's real stack - synthetic-but-learnable data pipeline,
scanned transformer, AdamW with fp32 masters, atomic checkpointing - on a
qwen3-family geometry scaled to ~100M params.  Loss should drop well below
the ln(vocab) random floor within the run.

Run:    PYTHONPATH=src python examples/train_lm.py
Quick:  PYTHONPATH=src python examples/train_lm.py --quick
"""
import argparse
import dataclasses
import sys

from repro.launch.train import main as train_main
from repro.models import ModelConfig

# ~100M params: 12L x d512 x ff2048, vocab 8192 (tied) -> ~0.1B
CFG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_head=64,
    d_ff=2048,
    vocab=8192,
    pattern=(("attn", "mlp"),),
    qk_norm=True,
    rope_theta=1e4,
    tie_embeddings=True,
    q_chunk=128,
    kv_chunk=256,
    loss_chunk=128,
    tp_pad=1,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny run for CI (2 layers, 30 steps)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # register the config under a temporary module-level name
    cfg = CFG_100M
    steps = args.steps
    lr = "2e-3"
    if args.quick:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=512,
                                  n_heads=4, n_kv_heads=2, vocab=1024)
        steps = 60
        lr = "5e-3"
    mod = type(sys)("repro.configs._train_lm_example")
    mod.CONFIG = cfg
    mod.SMOKE = cfg
    sys.modules["repro.configs._train_lm_example"] = mod

    losses = train_main([
        "--arch", "_train_lm_example", "--steps", str(steps),
        "--batch", "8", "--seq", "256", "--lr", lr,
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "10",
    ])
    import math
    floor = math.log(cfg.vocab)
    print(f"random floor ln(V) = {floor:.3f}; final = {losses[-1]:.3f}")
    assert losses[-1] < floor - 0.3, "model failed to learn"
    print("learned successfully.")


if __name__ == "__main__":
    main()
