"""Serving example: batched prefill + decode with the smoke Qwen3 config,
plus a coded (straggler-tolerant) lm_head demonstration.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.launch.serve import main as serve_main  # noqa: E402

print("== batched serve (prefill + greedy decode) ==")
serve_main(["--arch", "qwen3_0_6b", "--smoke", "--batch", "4",
            "--prompt-len", "32", "--gen", "12"])

print("\n== coded lm_head: logits survive worker loss ==")
jax.config.update("jax_enable_x64", True)
from repro.core import make_plan  # noqa: E402
from repro.distributed.coded import CodedLinearPlan  # noqa: E402

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
d, V, B = 64, 512, 8
x = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)      # final hidden
W = jnp.asarray(rng.normal(size=(d, V)), jnp.float32)      # lm head
plan = make_plan("bec", p=2, m=2, n=1, K=4, L=d * 7 * 7 + 1,
                 points="chebyshev")
lin = CodedLinearPlan(plan, mesh, quant_bits=6, dtype=jnp.float64)
logits_ok = lin(x, W)
logits_lost = lin(x, W, mask=jnp.asarray([1.0, 0.0, 1.0, 1.0]))
agree = float(jnp.mean((jnp.argmax(logits_ok, -1) ==
                        jnp.argmax(logits_lost, -1)).astype(jnp.float32)))
drift = float(jnp.max(jnp.abs(logits_ok - logits_lost)))
print(f"argmax agreement with a lost worker: {agree*100:.0f}%  "
      f"(max logit drift {drift:.2e} - the coded grid is erasure-invariant)")
info = lin.matmul.cache_info()
print(f"runtime cache: {info['builds']} compiled executable(s), "
      f"{info['hits']} cache hits, {info['panel_builds']} decode panels")
