"""Quickstart: the paper's coded matmul in 30 lines.

Computes C = A^T B with the bounded-entry entangled code (threshold tau=mn,
paper Sec. III-B), kills 6 of 10 workers, and still decodes EXACTLY.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp  # noqa: E402

from repro.core import make_plan, uncoded_matmul  # noqa: E402
from repro.runtime import CodedMatmul  # noqa: E402

# integer matrices with bounded entries (paper Sec. V uses {0..50})
rng = np.random.default_rng(0)
v, r, t = 1024, 512, 512
A = jnp.asarray(rng.integers(0, 51, size=(v, r)), jnp.float64)
B = jnp.asarray(rng.integers(0, 51, size=(v, t)), jnp.float64)

# m=n=p=2 block split, K=10 workers -> BEC threshold tau = mn = 4
# (the baseline polynomial code would need tau = pmn + p - 1 = 9)
L = v * 50 * 50 + 1                       # entry-product bound (Sec. III-D)
plan = make_plan("bec", p=2, m=2, n=2, K=10, L=L, points="unit_circle")
print(f"scheme=BEC  workers={plan.K}  recovery threshold tau={plan.tau}  "
      f"scale base s=2^{int(np.log2(plan.s))}")

# one facade, pluggable backends; six stragglers die, any tau=4 survive
cm = CodedMatmul(plan)                    # fused Pallas backend by default
C = cm(A, B, erased=[0, 2, 4, 6, 8, 9])
C_ref = uncoded_matmul(A, B)
err = float(jnp.max(jnp.abs(C - C_ref)))
print(f"erased 6/10 workers -> max |C - A^T B| = {err}")
assert err == 0.0, "decode must be exact"
print("exact recovery despite 6 erasures - straggler-proof matmul.")
