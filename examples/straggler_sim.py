"""Straggler simulation (paper Fig. 1 protocol) + on-mesh fault tolerance.

Part 1 - async-cluster model: measured per-worker compute, stragglers
compute twice, completion = tau-th finisher.  BEC (tau=4) stays flat to
S=6; the polynomial-code baseline (tau=9) degrades from S=2.

Part 2 - synchronous-mesh model: the same code as a shard_map program on 8
fake CPU devices, where erasures are a runtime MASK (lost chips) and the
step still returns the exact product (run with
XLA_FLAGS=--xla_force_host_platform_device_count=8).

Run:  PYTHONPATH=src python examples/straggler_sim.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp  # noqa: E402

from benchmarks.fig1_latency import run as fig1_run  # noqa: E402
from repro.core import make_plan, uncoded_matmul  # noqa: E402
from repro.runtime import CodedMatmul  # noqa: E402

print("== Part 1: async-cluster latency (paper Fig. 1, scaled) ==")
rows = fig1_run(size=512, trials=10)
by_scheme = {}
for r in rows:
    by_scheme.setdefault(r["scheme"], []).append(r)
for scheme, rs in by_scheme.items():
    lat = " ".join(f"S={r['stragglers']}:{r['latency_s']:.3f}s" for r in rs)
    print(f"{scheme} (tau={rs[0]['tau']}): {lat}")

print("\n== Part 2: synchronous mesh - chip loss absorbed in-step ==")
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
A = jnp.asarray(rng.integers(0, 9, size=(256, 128)), jnp.float64)
B = jnp.asarray(rng.integers(0, 9, size=(256, 128)), jnp.float64)
plan = make_plan("bec", p=2, m=2, n=1, K=4, L=256 * 8 * 8 + 1,
                 points="chebyshev")
cm = CodedMatmul(plan, "mesh", mesh=mesh, dtype=jnp.float64)
C_ref = uncoded_matmul(A, B)
for lost in ([], [2], [0, 1]):
    C = cm(A, B, erased=lost)
    err = float(jnp.max(jnp.abs(C - C_ref)))
    print(f"lost chips {str(lost or 'none'):<8} -> max error {err} "
          f"({'exact' if err == 0 else 'FAIL'})")
info = cm.cache_info()
print(f"(served {info['hits'] + info['builds']} erasure patterns from "
      f"{info['builds']} compiled executable(s) - the jit cache absorbs "
      f"mask churn)")
