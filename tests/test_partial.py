"""Partial-straggler sub-tasking: patterns, decode parity, planner, laws.

Covers the acceptance bar for graceful degradation:
  * cyclic chunk schedule invariants (coverage spreads over prefixes);
  * PartialPattern normalisation / quantisation / decodability checks;
  * bit-identical partial decode vs the uncoded oracle on SPANNING
    progress vectors — every scheme family, Q in {1, 2, 4}, all local
    backends, batched operands, traced progress under jit;
  * loud ValueError (never garbage output) on NON-spanning vectors;
  * randomized fuzz plus hypothesis property tests for the span/raise
    dichotomy;
  * per-chunk decode kernel parity against the jnp reference;
  * progress planner: binary-mask equivalence when the healthy pool
    spans, cheapest-straggler consumption otherwise, always decodable;
  * fractional completion law (w * (base + Exp(scale)) closed forms vs
    Monte-Carlo) and the adaptive monitor-threshold feedback law;
  * zero executable rebuilds across partial serving calls.
"""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.control.feedback import FeedbackConfig, ViolationFeedback  # noqa: E402
from repro.control.partial import (  # noqa: E402
    expected_wait,
    plan_partial_progress,
)
from repro.core import make_plan, make_scheme, uncoded_matmul  # noqa: E402
from repro.core.simulator import (  # noqa: E402
    LatencyModel,
    WorkerTimes,
    _masked_shifted_exp,
    masked_completion_cdf,
    masked_completion_mean,
    masked_completion_quantile,
)
from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import ref as kref  # noqa: E402
from repro.runtime import (  # noqa: E402
    CodedMatmul,
    ErasurePattern,
    MeshExecutor,
    PartialPattern,
    chunk_bounds,
    chunk_coverage,
    chunk_masks_for,
)

LOCAL_BACKENDS = ("reference", "staged", "fused")

# (kind, p, m, n, p_prime) - one geometry per scheme family.
SCHEMES = [
    ("bec", 2, 2, 2, 1),
    ("tradeoff", 4, 2, 1, 2),
    ("polycode", 2, 2, 1, 1),
]
SUB_TASKS = (1, 2, 4)


def _int_problem(rng, plan, v, r, t):
    A = jnp.asarray(rng.integers(-3, 4, size=(v, r)), jnp.float64)
    B = jnp.asarray(rng.integers(-3, 4, size=(v, t)), jnp.float64)
    return A, B, np.asarray(uncoded_matmul(A, B))


def _make(kind, p, m, n, pp, *, extra=2, v_mult=8, points="chebyshev"):
    tau = make_scheme(kind, p, m, n, p_prime=pp).tau
    v = v_mult * p
    return make_plan(kind, p, m, n, K=tau + extra, L=v * 3 * 3 + 1,
                     p_prime=pp, points=points), v


def _spanning_progress(K, Q):
    """A fractional progress vector whose every chunk has >= K - 2 workers.

    Worker 0 misses only chunk Q-1 and worker 1 only chunk 0 under the
    cyclic schedule, so with K = tau + 2 every chunk still spans.  Q = 1
    cannot be fractional; erase worker 0 outright instead.
    """
    prog = np.ones(K)
    if Q > 1:
        prog[0] = (Q - 1) / Q
        prog[1] = (Q - 1) / Q
    else:
        prog[0] = 0.0
    return prog


class TestChunkSchedule:
    @pytest.mark.parametrize("rows,Q", [(8, 1), (8, 3), (9, 4), (30, 4)])
    def test_bounds_partition_rows(self, rows, Q):
        offs = chunk_bounds(rows, Q)
        assert len(offs) == Q + 1
        assert offs[0] == 0 and offs[-1] == rows
        sizes = np.diff(offs)
        assert sizes.min() >= 1
        assert sizes.max() - sizes.min() <= 1

    def test_bounds_errors(self):
        with pytest.raises(ValueError, match="non-empty"):
            chunk_bounds(3, 4)
        with pytest.raises(ValueError, match="Q >= 1"):
            chunk_bounds(8, 0)

    def test_cyclic_membership_identity(self):
        K, Q = 7, 4
        counts = np.array([0, 1, 2, 3, 4, 2, 1])
        masks = chunk_masks_for(counts, Q)
        assert masks.shape == (Q, K)
        for c in range(Q):
            for k in range(K):
                assert masks[c, k] == (1.0 if ((c - k) % Q) < counts[k]
                                       else 0.0)
        # column k holds exactly counts[k] ones: a prefix covers its length.
        np.testing.assert_array_equal(masks.sum(axis=0),
                                      np.minimum(counts, Q).astype(float))

    def test_prefixes_spread_over_chunks(self):
        # Single-sub-task prefixes land on DIFFERENT chunks (the point of
        # the cyclic order): a naive schedule would pile all K onto chunk 0.
        K, Q = 8, 4
        cov = chunk_coverage(np.ones(K, dtype=np.int64), Q)
        np.testing.assert_array_equal(cov, np.full(Q, K // Q))

    def test_coverage_matches_masks(self):
        counts = np.array([4, 0, 2, 3, 1, 4])
        masks = chunk_masks_for(counts, 4)
        np.testing.assert_array_equal(chunk_coverage(counts, 4),
                                      masks.sum(axis=1).astype(np.int64))


class TestPartialPattern:
    def test_equivalent_specs_same_key(self):
        K, Q = 6, 3
        mask = [0, 1, 1, 1, 0, 1]
        by_erased = PartialPattern.normalize(K, Q, erased=[0, 4])
        by_mask = PartialPattern.normalize(K, Q, mask=mask)
        by_progress = PartialPattern.normalize(K, Q, progress=np.array(
            mask, dtype=np.float64))
        lifted = PartialPattern.normalize(
            K, Q, ErasurePattern.normalize(K, erased=[0, 4]))
        assert (by_erased.key == by_mask.key == by_progress.key
                == lifted.key)
        np.testing.assert_array_equal(by_erased.chunk_counts,
                                      np.array(mask) * Q)

    def test_default_is_full(self):
        pat = PartialPattern.normalize(5, 2)
        np.testing.assert_array_equal(pat.chunk_counts, np.full(5, 2))
        assert pat.decodable(5)

    def test_pattern_spec_k_mismatch_raises(self):
        pat = PartialPattern.full(4, 2)
        with pytest.raises(ValueError, match="K=4"):
            PartialPattern.normalize(6, 2, pat)

    def test_conflicting_specs_raise(self):
        with pytest.raises(ValueError, match="only one"):
            PartialPattern.normalize(4, 2, np.ones(4), progress=np.ones(4))

    def test_validation(self):
        with pytest.raises(ValueError, match="Q >= 1"):
            PartialPattern.full(4, 0)
        with pytest.raises(ValueError, match="shape"):
            PartialPattern.from_progress(4, 2, np.ones(5))
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            PartialPattern.from_progress(4, 2, [0.5, 1.0, 1.5, 0.0])
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            PartialPattern.from_progress(4, 2, [0.5, 1.0, -0.1, 0.0])

    def test_chunk_counts_floor(self):
        pat = PartialPattern.from_progress(4, 2, [0.49, 0.5, 0.99, 1.0])
        np.testing.assert_array_equal(pat.chunk_counts, [0, 1, 1, 2])

    def test_key_quantizes_progress(self):
        a = PartialPattern.from_progress(3, 2, [0.5, 1.0, 0.0])
        b = PartialPattern.from_progress(3, 2, [0.6, 1.0, 0.49])
        c = PartialPattern.from_progress(3, 2, [1.0, 1.0, 0.0])
        assert a.key == b.key
        assert a.key != c.key

    def test_require_decodable_names_chunks(self):
        # worker prefixes never reach chunk 1 with enough multiplicity
        pat = PartialPattern.from_progress(4, 2, [0.5, 0.0, 0.5, 0.0])
        assert not pat.decodable(2)
        with pytest.raises(ValueError, match="chunk"):
            pat.require_decodable(2)

    def test_q1_lift_is_erasure_semantics(self):
        era = ErasurePattern.normalize(5, erased=[2])
        pat = PartialPattern.from_erasure(era, 1)
        np.testing.assert_array_equal(pat.chunk_counts, [1, 1, 0, 1, 1])
        assert pat.decodable(4)
        assert not pat.decodable(5)

    def test_traced_progress_is_traced_kind(self):
        seen = {}

        def f(prog):
            pat = PartialPattern.from_progress(4, 2, prog)
            seen["kind"] = pat.kind
            with pytest.raises(ValueError, match="traced"):
                pat.chunk_counts  # noqa: B018 - asserting the raise
            return prog

        jax.jit(f)(jnp.ones(4))
        assert seen["kind"] == "traced"


class TestPartialDecodeParity:
    @pytest.mark.parametrize("kind,p,m,n,pp", SCHEMES)
    @pytest.mark.parametrize("Q", SUB_TASKS)
    def test_spanning_progress_is_exact(self, rng, kind, p, m, n, pp, Q):
        plan, v = _make(kind, p, m, n, pp)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        out = cm(A, B, progress=_spanning_progress(plan.K, Q), sub_tasks=Q)
        np.testing.assert_array_equal(np.asarray(out), C0)

    @pytest.mark.parametrize("kind,p,m,n,pp", SCHEMES)
    @pytest.mark.parametrize("Q", (2, 4))
    def test_tau_exact_coverage_decodes(self, rng, kind, p, m, n, pp, Q):
        # worker 0 dead, worker 1 one chunk short: chunk 0's coverage is
        # EXACTLY tau (K = tau + 2) - the tightest decodable pattern.
        plan, v = _make(kind, p, m, n, pp)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        prog = np.ones(plan.K)
        prog[0] = 0.0
        prog[1] = (Q - 1) / Q
        pat = PartialPattern.from_progress(plan.K, Q, prog)
        assert pat.coverage.min() == plan.tau
        cm = CodedMatmul(plan, "reference")
        out = cm(A, B, progress=prog, sub_tasks=Q)
        np.testing.assert_array_equal(np.asarray(out), C0)

    @pytest.mark.parametrize("kind,p,m,n,pp", SCHEMES)
    def test_non_spanning_raises_loudly(self, rng, kind, p, m, n, pp):
        plan, v = _make(kind, p, m, n, pp)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        Q = 2
        # only tau - 1 workers report ANY progress: chunk coverage < tau.
        prog = np.zeros(plan.K)
        prog[: plan.tau - 1] = 1.0
        with pytest.raises(ValueError, match="does not span"):
            cm(A, B, progress=prog, sub_tasks=Q)

    def test_backend_parity(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        prog = _spanning_progress(plan.K, 2)
        outs = [np.asarray(CodedMatmul(plan, b)(A, B, progress=prog,
                                                sub_tasks=2))
                for b in LOCAL_BACKENDS]
        for out in outs:
            np.testing.assert_array_equal(out, C0)

    def test_q1_binary_spec_matches_legacy_path(self, rng):
        # the SAME binary mask through the partial executable and the
        # legacy erasure executable must be bitwise identical.
        for kind, p, m, n, pp in SCHEMES:
            plan, v = _make(kind, p, m, n, pp)
            A, B, _ = _int_problem(rng, plan, v, 12, 10)
            cm = CodedMatmul(plan, "reference")
            mask = np.ones(plan.K)
            mask[[0, plan.K - 1]] = 0
            legacy = np.asarray(cm(A, B, mask=mask))
            partial = np.asarray(cm(A, B, progress=mask, sub_tasks=1))
            np.testing.assert_array_equal(partial, legacy)

    def test_batched_operands(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        A2, B2, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        prog = _spanning_progress(plan.K, 2)
        Cb = cm(jnp.stack([A, A2]), jnp.stack([B, B2]), progress=prog,
                sub_tasks=2)
        assert Cb.shape == (2, 12, 10)
        np.testing.assert_array_equal(
            np.asarray(Cb[0]), np.asarray(cm(A, B, progress=prog,
                                             sub_tasks=2)))
        np.testing.assert_array_equal(
            np.asarray(Cb[1]), np.asarray(cm(A2, B2, progress=prog,
                                             sub_tasks=2)))

    @pytest.mark.parametrize("kind,p,m,n,pp", SCHEMES)
    def test_traced_progress_under_jit(self, rng, kind, p, m, n, pp):
        plan, v = _make(kind, p, m, n, pp)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        Q = 2
        f = jax.jit(lambda a, b, w: cm(a, b, progress=w, sub_tasks=Q))
        prog = jnp.asarray(_spanning_progress(plan.K, Q))
        np.testing.assert_array_equal(np.asarray(f(A, B, prog)), C0)

    def test_fuzz_random_counts_span_or_raise(self, rng):
        # seeded fuzz (always runs): any random chunk-count vector either
        # spans every chunk tau times and decodes EXACTLY, or raises.
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        Q, K, tau = 4, plan.K, plan.tau
        fuzz = np.random.default_rng(1234)
        decoded = failed = 0
        for _ in range(30):
            counts = fuzz.integers(0, Q + 1, size=K)
            pat = PartialPattern.from_progress(K, Q, counts / Q)
            if pat.decodable(tau):
                out = cm(A, B, progress=counts / Q, sub_tasks=Q)
                np.testing.assert_array_equal(np.asarray(out), C0)
                decoded += 1
            else:
                with pytest.raises(ValueError, match="does not span"):
                    cm(A, B, progress=counts / Q, sub_tasks=Q)
                failed += 1
        # the seed exercises BOTH branches; if not, the fuzz is vacuous.
        assert decoded > 0 and failed > 0

    def test_hypothesis_span_or_raise(self, rng):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need the 'test' extra "
                   "(pip install .[test])")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        plan, v = _make("polycode", 2, 2, 1, 1)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        Q, K, tau = 2, plan.K, plan.tau

        @settings(max_examples=25, deadline=None)
        @given(st.lists(st.integers(min_value=0, max_value=Q),
                        min_size=K, max_size=K))
        def check(counts):
            prog = np.asarray(counts, dtype=np.float64) / Q
            pat = PartialPattern.from_progress(K, Q, prog)
            if pat.decodable(tau):
                out = cm(A, B, progress=prog, sub_tasks=Q)
                np.testing.assert_array_equal(np.asarray(out), C0)
            else:
                with pytest.raises(ValueError, match="does not span"):
                    cm(A, B, progress=prog, sub_tasks=Q)

        check()

    def test_mesh_backend_rejects_unknown_tuple_kind(self):
        # partial kinds are supported on mesh now (tests/test_mesh.py has
        # the multi-device parity suite); a MALFORMED tuple kind must still
        # fail loudly instead of building a wrong pipeline.
        plan, _ = _make("bec", 2, 2, 2, 1)
        ex = MeshExecutor(object())
        with pytest.raises(ValueError, match="unknown mesh pipeline kind"):
            ex.make_pipeline(plan, ("partial",), jnp.float64)
        with pytest.raises(ValueError, match="unknown mesh pipeline kind"):
            ex.make_pipeline(plan, ("chunked", 2), jnp.float64)

    def test_decode_stage_rejects_partial_specs(self, rng):
        # split-stage decode has no per-chunk panel path: partial specs
        # must raise loudly, pointing at the one-shot entry point, instead
        # of silently funnelling through the binary normalizer.
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        Y = cm.worker_stage(A, B)
        rt = (A.shape[1], B.shape[1])
        prog = _spanning_progress(plan.K, 2)
        with pytest.raises(NotImplementedError, match="per-chunk panel"):
            cm.decode_stage(Y, rt, progress=prog)
        with pytest.raises(NotImplementedError, match="sub_tasks"):
            cm.decode_stage(Y, rt, sub_tasks=2)
        with pytest.raises(NotImplementedError, match="one-shot"):
            cm.decode_stage(Y, rt, PartialPattern.from_progress(
                plan.K, 2, prog))
        # sub_tasks=1 is the binary path and stays allowed
        C = cm.decode_stage(Y, rt, erased=[0], sub_tasks=1)
        np.testing.assert_array_equal(
            np.asarray(C), np.asarray(cm(A, B, erased=[0])))


class TestFractionalMaskRejection:
    def test_from_mask_rejects_fractional_values(self):
        with pytest.raises(ValueError, match="progress="):
            ErasurePattern.from_mask(4, [1.0, 0.5, 1.0, 1.0])
        with pytest.raises(ValueError, match="sub_tasks"):
            ErasurePattern.from_mask(3, np.array([0.25, 1.0, 1.0]))
        # negative / out-of-range values are just as silently wrong
        with pytest.raises(ValueError, match="0 or 1"):
            ErasurePattern.from_mask(3, [1.0, -1.0, 1.0])
        with pytest.raises(ValueError, match="0 or 1"):
            ErasurePattern.from_mask(3, [2.0, 1.0, 1.0])

    def test_from_mask_accepts_binary_in_any_dtype(self):
        for mask in ([1, 0, 1], [True, False, True],
                     np.array([1.0, 0.0, 1.0])):
            pat = ErasurePattern.from_mask(3, mask)
            np.testing.assert_array_equal(pat.mask, [1.0, 0.0, 1.0])

    def test_call_rejects_progress_passed_as_mask(self, rng):
        # the end-to-end failure the bugfix closes: a progress vector
        # passed as mask= used to decode as if every straggler were alive.
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        prog = _spanning_progress(plan.K, 4)
        with pytest.raises(ValueError, match="progress="):
            cm(A, B, mask=prog)


class TestDecodePartialKernel:
    def test_matches_per_chunk_decode_and_reference(self, rng):
        Q, mn, K, E = 3, 6, 5, 10
        W = jnp.asarray(rng.integers(-3, 4, size=(Q, mn, K)), jnp.float64)
        Y = jnp.asarray(rng.integers(-5, 6, size=(Q, K, E)), jnp.float64)
        s = 7.0
        out = np.asarray(kops.decode_partial(W, Y, s))
        per_chunk = np.stack([np.asarray(kops.decode(W[q], Y[q], s))
                              for q in range(Q)])
        oracle = np.stack([np.asarray(kref.decode_ref(W[q], Y[q], s))
                           for q in range(Q)])
        np.testing.assert_array_equal(out, per_chunk)
        np.testing.assert_array_equal(out, oracle)

    def test_complex_panels_fall_back_to_oracle(self, rng):
        Q, mn, K, E = 2, 4, 3, 6
        W = jnp.asarray(rng.integers(-2, 3, size=(Q, mn, K))
                        + 1j * rng.integers(-2, 3, size=(Q, mn, K)))
        Y = jnp.asarray(rng.integers(-3, 4, size=(Q, K, E)), jnp.float64)
        s = 5.0
        out = np.asarray(kops.decode_partial(W, Y, s))
        oracle = np.stack([np.asarray(kref.decode_ref(W[q], Y[q], s))
                           for q in range(Q)])
        np.testing.assert_array_equal(out, oracle)


class TestProgressPlanner:
    def test_binary_mask_when_healthy_pool_spans(self):
        K, tau, Q = 8, 5, 4
        plan = plan_partial_progress(np.ones(K), [1, 2], Q, tau)
        expect = np.ones(K)
        expect[[1, 2]] = 0.0
        np.testing.assert_array_equal(plan, expect)

    def test_consumes_cheapest_straggler(self):
        # healthy pool (4) < tau (5): chunks must be repaired from the
        # flagged pair; the planner picks the FASTER straggler's prefix.
        K, tau, Q = 6, 5, 4
        mean = np.array([1.0, 1.0, 1.0, 10.0, 2.0, 1.0])
        plan = plan_partial_progress(mean, [3, 4], Q, tau)
        assert plan[3] == 0.0
        assert plan[4] > 0.0
        counts = np.round(plan * Q).astype(np.int64)
        assert chunk_coverage(counts, Q).min() >= tau

    def test_q1_degenerates_to_revival(self):
        K, tau = 6, 5
        plan = plan_partial_progress(np.ones(K), [2, 4], 1, tau)
        # one flagged worker must be fully revived to reach tau survivors.
        assert sorted(plan.tolist()).count(1.0) == tau
        assert set(plan.tolist()) <= {0.0, 1.0}

    def test_fuzz_always_spans_and_keeps_healthy_full(self):
        fuzz = np.random.default_rng(99)
        for _ in range(50):
            K = int(fuzz.integers(3, 10))
            tau = int(fuzz.integers(1, K + 1))
            Q = int(fuzz.integers(1, 5))
            n_flag = int(fuzz.integers(0, K))
            flagged = fuzz.choice(K, size=n_flag, replace=False).tolist()
            mean = fuzz.uniform(0.5, 3.0, size=K)
            plan = plan_partial_progress(mean, flagged, Q, tau)
            counts = np.round(plan * Q).astype(np.int64)
            # multiples of 1/Q, healthy workers untouched, always spans
            np.testing.assert_allclose(plan, counts / Q, atol=1e-12)
            healthy = [k for k in range(K) if k not in flagged]
            np.testing.assert_array_equal(plan[healthy], 1.0)
            assert chunk_coverage(counts, Q).min() >= tau

    def test_validation(self):
        with pytest.raises(ValueError, match="tau"):
            plan_partial_progress(np.ones(4), [], 2, 5)
        with pytest.raises(ValueError, match="positive"):
            plan_partial_progress([1.0, -1.0, 1.0], [], 2, 2)
        with pytest.raises(ValueError, match="out of range"):
            plan_partial_progress(np.ones(4), [4], 2, 2)
        with pytest.raises(ValueError, match="duplicate"):
            plan_partial_progress(np.ones(4), [1, 1], 2, 2)
        with pytest.raises(ValueError, match="Q >= 1"):
            plan_partial_progress(np.ones(4), [], 0, 2)
        with pytest.raises(ValueError, match="unknown method"):
            plan_partial_progress(np.ones(4), [], 2, 2, method="ilp")

    def test_lp_never_worse_than_greedy_fuzz(self):
        # the LP planner's contract: same feasibility invariants as greedy
        # (spans, healthy untouched, multiples of 1/Q) and an expected
        # wait that NEVER exceeds greedy's — greedy's achieved wait is a
        # feasible bound in the LP's candidate scan.
        fuzz = np.random.default_rng(7)
        for _ in range(200):
            K = int(fuzz.integers(2, 10))
            tau = int(fuzz.integers(1, K + 1))
            Q = int(fuzz.integers(1, 6))
            n_flag = int(fuzz.integers(0, K + 1))
            flagged = fuzz.choice(K, size=n_flag, replace=False).tolist()
            mean = fuzz.uniform(0.1, 10.0, size=K)
            lp = plan_partial_progress(mean, flagged, Q, tau)
            greedy = plan_partial_progress(mean, flagged, Q, tau,
                                           method="greedy")
            for plan in (lp, greedy):
                counts = np.round(plan * Q).astype(np.int64)
                assert chunk_coverage(counts, Q).min() >= tau
            healthy = [k for k in range(K) if k not in flagged]
            np.testing.assert_array_equal(lp[healthy], 1.0)
            assert (expected_wait(lp, mean)
                    <= expected_wait(greedy, mean) + 1e-9)

    def test_lp_never_worse_than_greedy_hypothesis(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need the 'test' extra "
                   "(pip install .[test])")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(max_examples=80, deadline=None)
        @given(st.data())
        def check(data):
            K = data.draw(st.integers(min_value=2, max_value=9))
            tau = data.draw(st.integers(min_value=1, max_value=K))
            Q = data.draw(st.integers(min_value=1, max_value=5))
            flagged = data.draw(st.lists(
                st.integers(min_value=0, max_value=K - 1),
                unique=True, max_size=K))
            mean = data.draw(st.lists(
                st.floats(min_value=0.05, max_value=50.0,
                          allow_nan=False, allow_infinity=False),
                min_size=K, max_size=K))
            lp = plan_partial_progress(mean, flagged, Q, tau)
            greedy = plan_partial_progress(mean, flagged, Q, tau,
                                           method="greedy")
            assert (expected_wait(lp, mean)
                    <= expected_wait(greedy, mean) + 1e-9)
            counts = np.round(lp * Q).astype(np.int64)
            assert chunk_coverage(counts, Q).min() >= tau

        check()


class TestFractionalCompletion:
    def test_binary_progress_reproduces_mask(self, rng):
        times = WorkerTimes(finish=rng.uniform(1.0, 4.0, size=8))
        mask = np.array([1, 0, 1, 1, 0, 1, 1, 1], dtype=np.float64)
        assert (times.completion_with_progress(mask)
                == times.completion_with_mask(mask))

    def test_fractional_is_max_weighted_finish(self, rng):
        finish = rng.uniform(1.0, 4.0, size=6)
        times = WorkerTimes(finish=finish)
        w = np.array([1.0, 0.5, 0.0, 0.25, 1.0, 0.75])
        kept = w > 0
        assert times.completion_with_progress(w) == pytest.approx(
            (w[kept] * finish[kept]).max())

    def test_progress_validation(self, rng):
        times = WorkerTimes(finish=rng.uniform(1.0, 2.0, size=4))
        with pytest.raises(ValueError, match="nothing to wait"):
            times.completion_with_progress(np.zeros(4))
        with pytest.raises(ValueError, match="shape"):
            times.completion_with_progress(np.ones(5))
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            times.completion_with_progress([0.5, 1.5, 1.0, 1.0])

    def test_shifted_exp_scaling_law(self):
        # w * (base + Exp(scale)) = w*base + Exp(w*scale): both parameters
        # scale, so the closed forms generalise for free.
        model = LatencyModel(base=np.array([1.0, 2.0, 3.0]),
                             jitter=np.array([0.1, 0.2, 0.0]))
        w = np.array([1.0, 0.5, 0.25])
        base_f, scale_f = _masked_shifted_exp(model, np.ones(3))
        base_w, scale_w = _masked_shifted_exp(model, w)
        np.testing.assert_allclose(base_w, w * base_f)
        np.testing.assert_allclose(scale_w, w * scale_f)

    def test_closed_forms_match_monte_carlo(self):
        model = LatencyModel(base=np.array([1.0, 1.5, 2.0, 1.2]),
                             jitter=np.array([0.3, 0.1, 0.2, 0.4]))
        w = np.array([1.0, 0.5, 0.75, 0.0])
        base, scale = _masked_shifted_exp(model, w)
        mc = np.random.default_rng(7)
        draws = base + mc.exponential(1.0, size=(20000, base.size)) * scale
        emp = draws.max(axis=1)
        assert masked_completion_mean(model, w) == pytest.approx(
            emp.mean(), rel=0.02)
        t = float(np.median(emp))
        cdf = masked_completion_cdf(model, w, np.array([t]))[0]
        assert cdf == pytest.approx((emp <= t).mean(), abs=0.02)
        assert masked_completion_quantile(model, w, 0.9) == pytest.approx(
            np.quantile(emp, 0.9), rel=0.03)

    def test_quantile_monotone_and_validated(self):
        model = LatencyModel(base=1.0, jitter=0.2)
        w = np.array([1.0, 0.5, 0.25])
        qs = [masked_completion_quantile(model, w, q)
              for q in (0.1, 0.5, 0.9, 0.99)]
        assert qs == sorted(qs)
        with pytest.raises(ValueError, match="outside"):
            masked_completion_quantile(model, w, 1.5)


class TestThresholdFeedback:
    def _fb(self, **cfg):
        defaults = dict(window=8, min_observations=4, threshold_gain=1.0,
                        threshold_min=0.1)
        defaults.update(cfg)
        return ViolationFeedback(0.99, 1.0, FeedbackConfig(**defaults))

    def test_base_until_min_observations(self):
        fb = self._fb()
        for _ in range(3):
            fb.observe(10.0)  # violations, but the window is near-empty
            assert fb.effective_threshold(0.5) == 0.5
        fb.observe(10.0)
        assert fb.effective_threshold(0.5) < 0.5

    def test_monotone_nonincreasing_in_rate(self):
        thresholds = []
        for n_viol in range(9):
            fb = self._fb()
            for i in range(8):
                fb.observe(10.0 if i < n_viol else 0.1)
            thresholds.append(fb.effective_threshold(0.5))
        assert thresholds == sorted(thresholds, reverse=True)

    def test_floors_at_threshold_min(self):
        fb = self._fb(threshold_gain=100.0)
        for _ in range(8):
            fb.observe(10.0)
        assert fb.effective_threshold(0.5) == 0.1
        # a base BELOW the floor wins: the law never raises the threshold.
        assert fb.effective_threshold(0.05) == 0.05

    def test_clean_window_never_exceeds_base(self):
        fb = self._fb(threshold_gain=100.0)
        for _ in range(8):
            fb.observe(0.1)  # zero violations: excess rate is negative
        assert fb.effective_threshold(0.5) == 0.5

    def test_config_validation(self):
        with pytest.raises(ValueError, match="threshold_gain"):
            FeedbackConfig(threshold_gain=-1.0)
        with pytest.raises(ValueError, match="threshold_min"):
            FeedbackConfig(threshold_min=0.0)
        with pytest.raises(ValueError, match="threshold_min"):
            FeedbackConfig(threshold_min=1.5)


class TestPartialServingCaches:
    def test_builds_flat_across_progress_patterns(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        Q = 2
        cm(A, B, progress=_spanning_progress(plan.K, Q), sub_tasks=Q)
        builds = cm.cache_info()["builds"]
        for k in range(2, plan.K):
            prog = np.ones(plan.K)
            prog[k] = (Q - 1) / Q
            out = cm(A, B, progress=prog, sub_tasks=Q)
            np.testing.assert_array_equal(np.asarray(out), C0)
        # fresh fractional patterns hit the SAME partial executable
        assert cm.cache_info()["builds"] == builds

    def test_panel_stacks_memoised_by_signature(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        prog = _spanning_progress(plan.K, 2)
        cm(A, B, progress=prog, sub_tasks=2)
        panels = cm.cache_info()["panel_builds"]
        cm(A, B, progress=prog, sub_tasks=2)  # identical signature
        assert cm.cache_info()["panel_builds"] == panels

    def test_distinct_q_distinct_executables(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        cm(A, B, progress=_spanning_progress(plan.K, 2), sub_tasks=2)
        builds = cm.cache_info()["builds"]
        cm(A, B, progress=_spanning_progress(plan.K, 4), sub_tasks=4)
        assert cm.cache_info()["builds"] == builds + 1
