"""Tests for gradient compression and elastic policy."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.distributed.compression import (
    dequantize_tree,
    error_feedback_update,
    quantize_tree,
)
from repro.distributed.elastic import CodedElasticPolicy, plan_shrink


class TestCompression:
    def test_quantize_roundtrip_accuracy(self, rng):
        g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
        q, s = quantize_tree(g, bits=15)
        back = dequantize_tree(q, s)
        rel = float(jnp.max(jnp.abs(back["w"] - g["w"])) /
                    jnp.max(jnp.abs(g["w"])))
        assert rel < 1e-3
        assert q["w"].dtype == jnp.int32

    def test_scale_is_power_of_two(self, rng):
        g = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
        _, s = quantize_tree(g, bits=15)
        l2 = float(jnp.log2(s["w"]))
        assert l2 == int(l2)

    def test_error_feedback_unbiased(self, rng):
        """Sum of EF-compressed grads converges to sum of true grads."""
        true_sum = np.zeros(16, np.float32)
        ef_sum = np.zeros(16, np.float32)
        res = None
        for t in range(50):
            g = {"w": jnp.asarray(rng.normal(size=16), jnp.float32)}
            true_sum += np.asarray(g["w"])
            deq, res = error_feedback_update(g, res, bits=6)
            ef_sum += np.asarray(deq["w"])
        # residual bounds the gap: |sum_true - sum_ef| = |residual|
        gap = np.abs(true_sum - ef_sum).max()
        res_mag = float(jnp.abs(res["w"]).max())
        assert gap <= res_mag + 1e-5

    def test_int_sum_exact_across_orders(self, rng):
        """The point of the integer grid: order-independent reduction."""
        g = [jnp.asarray(rng.normal(size=8), jnp.float32) for _ in range(5)]
        qs = [quantize_tree({"w": x}, bits=12) for x in g]
        scale = max(float(s["w"]) for _, s in qs)
        ints = [np.round(np.asarray(x) / scale).astype(np.int64) for x in g]
        fwd = sum(ints)
        rev = sum(reversed(ints))
        np.testing.assert_array_equal(fwd, rev)


class TestElastic:
    def test_slack_tracking(self):
        pol = CodedElasticPolicy(K=10, tau=4)
        assert pol.slack == 6
        for w in (0, 1, 2, 3, 4, 5):
            pol.mark_failed(w)
        assert pol.slack == 0 and pol.must_respecialize
        pol.mark_recovered(0)
        assert not pol.must_respecialize

    def test_mark_transitions_idempotent(self):
        pol = CodedElasticPolicy(K=6, tau=3)
        pol.mark_failed(2)
        pol.mark_failed(2)  # double-fail is not double-counted
        assert pol.slack == 2
        pol.mark_recovered(2)
        pol.mark_recovered(2)
        assert pol.slack == 3
        np.testing.assert_array_equal(pol.mask(), np.ones(6))
        assert pol.mask().dtype == np.float64

    def test_must_respecialize_boundary_is_exact(self):
        """Flips precisely when healthy == tau, not one failure later."""
        pol = CodedElasticPolicy(K=5, tau=3)
        pol.mark_failed(0)
        assert pol.slack == 1 and not pol.must_respecialize
        pol.mark_failed(1)
        assert pol.slack == 0 and pol.must_respecialize

    def test_observe_mask_adopts_monitor_view(self):
        pol = CodedElasticPolicy(K=4, tau=2)
        pol.observe_mask([1.0, 0.0, 1.0, 0.0])
        np.testing.assert_array_equal(pol.healthy,
                                      [True, False, True, False])
        assert pol.slack == 0 and pol.must_respecialize
        pol.observe_mask(np.ones(4))  # next step's mask fully replaces it
        assert pol.slack == 2
        with pytest.raises(ValueError):
            pol.observe_mask([1.0, 0.0])

    def test_plan_shrink_prefers_model_preserving(self):
        assert plan_shrink(256) == (16, 16)
        assert plan_shrink(255) == (8, 16)
        assert plan_shrink(100) == (8, 8)
        assert plan_shrink(1) == (1, 1)
        with pytest.raises(ValueError):
            plan_shrink(0)
