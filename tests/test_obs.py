"""Observability layer: spans, metrics, exporters, report, trace IDs.

Covers the ``repro.obs`` contracts end to end:

* span nesting, exception safety, and the injectable clock;
* histogram bucket-edge semantics (Prometheus ``le``-inclusive);
* the disabled fast path — instrumented code returns bit-identical
  results with observability off, and the conveniences are no-ops;
* Perfetto / Prometheus exporter schemas (and the text-dump round
  trip through ``parse_prometheus``);
* the ``obs_report`` renderer against a golden expected output;
* the serialize seam: ``report_to_dict -> JSON -> dict`` equality
  modulo volatile fields (randomized, seeded — no hypothesis dep),
  and the TraceStep/StepReport schema-consistency contract;
* seed-derived span IDs: deterministic with obs OFF, stamped into the
  golden chaos/serve traces byte-identically.
"""
import dataclasses
import json
import math
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.obs.export import (parse_prometheus, perfetto_events,
                              write_perfetto, write_prometheus)
from repro.obs.metrics import DEFAULT_BUCKETS, Histogram, MetricsRegistry
from repro.obs.report import render
from repro.obs.spans import span_id_for

GOLDEN_DIR = Path(__file__).parent / "golden"


@pytest.fixture(autouse=True)
def _fresh_session():
    """Every test starts with observability OFF and leaves it off."""
    obs.disable()
    yield
    obs.disable()


# -- spans --------------------------------------------------------------------

class TestSpans:
    def test_nesting_records_parent_chain(self):
        obs.enable(fresh=True)
        with obs.span("outer") as outer:
            with obs.span("inner") as inner:
                pass
        spans = {s.name: s for s in obs.session().recorder.spans}
        assert spans["inner"].parent == outer.sid
        assert spans["outer"].parent is None
        assert inner.sid != outer.sid
        # children close before parents: creation order is inner, outer
        assert [s.name for s in obs.session().recorder.spans] == \
            ["inner", "outer"]

    def test_exception_marks_span_failed_and_unwinds_stack(self):
        obs.enable(fresh=True)
        with pytest.raises(RuntimeError, match="boom"):
            with obs.span("outer"):
                with obs.span("inner"):
                    raise RuntimeError("boom")
        spans = {s.name: s for s in obs.session().recorder.spans}
        assert spans["inner"].ok is False
        assert spans["outer"].ok is False
        # the stack fully unwound: a new span is a root again
        with obs.span("after"):
            pass
        assert {s.name: s.parent for s in obs.session().recorder.spans}[
            "after"] is None

    def test_generator_leak_does_not_corrupt_siblings(self):
        # a span left open by an abandoned generator must not become the
        # parent of later siblings once its enclosing span closes
        obs.enable(fresh=True)

        def gen():
            with obs.span("leaked"):
                yield

        with obs.span("outer"):
            g = gen()
            next(g)  # opens "leaked" and never closes it
            del g
        with obs.span("after"):
            pass
        spans = {s.name: s for s in obs.session().recorder.spans}
        assert spans["after"].parent is None

    def test_settable_clock_stamps_simulated_time(self):
        clock = obs.SettableClock(10.0)
        obs.enable(fresh=True, clock=clock)
        with obs.span("step"):
            clock.set(12.5)
        (s,) = obs.session().recorder.spans
        assert (s.start_s, s.end_s) == (10.0, 12.5)
        assert s.duration_s == 2.5
        # the clock never goes backwards
        clock.set(1.0)
        assert clock() == 12.5

    def test_emit_records_pretimed_interval_verbatim(self):
        obs.enable(fresh=True)
        s = obs.emit_span("serve.worker_stage", 3.0, 7.0,
                          track="premium", lane="workers", batch=4)
        assert (s.start_s, s.end_s, s.track, s.lane) == \
            (3.0, 7.0, "premium", "workers")
        assert s.attrs == {"batch": "4"}

    def test_span_ids_unique_and_ordered(self):
        obs.enable(fresh=True)
        for _ in range(5):
            with obs.span("x"):
                pass
        sids = [s.sid for s in obs.session().recorder.spans]
        assert sids == sorted(sids) and len(set(sids)) == 5


# -- metrics ------------------------------------------------------------------

class TestMetrics:
    def test_histogram_bucket_edges_are_le_inclusive(self):
        h = Histogram(edges=(1.0, 2.0, 5.0))
        for v in (0.5, 1.0, 1.5, 2.0, 5.0, 5.0001):
            h.observe(v)
        # v == edge lands IN that edge's bucket (Prometheus le semantics)
        assert h.counts == [2, 2, 1, 1]
        assert h.cumulative() == ((1.0, 2), (2.0, 4), (5.0, 5),
                                  (math.inf, 6))
        assert h.count == 6
        assert h.sum == pytest.approx(0.5 + 1.0 + 1.5 + 2.0 + 5.0 + 5.0001)

    def test_histogram_rejects_unsorted_edges_and_rebucketing(self):
        with pytest.raises(ValueError, match="ascending"):
            Histogram(edges=(2.0, 1.0))
        reg = MetricsRegistry()
        reg.histogram("lat", buckets=(1.0, 2.0)).observe(0.5)
        with pytest.raises(ValueError, match="re-bucket"):
            reg.histogram("lat", buckets=(1.0, 3.0))

    def test_counter_monotone_and_totals(self):
        reg = MetricsRegistry()
        reg.counter("serve.shed", reason="rate_limited").inc()
        reg.counter("serve.shed", reason="queue_full").inc(2)
        with pytest.raises(ValueError):
            reg.counter("serve.shed", reason="queue_full").inc(-1)
        assert reg.total("serve.shed") == 3
        assert reg.value("serve.shed", reason="queue_full") == 2
        assert reg.value("serve.shed", reason="nope") is None
        assert reg.total("never.touched") == 0.0

    def test_name_bound_to_one_kind(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError, match="already a counter"):
            reg.histogram("x")


# -- disabled-mode no-op ------------------------------------------------------

class TestDisabledNoOp:
    def test_conveniences_are_noops_while_disabled(self):
        assert not obs.enabled()
        obs.count("a.counter")
        obs.observe("a.hist", 1.0)
        obs.gauge("a.gauge", 2.0)
        assert obs.emit_span("x", 0.0, 1.0) is None
        assert obs.span("x") is obs.span("y")  # the shared NULL_SPAN
        with obs.span("x"):
            pass
        with pytest.raises(RuntimeError, match="disabled"):
            obs.session()

    def test_instrumented_facade_results_bit_identical(self):
        """The same coded matmul with obs off vs on: identical bits,
        identical cache behaviour — instrumentation is observation only."""
        import jax

        from repro.control import PlanLadder
        from repro.core.numerics import enable_x64

        def serve():
            ladder = PlanLadder(4, 2, 1, K=12, L=257, backend="reference")
            ladder.prewarm((16, 8), (16, 4))
            rng = np.random.default_rng(3)
            A = jax.numpy.asarray(rng.integers(-4, 5, size=(16, 8)),
                                  jax.numpy.float64)
            B = jax.numpy.asarray(rng.integers(-4, 5, size=(16, 4)),
                                  jax.numpy.float64)
            outs = [np.asarray(ladder(A, B, erased=[1, 7]))]
            ladder.switch(ladder.rungs[-1])
            outs.append(np.asarray(ladder(A, B)))
            return outs, ladder.cache_info()

        with enable_x64():
            obs.disable()
            off, info_off = serve()
            obs.enable(fresh=True)
            on, info_on = serve()
        for a, b in zip(off, on):
            assert a.tobytes() == b.tobytes()
        assert info_off == info_on
        # and the instrumented run actually counted its compiles
        assert obs.session().registry.total("runtime.executable.compile") > 0

    def test_span_id_for_works_with_obs_disabled(self):
        assert not obs.enabled()
        sid = span_id_for(11, "step.premium", 0)
        assert sid == span_id_for(11, "step.premium", 0)
        assert len(sid) == 16 and int(sid, 16) >= 0
        assert sid != span_id_for(11, "step.premium", 1)
        assert sid != span_id_for(12, "step.premium", 0)
        assert sid != span_id_for(11, "step.standard", 0)


# -- exporters ----------------------------------------------------------------

class TestExporters:
    def _spans(self):
        obs.enable(fresh=True)
        rec = obs.session().recorder
        rec.emit("serve.worker_stage", 0.0, 2.0, track="premium",
                 lane="workers", batch=0)
        rec.emit("serve.decode_stage", 2.0, 3.0, track="premium",
                 lane="decode", batch=0)
        rec.emit("serve.worker_stage", 2.5, 4.0, track="standard",
                 lane="workers", batch=1)
        return rec.spans

    def test_perfetto_schema(self):
        events = perfetto_events(self._spans())
        meta = [e for e in events if e["ph"] == "M"]
        slices = [e for e in events if e["ph"] == "X"]
        # one process row per track, one thread row per (track, lane)
        procs = {e["args"]["name"] for e in meta
                 if e["name"] == "process_name"}
        assert procs == {"premium", "standard"}
        threads = [(e["pid"], e["args"]["name"]) for e in meta
                   if e["name"] == "thread_name"]
        assert len(threads) == 3
        assert len(slices) == 3
        for ev in slices:
            assert set(ev) == {"ph", "name", "pid", "tid", "ts", "dur",
                               "args"}
        # microsecond timestamps
        by = {(e["name"], e["args"]["batch"]): e for e in slices}
        ev = by[("serve.worker_stage", "0")]
        assert (ev["ts"], ev["dur"]) == (0.0, 2_000_000.0)

    def test_write_perfetto_loads_as_json(self, tmp_path):
        path = tmp_path / "t.json"
        write_perfetto(str(path), self._spans())
        doc = json.loads(path.read_text())
        assert doc["displayTimeUnit"] == "ms"
        assert any(e["ph"] == "X" for e in doc["traceEvents"])

    def test_failed_span_flagged_in_args(self):
        obs.enable(fresh=True)
        with pytest.raises(ValueError):
            with obs.span("bad"):
                raise ValueError
        (ev,) = [e for e in perfetto_events(obs.session().recorder.spans)
                 if e["ph"] == "X"]
        assert ev["args"]["error"] == "1"

    def test_prometheus_round_trip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("runtime.executable.compile", kind="concrete").inc(3)
        reg.gauge("pool.size").set(12)
        h = reg.histogram("serve.latency_s", buckets=(1.0, 10.0),
                          slo_class="premium")
        h.observe(0.5)
        h.observe(1.0)
        h.observe(20.0)
        text = reg.to_prometheus()
        # schema: TYPE lines, sanitised names, cumulative buckets
        assert "# TYPE runtime_executable_compile counter" in text
        assert 'runtime_executable_compile{kind="concrete"} 3' in text
        assert "# TYPE serve_latency_s histogram" in text
        assert 'le="+Inf"' in text

        path = tmp_path / "m.prom"
        write_prometheus(str(path), reg)
        samples = parse_prometheus(path.read_text())
        assert samples["pool_size"] == [({}, 12.0)]
        buckets = {lab["le"]: v
                   for lab, v in samples["serve_latency_s_bucket"]}
        assert buckets == {"1.0": 2.0, "10.0": 2.0, "+Inf": 3.0}
        assert samples["serve_latency_s_count"] == \
            [({"slo_class": "premium"}, 3.0)]

    def test_parse_rejects_garbage(self):
        with pytest.raises(ValueError, match="unparseable"):
            parse_prometheus("not a metric line at all!")


# -- obs_report ---------------------------------------------------------------

class TestReport:
    def test_render_golden(self):
        """The full report for a fixed dump pair, golden-checked."""
        reg = MetricsRegistry()
        reg.counter("runtime.executable.hit", kind="concrete").inc(9)
        reg.counter("runtime.executable.compile", kind="concrete").inc(3)
        reg.counter("serve.admit", tenant="gold").inc(5)
        reg.counter("serve.shed", reason="rate_limited", tenant="free").inc(2)
        h = reg.histogram("serve.stage.worker_s", buckets=(1.0, 5.0),
                          rung="bec")
        for v in (0.5, 0.75, 4.0):
            h.observe(v)
        perfetto = {"traceEvents": [
            {"ph": "M", "name": "process_name", "pid": 1,
             "args": {"name": "premium"}},
            {"ph": "X", "name": "serve.worker_stage", "pid": 1, "tid": 1,
             "ts": 0.0, "dur": 2_000_000.0, "args": {}},
            {"ph": "X", "name": "serve.worker_stage", "pid": 1, "tid": 1,
             "ts": 2.5e6, "dur": 1_500_000.0, "args": {}},
            {"ph": "X", "name": "serve.decode_stage", "pid": 1, "tid": 2,
             "ts": 2e6, "dur": 1_000_000.0, "args": {}},
        ]}
        expected = (
            "== top spans (by total time, top 10) ==\n"
            "  serve.worker_stage: n=2 total=3.5s mean=1.75s\n"
            "  serve.decode_stage: n=1 total=1s mean=1s\n"
            "== cache hit ratios ==\n"
            "  runtime.executable: 9 hit / 3 other = 75.0%\n"
            "== admission ==\n"
            "  admitted = 5\n"
            "  shed = 2\n"
            "    reason=rate_limited,tenant=free: 2\n"
            "== latency histograms ==\n"
            "  serve_stage_worker_s{rung=bec}: n=3 mean=1.75s\n"
            "    le 1: 2\n"
            "    le 5: 1\n"
            "== counters ==\n"
            "  runtime_executable_compile{kind=concrete} = 3\n"
            "  runtime_executable_hit{kind=concrete} = 9\n"
            "  serve_admit{tenant=gold} = 5\n"
            "  serve_shed{reason=rate_limited,tenant=free} = 2\n"
        )
        assert render(reg.to_prometheus(), perfetto) == expected

    def test_render_empty_dump(self):
        out = render("")
        assert "(no cache activity recorded)" in out
        assert "(no histograms recorded)" in out
        assert "shed = 0" in out


# -- serialize seam -----------------------------------------------------------

class TestSerializeRoundTrip:
    def _random_step(self, rng) -> "object":
        from repro.chaos.trace import TraceStep

        maybe = lambda v: None if rng.random() < 0.3 else v  # noqa: E731
        return TraceStep(
            step=int(rng.integers(0, 100)),
            times=tuple(float(t) for t in rng.standard_normal(4) ** 2),
            rung=str(rng.choice(["bec", "polycode", "tradeoff(p'=2)"])),
            switched=bool(rng.integers(0, 2)),
            erased=tuple(int(i) for i in rng.choice(
                12, size=rng.integers(0, 4), replace=False)),
            sim_latency_s=float(rng.standard_normal() ** 2),
            slack=int(rng.integers(0, 10)),
            respecialize=bool(rng.integers(0, 2)),
            shrink_target=maybe((int(rng.integers(1, 5)),
                                 int(rng.integers(1, 5)))),
            exact=maybe(bool(rng.integers(0, 2))),
            slo_violation=bool(rng.integers(0, 2)),
            predicted_tail_s=maybe(float(rng.standard_normal() ** 2)),
            realized_s=maybe(float(rng.standard_normal() ** 2)),
            realized_violation=bool(rng.integers(0, 2)),
            q_effective=maybe(float(rng.random())),
            progress=maybe(tuple(float(p) for p in rng.random(12))),
            threshold_effective=maybe(float(rng.random())),
            span_id=maybe(span_id_for(int(rng.integers(0, 99)), "step",
                                      int(rng.integers(0, 99)))),
        )

    def test_report_to_dict_json_round_trip_property(self):
        """report_to_dict -> JSON -> dict equality modulo volatile fields,
        over randomized records (seeded; stands in for hypothesis)."""
        from repro.chaos.serialize import report_to_dict, tuplify
        from repro.chaos.trace import TraceStep

        rng = np.random.default_rng(0)
        for _ in range(200):
            step = self._random_step(rng)
            rec = report_to_dict(step, exclude=())
            rec2 = json.loads(json.dumps(rec))
            assert rec2 == rec  # floats survive bit-exactly
            rebuilt = TraceStep(**{
                k: tuplify(v) if isinstance(v, list) else v
                for k, v in rec2.items()})
            assert rebuilt == step

    def test_volatile_fields_routed_through_one_place(self):
        from repro.chaos.serialize import (REPORT_VOLATILE_FIELDS,
                                           report_field_names,
                                           report_to_dict)
        from repro.control.driver import StepReport

        names = report_field_names(StepReport)
        assert "wall_ms" in REPORT_VOLATILE_FIELDS
        assert "wall_ms" not in names
        # the dict serialisation uses the same selection
        fields = {f.name: None for f in dataclasses.fields(StepReport)}
        fields.update(step=0, rung="bec", switched=False, erased=(),
                      sim_latency_s=0.0, slack=0, respecialize=False,
                      slo_violation=False, realized_violation=False,
                      wall_ms=123.0)
        rep = StepReport(**{k: v for k, v in fields.items()})
        assert tuple(report_to_dict(rep)) == names

    def test_field_names_requires_dataclass(self):
        from repro.chaos.serialize import report_field_names

        with pytest.raises(TypeError):
            report_field_names(dict)

    def test_tracestep_covers_stepreport_schema(self):
        """Every non-volatile StepReport field has a TraceStep slot (the
        from_report contract) — and COMPARED_FIELDS derives from it."""
        from repro.chaos.serialize import report_field_names
        from repro.chaos.trace import COMPARED_FIELDS, TraceStep
        from repro.control.driver import StepReport

        report_names = set(report_field_names(StepReport))
        step_names = {f.name for f in dataclasses.fields(TraceStep)}
        assert report_names <= step_names
        assert COMPARED_FIELDS == report_field_names(
            TraceStep, volatile=("step", "times"))
        assert "span_id" in COMPARED_FIELDS


# -- golden trace span IDs ----------------------------------------------------

class TestGoldenSpanIds:
    def test_chaos_golden_span_ids_are_seed_derived(self):
        # the canonical golden recipe constructs its AdaptiveServer with
        # the DEFAULT server seed (0); the meta seed feeds the scenario.
        path = GOLDEN_DIR / "heavy_tail.jsonl"
        lines = path.read_text().splitlines()
        for line in lines[1:]:
            rec = json.loads(line)
            assert rec["span_id"] == span_id_for(0, "step", rec["step"])

    def test_serve_golden_span_ids_are_seed_derived(self):
        from repro.serve import GOLDEN_SERVE_SEED

        path = GOLDEN_DIR / "serve_heavy_tail.jsonl"
        requests, batches = [], []
        for line in path.read_text().splitlines()[1:]:
            rec = json.loads(line)
            (requests if rec["kind"] == "request" else batches).append(rec)
        assert requests and batches
        for rec in requests:
            assert rec["span_id"] == span_id_for(
                GOLDEN_SERVE_SEED, "request", rec["rid"])
        for rec in batches:
            assert rec["span_id"] == span_id_for(
                GOLDEN_SERVE_SEED, "batch", rec["index"])
            # the batch report carries the per-class control span ID
            report = rec["report"]
            assert report["span_id"] == span_id_for(
                GOLDEN_SERVE_SEED, f"step.{rec['slo_class']}",
                report["step"])


# -- serve-tier integration ---------------------------------------------------

class TestServeObsIntegration:
    def _run_tier(self):
        import jax

        from repro.chaos import make_scenario
        from repro.control import PlanLadder
        from repro.core.numerics import enable_x64
        from repro.serve import (GOLDEN_SERVE_OVERHEAD_S, GOLDEN_SERVE_SEED,
                                 SLOClass, ServeTier, TenantSpec)

        with enable_x64():
            ladder = PlanLadder(4, 2, 1, K=12, L=257, backend="reference")
            ladder.prewarm((16, 8), (16, 4), batch_sizes=(1, 2, 4),
                           stages=True)
            tier = ServeTier(
                ladder,
                classes=(SLOClass(name="premium", quantile=0.99,
                                  slo_s=30.0),),
                tenants=(TenantSpec(name="gold", slo_class="premium",
                                    arrival_rps=2.0),),
                feed=make_scenario("heavy_tail").compile(
                    12, seed=GOLDEN_SERVE_SEED),
                overhead_s=GOLDEN_SERVE_OVERHEAD_S,
                seed=GOLDEN_SERVE_SEED, check_exact=True, pipelined=True)
            A = jax.numpy.asarray(np.arange(16 * 8).reshape(16, 8) % 5,
                                  jax.numpy.float64)
            B = jax.numpy.asarray(np.arange(16 * 4).reshape(16, 4) % 5,
                                  jax.numpy.float64)
            return tier.run(lambda req: A, B, 8)

    def test_spans_metrics_and_pipeline_overlap(self):
        obs.enable(fresh=True)
        result = self._run_tier()
        rec = obs.session().recorder
        workers = rec.by_name("serve.worker_stage")
        decodes = rec.by_name("serve.decode_stage")
        assert len(workers) == len(result.batches)
        assert len(decodes) == len(result.batches)
        assert all(s.track == "premium" for s in workers + decodes)
        assert {s.lane for s in workers} == {"workers"}
        assert {s.lane for s in decodes} == {"decode"}
        # spans stamp SIMULATED seconds, straight off the batch schedule
        for span, batch in zip(workers, result.batches):
            assert span.start_s == batch.compute_start_s
            assert span.end_s == batch.compute_done_s
        # the pipeline contract: some decode(t) overlaps worker(t+1)
        overlaps = sum(
            1 for d, w in zip(decodes, workers[1:])
            if d.start_s < w.end_s and w.start_s < d.end_s)
        assert overlaps > 0, "pipelined tier showed no stage overlap"
        reg = obs.session().registry
        assert reg.total("serve.admit") == len(result.admitted)
        assert reg.total("serve.batch") == len(result.batches)

    def test_obs_off_and_on_give_identical_serve_records(self):
        obs.disable()
        off = self._run_tier()
        obs.enable(fresh=True)
        on = self._run_tier()
        assert off.requests == on.requests
        assert off.batches == on.batches
        # span IDs are stamped either way (pure function of the seed)
        assert all(r.span_id for r in off.requests)
