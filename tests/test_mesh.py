"""Multi-device tests (8 fake CPU devices via subprocess - the main test
process must keep seeing ONE device, so anything needing a mesh runs in a
child interpreter with XLA_FLAGS set before jax imports)."""
import os
import subprocess
import sys
from pathlib import Path


SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_child(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(f"child failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


class TestCodedMesh:
    def test_erasure_tolerant_exact(self):
        out = run_child("""
import jax; jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core import make_plan, uncoded_matmul
from repro.distributed.coded import coded_matmul_mesh
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
A = jnp.asarray(rng.integers(-4, 5, size=(64, 48)), jnp.float64)
B = jnp.asarray(rng.integers(-4, 5, size=(64, 40)), jnp.float64)
plan = make_plan("bec", 2, 2, 1, K=4, L=64*4*4+1, points="chebyshev")
C0 = uncoded_matmul(A, B)
for erased in ([], [1], [0, 3]):
    mask = np.ones(4); mask[erased] = 0
    C = coded_matmul_mesh(A, B, plan, mesh, jnp.asarray(mask), dtype=jnp.float64)
    assert float(jnp.max(jnp.abs(C - C0))) == 0.0, erased
print("OK")
""")
        assert "OK" in out

    def test_coded_linear_quantized_grid_exact(self):
        out = run_child("""
import jax; jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core import make_plan
from repro.distributed.coded import CodedLinearPlan
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
W = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)
plan = make_plan("bec", 2, 2, 1, K=4, L=32*7*7+1, points="chebyshev")
lin = CodedLinearPlan(plan, mesh, quant_bits=4, dtype=jnp.float64)
y = lin(x, W, mask=jnp.asarray([1., 0., 1., 1.]))
# compare against the QUANTIZED reference: the coded path itself is exact
qmax = 7
sx = float(jnp.max(jnp.abs(x))) / qmax + 1e-9
sw = float(jnp.max(jnp.abs(W))) / qmax + 1e-9
y_ref = (jnp.round(x / sx) @ jnp.round(W / sw)) * (sx * sw)
assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-6
print("OK")
""")
        assert "OK" in out


class TestMeshPartial:
    """Partial-straggler sub-tasking on the mesh backend.

    Parity bar: ("partial", Q) output bit-identical to the reference
    executor for the same progress vector across all three scheme
    families, Q = 1 bit-identical to the legacy mesh erasure path, zero
    recompiles across progress changes, non-spanning vectors raise."""

    def test_partial_parity_all_schemes(self):
        out = run_child("""
import jax; jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core import make_plan, make_scheme, uncoded_matmul
from repro.runtime import CodedMatmul, MeshExecutor

def spanning(K, Q):
    prog = np.ones(K)
    if Q == 1:
        prog[0] = 0.0
    else:
        prog[0] = prog[1] = (Q - 1) / Q
    return prog

rng = np.random.default_rng(0)
for kind, p, m, n, pp in [("bec", 2, 2, 2, 1), ("tradeoff", 4, 2, 1, 2),
                          ("polycode", 2, 2, 1, 1)]:
    tau = make_scheme(kind, p, m, n, p_prime=pp).tau
    v = 8 * p
    plan = make_plan(kind, p, m, n, K=tau + 2, L=v * 3 * 3 + 1, p_prime=pp)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:plan.K]), ("model",))
    cm_mesh = CodedMatmul(plan, MeshExecutor(mesh, use_kernels=False),
                          dtype=jnp.float64)
    cm_ref = CodedMatmul(plan, "reference", dtype=jnp.float64)
    A = jnp.asarray(rng.integers(-3, 4, size=(v, 12)), jnp.float64)
    B = jnp.asarray(rng.integers(-3, 4, size=(v, 10)), jnp.float64)
    C0 = np.asarray(uncoded_matmul(A, B))
    for Q in (1, 2, 4):
        prog = spanning(plan.K, Q)
        Cm = np.asarray(cm_mesh(A, B, progress=prog, sub_tasks=Q))
        Cr = np.asarray(cm_ref(A, B, progress=prog, sub_tasks=Q))
        assert np.array_equal(Cm, Cr), (kind, Q)
        assert np.array_equal(Cm, C0), (kind, Q)
    # Q = 1 partial must be bit-identical to the legacy binary mesh path
    Cb = np.asarray(cm_mesh(A, B, erased=[0]))
    Cq1 = np.asarray(cm_mesh(A, B, progress=spanning(plan.K, 1), sub_tasks=1))
    assert np.array_equal(Cb, Cq1), kind
print("OK")
""")
        assert "OK" in out

    def test_partial_traced_zero_recompiles_and_raise_parity(self):
        out = run_child("""
import jax; jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core import make_plan, make_scheme, uncoded_matmul
from repro.runtime import CodedMatmul, MeshExecutor

tau = make_scheme("bec", 2, 2, 2, p_prime=1).tau
v = 16
plan = make_plan("bec", 2, 2, 2, K=tau + 2, L=v * 3 * 3 + 1, p_prime=1)
mesh = jax.sharding.Mesh(np.array(jax.devices()[:plan.K]), ("model",))
cm = CodedMatmul(plan, MeshExecutor(mesh, use_kernels=False),
                 dtype=jnp.float64)
rng = np.random.default_rng(1)
A = jnp.asarray(rng.integers(-3, 4, size=(v, 12)), jnp.float64)
B = jnp.asarray(rng.integers(-3, 4, size=(v, 10)), jnp.float64)
C0 = np.asarray(uncoded_matmul(A, B))
for Q in (2, 4):
    f = jax.jit(lambda a, b, w: cm(a, b, progress=w, sub_tasks=Q))
    prog = np.ones(plan.K); prog[0] = prog[1] = (Q - 1) / Q
    assert np.array_equal(np.asarray(f(A, B, jnp.asarray(prog))), C0), Q
    prog2 = np.ones(plan.K); prog2[2] = (Q - 1) / Q
    assert np.array_equal(np.asarray(f(A, B, jnp.asarray(prog2))), C0), Q
# one executable per Q; progress changes hit the memo, never rebuild
info = cm.cache_info()
assert info["builds"] == 2, info
# concrete progress changes reuse the traced-free ("partial", Q) pipeline
for trial in range(4):
    prog = np.ones(plan.K)
    prog[trial % plan.K] = 0.5
    assert np.array_equal(np.asarray(cm(A, B, progress=prog, sub_tasks=2)),
                          C0), trial
assert cm.cache_info()["builds"] == 3, cm.cache_info()
# non-spanning raise parity with the reference executor
bad = np.zeros(plan.K); bad[:plan.tau - 1] = 1.0
for backend in (cm, CodedMatmul(plan, "reference", dtype=jnp.float64)):
    try:
        backend(A, B, progress=bad, sub_tasks=2)
        raise SystemExit("non-spanning progress did not raise")
    except ValueError as e:
        assert "span" in str(e), e
print("OK")
""")
        assert "OK" in out

    def test_partial_parity_with_kernels(self):
        out = run_child("""
import jax; jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core import make_plan, make_scheme, uncoded_matmul
from repro.runtime import CodedMatmul, MeshExecutor

tau = make_scheme("bec", 2, 2, 1, p_prime=1).tau
v = 8
plan = make_plan("bec", 2, 2, 1, K=tau + 2, L=v * 3 * 3 + 1, p_prime=1)
mesh = jax.sharding.Mesh(np.array(jax.devices()[:plan.K]), ("model",))
cm = CodedMatmul(plan, MeshExecutor(mesh), dtype=jnp.float64)
rng = np.random.default_rng(2)
A = jnp.asarray(rng.integers(-3, 4, size=(v, 6)), jnp.float64)
B = jnp.asarray(rng.integers(-3, 4, size=(v, 6)), jnp.float64)
C0 = np.asarray(uncoded_matmul(A, B))
prog = np.ones(plan.K); prog[0] = prog[1] = 0.5
C = np.asarray(cm(A, B, progress=prog, sub_tasks=2))
assert np.array_equal(C, C0)
print("OK")
""")
        assert "OK" in out


class TestMoEParallel:
    def test_ep_matches_dense(self):
        """EP (all_to_all shard_map) == dense oracle at high capacity."""
        out = run_child("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.sharding import axis_rules, default_rules
from repro.models.moe import MoEConfig, init_moe, apply_moe, _moe_dense
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = default_rules(mesh)
cfg = MoEConfig(n_experts=8, top_k=2, d_expert_ff=32, n_shared=1,
                capacity_factor=64.0)  # no drops
key = jax.random.PRNGKey(0)
params = init_moe(key, 16, cfg, ep_size=4, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16), jnp.float32)
y_dense, aux_d = _moe_dense(params, x, cfg)
with axis_rules(rules):
    y_ep, aux_e = jax.jit(lambda p, x: apply_moe(p, x, cfg))(params, x)
err = float(jnp.max(jnp.abs(y_dense - y_ep)))
rel = err / (float(jnp.max(jnp.abs(y_dense))) + 1e-9)
assert rel < 2e-2, (err, rel)
print("OK", rel)
""")
        assert "OK" in out

    def test_ep_capacity_drops_tokens(self):
        out = run_child("""
import jax, jax.numpy as jnp
from repro.distributed.sharding import axis_rules, default_rules
from repro.models.moe import MoEConfig, init_moe, apply_moe
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = default_rules(mesh)
cfg = MoEConfig(n_experts=8, top_k=2, d_expert_ff=32, capacity_factor=0.1)
params = init_moe(jax.random.PRNGKey(0), 16, cfg, ep_size=4, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16), jnp.float32)
with axis_rules(rules):
    y, aux = jax.jit(lambda p, x: apply_moe(p, x, cfg))(params, x)
assert bool(jnp.all(jnp.isfinite(y)))
print("OK")
""")
        assert "OK" in out


class TestShardedTraining:
    def test_mesh_train_step_matches_single_device(self):
        """One train step on a 2x4 mesh == single device (same math)."""
        out = run_child("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_smoke_config
from repro.distributed.sharding import axis_rules, default_rules
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import OptConfig, adamw_init
cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), tp_pad=4,
                          dtype="float32")
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
opt = adamw_init(params)
batch = {"tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab),
         "labels": jax.random.randint(key, (8, 64), 0, cfg.vocab)}
ocfg = OptConfig()
# single device
p1, o1, m1 = jax.jit(make_train_step(cfg, ocfg, None))(params, opt, batch)
# mesh
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = default_rules(mesh)
p2, o2, m2 = jax.jit(make_train_step(cfg, ocfg, rules))(params, opt, batch)
l1, l2 = float(m1["loss"]), float(m2["loss"])
assert abs(l1 - l2) / abs(l1) < 1e-4, (l1, l2)
f32 = jnp.float32
d = jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a.astype(f32) - b.astype(f32)))),
    p1, p2)
mx = max(jax.tree.leaves(d))
assert mx < 1e-2, mx
print("OK", l1, l2, mx)
""", timeout=1200)
        assert "OK" in out
