"""Substrate tests: data determinism, checkpoint atomicity, optimizer."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import make_pipeline
from repro.optim import OptConfig, adamw_init, adamw_update, cosine_lr


class TestData:
    def test_deterministic_across_instances(self):
        p1 = make_pipeline(100, 32, 4, seed=7)
        p2 = make_pipeline(100, 32, 4, seed=7)
        b1, b2 = p1.batch(5), p2.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        b = make_pipeline(100, 32, 2, seed=0).batch(0)
        # labels[t] continues tokens: they come from one (seq_len+1) stream
        assert b["tokens"].shape == b["labels"].shape == (2, 32)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_slice_matches_global(self):
        pipe = make_pipeline(100, 16, 8, seed=3)
        full = pipe.batch(2)
        part = pipe.batch(2, host_slice=(2, 5))
        np.testing.assert_array_equal(full["tokens"][2:5], part["tokens"])

    def test_different_steps_differ(self):
        pipe = make_pipeline(100, 32, 2, seed=0)
        assert not np.array_equal(pipe.batch(0)["tokens"],
                                  pipe.batch(1)["tokens"])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
        save_checkpoint(tmp_path, 7, tree, extra={"data_step": 7})
        out, step, extra = restore_checkpoint(tmp_path, tree)
        assert step == 7 and extra["data_step"] == 7
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10))
        assert out["b"]["c"].shape == (3, 4)
        assert str(out["b"]["c"].dtype) == "bfloat16"
        np.testing.assert_array_equal(
            np.asarray(out["b"]["c"], np.float32), np.ones((3, 4)))

    def test_latest_step_picks_newest(self, tmp_path):
        tree = {"x": jnp.zeros(3)}
        save_checkpoint(tmp_path, 1, tree)
        save_checkpoint(tmp_path, 5, tree)
        assert latest_step(tmp_path) == 5

    def test_torn_checkpoint_ignored(self, tmp_path):
        tree = {"x": jnp.zeros(3)}
        save_checkpoint(tmp_path, 1, tree)
        # simulate a torn write: directory without COMMIT
        torn = tmp_path / "step_000000002"
        torn.mkdir()
        (torn / "manifest.json").write_text("{}")
        assert latest_step(tmp_path) == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"x": jnp.zeros(3)})
        with pytest.raises(ValueError):
            restore_checkpoint(tmp_path, {"x": jnp.zeros(4)})


class TestOptimizer:
    def test_descends_quadratic(self):
        cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                        total_steps=100)
        params = {"w": jnp.asarray([3.0, -2.0], jnp.bfloat16)}
        opt = adamw_init(params)
        for _ in range(60):
            grads = {"w": params["w"].astype(jnp.float32) * 2}  # d/dw w^2
            grads = {"w": grads["w"].astype(jnp.bfloat16)}
            params, opt, _ = adamw_update(cfg, grads, opt)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_master_weights_fp32(self):
        params = {"w": jnp.ones(4, jnp.bfloat16)}
        opt = adamw_init(params)
        assert opt["master"]["w"].dtype == jnp.float32

    def test_clip_bounds_update(self):
        cfg = OptConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                        warmup_steps=0, total_steps=10)
        params = {"w": jnp.zeros(2, jnp.float32)}
        opt = adamw_init(params)
        grads = {"w": jnp.asarray([1e6, -1e6], jnp.float32)}
        _, _, metrics = adamw_update(cfg, grads, opt)
        assert float(metrics["grad_norm"]) > 1e5  # raw norm reported

    def test_schedule_warmup_and_decay(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_ratio=0.1)
        assert float(cosine_lr(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(cosine_lr(cfg, jnp.int32(100))) == pytest.approx(0.1)


class TestTrainResume:
    def test_checkpoint_resume_bitexact(self, tmp_path):
        """Training N steps == training k, checkpointing, resuming N-k."""
        from repro.launch.train import main as train_main
        ck1 = tmp_path / "c1"
        l_full = train_main(["--arch", "qwen3_0_6b", "--smoke", "--steps", "6",
                             "--batch", "2", "--seq", "32", "--log-every", "100"])
        train_main(["--arch", "qwen3_0_6b", "--smoke", "--steps", "3",
                    "--batch", "2", "--seq", "32", "--ckpt-dir", str(ck1),
                    "--ckpt-every", "3", "--log-every", "100"])
        l_resumed = train_main(["--arch", "qwen3_0_6b", "--smoke", "--steps",
                                "6", "--batch", "2", "--seq", "32",
                                "--ckpt-dir", str(ck1), "--resume",
                                "--log-every", "100"])
        # restored state is bit-exact; residual diff is CPU matmul
        # reduction-order noise across executions (~1e-5 rel)
        assert l_resumed[-1] == pytest.approx(l_full[-1], rel=1e-3)

    def test_elastic_shrink_handoff_bitexact(self, tmp_path):
        """The elastic path — checkpoint at the shrink step, plan_shrink
        the mesh, re-lower, restore — matches the uninterrupted run."""
        from repro.launch.train import main as train_main
        base = ["--arch", "qwen3_0_6b", "--smoke", "--steps", "6",
                "--batch", "2", "--seq", "32", "--log-every", "100"]
        l_full = train_main(list(base))
        l_elastic = train_main(base + [
            "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "100",
            "--elastic-shrink-at", "3", "--elastic-devices", "3"])
        assert len(l_elastic) == len(l_full)
        assert l_elastic[-1] == pytest.approx(l_full[-1], rel=1e-3)

    def test_elastic_shrink_requires_checkpoint_dir(self):
        from repro.launch.train import main as train_main
        with pytest.raises(SystemExit):
            train_main(["--arch", "qwen3_0_6b", "--smoke", "--steps", "4",
                        "--elastic-shrink-at", "2"])
