"""Unit tests for the coding schemes: exponents, thresholds, exact recovery.

Reproduces the paper's core claims at test scale:
  * BEC threshold tau = mn (Sec. III-B), recovery from ANY tau workers
  * tradeoff threshold tau = mnp' + p' - 1 (Sec. IV) + Example 1 exponents
  * baseline polynomial code tau = pmn + p - 1 [Yu et al.]
  * digit extraction with sign recovery (Sec. III-C)
"""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    coded_matmul,
    digit_extract,
    make_plan,
    make_scheme,
    uncoded_matmul,
)


def _rand_pair(rng, v=48, r=32, t=24, lo=-5, hi=6):
    A = rng.integers(lo, hi, size=(v, r)).astype(np.float64)
    B = rng.integers(lo, hi, size=(v, t)).astype(np.float64)
    return A, B


class TestThresholds:
    @pytest.mark.parametrize("p,m,n", [(2, 2, 2), (3, 2, 2), (4, 3, 2), (2, 1, 1)])
    def test_bec_tau_optimal(self, p, m, n):
        assert make_scheme("bec", p, m, n).tau == m * n

    @pytest.mark.parametrize("p,m,n,pp", [(4, 2, 2, 2), (4, 2, 2, 4), (6, 2, 3, 3)])
    def test_tradeoff_tau(self, p, m, n, pp):
        assert make_scheme("tradeoff", p, m, n, p_prime=pp).tau == m * n * pp + pp - 1

    @pytest.mark.parametrize("p,m,n", [(2, 2, 2), (3, 2, 2)])
    def test_polycode_tau(self, p, m, n):
        assert make_scheme("polycode", p, m, n).tau == p * m * n + p - 1

    def test_tradeoff_pprime1_is_bec_tau(self):
        assert make_scheme("tradeoff", 4, 2, 3, p_prime=1).tau == \
            make_scheme("bec", 4, 2, 3).tau

    def test_tradeoff_invalid_pprime(self):
        with pytest.raises(ValueError):
            make_scheme("tradeoff", 4, 2, 2, p_prime=3)


class TestExample1:
    """Paper Sec. IV Example 1: m=n=2, p=4, p'=2."""

    def test_useful_powers(self):
        sch = make_scheme("tradeoff", 4, 2, 2, p_prime=2)
        assert sorted(sch.useful_z_exp().ravel().tolist()) == [1, 3, 5, 7]

    def test_degree(self):
        sch = make_scheme("tradeoff", 4, 2, 2, p_prime=2)
        az, _ = sch.a_exponents()
        bz, _ = sch.b_exponents()
        assert az.max() + bz.max() == sch.tau - 1 == 8

    def test_digit_depth(self):
        sch = make_scheme("tradeoff", 4, 2, 2, p_prime=2)
        assert sch.digit_depth == 1  # |X| <= 2L^2 vs BEC's 8L^4


class TestExactRecovery:
    @pytest.mark.parametrize("kind,p,pp", [("bec", 2, 1), ("bec", 3, 1),
                                           ("polycode", 2, 1),
                                           ("tradeoff", 4, 2)])
    def test_no_erasure(self, rng, kind, p, pp):
        A, B = _rand_pair(rng)
        L = 48 * 5 * 5 + 1
        plan = make_plan(kind, p, 2, 2, K=plan_k(kind, p, 2, 2, pp),
                         L=L, points="chebyshev", p_prime=pp)
        C = coded_matmul(A, B, plan)
        np.testing.assert_array_equal(np.asarray(C), np.asarray(uncoded_matmul(A, B)))

    @pytest.mark.parametrize("survivor_seed", range(4))
    def test_any_tau_subset(self, rng, survivor_seed):
        """ANY tau of K workers decode exactly (unit-circle: conditioning-free)."""
        A, B = _rand_pair(rng)
        L = 48 * 5 * 5 + 1
        plan = make_plan("bec", 2, 2, 2, K=10, L=L, points="unit_circle")
        srng = np.random.default_rng(survivor_seed)
        surv = srng.choice(10, size=plan.tau, replace=False).tolist()
        C = coded_matmul(A, B, plan, survivors=surv)
        np.testing.assert_allclose(np.asarray(C),
                                   np.asarray(uncoded_matmul(A, B)), atol=1e-9)

    def test_max_erasures(self, rng):
        """K - tau = 6 erasures with the paper's Sec. V geometry."""
        A, B = _rand_pair(rng)
        L = 48 * 5 * 5 + 1
        plan = make_plan("bec", 2, 2, 2, K=10, L=L, points="unit_circle")
        C = coded_matmul(A, B, plan, erased=[0, 2, 4, 6, 8, 9])
        np.testing.assert_allclose(np.asarray(C),
                                   np.asarray(uncoded_matmul(A, B)), atol=1e-9)

    def test_below_threshold_rejected(self, rng):
        A, B = _rand_pair(rng)
        plan = make_plan("bec", 2, 2, 2, K=6, L=100, points="chebyshev")
        with pytest.raises(ValueError, match="undecodable"):
            coded_matmul(A, B, plan, erased=[0, 1, 2])

    def test_negative_entries_sign_recovery(self, rng):
        A, B = _rand_pair(rng, lo=-9, hi=10)
        L = 48 * 9 * 9 + 1
        plan = make_plan("bec", 2, 2, 2, K=6, L=L, points="chebyshev")
        C = coded_matmul(A, B, plan, erased=[3])
        np.testing.assert_array_equal(np.asarray(C), np.asarray(uncoded_matmul(A, B)))

    def test_nonsquare_padding(self, rng):
        """Dims not divisible by the grid: zero-padding stays exact."""
        A = rng.integers(-3, 4, size=(50, 33)).astype(np.float64)
        B = rng.integers(-3, 4, size=(50, 17)).astype(np.float64)
        plan = make_plan("bec", 2, 2, 2, K=6, L=50 * 3 * 3 + 1, points="chebyshev")
        C = coded_matmul(A, B, plan)
        np.testing.assert_array_equal(np.asarray(C), np.asarray(uncoded_matmul(A, B)))


def plan_k(kind, p, m, n, pp):
    sch = make_scheme(kind, p, m, n, p_prime=pp)
    return sch.tau + 2


class TestDigitExtraction:
    def test_roundtrip(self, rng):
        s = 1 << 12
        C = rng.integers(-s // 2 + 1, s // 2, size=(64,)).astype(np.float64)
        hi = rng.integers(-100, 100, size=(64,)).astype(np.float64)
        lo = rng.uniform(-0.4, 0.4, size=64)
        X = jnp.asarray(C + hi * s + lo)
        out = digit_extract(X, float(s))
        np.testing.assert_array_equal(np.asarray(out), C)

    def test_power_of_two_exact(self):
        # s power of two: fp mod is exact even at large magnitudes
        s = float(1 << 30)
        X = jnp.asarray([(1 << 29) - 1 + (1 << 30) * 7.0])
        assert float(digit_extract(X, s)[0]) == (1 << 29) - 1
