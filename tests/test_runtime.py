"""Unified runtime (ISSUE-2): CodedMatmul facade, executors, erasure, caching.

Covers the acceptance bar:
  * ErasurePattern normalisation: erased / survivors / mask (concrete and
    traced-under-jit) produce IDENTICAL results across executors;
  * bit-identical parity across reference / staged / fused in-process and
    all four backends (incl. mesh) in a child interpreter;
  * zero recompiles after warm-up: repeated serving calls with fresh
    erasure patterns hit the executable memo (and the underlying jit cache
    stays at one specialisation per key);
  * batched leading dimensions via vmap;
  * legacy shims delegate and warn;
  * CodedLinearPlan quantisation guard + round-trip accuracy.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import make_plan, make_scheme, uncoded_matmul  # noqa: E402
from repro.runtime import (  # noqa: E402
    BACKENDS,
    CodedMatmul,
    ErasurePattern,
    FusedKernelExecutor,
    ReferenceExecutor,
    StagedKernelExecutor,
)

SRC = str(Path(__file__).resolve().parents[1] / "src")
LOCAL_BACKENDS = ("reference", "staged", "fused")

# (kind, p, m, n, p_prime) - one geometry per scheme family.
SCHEMES = [
    ("bec", 2, 2, 2, 1),
    ("tradeoff", 4, 2, 1, 2),
    ("polycode", 2, 2, 1, 1),
]


def _int_problem(rng, plan, v, r, t):
    A = jnp.asarray(rng.integers(-3, 4, size=(v, r)), jnp.float64)
    B = jnp.asarray(rng.integers(-3, 4, size=(v, t)), jnp.float64)
    return A, B, np.asarray(uncoded_matmul(A, B))


def _make(kind, p, m, n, pp, *, extra=2, v_mult=8, points="chebyshev"):
    tau = make_scheme(kind, p, m, n, p_prime=pp).tau
    v = v_mult * p
    return make_plan(kind, p, m, n, K=tau + extra, L=v * 3 * 3 + 1,
                     p_prime=pp, points=points), v


class TestErasurePattern:
    def test_equivalent_inputs_same_key(self):
        K = 6
        by_erased = ErasurePattern.normalize(K, erased=[1, 4])
        by_survivors = ErasurePattern.normalize(K, survivors=[0, 2, 3, 5])
        by_mask = ErasurePattern.normalize(K, mask=[1, 0, 1, 1, 0, 1])
        positional = ErasurePattern.normalize(K, np.array([1, 0, 1, 1, 0, 1.0]))
        assert (by_erased.key == by_survivors.key == by_mask.key
                == positional.key)
        assert by_erased.kind == "concrete"
        assert by_erased.survivors == (0, 2, 3, 5)
        assert by_erased.erased == (1, 4)
        assert by_erased.n_survivors == 4

    def test_positional_short_list_is_erased_ids(self):
        pat = ErasurePattern.normalize(6, [1, 4])
        assert pat.erased == (1, 4)

    def test_default_is_all_alive(self):
        pat = ErasurePattern.normalize(4)
        assert pat.n_survivors == 4 and pat.kind == "concrete"

    def test_rejects_multiple_specs(self):
        with pytest.raises(ValueError, match="only one"):
            ErasurePattern.normalize(4, erased=[0], survivors=[1, 2, 3])
        with pytest.raises(ValueError, match="only one"):
            ErasurePattern.normalize(4, [0], mask=[1, 1, 1, 0])

    def test_rejects_bad_ids_and_shapes(self):
        with pytest.raises(ValueError, match="duplicate"):
            ErasurePattern.normalize(4, erased=[1, 1])
        with pytest.raises(ValueError, match="out of range"):
            ErasurePattern.normalize(4, erased=[4])
        with pytest.raises(ValueError, match="mask shape"):
            ErasurePattern.normalize(4, mask=[1, 1, 1])

    def test_traced_mask_detected_under_jit(self):
        seen = {}

        def probe(m):
            seen["pat"] = ErasurePattern.normalize(4, mask=m)
            return m

        jax.jit(probe)(jnp.ones(4))
        assert seen["pat"].kind == "traced"
        assert seen["pat"].key == ("traced",)
        with pytest.raises(ValueError, match="traced"):
            _ = seen["pat"].survivors


class TestExecutorParity:
    """reference / staged / fused bit-identical, every erasure input form."""

    @pytest.mark.parametrize("kind,p,m,n,pp", SCHEMES)
    def test_backends_and_erasure_forms_identical(self, rng, kind, p, m, n, pp):
        plan, v = _make(kind, p, m, n, pp)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan)
        erased = [1, plan.K - 1]
        surv = [k for k in range(plan.K) if k not in erased]
        mask = np.ones(plan.K)
        mask[erased] = 0
        outs = []
        for backend in LOCAL_BACKENDS:
            b = cm.with_backend(backend)
            for C in (b(A, B, erased=erased), b(A, B, survivors=surv),
                      b(A, B, mask=mask), b(A, B, jnp.asarray(mask))):
                np.testing.assert_array_equal(np.asarray(C), C0,
                                              err_msg=backend)
                outs.append(np.asarray(C))
        for out in outs[1:]:  # bit-identical, not merely both-exact
            np.testing.assert_array_equal(out, outs[0])

    @pytest.mark.parametrize("backend", LOCAL_BACKENDS)
    def test_traced_mask_matches_concrete(self, rng, backend):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, backend)
        mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0])
        C_traced = jax.jit(lambda a, b, m: cm(a, b, mask=m))(A, B, mask)
        C_concrete = cm(A, B, mask=np.asarray(mask))
        np.testing.assert_array_equal(np.asarray(C_traced), C0)
        np.testing.assert_array_equal(np.asarray(C_traced),
                                      np.asarray(C_concrete))

    def test_complex_plan_parity(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1, extra=6, points="unit_circle")
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        outs = [np.asarray(CodedMatmul(plan, b)(A, B, erased=[0, 2, 4]))
                for b in LOCAL_BACKENDS]
        for out in outs:
            np.testing.assert_allclose(out, C0, atol=1e-9)
        for out in outs[1:]:
            np.testing.assert_array_equal(out, outs[0])

    def test_undecodable_raises(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan)
        with pytest.raises(ValueError, match="survivors"):
            cm(A, B, erased=list(range(plan.K - plan.tau + 1)))

    def test_unknown_backend_raises(self, rng):
        plan, _ = _make("bec", 2, 2, 2, 1)
        with pytest.raises(ValueError, match="unknown backend"):
            CodedMatmul(plan, "warp-drive")
        assert set(BACKENDS) == {"reference", "staged", "fused", "mesh"}


class TestJitCompileCache:
    def test_zero_recompiles_after_warmup(self, rng):
        """Serving loop: fresh erasure patterns reuse ONE executable."""
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "fused")
        cm(A, B)  # warm-up compile
        info = cm.cache_info()
        assert info["builds"] == 1
        n_exec = cm.executable_cache_size()
        assert n_exec == 1
        for erased in ([0], [1], [3, 5], [2], [0, 4]):
            np.testing.assert_array_equal(
                np.asarray(cm(A, B, erased=erased)), C0)
        info = cm.cache_info()
        assert info["builds"] == 1, "new erasure patterns must not rebuild"
        assert info["hits"] == 5
        assert cm.executable_cache_size() == n_exec, "jit recompiled"

    def test_cache_key_dimensions(self, rng):
        """backend / shape / erasure-kind each get their own executable."""
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        cm(A, B)
        assert cm.cache_info()["entries"] == 1
        cm(A, B[:, :8])                                    # new shape
        assert cm.cache_info()["entries"] == 2
        jax.jit(lambda a, b, m: cm(a, b, mask=m))(
            A, B, jnp.ones(plan.K))                        # new kind
        assert cm.cache_info()["entries"] == 3
        cm.with_backend("fused")(A, B)                     # new backend
        assert cm.cache_info()["entries"] == 4
        cm(A, B)
        assert cm.cache_info()["entries"] == 4             # all warm

    def test_cache_token_folds_in_executor_config(self):
        """Same backend name, different config -> distinct memo keys."""
        from repro.runtime import MeshExecutor

        class FakeMesh:  # hashable stand-in; make_pipeline is never called
            pass

        m = FakeMesh()
        base = MeshExecutor(m).cache_token()
        assert MeshExecutor(m, use_kernels=False).cache_token() != base
        assert MeshExecutor(m, fused=False).cache_token() != base
        assert MeshExecutor(m, axis="data").cache_token() != base
        assert MeshExecutor(FakeMesh()).cache_token() != base
        assert MeshExecutor(m).cache_token() == base

    def test_with_backend_shares_panel_cache(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        cm(A, B, erased=[1])
        builds = cm.panel_cache.builds
        other = cm.with_backend("fused")
        assert other.panel_cache is cm.panel_cache
        other(A, B, erased=[1])                            # same pattern
        assert cm.panel_cache.builds == builds


class TestBatching:
    @pytest.mark.parametrize("backend", LOCAL_BACKENDS)
    def test_batched_both(self, rng, backend):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        A2, B2, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, backend)
        Cb = cm(jnp.stack([A, A2]), jnp.stack([B, B2]), erased=[1])
        assert Cb.shape == (2, 12, 10)
        np.testing.assert_array_equal(np.asarray(Cb[0]),
                                      np.asarray(cm(A, B, erased=[1])))
        np.testing.assert_array_equal(np.asarray(Cb[1]),
                                      np.asarray(cm(A2, B2, erased=[1])))

    def test_batched_one_side_broadcasts(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        A2, _, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan, "reference")
        Cb = cm(jnp.stack([A, A2]), B, erased=[0])
        np.testing.assert_array_equal(np.asarray(Cb[1]),
                                      np.asarray(cm(A2, B, erased=[0])))
        Cb = cm(A, jnp.stack([B, B]), erased=[0])
        assert Cb.shape == (2, 12, 10)

    def test_two_leading_batch_dims(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        Ab = jnp.broadcast_to(A, (2, 3) + A.shape)
        Bb = jnp.broadcast_to(B, (2, 3) + B.shape)
        C = CodedMatmul(plan)(Ab, Bb)
        assert C.shape == (2, 3, 12, 10)
        np.testing.assert_array_equal(np.asarray(C[1, 2]),
                                      np.asarray(CodedMatmul(plan)(A, B)))

    def test_batch_rank_mismatch_raises(self, rng):
        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, _ = _int_problem(rng, plan, v, 12, 10)
        cm = CodedMatmul(plan)
        with pytest.raises(ValueError, match="batch rank"):
            cm(jnp.broadcast_to(A, (2, 3) + A.shape),
               jnp.broadcast_to(B, (3,) + B.shape))


class TestLegacyShims:
    def test_coded_matmul_warns_and_matches(self, rng):
        from repro.core import coded_matmul

        plan, v = _make("bec", 2, 2, 2, 1)
        A, B, C0 = _int_problem(rng, plan, v, 12, 10)
        with pytest.warns(DeprecationWarning, match="CodedMatmul"):
            C = coded_matmul(A, B, plan, erased=[1], fused=True)
        np.testing.assert_array_equal(np.asarray(C), C0)
        with pytest.raises(ValueError, match="only one"):
            with pytest.warns(DeprecationWarning):
                coded_matmul(A, B, plan, erased=[0], survivors=[1, 2, 3, 4])

    def test_make_plan_validates_s(self):
        with pytest.raises(ValueError, match="s=1.0"):
            make_plan("bec", 2, 2, 2, K=6, L=100, s=1)
        plan = make_plan("bec", 2, 2, 2, K=6, L=100, s=512.0)
        assert isinstance(plan.s, float) and plan.s == 512.0


class TestQuantScale:
    def test_zero_and_tiny_inputs_guarded(self):
        from repro.distributed.coded import _quant_scale

        qmax = 7
        assert float(_quant_scale(jnp.zeros((4, 4)), qmax)) == 1.0
        # tiny but nonzero: the old +1e-9 epsilon would collapse the grid
        x = jnp.full((4, 4), 1e-12)
        s = float(_quant_scale(x, qmax))
        assert float(jnp.round(x / s).max()) == qmax


@pytest.mark.parametrize("scenario", ["parity", "serving", "quant"])
def test_mesh_runtime_child(scenario):
    """Mesh backend scenarios on 8 fake devices (child interpreter)."""
    code = _MESH_CHILD_PROLOGUE + _MESH_CHILD[scenario]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
    assert "OK" in proc.stdout


_MESH_CHILD_PROLOGUE = """
import jax; jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core import make_plan, uncoded_matmul
from repro.runtime import CodedMatmul
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
A = jnp.asarray(rng.integers(-4, 5, size=(64, 48)), jnp.float64)
B = jnp.asarray(rng.integers(-4, 5, size=(64, 40)), jnp.float64)
plan = make_plan("bec", 2, 2, 1, K=4, L=64*4*4+1, points="chebyshev")
C0 = np.asarray(uncoded_matmul(A, B))
cm = CodedMatmul(plan, "mesh", mesh=mesh)
"""

_MESH_CHILD = {
    # all four executors bit-identical, every erasure input form, traced incl.
    "parity": """
for erased in ([], [1], [0, 3]):
    mask = np.ones(4); mask[erased] = 0
    outs = [np.asarray(cm.with_backend(b)(A, B, mask=mask))
            for b in ("mesh", "reference", "staged", "fused")]
    outs.append(np.asarray(cm(A, B, erased=erased)))
    outs.append(np.asarray(cm(A, B, survivors=np.flatnonzero(mask))))
    for out in outs:
        np.testing.assert_array_equal(out, C0, err_msg=str(erased))
mask = jnp.asarray([1., 0., 1., 1.])
C_tr = jax.jit(lambda a, b, m: cm(a, b, mask=m))(A, B, mask)
np.testing.assert_array_equal(np.asarray(C_tr), C0)
jx = str(jax.make_jaxpr(lambda a, b: cm(a, b, mask=np.array([1., 1., 0., 1.])))(A, B))
assert "triangular_solve" not in jx and " lu " not in jx
jx_dyn = str(jax.make_jaxpr(lambda a, b, m: cm(a, b, mask=m))(A, B, mask))
assert "triangular_solve" in jx_dyn or " lu " in jx_dyn
print("OK")
""",
    # serving loop: one executable, zero recompiles across fresh patterns
    "serving": """
cm(A, B)
assert cm.cache_info()["builds"] == 1
n_exec = cm.executable_cache_size()
for erased in ([0], [1], [2], [3], [1, 2]):
    np.testing.assert_array_equal(np.asarray(cm(A, B, erased=erased)), C0)
info = cm.cache_info()
assert info["builds"] == 1 and info["hits"] == 5, info
assert cm.executable_cache_size() == n_exec
Cb = cm(jnp.stack([A, A + 1]), B, erased=[2])   # batched via vmap
assert Cb.shape == (2, 48, 40)
np.testing.assert_array_equal(np.asarray(Cb[0]), C0)
print("OK")
""",
    # CodedLinearPlan: round-trip accuracy vs the float matmul + zero guard
    "quant": """
from repro.distributed.coded import CodedLinearPlan
rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
W = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)
plan_q = make_plan("bec", 2, 2, 1, K=4, L=32*129*129+1, points="chebyshev")
lin = CodedLinearPlan(plan_q, mesh, quant_bits=8, dtype=jnp.float64)
y = lin(x, W, mask=jnp.asarray([1., 0., 1., 1.]))
y_float = x @ W
# quantisation error bound: x = xi*sx + ex with |ex| <= sx/2 (likewise W),
# so |y - y_float| <= d*(sx/2*max|W| + sw/2*max|x| + sx*sw/4) per entry.
qmax = 127
sx = float(jnp.max(jnp.abs(x))) / qmax
sw = float(jnp.max(jnp.abs(W))) / qmax
d = x.shape[1]
bound = d * (sx / 2 * float(jnp.max(jnp.abs(W)))
             + sw / 2 * float(jnp.max(jnp.abs(x))) + sx * sw / 4)
err = float(jnp.max(jnp.abs(y - y_float)))
assert err <= bound, (err, bound)
rel = err / float(jnp.max(jnp.abs(y_float)))
assert rel < 0.05, rel
# all-zero activations: output must be exactly zero, not scale noise
y0 = lin(jnp.zeros_like(x), W)
assert float(jnp.max(jnp.abs(y0))) == 0.0
# tiny activations: signal must survive (old epsilon collapsed it to zero)
yt = lin(x * 1e-12, W)
rel_tiny = float(jnp.max(jnp.abs(yt - y_float * 1e-12)) /
                 jnp.max(jnp.abs(y_float * 1e-12)))
assert rel_tiny < 0.05, rel_tiny
print("OK")
""",
}
