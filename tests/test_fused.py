"""Fused encode+product megakernel and decode-panel cache tests.

Covers the ISSUE-1 acceptance bar:
  * kernel parity vs the staged oracle (all three schemes, f32/f64, ragged
    non-tile-multiple shapes);
  * coded_matmul(fused=True) end-to-end exactness for EVERY erasure pattern
    of size <= K - tau;
  * DecodePanel == masked-solve decode, cache builds once per mask, and the
    panel-based decode jaxpr contains NO factorisation/solve primitives;
  * the on-mesh fused + panel path (subprocess, 8 fake devices).
"""
import itertools
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    coded_matmul,
    decode_masked,
    decode_with_panel,
    make_plan,
    uncoded_matmul,
)
from repro.kernels import ops, ref  # noqa: E402

SRC = str(Path(__file__).resolve().parents[1] / "src")

# (kind, p, m, n, p_prime) - one geometry per scheme family.
SCHEMES = [
    ("bec", 2, 2, 2, 1),
    ("tradeoff", 4, 2, 1, 2),
    ("polycode", 2, 2, 1, 1),
]


def _tol(dtype):
    return {"float32": 1e-4, "float64": 1e-10}[np.dtype(dtype).name]


class TestFusedKernelParity:
    """ops.fused_worker vs the explicit staged oracle."""

    @pytest.mark.parametrize("K,P,Q,v,r,t", [
        (4, 4, 4, 256, 128, 128),
        (6, 8, 2, 300, 200, 150),     # ragged, non-tile-multiple
        (3, 1, 1, 64, 40, 24),
        (1, 5, 3, 129, 257, 65),      # off-by-one everywhere
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_vs_ref(self, rng, K, P, Q, v, r, t, dtype):
        ca = jnp.asarray(rng.normal(size=(K, P)), dtype)
        cb = jnp.asarray(rng.normal(size=(K, Q)), dtype)
        A = jnp.asarray(rng.normal(size=(P, v, r)), dtype)
        B = jnp.asarray(rng.normal(size=(Q, v, t)), dtype)
        out = ops.fused_worker(ca, cb, A, B)
        exp = ref.fused_worker_ref(ca, cb, A, B)
        scale = float(jnp.max(jnp.abs(exp))) + 1e-9
        assert float(jnp.max(jnp.abs(out - exp))) / scale < _tol(dtype)

    def test_complex_falls_back_to_ref(self, rng):
        ca = jnp.asarray(rng.normal(size=(3, 2)) + 1j * rng.normal(size=(3, 2)))
        cb = jnp.asarray(rng.normal(size=(3, 2)) + 1j * rng.normal(size=(3, 2)))
        A = jnp.asarray(rng.normal(size=(2, 32, 16)))
        B = jnp.asarray(rng.normal(size=(2, 32, 8)))
        out = ops.fused_worker(ca, cb, A, B)
        exp = ref.fused_worker_ref(ca, cb, A, B)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-10)


class TestFusedCodedMatmul:
    """coded_matmul(fused=True): exact end-to-end across schemes/erasures."""

    @pytest.mark.parametrize("kind,p,m,n,pp", SCHEMES)
    def test_every_erasure_pattern(self, rng, kind, p, m, n, pp):
        v, r, t = 8 * p, 12, 10
        A = jnp.asarray(rng.integers(-3, 4, size=(v, r)), jnp.float64)
        B = jnp.asarray(rng.integers(-3, 4, size=(v, t)), jnp.float64)
        L = v * 3 * 3 + 1
        # K = tau + 2 so every pattern up to 2 erasures is decodable.
        from repro.core import make_scheme
        tau = make_scheme(kind, p, m, n, p_prime=pp).tau
        K = tau + 2
        plan = make_plan(kind, p, m, n, K=K, L=L, p_prime=pp,
                         points="chebyshev")
        C0 = np.asarray(uncoded_matmul(A, B))
        n_checked = 0
        for sz in range(K - plan.tau + 1):
            for erased in itertools.combinations(range(K), sz):
                C = coded_matmul(A, B, plan, erased=list(erased), fused=True)
                np.testing.assert_array_equal(np.asarray(C), C0, err_msg=str(erased))
                n_checked += 1
        # K - tau = 2: patterns of size 0, 1, 2 -> 1 + K + K(K-1)/2.
        assert n_checked == 1 + K + K * (K - 1) // 2

    @pytest.mark.parametrize("kind,p,m,n,pp", SCHEMES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_fused_matches_staged(self, rng, kind, p, m, n, pp, dtype):
        """Fused == staged pipeline output, float inputs, ragged shapes."""
        v, r, t = 8 * p + 3, 13, 11          # non-multiples of every tile
        A = jnp.asarray(rng.integers(-3, 4, size=(v, r)), dtype)
        B = jnp.asarray(rng.integers(-3, 4, size=(v, t)), dtype)
        L = v * 3 * 3 + 1
        from repro.core import make_scheme
        tau = make_scheme(kind, p, m, n, p_prime=pp).tau
        plan = make_plan(kind, p, m, n, K=tau + 1, L=L, p_prime=pp,
                         points="chebyshev")
        Cf = coded_matmul(A, B, plan, erased=[0], dtype=dtype, fused=True)
        Cs = coded_matmul(A, B, plan, erased=[0], dtype=dtype, fused=False)
        np.testing.assert_array_equal(np.asarray(Cf), np.asarray(Cs))

    def test_unit_circle_plan_falls_back(self, rng):
        """Complex (unit-circle) plans route through the jnp oracle."""
        v, r, t = 16, 12, 10
        A = jnp.asarray(rng.integers(-3, 4, size=(v, r)), jnp.float64)
        B = jnp.asarray(rng.integers(-3, 4, size=(v, t)), jnp.float64)
        plan = make_plan("bec", 2, 2, 2, K=6, L=v * 3 * 3 + 1,
                         points="unit_circle")
        C = coded_matmul(A, B, plan, erased=[1, 3], fused=True)
        np.testing.assert_array_equal(np.asarray(C),
                                      np.asarray(uncoded_matmul(A, B)))


class TestDecodePanel:
    def _setup(self, rng, erased=(1,)):
        v, r, t = 16, 12, 10
        A = jnp.asarray(rng.integers(-3, 4, size=(v, r)), jnp.float64)
        B = jnp.asarray(rng.integers(-3, 4, size=(v, t)), jnp.float64)
        plan = make_plan("bec", 2, 2, 2, K=6, L=v * 3 * 3 + 1,
                         points="chebyshev")
        from repro.core import fused_worker_products
        from repro.core.partition import block_decompose
        g = plan.scheme.grid
        ab = block_decompose(A, g.p, g.m)
        bb = block_decompose(B, g.p, g.n)
        Y = fused_worker_products(plan, ab, bb)
        mask = np.ones(plan.K)
        mask[list(erased)] = 0
        Ym = Y * jnp.asarray(mask)[:, None, None]
        return plan, Ym, mask

    def test_panel_matches_masked_solve(self, rng):
        plan, Y, mask = self._setup(rng)
        cache = plan.make_panel_cache()
        panel = cache.get(mask)
        C_panel = decode_with_panel(plan.scheme, panel, Y, plan.s)
        C_solve = decode_masked(plan.scheme, jnp.asarray(plan.z_points), Y,
                                jnp.asarray(mask), plan.s)
        np.testing.assert_array_equal(np.asarray(C_panel), np.asarray(C_solve))

    def test_cache_builds_once_per_mask(self, rng):
        plan, _, mask = self._setup(rng)
        cache = plan.make_panel_cache()
        p1 = cache.get(mask)
        p2 = cache.get(mask)
        assert p1 is p2 and cache.builds == 1
        mask2 = mask.copy()
        mask2[0] = 0
        cache.get(mask2)
        assert cache.builds == 2
        cache.get(mask)                      # still cached
        assert cache.builds == 2

    def test_panel_decode_jaxpr_has_no_solve(self, rng):
        """The per-step decode with a panel is factorisation-free; the
        dynamic-mask baseline is not (trace-level proof of the cache win)."""
        plan, Y, mask = self._setup(rng)
        panel = plan.make_panel_cache().get(mask)
        jx_panel = str(jax.make_jaxpr(
            lambda y: decode_with_panel(plan.scheme, panel, y, plan.s))(Y))
        for prim in ("lu", "triangular_solve", "inv"):
            assert prim not in jx_panel, prim
        jx_solve = str(jax.make_jaxpr(
            lambda y, m: decode_masked(plan.scheme, jnp.asarray(plan.z_points),
                                       y, m, plan.s))(Y, jnp.asarray(mask)))
        assert "triangular_solve" in jx_solve or "lu" in jx_solve

    def test_undecodable_mask_raises(self, rng):
        plan, _, _ = self._setup(rng)
        bad = np.zeros(plan.K)
        bad[0] = 1
        with pytest.raises(ValueError, match="survivors"):
            plan.make_panel_cache().get(bad)


class TestFusedMesh:
    """On-mesh fused + panel path (child interpreter, 8 fake devices)."""

    def test_fused_panel_mesh_exact_and_solve_free(self):
        code = """
import jax; jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core import make_plan, uncoded_matmul
from repro.distributed.coded import coded_matmul_mesh
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
A = jnp.asarray(rng.integers(-4, 5, size=(64, 48)), jnp.float64)
B = jnp.asarray(rng.integers(-4, 5, size=(64, 40)), jnp.float64)
plan = make_plan("bec", 2, 2, 1, K=4, L=64*4*4+1, points="chebyshev")
C0 = uncoded_matmul(A, B)
cache = plan.make_panel_cache()
for erased in ([], [1], [0, 3]):
    mask = np.ones(4); mask[erased] = 0
    C = coded_matmul_mesh(A, B, plan, mesh, jnp.asarray(mask),
                          fused=True, panel_cache=cache, dtype=jnp.float64)
    assert float(jnp.max(jnp.abs(C - C0))) == 0.0, erased
# repeat a mask: panel reused, not rebuilt
C = coded_matmul_mesh(A, B, plan, mesh, jnp.asarray([1., 0., 1., 1.]),
                      fused=True, panel_cache=cache, dtype=jnp.float64)
assert cache.builds == 3, cache.builds
# the traced mesh computation contains no factorisation/solve for a
# concrete (host-known) mask closed over from outside the trace...
mfix = jnp.asarray([1., 1., 0., 1.])
jx = str(jax.make_jaxpr(lambda a, b: coded_matmul_mesh(
    a, b, plan, mesh, mfix, fused=True,
    panel_cache=cache, dtype=jnp.float64))(A, B))
assert "triangular_solve" not in jx and " lu " not in jx
# ...while a traced (dynamic) mask falls back to the in-body LU solve.
jx_dyn = str(jax.make_jaxpr(lambda a, b, m: coded_matmul_mesh(
    a, b, plan, mesh, m, fused=True,
    panel_cache=cache, dtype=jnp.float64))(A, B, mfix))
assert "triangular_solve" in jx_dyn or " lu " in jx_dyn
print("OK")
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=900)
        assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
        assert "OK" in proc.stdout
