"""Property-based tests (hypothesis) for the coding-scheme invariants.

Invariants checked over randomized geometries and erasure patterns:
  1. exact recovery from any >= tau survivors (unit-circle points);
  2. exponent collision-freedom: useful and interference terms never share
     a (z, s) monomial (the paper's Sec. III-B / IV 'distinctness' claims);
  3. the z-degree equals tau - 1 (threshold = degree + 1);
  4. the digit-extraction bound |sum of negative digits| < 1/2 holds for
     any L and s >= 2L;
  5. encode coefficients are consistent with the exponent tables.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'test' extra (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import coded_matmul, make_plan, make_scheme, uncoded_matmul  # noqa: E402


def geometries():
    return st.tuples(
        st.integers(1, 4),   # p
        st.integers(1, 3),   # m
        st.integers(1, 3),   # n
    )


@st.composite
def tradeoff_geometries(draw):
    p = draw(st.integers(1, 6))
    divisors = [d for d in range(1, p + 1) if p % d == 0]
    pp = draw(st.sampled_from(divisors))
    m = draw(st.integers(1, 3))
    n = draw(st.integers(1, 3))
    return p, m, n, pp


@settings(max_examples=25, deadline=None)
@given(geometries(), st.integers(0, 2 ** 31 - 1))
def test_bec_exact_recovery_any_survivors(geom, seed):
    p, m, n = geom
    rng = np.random.default_rng(seed)
    v = p * 4
    A = rng.integers(-3, 4, size=(v, m * 3)).astype(np.float64)
    B = rng.integers(-3, 4, size=(v, n * 3)).astype(np.float64)
    L = v * 3 * 3 + 1
    sch = make_scheme("bec", p, m, n)
    K = sch.tau + 3
    plan = make_plan("bec", p, m, n, K=K, L=L, points="unit_circle")
    surv = rng.choice(K, size=sch.tau, replace=False).tolist()
    C = coded_matmul(A, B, plan, survivors=surv)
    np.testing.assert_allclose(np.asarray(C), np.asarray(uncoded_matmul(A, B)),
                               atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(tradeoff_geometries())
def test_exponent_collision_freedom(geom):
    """Useful (z, s=0) monomials are hit ONLY by u=v (depth-matched) pairs."""
    p, m, n, pp = geom
    sch = make_scheme("tradeoff", p, m, n, p_prime=pp)
    az, asx = sch.a_exponents()
    bz, bsx = sch.b_exponents()
    useful = set(map(int, sch.useful_z_exp().ravel()))
    # enumerate every product monomial
    for ua in range(p):
        for ia in range(m):
            for ub in range(p):
                for jb in range(n):
                    ze = int(az[ua, ia] + bz[ub, jb])
                    se = int(asx[ua, ia] + bsx[ub, jb])
                    if se == 0 and ze in useful:
                        # must be a depth-matched (contributing) pair
                        assert ua == ub, (geom, ua, ia, ub, jb)


@settings(max_examples=50, deadline=None)
@given(tradeoff_geometries())
def test_degree_matches_threshold(geom):
    p, m, n, pp = geom
    sch = make_scheme("tradeoff", p, m, n, p_prime=pp)
    az, _ = sch.a_exponents()
    bz, _ = sch.b_exponents()
    assert int(az.max() + bz.max()) == sch.tau - 1
    # every useful power is within range
    assert int(sch.useful_z_exp().max()) <= sch.tau - 1
    assert int(sch.useful_z_exp().min()) >= 0


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 10), st.integers(2, 40))
def test_negative_digit_tail_below_half(depth, L):
    """Paper Sec. III-C: |sum_{d<0} * s^d| <= (L-1)/(2L-1) < 1/2."""
    s = 2 * L
    tail = sum((L - 1) * float(s) ** (-d) for d in range(1, depth + 1))
    assert tail < 0.5


@settings(max_examples=30, deadline=None)
@given(tradeoff_geometries(), st.integers(0, 2 ** 31 - 1))
def test_encode_coeffs_match_exponents(geom, seed):
    p, m, n, pp = geom
    sch = make_scheme("tradeoff", p, m, n, p_prime=pp)
    rng = np.random.default_rng(seed)
    z = rng.uniform(-1, 1, size=3)
    s = 8.0
    ca, cb = sch.encode_coeffs(z, s)
    az, asx = sch.a_exponents()
    bz, bsx = sch.b_exponents()
    for k in range(3):
        np.testing.assert_allclose(
            ca[k], (s ** asx.astype(float)) * z[k] ** az, rtol=1e-12)
        np.testing.assert_allclose(
            cb[k], (s ** bsx.astype(float)) * z[k] ** bz, rtol=1e-12)
