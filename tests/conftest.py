"""Shared test fixtures.

NOTE: device count is NOT forced here (smoke tests and benches must see one
device); multi-device tests run in subprocesses (see tests/test_mesh.py).
x64 is enabled per-module where the paper's decode math needs it.
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def chaos_feed():
    """Factory for deterministic ``repro.chaos`` scenario time feeds.

    ``chaos_feed(name, K=12, seed=0, **overrides)`` returns a compiled
    ``TimeFeed`` — ``(step, rng) -> (K,) seconds`` — for the registered
    scenario ``name`` with dataclass-field ``overrides`` applied.  The
    feed is a pure function of ``(scenario, K, seed)``, so any test module
    can share regimes with the control-plane suite and the bench without
    hand-rolling latency feeds.
    """
    from repro.chaos import make_scenario

    def make(name="iid", K=12, seed=0, **overrides):
        return make_scenario(name, **overrides).compile(K, seed=seed)

    return make


@pytest.fixture
def chaos_scenario():
    """Factory for ``repro.chaos`` Scenario objects (uncompiled).

    Use when a test needs the declarative form — ``calm()`` variants,
    ``trace_matrix`` dumps, field overrides — rather than a bare feed.
    """
    from repro.chaos import make_scenario

    return make_scenario
