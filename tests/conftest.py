"""Shared test fixtures.

NOTE: device count is NOT forced here (smoke tests and benches must see one
device); multi-device tests run in subprocesses (see tests/test_mesh.py).
x64 is enabled per-module where the paper's decode math needs it.
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
