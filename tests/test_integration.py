"""Integration tests: HLO analyzer, roofline plumbing, examples smoke."""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch.hlo_analysis import analyze_hlo

ROOT = Path(__file__).resolve().parents[1]


class TestHloAnalysis:
    HLO = """
HloModule test

%body (p: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
  %p = (s32[], f32[8,64]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,64]{1,0} get-tuple-element(%p), index=1
  %all-gather.1 = f32[8,64]{1,0} all-gather(%g1), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %t = (s32[], f32[8,64]) tuple(%g0, %all-gather.1)
}

%cond (p: (s32[], f32[8,64])) -> pred[] {
  %p = (s32[], f32[8,64]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%g0, %c), direction=LT
}

ENTRY %main (a: f32[8,16], b: f32[16,32]) -> f32[8,64] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,32]{1,0} parameter(1)
  %dot.1 = f32[8,32]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,32]{1,0} all-reduce(%dot.1), replica_groups={{0,1},{2,3}}, to_apply=%add
  %init = (s32[], f32[8,64]) tuple()
  %w = (s32[], f32[8,64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[8,64]{1,0} get-tuple-element(%w), index=1
}
"""

    def test_collective_bytes_with_trip_counts(self):
        s = analyze_hlo(self.HLO)
        # all-gather inside while x7: 8*64*4 bytes * (4-1)/4 * 7
        expect_ag = 8 * 64 * 4 * 0.75 * 7
        assert s.bytes_by_kind["all-gather"] == pytest.approx(expect_ag)
        # all-reduce at entry: 8*32*4 * 2*(2-1)/2
        assert s.bytes_by_kind["all-reduce"] == pytest.approx(8 * 32 * 4 * 1.0)

    def test_dot_flops(self):
        s = analyze_hlo(self.HLO)
        assert s.dot_flops == pytest.approx(2 * 8 * 32 * 16)

    def test_lhs_name_collision_not_double_counted(self):
        s = analyze_hlo(self.HLO)
        assert s.count_by_kind["all-gather"] == 1
        assert s.count_by_kind["all-reduce"] == 1


class TestRoofline:
    def test_roofline_row_math(self, tmp_path):
        from benchmarks.roofline import roofline_row
        cell = {
            "arch": "qwen3_0_6b", "shape": "train_4k", "multi_pod": False,
            "n_devices": 256, "compile_s": 1.0,
            "dot_flops": 4.8e13, "hbm_bytes": 1.1e12,
            "cost": {"flops": 2e12, "bytes accessed": 1.1e11},
            "collectives": {"total_bytes": 1.5e11},
            "memory": {"argument_bytes": 8e10, "temp_bytes": 5e9},
        }
        r = roofline_row(cell)
        assert r["dominant"] == "collective"
        assert 0 < r["roofline_fraction"] < 1
        assert r["compute_s"] == pytest.approx(4.8e13 / 197e12)


class TestExamples:
    def _run(self, script, timeout=1500, extra=()):
        env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
        import os
        env.update({k: v for k, v in os.environ.items()
                    if k not in ("XLA_FLAGS",)})
        env["PYTHONPATH"] = str(ROOT / "src")
        p = subprocess.run([sys.executable, str(ROOT / "examples" / script),
                            *extra],
                           capture_output=True, text=True, timeout=timeout,
                           env=env)
        assert p.returncode == 0, f"{script}:\n{p.stdout}\n{p.stderr}"
        return p.stdout

    def test_quickstart(self):
        out = self._run("quickstart.py")
        assert "exact recovery" in out

    def test_train_lm_quick(self):
        out = self._run("train_lm.py", extra=("--quick",))
        assert "learned successfully" in out
