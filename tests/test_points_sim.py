"""Evaluation points, Vandermonde conditioning, and the straggler simulator."""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import LatencyModel, make_points, simulate_completion  # noqa: E402
from repro.core.vandermonde import (  # noqa: E402
    inverse_vandermonde,
    vandermonde,
)


class TestPoints:
    def test_kinds_distinct(self):
        for kind in ("equispaced", "chebyshev", "unit_circle"):
            z = make_points(kind, 10)
            assert len(np.unique(np.round(z, 12))) == 10

    def test_equispaced_matches_paper(self):
        z = make_points("equispaced", 10)
        assert z[0] == -1.0 and z[-1] == 1.0
        np.testing.assert_allclose(np.diff(z), 2 / 9)

    def test_unit_circle_modulus(self):
        z = make_points("unit_circle", 8)
        np.testing.assert_allclose(np.abs(z), 1.0)

    def test_conditioning_ordering(self):
        """cheb < equispaced condition number; unit circle ~ 1 (paper Sec. V)."""
        K = 12
        conds = {}
        for kind in ("equispaced", "chebyshev", "unit_circle"):
            V = vandermonde(make_points(kind, K), K)
            conds[kind] = np.linalg.cond(V)
        assert conds["chebyshev"] < conds["equispaced"]
        assert conds["unit_circle"] < 10  # DFT-like
        assert conds["unit_circle"] < conds["chebyshev"]


class TestInverseVandermonde:
    def test_matches_inv(self):
        z = make_points("chebyshev", 7)
        W = inverse_vandermonde(z)
        V = vandermonde(z, 7)
        np.testing.assert_allclose(W @ V, np.eye(7), atol=1e-9)

    def test_lagrange_beats_lu_on_clustered_points(self):
        """Beyond-paper: explicit Lagrange inverse is more accurate than LU
        on clustered real nodes (the decode path uses it for static sets)."""
        z = make_points("chebyshev", 24)[:10]  # clustered subset
        V = vandermonde(z, 10)
        W = inverse_vandermonde(z)
        x = np.random.default_rng(0).normal(size=10)
        y = V @ x
        err_lagrange = np.abs(W @ y - x).max()
        err_lu = np.abs(np.linalg.solve(V, y) - x).max()
        assert err_lagrange <= err_lu * 10  # at least comparable
        assert err_lagrange < 1e-4


class TestSimulator:
    def test_threshold_latency_flat_then_jump(self):
        """Paper Fig. 1 shape: tau=4, K=10 -> flat for S <= 6, jump at 7."""
        model = LatencyModel(base=1.0, straggler_slowdown=2.0)
        med = {}
        for S in (0, 2, 4, 6, 7, 8):
            lat = simulate_completion(10, 4, S, model, trials=50, seed=1)
            med[S] = float(np.median(lat))
        assert med[0] == med[2] == med[4] == med[6] == 1.0
        assert med[7] == 2.0 and med[8] == 2.0

    def test_baseline_degrades_earlier(self):
        """tau=9 (polycode): ANY 2 stragglers already hurt (paper Fig. 1)."""
        model = LatencyModel(base=1.0, straggler_slowdown=2.0)
        lat = simulate_completion(10, 9, 2, model, trials=50, seed=2)
        assert float(np.median(lat)) == 2.0

    def test_survivor_set(self):
        from repro.core import WorkerTimes
        wt = WorkerTimes(np.array([5.0, 1.0, 3.0, 2.0]))
        assert wt.survivors_at_threshold(2).tolist() == [1, 3]


class TestSimulatorProperties:
    """Order-statistic invariants of the straggler model (control-plane
    contract: the expected-latency policy builds on exactly these)."""

    def _times(self, K=10, seed=0):
        from repro.core import WorkerTimes
        return WorkerTimes(np.random.default_rng(seed).exponential(1.0, K))

    def test_completion_monotone_in_tau(self):
        for seed in range(5):
            wt = self._times(seed=seed)
            lats = [wt.completion_for_threshold(tau) for tau in range(1, 11)]
            assert all(a <= b for a, b in zip(lats, lats[1:]))

    def test_survivors_consistent_with_finish_order(self):
        """The tau survivors are the tau smallest finish times, and the
        slowest of them IS the completion latency."""
        for seed in range(5):
            wt = self._times(seed=seed)
            for tau in (1, 4, 10):
                surv = wt.survivors_at_threshold(tau)
                assert len(set(surv.tolist())) == tau
                cutoff = wt.completion_for_threshold(tau)
                assert wt.finish[surv].max() == cutoff
                others = np.setdiff1d(np.arange(10), surv)
                if others.size:
                    assert wt.finish[others].min() >= cutoff

    def test_jitter_path_deterministic_under_seed(self):
        model = LatencyModel(base=1.0, straggler_slowdown=2.0, jitter=0.3)
        a = simulate_completion(10, 4, 3, model, trials=40, seed=7)
        b = simulate_completion(10, 4, 3, model, trials=40, seed=7)
        np.testing.assert_array_equal(a, b)
        c = simulate_completion(10, 4, 3, model, trials=40, seed=8)
        assert not np.array_equal(a, c)

    def test_per_worker_base_and_validation(self):
        base = np.linspace(1.0, 2.0, 10)
        model = LatencyModel(base=base, straggler_slowdown=3.0)
        t = model.sample(10, [0], np.random.default_rng(0))
        np.testing.assert_allclose(t[1:], base[1:])
        assert t[0] == 3.0
        with pytest.raises(ValueError):
            model.sample(8, [], np.random.default_rng(0))

    def test_injectable_feed_overrides_model(self, chaos_feed):
        """A repro.chaos scenario feed drives the Fig. 1 protocol: the
        model argument is ignored, trials replay the scenario's seeded
        steps, and the tau-th order statistic + decode time comes out."""
        feed = chaos_feed("heavy_tail", K=10, seed=5)
        lat = simulate_completion(10, 4, 0, None, decode_time=0.5, trials=6,
                                  feed=feed)
        again = simulate_completion(10, 4, 0, None, decode_time=0.5, trials=6,
                                    feed=feed)
        np.testing.assert_array_equal(lat, again)  # scenario feeds are seeded
        expect = [np.sort(feed(t, None))[3] + 0.5 for t in range(6)]
        np.testing.assert_allclose(lat, expect)
        with pytest.raises(ValueError):
            simulate_completion(10, 4, 0, None)  # neither model nor feed

    def test_scenario_feed_pool_shrink_ignores_departed(self, chaos_feed):
        """Beyond-paper: under a pool-shrink regime the async master at a
        low tau never waits for departed workers, so completion stays at
        the healthy level before AND after the departure step."""
        feed = chaos_feed("pool_resize", K=10, seed=2, num_arriving=0,
                          healthy_jitter=0.0)
        lat = simulate_completion(10, 4, 0, None, trials=16, feed=feed)
        assert lat.max() < 2.0  # departed workers never in the first 4

    def test_masked_completion_bridges_sync_and_async(self):
        """Erasing the K - tau slowest makes the synchronous step complete
        exactly at the tau-th order statistic (the control-plane identity)."""
        from repro.core import WorkerTimes
        wt = self._times(seed=3)
        tau = 4
        mask = np.ones(10)
        mask[np.argsort(wt.finish)[tau:]] = 0.0
        assert wt.completion_with_mask(mask) == wt.completion_for_threshold(tau)
        # a sloppier mask can only wait longer
        assert wt.completion_with_mask(np.ones(10)) >= \
            wt.completion_for_threshold(tau)
        with pytest.raises(ValueError):
            wt.completion_with_mask(np.zeros(10))

    def test_completion_cdf_and_quantile(self):
        from repro.core.simulator import completion_cdf, completion_quantile
        lat = np.array([1.0, 2.0, 3.0, 4.0])
        np.testing.assert_allclose(
            completion_cdf(lat, np.array([0.5, 1.0, 2.5, 4.0])),
            [0.0, 0.25, 0.5, 1.0])
        assert completion_quantile(lat, 0.5) == 2.5


class TestMaskedCompletionDistribution:
    """Closed-form masked completion CDF/quantile under a LatencyModel."""

    def _model(self, K=6):
        base = np.linspace(1.0, 2.0, K)
        jitter = np.full(K, 0.3)
        return LatencyModel(base=base, straggler_slowdown=1.0, jitter=jitter)

    def test_matches_empirical(self):
        from repro.core.simulator import masked_completion_quantile
        model = self._model()
        mask = np.array([1, 1, 0, 1, 1, 0], dtype=float)
        rng = np.random.default_rng(0)
        keep = mask.astype(bool)
        samples = np.array([model.sample(6, (), rng)[keep].max()
                            for _ in range(40000)])
        for q in (0.1, 0.5, 0.9, 0.99):
            analytic = masked_completion_quantile(model, mask, q)
            empirical = float(np.quantile(samples, q))
            assert abs(analytic - empirical) < 0.05 * max(empirical, 1.0)

    def test_analytic_mean_matches_empirical(self):
        from repro.core.simulator import masked_completion_mean
        model = self._model()
        mask = np.array([1, 0, 1, 1, 0, 1], dtype=float)
        rng = np.random.default_rng(1)
        keep = mask.astype(bool)
        samples = np.array([model.sample(6, (), rng)[keep].max()
                            for _ in range(40000)])
        assert masked_completion_mean(model, mask) == pytest.approx(
            samples.mean(), rel=0.02)
        det = LatencyModel(base=np.linspace(1.0, 2.0, 6),
                           straggler_slowdown=1.0, jitter=0.0)
        assert masked_completion_mean(det, np.ones(6)) == 2.0

    def test_q_zero_is_essential_min(self):
        from repro.core.simulator import masked_completion_quantile
        model = self._model()
        # q=0: nothing has finished before the slowest kept worker's base
        assert masked_completion_quantile(model, np.ones(6), 0.0) == 2.0
        mask = np.array([1, 1, 1, 0, 0, 0], dtype=float)
        assert masked_completion_quantile(model, mask, 0.0) == pytest.approx(1.4)

    def test_q_one_unbounded_iff_jitter(self):
        from repro.core.simulator import masked_completion_quantile
        assert masked_completion_quantile(self._model(), np.ones(6), 1.0) == np.inf
        det = LatencyModel(base=np.linspace(1.0, 2.0, 6),
                           straggler_slowdown=1.0, jitter=0.0)
        # deterministic: every quantile collapses to the kept max base
        for q in (0.0, 0.5, 1.0):
            assert masked_completion_quantile(det, np.ones(6), q) == 2.0

    def test_single_worker(self):
        from repro.core.simulator import (
            masked_completion_cdf,
            masked_completion_quantile,
        )
        model = LatencyModel(base=2.0, straggler_slowdown=1.0, jitter=0.5)
        mask = np.ones(1)
        # exact shifted-exponential quantile: base + scale * (-ln(1-q))
        q = 0.9
        expect = 2.0 + 1.0 * (-np.log(1 - q))
        assert masked_completion_quantile(model, mask, q) == pytest.approx(expect)
        assert masked_completion_cdf(model, mask, expect) == pytest.approx(q)
        assert masked_completion_cdf(model, mask, 1.9) == 0.0

    def test_saturated_budget_mask(self):
        """Erasing all but one worker: the distribution IS that worker's."""
        from repro.core.simulator import masked_completion_quantile
        model = self._model()
        mask = np.zeros(6)
        mask[0] = 1.0  # base 1.0, scale 0.3
        q = 0.5
        expect = 1.0 + 0.3 * (-np.log(1 - q))
        assert masked_completion_quantile(model, mask, q) == pytest.approx(
            expect, rel=1e-6)

    def test_all_erased_and_bad_q_raise(self):
        from repro.core.simulator import masked_completion_quantile
        with pytest.raises(ValueError):
            masked_completion_quantile(self._model(), np.zeros(6), 0.5)
        with pytest.raises(ValueError):
            masked_completion_quantile(self._model(), np.ones(6), 1.5)

    def test_cdf_vectorised_and_monotone(self):
        from repro.core.simulator import masked_completion_cdf
        model = self._model()
        ts = np.linspace(0.0, 10.0, 50)
        F = masked_completion_cdf(model, np.ones(6), ts)
        assert F.shape == ts.shape
        assert np.all(np.diff(F) >= 0)
        assert F[0] == 0.0 and F[-1] > 0.99

    def test_per_worker_jitter_sampling(self):
        """A (K,)-jitter vector perturbs exactly the jittered workers."""
        jitter = np.array([0.0, 0.0, 1.0])
        model = LatencyModel(base=1.0, straggler_slowdown=1.0, jitter=jitter)
        t = model.sample(3, (), np.random.default_rng(0))
        np.testing.assert_allclose(t[:2], 1.0)
        assert t[2] > 1.0
        assert model.has_jitter
        with pytest.raises(ValueError):
            model.jitter_vector(5)
