"""Evaluation points, Vandermonde conditioning, and the straggler simulator."""
import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import LatencyModel, make_points, simulate_completion  # noqa: E402
from repro.core.vandermonde import (  # noqa: E402
    inverse_vandermonde,
    vandermonde,
)


class TestPoints:
    def test_kinds_distinct(self):
        for kind in ("equispaced", "chebyshev", "unit_circle"):
            z = make_points(kind, 10)
            assert len(np.unique(np.round(z, 12))) == 10

    def test_equispaced_matches_paper(self):
        z = make_points("equispaced", 10)
        assert z[0] == -1.0 and z[-1] == 1.0
        np.testing.assert_allclose(np.diff(z), 2 / 9)

    def test_unit_circle_modulus(self):
        z = make_points("unit_circle", 8)
        np.testing.assert_allclose(np.abs(z), 1.0)

    def test_conditioning_ordering(self):
        """cheb < equispaced condition number; unit circle ~ 1 (paper Sec. V)."""
        K = 12
        conds = {}
        for kind in ("equispaced", "chebyshev", "unit_circle"):
            V = vandermonde(make_points(kind, K), K)
            conds[kind] = np.linalg.cond(V)
        assert conds["chebyshev"] < conds["equispaced"]
        assert conds["unit_circle"] < 10  # DFT-like
        assert conds["unit_circle"] < conds["chebyshev"]


class TestInverseVandermonde:
    def test_matches_inv(self):
        z = make_points("chebyshev", 7)
        W = inverse_vandermonde(z)
        V = vandermonde(z, 7)
        np.testing.assert_allclose(W @ V, np.eye(7), atol=1e-9)

    def test_lagrange_beats_lu_on_clustered_points(self):
        """Beyond-paper: explicit Lagrange inverse is more accurate than LU
        on clustered real nodes (the decode path uses it for static sets)."""
        z = make_points("chebyshev", 24)[:10]  # clustered subset
        V = vandermonde(z, 10)
        W = inverse_vandermonde(z)
        x = np.random.default_rng(0).normal(size=10)
        y = V @ x
        err_lagrange = np.abs(W @ y - x).max()
        err_lu = np.abs(np.linalg.solve(V, y) - x).max()
        assert err_lagrange <= err_lu * 10  # at least comparable
        assert err_lagrange < 1e-4


class TestSimulator:
    def test_threshold_latency_flat_then_jump(self):
        """Paper Fig. 1 shape: tau=4, K=10 -> flat for S <= 6, jump at 7."""
        model = LatencyModel(base=1.0, straggler_slowdown=2.0)
        med = {}
        for S in (0, 2, 4, 6, 7, 8):
            lat = simulate_completion(10, 4, S, model, trials=50, seed=1)
            med[S] = float(np.median(lat))
        assert med[0] == med[2] == med[4] == med[6] == 1.0
        assert med[7] == 2.0 and med[8] == 2.0

    def test_baseline_degrades_earlier(self):
        """tau=9 (polycode): ANY 2 stragglers already hurt (paper Fig. 1)."""
        model = LatencyModel(base=1.0, straggler_slowdown=2.0)
        lat = simulate_completion(10, 9, 2, model, trials=50, seed=2)
        assert float(np.median(lat)) == 2.0

    def test_survivor_set(self):
        from repro.core import WorkerTimes
        wt = WorkerTimes(np.array([5.0, 1.0, 3.0, 2.0]))
        assert wt.survivors_at_threshold(2).tolist() == [1, 3]
