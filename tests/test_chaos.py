"""Scenario DSL, trace record/replay, golden regressions, feedback law."""
from pathlib import Path

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

from repro.chaos import (  # noqa: E402
    Scenario,
    Trace,
    TraceRecorder,
    make_scenario,
    scenario_names,
    trace_matrix,
    verify_replay,
)
from repro.chaos.golden import (  # noqa: E402
    GOLDEN_K,
    golden_names,
    golden_trace,
    replay_golden,
)
from repro.control import WorkerHealthMonitor  # noqa: E402
from repro.control.feedback import FeedbackConfig, ViolationFeedback  # noqa: E402

K = 12
STEPS = 16
GOLDEN_DIR = Path(__file__).parent / "golden"

ARCHETYPES = ("iid", "heavy_tail", "pareto", "bursty", "flapping", "rack",
              "pool_resize", "crawler", "degrading")


class TestScenarioDSL:
    def test_catalog_registered(self):
        assert set(ARCHETYPES) <= set(scenario_names())
        with pytest.raises(KeyError):
            make_scenario("thundering_herd")

    def test_overrides_and_frozen(self):
        sc = make_scenario("heavy_tail", num_stragglers=5, heavy_jitter=2.0)
        assert sc.num_stragglers == 5 and sc.heavy_jitter == 2.0
        with pytest.raises(Exception):  # frozen dataclass
            sc.num_stragglers = 1

    @pytest.mark.parametrize("name", ARCHETYPES)
    def test_seeded_scenarios_reproducible(self, name):
        """Property: the compiled feed is a pure function of (K, seed)."""
        sc = make_scenario(name)
        a = trace_matrix(sc, K, STEPS, seed=3)
        b = trace_matrix(sc, K, STEPS, seed=3)
        c = trace_matrix(sc, K, STEPS, seed=4)
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)
        assert a.shape == (STEPS, K)
        assert np.all(np.isfinite(a)) and np.all(a > 0)

    @pytest.mark.parametrize("name", ARCHETYPES)
    def test_calm_variant_flags_nobody(self, name):
        """The calm() control: the monitor never flags a straggler."""
        feed = make_scenario(name).calm().compile(K, seed=3)
        mon = WorkerHealthMonitor(K)
        for step in range(8):
            mon.record_step(feed(step, None))
        assert mon.stragglers().size == 0

    def test_heavy_tail_monitor_flags_slow_set(self, chaos_feed):
        feed = chaos_feed("heavy_tail", K=K, seed=3)
        mon = WorkerHealthMonitor(K)
        for step in range(10):
            mon.record_step(feed(step, None))
        assert mon.stragglers().size == 3  # num_stragglers default

    def test_rack_failure_degrades_one_rack_together(self, chaos_scenario):
        sc = chaos_scenario("rack", healthy_jitter=0.0, rack_jitter=0.0)
        before = sc.times(sc.fail_step - 1, K, seed=5)
        after = sc.times(sc.fail_step, K, seed=5)
        slowed = np.flatnonzero(after > 2.0 * before)
        assert slowed.size == K // sc.racks  # the whole rack, at once
        assert len({int(w) % sc.racks for w in slowed}) == 1  # same rack

    def test_pool_resize_departures_and_arrivals(self, chaos_scenario):
        sc = chaos_scenario("pool_resize", healthy_jitter=0.0)
        pre = sc.times(0, K, seed=1)       # arrivals not joined yet
        mid = sc.times(sc.join_step, K, seed=1)   # everyone present
        post = sc.times(sc.depart_step, K, seed=1)  # departures gone
        assert (pre > 10).sum() == sc.num_arriving
        assert (mid > 10).sum() == 0
        assert (post > 10).sum() == sc.num_departing

    def test_crawler_set_is_persistent(self, chaos_scenario):
        """The crawler set is seed-fixed and slow at every step."""
        sc = chaos_scenario("crawler", healthy_jitter=0.0, crawl_jitter=0.0)
        early = sc.times(0, K, seed=2)
        late = sc.times(40, K, seed=2)
        slow = np.flatnonzero(early > 1.5 * sc.base)
        assert slow.size == sc.num_crawlers
        np.testing.assert_array_equal(
            slow, np.flatnonzero(late > 1.5 * sc.base))

    def test_degrading_ramp_monotone_then_capped(self, chaos_scenario):
        """Degrading workers slow down over steps until max_factor caps."""
        sc = chaos_scenario("degrading", healthy_jitter=0.0,
                            degrade_jitter=0.0)
        victims = np.flatnonzero(sc.times(100, K, seed=4) > 2.0 * sc.base)
        assert victims.size == sc.num_degrading
        v = victims[0]
        ramp = [sc.times(s, K, seed=4)[v] for s in (0, 10, 20, 100, 200)]
        assert all(a <= b + 1e-12 for a, b in zip(ramp, ramp[1:]))
        # the cap: deep into the run the factor stops growing
        assert ramp[-1] == pytest.approx(ramp[-2])
        assert ramp[-1] <= sc.max_factor * sc.base + 1e-9

    def test_compile_validates(self):
        with pytest.raises(ValueError):
            make_scenario("iid").compile(0)

        class Broken(Scenario):
            def times(self, step, K, seed):
                return np.zeros(K - 1)

        with pytest.raises(ValueError):
            Broken().compile(4)(0, None)
        with pytest.raises(NotImplementedError):
            Scenario().times(0, 4, 0)


class TestTraceRoundTrip:
    def _small_trace(self, tmp_path=None):
        trace = golden_trace("heavy_tail", steps=6)
        if tmp_path is None:
            return trace
        return Trace.load(trace.save(tmp_path / "t.jsonl"))

    def test_jsonl_roundtrip_bit_exact(self, tmp_path):
        trace = self._small_trace()
        loaded = Trace.load(trace.save(tmp_path / "t.jsonl"))
        assert loaded == trace  # dataclass equality: every float bit-equal

    def test_header_validation(self, tmp_path):
        p = tmp_path / "bad.jsonl"
        p.write_text('{"kind": "step"}\n')
        with pytest.raises(ValueError):
            Trace.load(p)
        p.write_text("")
        with pytest.raises(ValueError):
            Trace.load(p)

    def test_replay_feed_is_verbatim_and_bounded(self):
        trace = self._small_trace()
        feed = trace.feed()
        for s in trace.steps:
            np.testing.assert_array_equal(feed(s.step, None),
                                          np.asarray(s.times))
        with pytest.raises(IndexError):
            feed(len(trace.steps), None)

    def test_recorder_requires_recorded_steps(self):
        trace = self._small_trace()
        rec = TraceRecorder(lambda step, rng: np.ones(GOLDEN_K), GOLDEN_K)
        with pytest.raises(ValueError):
            rec.finish([_report_like(trace.steps[0])])

    def test_diff_catches_divergence(self):
        trace = self._small_trace()
        reports = [_report_like(s) for s in trace.steps]
        assert trace.diff(reports) == []
        tampered = list(reports)
        import dataclasses

        tampered[2] = dataclasses.replace(tampered[2], rung="polycode",
                                          sim_latency_s=999.0)
        diffs = trace.diff(tampered)
        assert any("rung" in d for d in diffs)
        assert any("sim_latency_s" in d for d in diffs)
        with pytest.raises(AssertionError):
            verify_replay(trace, tampered)
        assert len(trace.diff(reports[:-1])) == 1  # step-count mismatch


class TestReplayDeterminism:
    @pytest.mark.parametrize("key", ["heavy_tail", "pool_resize",
                                     "pareto_feedback", "crawler_partial"])
    def test_replay_reproduces_run_bit_exactly(self, key):
        """The tentpole contract: record a run, rebuild the server from
        scratch, replay the recorded times — identical rung choices,
        masks, latencies, tails, and feedback quantiles."""
        trace = golden_trace(key, steps=8)
        reports = replay_golden(key, trace)
        verify_replay(trace, reports)

    def test_replay_exercises_switches(self):
        """The replayed stream must actually contain control decisions
        (otherwise the determinism assertion is vacuous)."""
        trace = golden_trace("heavy_tail", steps=8)
        assert any(s.switched for s in trace.steps)
        assert any(s.erased for s in trace.steps)

    @pytest.mark.parametrize("key", ["pool_resize_shrink",
                                     "pool_resize_grow"])
    def test_elastic_replay_reproduces_handoff(self, key):
        """Elastic record/replay: the executed shrink (and grow) handoff —
        pool membership, rung re-lowering, exactness — replays bit-exactly
        through a freshly built elastic server."""
        trace = golden_trace(key)  # default steps cover shrink AND grow
        pools = {s.pool for s in trace.steps}
        assert len(pools) >= (3 if key == "pool_resize_grow" else 2)
        assert any(s.respecialize for s in trace.steps)
        assert all(s.exact for s in trace.steps)
        reports = replay_golden(key, trace)
        verify_replay(trace, reports)


class TestGoldenTraces:
    """Drift check: today's control plane vs. the checked-in recordings.

    On an INTENDED behaviour change, regenerate via
    ``PYTHONPATH=src python scripts/regen_golden_traces.py`` and commit
    the diff.
    """

    @pytest.mark.parametrize("key", golden_names())
    def test_matches_checked_in_golden(self, key):
        path = GOLDEN_DIR / f"{key}.jsonl"
        assert path.exists(), f"missing golden trace {path}; regenerate"
        golden = Trace.load(path)
        fresh = golden_trace(key)
        mismatches = fresh.diff([_report_like(s) for s in golden.steps])
        for s_new, s_old in zip(fresh.steps, golden.steps):
            if s_new.times != s_old.times:
                mismatches.append(f"step {s_new.step}: scenario times drifted")
        assert not mismatches, (
            "golden trace drift (run scripts/regen_golden_traces.py if "
            "intended):\n  " + "\n  ".join(mismatches))

    def test_catalog_covers_at_least_four_archetypes(self):
        assert len(golden_names()) >= 4
        assert set(golden_names()) >= {"iid", "heavy_tail", "bursty", "rack",
                                       "crawler", "degrading",
                                       "crawler_partial"}

    def test_elastic_goldens_pin_the_handoff(self):
        """The checked-in elastic pair must contain the REAL transitions:
        shrink drops members and re-lowers the rung; the grow variant then
        readmits the joiners (appended at the tail, on extended points)
        and returns to the low-overhead rung — every step exact."""
        shrink = Trace.load(GOLDEN_DIR / "pool_resize_shrink.jsonl")
        grow = Trace.load(GOLDEN_DIR / "pool_resize_grow.jsonl")
        for golden in (shrink, grow):
            assert all(s.pool is not None for s in golden.steps)
            assert all(s.exact for s in golden.steps)
        first, last = shrink.steps[0].pool, shrink.steps[-1].pool
        assert len(last) < len(first)
        assert set(last) < set(first)  # survivors only, order preserved
        assert shrink.steps[0].rung != shrink.steps[-1].rung  # re-lowered
        mid = next(s for s in grow.steps if len(s.pool) <
                   len(grow.steps[0].pool))
        final = grow.steps[-1].pool
        assert len(final) > len(mid.pool)  # grew back
        assert final[:len(mid.pool)] == mid.pool  # joiners appended at end
        assert grow.steps[-1].rung == grow.steps[0].rung  # rung recovered

    def test_crawler_partial_golden_consumes_fractions(self):
        """The partial variant must actually emit FRACTIONAL progress —
        some worker consumed at a strict fraction (not just 0/1 masking) —
        and every step must decode exactly."""
        golden = Trace.load(GOLDEN_DIR / "crawler_partial.jsonl")
        assert all(s.progress is not None for s in golden.steps)
        fractions = [x for s in golden.steps for x in s.progress
                     if 0.0 < x < 1.0]
        assert fractions, "no step consumed a strict fraction of a worker"
        assert all(s.exact for s in golden.steps)


class TestFeedbackLaw:
    def _rate(self, violations, window=8, **cfg):
        """A feedback tracker whose window holds ``violations`` misses."""
        fb = ViolationFeedback(0.95, 1.0, FeedbackConfig(
            window=window, min_observations=window, **cfg))
        for i in range(window):
            fb.observe(2.0 if i < violations else 0.5)
        return fb

    def test_q_monotone_in_realized_violation_rate(self):
        """Property: effective q never decreases as the realized rate
        rises (the control law is monotone)."""
        for cfg in ({}, {"q_min": 0.5}, {"gain": 5.0}):
            qs = [self._rate(v, **cfg).effective_q() for v in range(9)]
            assert all(a <= b for a, b in zip(qs, qs[1:])), cfg
            assert qs[-1] == 0.999   # saturated window clips at q_max

    def test_loosening_floors_at_base_unless_opted_in(self):
        """A clean window never drops q below the SLO's own quantile by
        default; an explicit q_min opts in to below-base loosening."""
        assert self._rate(0).effective_q() == 0.95
        assert self._rate(0, q_min=0.5).effective_q() < 0.95

    def test_holds_base_until_min_observations(self):
        fb = ViolationFeedback(0.95, 1.0, FeedbackConfig(min_observations=4))
        for _ in range(3):
            fb.observe(5.0)
            assert fb.effective_q() == 0.95
        fb.observe(5.0)
        assert fb.effective_q() > 0.95

    def test_force_tail_optimal_after_consecutive_misses(self):
        fb = ViolationFeedback(0.99, 1.0, FeedbackConfig(force_after=3))
        for _ in range(2):
            fb.observe(2.0)
        assert not fb.force_tail_optimal
        fb.observe(2.0)
        assert fb.force_tail_optimal
        fb.observe(0.5)  # one clean step resets the run
        assert not fb.force_tail_optimal

    def test_window_slides(self):
        fb = ViolationFeedback(0.95, 1.0, FeedbackConfig(
            window=4, min_observations=1))
        for _ in range(4):
            fb.observe(2.0)
        assert fb.realized_rate == 1.0
        for _ in range(4):
            fb.observe(0.5)
        assert fb.realized_rate == 0.0
        assert fb.violations == 4 and fb.observations == 8

    def test_validation(self):
        with pytest.raises(ValueError):
            ViolationFeedback(0.0, 1.0)
        with pytest.raises(ValueError):
            ViolationFeedback(0.99, -1.0)
        with pytest.raises(ValueError):
            FeedbackConfig(window=0)
        with pytest.raises(ValueError):
            FeedbackConfig(q_min=0.9, q_max=0.5)
        with pytest.raises(ValueError):
            FeedbackConfig(target_rate=2.0)
        with pytest.raises(ValueError):
            # a window that can never hold min_observations would leave
            # the law at q_base forever
            FeedbackConfig(window=4, min_observations=8)
        with pytest.raises(ValueError):
            # clip range collapses: the law could never tighten
            ViolationFeedback(0.9995, 1.0)


def _report_like(step):
    """A StepReport carrying a TraceStep's compared fields (wall_ms 0)."""
    from repro.control import StepReport

    return StepReport(
        step=step.step, rung=step.rung, switched=step.switched,
        erased=step.erased, sim_latency_s=step.sim_latency_s, wall_ms=0.0,
        slack=step.slack, respecialize=step.respecialize,
        shrink_target=step.shrink_target, exact=step.exact,
        slo_violation=step.slo_violation,
        predicted_tail_s=step.predicted_tail_s, realized_s=step.realized_s,
        realized_violation=step.realized_violation,
        q_effective=step.q_effective, progress=step.progress,
        threshold_effective=step.threshold_effective,
        span_id=step.span_id, pool=step.pool)
