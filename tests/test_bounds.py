"""Tests for the precision/threshold tradeoff policy (paper Sec. III-D, IV)."""

from repro.core import bounds
from repro.core.schemes import make_scheme


class TestBasics:
    def test_conservative_L_matches_paper(self):
        # paper Sec. V: v=8000, entries in {0..50} -> L = 8000*50*50 + 1
        assert bounds.conservative_L(8000, 50, 50) == 20_000_001

    def test_choose_s_power_of_two(self):
        s = bounds.choose_s(100)
        assert s >= 200 and (s & (s - 1)) == 0

    def test_table1_s_values(self):
        """Paper Table I: L -> s mapping (s = 2^ceil(log2(2L)) with
        L = v*bound^2+1, v=8000)."""
        for bound, expected_s in [(100, 2 ** 28), (200, 2 ** 30),
                                  (500, 2 ** 32), (1000, 2 ** 34),
                                  (2000, 2 ** 36)]:
            L = bounds.conservative_L(8000, bound, bound)
            assert bounds.choose_s(L) == expected_s, bound

    def test_max_abs_matches_paper_form(self):
        # paper: with s=2L, |X| <= (2L)^{p/p'}/2 (up to the negative tail)
        L, p, pp = 1000, 4, 2
        s = 2 * L
        depth = p // pp - 1
        got = bounds.max_abs_coefficient(L, s, depth)
        paper = (2 * L) ** (p // pp) / 2
        assert got <= paper * 1.01


class TestPlanner:
    def test_small_L_picks_optimal_threshold(self):
        rep = bounds.plan_p_prime(4, 2, 2, L=20, dtype="float64")
        assert rep.p_prime == 1 and rep.tau == 4 and rep.safe

    def test_huge_L_forces_higher_threshold(self):
        # L = 2e7 (paper scale) with p=4: (2L)^4 ~ 2^100 >> 2^53
        rep64 = bounds.plan_p_prime(4, 2, 2, L=20_000_001, dtype="float64")
        assert rep64.p_prime > 1
        assert rep64.safe

    def test_f32_stricter_than_f64(self):
        rep32 = bounds.plan_p_prime(4, 2, 2, L=1000, dtype="float32")
        rep64 = bounds.plan_p_prime(4, 2, 2, L=1000, dtype="float64")
        assert rep32.p_prime >= rep64.p_prime
        assert rep32.tau >= rep64.tau

    def test_monotone_tradeoff(self):
        """Larger p' -> smaller digit stack, higher tau (the paper's curve)."""
        p, m, n = 8, 2, 2
        taus, maxes = [], []
        for pp in (1, 2, 4, 8):
            sch = make_scheme("tradeoff", p, m, n, p_prime=pp)
            taus.append(sch.tau)
            maxes.append(bounds.max_abs_coefficient(1000, 2048, sch.digit_depth))
        assert taus == sorted(taus)
        assert maxes == sorted(maxes, reverse=True)

    def test_overflow_detection_table1_row5(self):
        """Table I row 5 (bound 2000 -> error ~ 1): planner flags p'=1
        as UNSAFE for f64 at the paper's L."""
        L = bounds.conservative_L(8000, 2000, 2000)
        s = bounds.choose_s(L)
        sch = make_scheme("bec", 2, 2, 2)
        assert not bounds.is_safe(L, s, sch.digit_depth, "float64",
                                  tau=sch.tau, conditioning_slack_bits=0.0)
