"""Adaptive straggler control plane: monitor, policy, ladder, driver."""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.control import (  # noqa: E402
    AdaptiveServer,
    ExpectedLatencyPolicy,
    FeedbackConfig,
    PlanLadder,
    QuantileLatencyPolicy,
    WorkerHealthMonitor,
)
from repro.core.simulator import LatencyModel  # noqa: E402
from repro.runtime import CacheGroup, CodedMatmul, plan_token  # noqa: E402
from repro.core import make_plan  # noqa: E402

K = 12
GRID = (4, 2, 1)  # p, m, n -> rungs bec(tau=2), tradeoff p'=2 (5), polycode(11)
L_ALL_FEASIBLE = 257
L_BEC_INFEASIBLE = 1 << 14
SHAPES = ((16, 8), (16, 4))  # (v, r), (v, t)


def _ladder(L=L_ALL_FEASIBLE, **kw):
    return PlanLadder(*GRID, K=K, L=L, backend="reference", **kw)


def _steady_times(slow=(), base=1.0, slowdown=2.0):
    t = np.full(K, base)
    t[list(slow)] *= slowdown
    return t


class TestMonitor:
    def test_ewma_tracks_means(self):
        mon = WorkerHealthMonitor(K, alpha=0.5)
        for _ in range(30):
            mon.record_step(_steady_times(slow=[3]))
        np.testing.assert_allclose(mon.mean, _steady_times(slow=[3]))
        assert mon.std.max() < 1e-6

    def test_scores_rise_and_decay(self):
        mon = WorkerHealthMonitor(K, score_decay=0.5)
        for _ in range(4):
            mon.record_step(_steady_times(slow=[7]))
        assert mon.straggler_scores()[7] > 0.9
        assert list(mon.stragglers()) == [7]
        for _ in range(4):
            mon.record_step(_steady_times())  # worker 7 recovers
        assert mon.straggler_scores()[7] < 0.1
        assert mon.stragglers().size == 0

    def test_erasure_mask_respects_budget_and_history(self):
        mon = WorkerHealthMonitor(K, min_history=2)
        mon.record_step(_steady_times(slow=[0, 1, 2]))
        # one step < min_history: cold monitor never erases
        np.testing.assert_array_equal(mon.erasure_mask(K), np.ones(K))
        for _ in range(3):
            mon.record_step(_steady_times(slow=[0, 1, 2]))
        mask = mon.erasure_mask(budget=2)
        assert mask.sum() == K - 2  # clamped at the budget
        assert set(np.flatnonzero(mask == 0)) <= {0, 1, 2}
        full = mon.erasure_mask(budget=6)
        assert set(np.flatnonzero(full == 0)) == {0, 1, 2}

    def test_majority_stragglers_still_flagged(self):
        """Quartile-relative flagging survives >K/2 simultaneous stragglers."""
        mon = WorkerHealthMonitor(K)
        slow = list(range(7))
        for _ in range(3):
            mon.record_step(_steady_times(slow=slow))
        assert set(mon.stragglers()) == set(slow)

    def test_fitted_model_per_worker(self):
        mon = WorkerHealthMonitor(K)
        for _ in range(10):
            mon.record_step(_steady_times(slow=[4], slowdown=3.0))
        model = mon.fitted_model()
        base = model.base_vector(K)
        assert base[4] == pytest.approx(3.0, rel=1e-3)
        assert base[0] == pytest.approx(1.0, rel=1e-3)
        # fitted means already include slowness: no extra slowdown factor
        assert model.straggler_slowdown == 1.0
        t = model.sample(K, (), np.random.default_rng(0))
        assert t.shape == (K,)

    def test_fitted_model_survives_transient_spike(self):
        """One huge outlier can push the EWMA std past the EWMA mean; the
        shifted-exp fit must cap the scale at the mean (preserving the
        observed mean latency) instead of collapsing the base to ~0."""
        mon = WorkerHealthMonitor(K, alpha=0.3)
        for _ in range(5):
            mon.record_step(_steady_times())
        spike = _steady_times()
        spike[3] = 20.0
        mon.record_step(spike)
        assert mon.std[3] > mon.mean[3]  # the degenerate regime
        model = mon.fitted_model()
        fitted_mean = model.base_vector(K) + \
            model.jitter_vector(K) * model.base_vector(K)
        assert fitted_mean[3] == pytest.approx(mon.mean[3], rel=1e-6)
        assert np.all(model.base_vector(K) > 0)

    def test_input_validation(self):
        mon = WorkerHealthMonitor(K)
        with pytest.raises(ValueError):
            mon.record_step(np.ones(K - 1))
        with pytest.raises(ValueError):
            mon.record_step(np.full(K, np.nan))
        with pytest.raises(ValueError):
            mon.erasure_mask(budget=-1)
        with pytest.raises(ValueError):
            WorkerHealthMonitor(K, alpha=0.0)


class TestLadder:
    def test_rungs_ascend_in_tau(self):
        lad = _ladder()
        assert lad.rungs == ("bec", "tradeoff(p'=2)", "polycode")
        taus = [lad.tau(r) for r in lad.rungs]
        assert taus == sorted(taus) == [2, 5, 11]
        assert [lad.budget(r) for r in lad.rungs] == [10, 7, 1]

    def test_rungs_beyond_K_dropped(self):
        lad = PlanLadder(4, 2, 1, K=6, L=L_ALL_FEASIBLE, backend="reference")
        assert lad.rungs == ("bec", "tradeoff(p'=2)")  # polycode tau=11 > 6

    def test_initial_rung_respects_entry_bound(self):
        assert _ladder().active == "bec"
        lad = _ladder(L=L_BEC_INFEASIBLE)
        assert not lad.feasible("bec")
        assert lad.active == "tradeoff(p'=2)"

    def test_every_rung_exact(self):
        lad = _ladder()
        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.integers(-4, 5, size=SHAPES[0]), jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        oracle = np.asarray(A).T @ np.asarray(B)
        for rung in lad.rungs:
            lad.switch(rung)
            erased = list(range(lad.budget(rung)))
            np.testing.assert_array_equal(
                np.asarray(lad(A, B, erased=erased)), oracle)

    def test_prewarm_makes_switch_recompile_free(self):
        lad = _ladder()
        info = lad.prewarm(*SHAPES)
        assert info["builds"] == len(lad.rungs)
        assert set(info["overhead_s"]) == set(lad.rungs)
        builds = lad.cache_info()["builds"]
        A = jnp.zeros(SHAPES[0], jnp.float64)
        B = jnp.zeros(SHAPES[1], jnp.float64)
        for step in range(6):  # rotate rungs AND erasure patterns
            rung = lad.rungs[step % len(lad.rungs)]
            lad.switch(rung)
            lad(A, B, erased=[step % (lad.budget(rung) + 1)])
        info = lad.cache_info()
        assert info["builds"] == builds, "rung switch recompiled"
        assert info["switches"] >= 5

    def test_unknown_rung_raises(self):
        with pytest.raises(KeyError):
            _ladder().switch("raptor")


class TestCacheGroup:
    def test_plans_do_not_alias_executables(self):
        """Same backend/shape/dtype/kind, different plans: the group memo
        must key them apart (and both stay exact)."""
        group = CacheGroup()
        p1 = make_plan("bec", 4, 2, 1, K=K, L=L_ALL_FEASIBLE,
                       points="chebyshev")
        p2 = make_plan("polycode", 4, 2, 1, K=K, L=L_ALL_FEASIBLE,
                       points="chebyshev")
        cm1 = CodedMatmul(p1, "reference", cache_group=group)
        cm2 = CodedMatmul(p2, "reference", cache_group=group)
        rng = np.random.default_rng(1)
        A = jnp.asarray(rng.integers(-4, 5, size=SHAPES[0]), jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        oracle = np.asarray(A).T @ np.asarray(B)
        np.testing.assert_array_equal(np.asarray(cm1(A, B, erased=[0])), oracle)
        np.testing.assert_array_equal(np.asarray(cm2(A, B, erased=[0])), oracle)
        assert group.stats["builds"] == 2  # one executable per plan
        assert plan_token(p1) != plan_token(p2)

    def test_equal_plans_share_everything(self):
        group = CacheGroup()
        mk = lambda: make_plan("bec", 2, 2, 1, K=4, L=257)  # noqa: E731
        cm1 = CodedMatmul(mk(), "reference", cache_group=group)
        cm2 = CodedMatmul(mk(), "reference", cache_group=group)
        assert cm1.panel_cache is cm2.panel_cache
        A = jnp.ones((8, 4), jnp.float64)
        B = jnp.ones((8, 4), jnp.float64)
        cm1(A, B, erased=[0])
        cm2(A, B, erased=[0])
        assert group.stats["builds"] == 1 and group.stats["hits"] == 1

    def test_group_and_shared_are_exclusive(self):
        plan = make_plan("bec", 2, 2, 1, K=4, L=257)
        cm = CodedMatmul(plan, "reference")
        with pytest.raises(ValueError):
            CodedMatmul(plan, "reference", cache_group=CacheGroup(),
                        _shared=(cm.panel_cache, {}, {"builds": 0, "hits": 0}))


class TestPolicy:
    def _fitted(self, slow=(), slowdown=2.0):
        mon = WorkerHealthMonitor(K)
        for _ in range(5):
            mon.record_step(_steady_times(slow=slow, slowdown=slowdown))
        return mon.fitted_model(), mon.straggler_scores()

    def test_zero_stragglers_prefers_lowest_tau(self):
        lad = _ladder()
        pol = ExpectedLatencyPolicy(lad,
                                    overhead_s={r: 0.0 for r in lad.rungs})
        model, scores = self._fitted()
        assert pol.select(model, scores).rung == "bec"

    def test_expected_latency_reflects_masking_budget(self):
        lad = _ladder()
        pol = ExpectedLatencyPolicy(lad,
                                    overhead_s={r: 0.0 for r in lad.rungs})
        model, scores = self._fitted(slow=[0, 1, 2])
        est = {e.rung: e for e in pol.rank(model, scores)}
        # bec/tradeoff budgets cover all 3 stragglers -> completion ~ base;
        # polycode (budget 1) must wait for 2 unmasked stragglers
        assert est["bec"].expected_latency_s == pytest.approx(1.0)
        assert est["tradeoff(p'=2)"].expected_latency_s == pytest.approx(1.0)
        assert est["polycode"].expected_latency_s == pytest.approx(2.0)
        assert est["polycode"].unmasked_stragglers == 2
        assert pol.select(model, scores).rung == "bec"

    def test_entry_bound_gates_bec(self):
        lad = _ladder(L=L_BEC_INFEASIBLE)
        pol = ExpectedLatencyPolicy(lad,
                                    overhead_s={r: 0.0 for r in lad.rungs})
        model, scores = self._fitted(slow=[3])
        est = pol.select(model, scores)
        assert est.rung == "tradeoff(p'=2)" and est.feasible
        assert not pol.feasible("bec")

    def test_overhead_breaks_ties(self):
        lad = _ladder()
        pol = ExpectedLatencyPolicy(
            lad, overhead_s={"bec": 0.5, "tradeoff(p'=2)": 0.0,
                             "polycode": 0.0})
        model, scores = self._fitted()
        assert pol.select(model, scores).rung == "tradeoff(p'=2)"

    def test_no_feasible_rung_raises(self):
        lad = _ladder(L=1 << 40, include=["bec"])  # digit stack >> f64
        pol = ExpectedLatencyPolicy(lad)
        model, scores = self._fitted()
        with pytest.raises(ValueError):
            pol.select(model, scores)


class TestAdaptiveServer:
    def _request(self, seed=0):
        rng = np.random.default_rng(seed)
        A = jnp.asarray(rng.integers(-4, 5, size=SHAPES[0]), jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        return A, B

    def test_learns_and_masks_persistent_stragglers(self):
        lad = _ladder()
        lad.prewarm(*SHAPES)
        builds = lad.cache_info()["builds"]
        model = LatencyModel(base=1.0, straggler_slowdown=2.0)
        feed = lambda step, rng: model.sample(K, [2, 9], rng)  # noqa: E731
        srv = AdaptiveServer(lad, feed=feed, check_exact=True)
        A, B = self._request()
        reports = srv.run(8, lambda i: (A, B))
        assert all(r.exact for r in reports)
        # after min_history warmup the mask drops exactly the slow pair
        for rep in reports[3:]:
            assert rep.erased == (2, 9)
            assert rep.sim_latency_s == pytest.approx(1.0)
        assert reports[0].sim_latency_s == pytest.approx(2.0)  # cold monitor
        assert lad.cache_info()["builds"] == builds

    def test_respecialize_handoff_when_budget_exhausted(self):
        lad = _ladder(include=["polycode"])  # budget 1
        lad.prewarm(*SHAPES)
        model = LatencyModel(base=1.0, straggler_slowdown=2.0)
        feed = lambda step, rng: model.sample(K, [0, 1, 2], rng)  # noqa: E731
        srv = AdaptiveServer(lad, feed=feed, check_exact=True)
        A, B = self._request(1)
        reports = srv.run(6, lambda i: (A, B))
        late = reports[-1]
        assert late.respecialize
        assert late.shrink_target == (2, 4)  # plan_shrink(12 - 3)
        assert late.slack == 0 and srv.elastic.must_respecialize
        assert late.exact  # still serving correctly while flagging handoff

    def test_switches_rungs_when_entry_bound_changes_ranking(self):
        lad = _ladder(L=L_BEC_INFEASIBLE)
        lad.prewarm(*SHAPES)
        builds = lad.cache_info()["builds"]
        # zero measured overheads: latency ties resolve by tau, so the
        # selection is deterministic (prewarm timings carry wall noise)
        pol = ExpectedLatencyPolicy(lad,
                                    overhead_s={r: 0.0 for r in lad.rungs})
        srv = AdaptiveServer(lad, policy=pol,
                             feed=lambda s, r: _steady_times(slow=[5]),
                             check_exact=True)
        A, B = self._request(2)
        reports = srv.run(6, lambda i: (A, B))
        assert {r.rung for r in reports} == {"tradeoff(p'=2)"}
        assert all(r.exact for r in reports)
        assert lad.cache_info()["builds"] == builds

    def test_elastic_policy_consumes_monitor_mask(self):
        lad = _ladder()
        lad.prewarm(*SHAPES)
        srv = AdaptiveServer(lad, feed=lambda s, r: _steady_times(slow=[4]))
        A, B = self._request(3)
        srv.run(4, lambda i: (A, B))
        assert not srv.elastic.healthy[4]
        assert srv.elastic.slack == K - 1 - lad.tau(lad.active)

    def test_feed_shape_validated(self):
        lad = _ladder()
        srv = AdaptiveServer(lad, feed=lambda s, r: np.ones(3))
        with pytest.raises(ValueError):
            srv.step(*self._request())


class TestQuantilePolicy:
    def _heavy_fit(self, slow=(0, 1, 2)):
        """Monitor fitted on a heavy-tailed mix: slow workers 2x + fat tail."""
        from repro.core.simulator import LatencyModel

        base = np.ones(K)
        jitter = np.full(K, 0.05)
        base[list(slow)] = 2.0
        jitter[list(slow)] = 1.5
        model = LatencyModel(base=base, straggler_slowdown=1.0, jitter=jitter)
        mon = WorkerHealthMonitor(K)
        rng = np.random.default_rng(0)
        for _ in range(12):
            mon.record_step(model.sample(K, (), rng))
        return mon.fitted_model(), mon.straggler_scores()

    def test_policy_protocol(self):
        from repro.control import Policy

        lad = _ladder()
        assert isinstance(ExpectedLatencyPolicy(lad), Policy)
        assert isinstance(QuantileLatencyPolicy(lad), Policy)

    def test_invalid_q_raises(self):
        with pytest.raises(ValueError):
            QuantileLatencyPolicy(_ladder(), q=1.5)

    def test_tail_ranking_disagrees_with_mean_under_heavy_tails(self):
        """The tentpole scenario: per-rung digit-stack overheads outweigh the
        MEAN cost of an unmasked heavy-tailed straggler but not its p99 cost,
        so the two policies pick different rungs."""
        lad = _ladder()
        overhead = {"bec": 10.0, "tradeoff(p'=2)": 9.0, "polycode": 0.5}
        model, scores = self._heavy_fit()
        mean_pick = ExpectedLatencyPolicy(
            lad, overhead_s=overhead).select(model, scores)
        tail_pick = QuantileLatencyPolicy(
            lad, q=0.99, overhead_s=overhead).select(model, scores)
        assert mean_pick.rung == "polycode"      # cheap, eats the tail
        assert tail_pick.rung == "tradeoff(p'=2)"  # pays the digit tax
        assert tail_pick.quantile == 0.99
        assert tail_pick.quantile_latency_s > tail_pick.expected_latency_s

    def test_analytic_matches_sampled(self):
        lad = _ladder()
        model, scores = self._heavy_fit()
        zero = {r: 0.0 for r in lad.rungs}
        a = QuantileLatencyPolicy(lad, q=0.9, overhead_s=zero,
                                  analytic=True).estimate("bec", model, scores)
        s = QuantileLatencyPolicy(lad, q=0.9, overhead_s=zero, analytic=False,
                                  trials=4000).estimate("bec", model, scores)
        assert a.quantile_latency_s == pytest.approx(
            s.quantile_latency_s, rel=0.1)

    def test_entry_bound_still_gates(self):
        lad = _ladder(L=L_BEC_INFEASIBLE)
        model, scores = self._heavy_fit()
        pol = QuantileLatencyPolicy(lad,
                                    overhead_s={r: 0.0 for r in lad.rungs})
        est = pol.select(model, scores)
        assert est.feasible and est.rung != "bec"

    def test_median_ranking_coincides_with_mean_for_iid_workers(self):
        """Property: for i.i.d. exponential-jitter workers, ranking by the
        q=0.5 quantile orders rungs exactly like ranking by the mean.  The
        kept sets are nested (victims are cut worst-first from one flagged
        list), so on shared sample paths max-over-kept is pointwise
        monotone and every summary statistic agrees on the order."""
        from repro.core.simulator import LatencyModel

        lad = _ladder()
        zero = {r: 0.0 for r in lad.rungs}
        for seed in range(8):
            rng = np.random.default_rng(seed)
            model = LatencyModel(base=float(rng.uniform(0.5, 2.0)),
                                 straggler_slowdown=1.0,
                                 jitter=float(rng.uniform(0.1, 1.0)))
            scores = rng.uniform(0, 1, size=K)
            mean_rank = [e.rung for e in ExpectedLatencyPolicy(
                lad, overhead_s=zero, seed=seed).rank(model, scores)]
            med_rank = [e.rung for e in QuantileLatencyPolicy(
                lad, q=0.5, overhead_s=zero, analytic=False,
                seed=seed).rank(model, scores)]
            assert mean_rank == med_rank


class TestBatchedLadder:
    def test_bucket_roundup_serves_exactly(self):
        """Batch sizes without their own executable round up to a bucket;
        the padded rows are sliced off and the result stays exact."""
        lad = _ladder()
        info = lad.prewarm(*SHAPES, batch_sizes=(4, 8))
        assert info["batch_buckets"] == (4, 8)
        # 3 rungs x (unbatched + 2 buckets)
        assert info["builds"] == 3 * 3
        builds = lad.cache_info()["builds"]
        rng = np.random.default_rng(0)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        for step, n in enumerate([3, 5, 8, 1, 4, 7]):
            rung = lad.rungs[step % len(lad.rungs)]
            lad.switch(rung)
            A = jnp.asarray(rng.integers(-4, 5, size=(n,) + SHAPES[0]),
                            jnp.float64)
            C = lad(A, B, erased=[0])
            assert C.shape[0] == n
            oracle = np.einsum("bvr,vt->brt", np.asarray(A), np.asarray(B))
            np.testing.assert_array_equal(np.asarray(C), oracle)
        assert lad.cache_info()["builds"] == builds, (
            "batched rung switch recompiled")

    def test_bucket_for(self):
        lad = _ladder()
        lad.prewarm(*SHAPES, batch_sizes=(4, 8))
        assert lad.bucket_for(1) == 4
        assert lad.bucket_for(4) == 4
        assert lad.bucket_for(5) == 8
        assert lad.bucket_for(9) is None
        assert lad.batch_buckets == (4, 8)

    def test_batched_B_bypasses_buckets(self):
        """Buckets compile batched-A-only executables; a batched-B call must
        serve at its true size (never pad A against an unpadded B)."""
        lad = _ladder(include=["bec"])
        lad.prewarm(*SHAPES, batch_sizes=(4,))
        rng = np.random.default_rng(2)
        A = jnp.asarray(rng.integers(-4, 5, size=(3,) + SHAPES[0]),
                        jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=(3,) + SHAPES[1]),
                        jnp.float64)
        C = lad(A, B, erased=[0])
        oracle = np.einsum("bvr,bvt->brt", np.asarray(A), np.asarray(B))
        np.testing.assert_array_equal(np.asarray(C), oracle)

    def test_batch_beyond_buckets_compiles_fresh(self):
        lad = _ladder(include=["bec"])
        lad.prewarm(*SHAPES, batch_sizes=(2,))
        builds = lad.cache_info()["builds"]
        A = jnp.zeros((5,) + SHAPES[0], jnp.float64)
        B = jnp.zeros(SHAPES[1], jnp.float64)
        assert lad(A, B, erased=[]).shape[0] == 5
        assert lad.cache_info()["builds"] == builds + 1

    def test_invalid_bucket_raises(self):
        with pytest.raises(ValueError):
            _ladder().prewarm(*SHAPES, batch_sizes=(0,))

    def test_batch_exactly_on_bucket_boundary(self):
        """A batch landing exactly on a prewarmed bucket serves through
        that executable verbatim: zero new builds, zero padding rows."""
        lad = _ladder(include=["bec"])
        lad.prewarm(*SHAPES, batch_sizes=(2, 4))
        builds = lad.cache_info()["builds"]
        rng = np.random.default_rng(3)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        for n in (2, 4):
            A = jnp.asarray(rng.integers(-4, 5, size=(n,) + SHAPES[0]),
                            jnp.float64)
            C = lad(A, B, erased=[1])
            assert C.shape[0] == n
            oracle = np.einsum("bvr,vt->brt", np.asarray(A), np.asarray(B))
            np.testing.assert_array_equal(np.asarray(C), oracle)
        assert lad.cache_info()["builds"] == builds, (
            "a boundary-sized batch recompiled instead of reusing its "
            "bucket executable")

    def test_batch_larger_than_largest_bucket(self):
        """Past the largest bucket there is nothing to round up to: the
        call serves EXACTLY at its true size (one new build, memoized on
        repeat) rather than truncating or failing."""
        lad = _ladder(include=["bec"])
        lad.prewarm(*SHAPES, batch_sizes=(2, 4))
        builds = lad.cache_info()["builds"]
        rng = np.random.default_rng(4)
        A = jnp.asarray(rng.integers(-4, 5, size=(6,) + SHAPES[0]),
                        jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        C = lad(A, B, erased=[0])
        assert C.shape[0] == 6
        oracle = np.einsum("bvr,vt->brt", np.asarray(A), np.asarray(B))
        np.testing.assert_array_equal(np.asarray(C), oracle)
        assert lad.cache_info()["builds"] == builds + 1
        lad(A, B, erased=[0])  # the fresh executable is memoized
        assert lad.cache_info()["builds"] == builds + 1

    def test_batch_one_after_batched_call(self):
        """A batch-1 request after larger batched traffic pads up to the
        smallest bucket and slices back to one row — no recompile, and
        the single row is the single-request answer."""
        lad = _ladder(include=["bec"])
        lad.prewarm(*SHAPES, batch_sizes=(4,))
        rng = np.random.default_rng(5)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        A3 = jnp.asarray(rng.integers(-4, 5, size=(3,) + SHAPES[0]),
                         jnp.float64)
        lad(A3, B, erased=[2])  # batched traffic first
        builds = lad.cache_info()["builds"]
        A1 = jnp.asarray(rng.integers(-4, 5, size=(1,) + SHAPES[0]),
                         jnp.float64)
        C = lad(A1, B, erased=[2])
        assert C.shape[0] == 1
        oracle = np.einsum("bvr,vt->brt", np.asarray(A1), np.asarray(B))
        np.testing.assert_array_equal(np.asarray(C), oracle)
        assert lad.cache_info()["builds"] == builds, (
            "batch=1 after a batched call recompiled instead of padding "
            "into the existing bucket")


class TestSLOFallback:
    def _heavy_feed(self, slow=(0, 1, 2)):
        from repro.core.simulator import LatencyModel

        base = np.ones(K)
        jitter = np.full(K, 0.05)
        base[list(slow)] = 2.0
        jitter[list(slow)] = 1.5
        model = LatencyModel(base=base, straggler_slowdown=1.0, jitter=jitter)
        return lambda step, rng: model.sample(K, (), rng)

    def test_slo_s_requires_quantile(self):
        with pytest.raises(ValueError):
            AdaptiveServer(_ladder(), slo_s=1.0)

    def test_slo_quantile_becomes_primary_policy(self):
        srv = AdaptiveServer(_ladder(), slo_quantile=0.95)
        assert isinstance(srv.policy, QuantileLatencyPolicy)
        assert srv.policy is srv.slo_policy
        assert srv.policy.q == 0.95

    def test_violation_forces_switch_against_mean_ranking(self):
        """With the mean policy primary, a predicted tail-SLO violation on
        its pick forces the quantile policy's rung instead — the fallback
        overrides the mean ranking every step it disagrees."""
        overhead = {"bec": 10.0, "tradeoff(p'=2)": 9.0, "polycode": 0.5}
        lad = _ladder()
        lad.prewarm(*SHAPES)
        builds = lad.cache_info()["builds"]
        srv = AdaptiveServer(
            lad, policy=ExpectedLatencyPolicy(lad, overhead_s=overhead),
            feed=self._heavy_feed(), check_exact=True,
            slo_quantile=0.99, slo_s=12.0)
        assert srv.slo_policy.overhead_s == overhead  # inherited pricing
        A, B = jnp.zeros(SHAPES[0], jnp.float64), jnp.zeros(SHAPES[1],
                                                            jnp.float64)
        reports = srv.run(10, lambda i: (A, B))
        warm = reports[4:]
        assert any(r.slo_violation for r in warm)
        for r in warm:
            if r.slo_violation:
                # mean ranking wants polycode; the fallback forbids it,
                # and the report carries the SERVED rung's tail (within SLO)
                assert r.rung == "tradeoff(p'=2)"
                assert r.predicted_tail_s < 12.0
        assert all(r.exact for r in reports)
        assert lad.cache_info()["builds"] == builds

    def test_no_violation_below_slo(self):
        lad = _ladder()
        lad.prewarm(*SHAPES)
        srv = AdaptiveServer(lad, feed=lambda s, r: _steady_times(slow=[5]),
                             slo_quantile=0.99, slo_s=50.0)
        A, B = jnp.zeros(SHAPES[0], jnp.float64), jnp.zeros(SHAPES[1],
                                                            jnp.float64)
        reports = srv.run(5, lambda i: (A, B))
        assert not any(r.slo_violation for r in reports)
        assert all(r.predicted_tail_s is not None for r in reports[2:])
        # no feedback configured: the observed-violation fields stay inert
        assert all(r.realized_s is None and r.q_effective is None
                   and not r.realized_violation for r in reports)


class TestObservedViolationFeedback:
    _AB = (jnp.zeros(SHAPES[0], jnp.float64), jnp.zeros(SHAPES[1],
                                                        jnp.float64))

    def test_feedback_requires_slo(self):
        with pytest.raises(ValueError):
            AdaptiveServer(_ladder(), feedback=True)
        with pytest.raises(ValueError):
            AdaptiveServer(_ladder(), slo_quantile=0.99, feedback=True)

    def test_realized_misses_tighten_q_and_force_tail_optimal(self):
        """Every realized step blows a tiny SLO: the window rate saturates,
        q climbs to q_max, and consecutive misses arm the forced switch."""
        lad = _ladder()
        lad.prewarm(*SHAPES)
        pol = ExpectedLatencyPolicy(lad,
                                    overhead_s={r: 0.0 for r in lad.rungs})
        srv = AdaptiveServer(lad, policy=pol,
                             feed=lambda s, r: _steady_times(),
                             slo_quantile=0.9, slo_s=0.5, feedback=True)
        reports = srv.run(8, lambda i: self._AB)
        assert all(r.realized_violation for r in reports)
        assert all(r.realized_s == pytest.approx(1.0) for r in reports)
        assert reports[0].q_effective == 0.9          # window still filling
        assert reports[-1].q_effective == 0.999       # clipped at q_max
        assert srv.feedback.force_tail_optimal
        assert srv.feedback.violations == 8

    def test_feedback_restates_user_supplied_quantile_primary(self):
        """A quantile PRIMARY passed explicitly must rank at the
        feedback-adjusted q, not its stale construction-time base."""
        lad = _ladder()
        lad.prewarm(*SHAPES)
        primary = QuantileLatencyPolicy(
            lad, q=0.8, overhead_s={r: 0.0 for r in lad.rungs})
        srv = AdaptiveServer(lad, policy=primary,
                             feed=lambda s, r: _steady_times(),
                             slo_quantile=0.8, slo_s=0.5, feedback=True)
        srv.run(8, lambda i: self._AB)
        assert primary is not srv.slo_policy
        assert primary.q == srv.slo_policy.q == 0.999  # both tightened

    def test_clean_run_holds_base_q(self):
        """Default config never loosens below the SLO's own quantile."""
        lad = _ladder()
        lad.prewarm(*SHAPES)
        srv = AdaptiveServer(lad, feed=lambda s, r: _steady_times(),
                             slo_quantile=0.9, slo_s=50.0, feedback=True)
        reports = srv.run(8, lambda i: self._AB)
        assert not any(r.realized_violation for r in reports)
        assert all(r.q_effective == 0.9 for r in reports)

    def test_feedback_reduces_realized_violations_vs_static_q(self):
        """The ROADMAP acceptance scenario, at the bench's CANONICAL
        config (imported, not copied, so retuning the controller cannot
        silently leave this test exercising stale constants): an
        understated base quantile under heavy tails lets the cheap
        narrow-budget rung serve and eat realized misses;
        observed-violation feedback tightens q off the misses, pinning
        the wide-budget rung while the window remembers — strictly fewer
        realized violations, no worse p99."""
        from benchmarks.control_bench import (
            FB_CONFIG,
            FB_Q_BASE,
            FB_SEEDS,
            FB_SLO_S,
            FB_STEPS,
            FB_WARMUP,
            Q_OVERHEAD,
        )
        from repro.chaos import make_scenario

        results = {}
        for fb in (False, FeedbackConfig(**FB_CONFIG)):
            feed = make_scenario("heavy_tail").compile(K, seed=FB_SEEDS[0])
            lad = _ladder()
            lad.prewarm(*SHAPES)
            pol = ExpectedLatencyPolicy(lad, overhead_s=Q_OVERHEAD)
            srv = AdaptiveServer(lad, policy=pol, feed=feed,
                                 seed=FB_SEEDS[0], slo_quantile=FB_Q_BASE,
                                 slo_s=FB_SLO_S, feedback=fb)
            reports = srv.run(FB_STEPS, lambda i: self._AB)[FB_WARMUP:]
            realized = np.array([r.sim_latency_s + Q_OVERHEAD[r.rung]
                                 for r in reports])
            results[bool(fb)] = ((realized > FB_SLO_S).sum(),
                                 np.quantile(realized, 0.99))
        assert results[True][0] < results[False][0]
        assert results[True][1] <= results[False][1]
