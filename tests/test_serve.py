"""Multi-tenant serve tier: admission, batching, SLO floors, pipeline."""
import dataclasses
from pathlib import Path

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.chaos import make_scenario  # noqa: E402
from repro.chaos.serialize import (  # noqa: E402
    REPORT_VOLATILE_FIELDS,
    dataclass_to_dict,
    jsonable,
    report_to_dict,
    tuplify,
)
from repro.control import (  # noqa: E402
    AdaptiveServer,
    PlanLadder,
    QuantileLatencyPolicy,
)
from repro.core.simulator import LatencyModel  # noqa: E402
from repro.serve import (  # noqa: E402
    REJECT_QUEUE_FULL,
    REJECT_RATE_LIMITED,
    AdmissionController,
    ContinuousBatcher,
    Request,
    RungFloorPolicy,
    ServeTier,
    ServeTrace,
    SLOClass,
    TenantSpec,
    TokenBucket,
    TwoStagePipeline,
    parse_tenant_spec,
)

K = 12
GRID = (4, 2, 1)
L = 257
SHAPES = ((16, 8), (16, 4))
OVERHEAD = {"bec": 2.0, "tradeoff(p'=2)": 1.0, "polycode": 0.1}
GOLDEN_DIR = Path(__file__).parent / "golden"


@pytest.fixture(scope="module")
def ladder():
    """One prewarmed ladder shared by every tier test in this module."""
    lad = PlanLadder(*GRID, K=K, L=L, backend="reference")
    lad.prewarm(*SHAPES, batch_sizes=(2, 4), stages=True)
    return lad


def _req(rid, tenant="a", cls="c", arrival=0.0, deadline=10.0):
    return Request(rid=rid, tenant=tenant, slo_class=cls,
                   arrival_s=arrival, deadline_s=deadline)


class TestTokenBucket:
    def test_starts_full_and_caps_at_burst(self):
        b = TokenBucket(rate_rps=1.0, burst=2)
        assert b.take(0.0) and b.take(0.0)
        assert not b.take(0.0)          # drained
        assert b.take(100.0)            # refilled, but capped at burst
        assert b.take(100.0)
        assert not b.take(100.0)

    def test_refills_at_rate(self):
        b = TokenBucket(rate_rps=0.5, burst=1)
        assert b.take(0.0)
        assert not b.take(1.0)          # only 0.5 tokens back
        assert b.take(2.0)              # one full token after 2 s

    def test_infinite_rate_always_admits(self):
        b = TokenBucket(rate_rps=float("inf"), burst=1)
        assert all(b.take(0.0) for _ in range(50))


class TestAdmission:
    def _ctrl(self, rate=1.0, burst=2, max_queue=2):
        spec = TenantSpec(name="a", slo_class="c", rate_rps=rate,
                          burst=burst, max_queue=max_queue)
        return AdmissionController({"a": spec})

    def test_rate_limited_reason(self):
        ctrl = self._ctrl(rate=0.1, burst=1, max_queue=8)
        assert ctrl.offer(_req(0), 0.0) is None
        assert ctrl.offer(_req(1), 0.0) == REJECT_RATE_LIMITED
        assert ctrl.queued() == 1

    def test_queue_full_reason(self):
        ctrl = self._ctrl(rate=float("inf"), max_queue=2)
        assert ctrl.offer(_req(0), 0.0) is None
        assert ctrl.offer(_req(1), 0.0) is None
        assert ctrl.offer(_req(2), 0.0) == REJECT_QUEUE_FULL
        assert ctrl.queued() == 2

    def test_unknown_tenant_raises(self):
        with pytest.raises(KeyError):
            self._ctrl().offer(_req(0, tenant="nobody"), 0.0)


class TestBatcher:
    def _queues(self, *reqs):
        from collections import deque

        out = {}
        for r in reqs:
            out.setdefault(r.tenant, deque()).append(r)
        return out

    def test_earliest_deadline_class_wins(self):
        b = ContinuousBatcher({"a": "fast", "b": "slow"}, max_batch=4)
        queues = self._queues(
            _req(0, tenant="b", cls="slow", arrival=0.0, deadline=60.0),
            _req(1, tenant="a", cls="fast", arrival=1.0, deadline=5.0))
        batch = b.form(queues)
        assert batch.slo_class == "fast"
        assert [r.rid for r in batch.requests] == [1]
        # the slow request is still queued for the next step
        assert b.form(queues).slo_class == "slow"
        assert b.form(queues) is None

    def test_coalesces_across_tenants_and_caps(self):
        b = ContinuousBatcher({"a": "c", "b": "c"}, max_batch=2)
        queues = self._queues(
            _req(0, tenant="a", deadline=9.0),
            _req(1, tenant="b", deadline=7.0),
            _req(2, tenant="a", deadline=8.0))
        batch = b.form(queues)
        # EDF order across BOTH tenant queues, capped at max_batch
        assert [r.rid for r in batch.requests] == [1, 2]
        assert [r.rid for r in queues["a"]] == [0]
        assert not queues["b"]

    def test_empty_returns_none(self):
        b = ContinuousBatcher({"a": "c"}, max_batch=4)
        assert b.form(self._queues()) is None

    def test_bad_max_batch_raises(self):
        with pytest.raises(ValueError):
            ContinuousBatcher({}, max_batch=0)


class TestTwoStagePipeline:
    def test_pipelined_overlaps_decode(self):
        pipe = TwoStagePipeline(pipelined=True)
        first = pipe.schedule(0.0, worker_s=3.0, decode_s=2.0)
        assert (first.compute_done_s, first.decode_done_s) == (3.0, 5.0)
        # the next batch's workers start while the decoder drains batch 1
        assert pipe.next_free_s == 3.0
        second = pipe.schedule(3.0, worker_s=1.0, decode_s=2.0)
        assert second.compute_start_s == 3.0
        # decode of batch 2 queues behind the busy decoder
        assert second.decode_start_s == 5.0
        assert second.decode_done_s == 7.0

    def test_serial_holds_both_resources(self):
        pipe = TwoStagePipeline(pipelined=False)
        first = pipe.schedule(0.0, worker_s=3.0, decode_s=2.0)
        assert pipe.next_free_s == 5.0
        second = pipe.schedule(0.0, worker_s=1.0, decode_s=2.0)
        assert second.compute_start_s == first.decode_done_s == 5.0
        assert second.decode_done_s == 8.0

    def test_idle_pipeline_starts_at_now(self):
        pipe = TwoStagePipeline()
        t = pipe.schedule(7.5, worker_s=1.0, decode_s=0.5)
        assert t.compute_start_s == 7.5 and t.decode_done_s == 9.0


class TestRungFloorPolicy:
    def _model(self):
        return LatencyModel(base=np.ones(K), straggler_slowdown=2.0,
                            jitter=np.full(K, 0.02))

    def test_floor_clamps_thin_budget_winner(self, ladder):
        # overheads make polycode (budget 1) the ranked winner ...
        base = QuantileLatencyPolicy(ladder, q=0.9, overhead_s=OVERHEAD)
        assert base.select(self._model()).rung == "polycode"
        # ... but the floor refuses anything thinner than tradeoff
        floored = RungFloorPolicy(ladder, q=0.9, overhead_s=OVERHEAD,
                                  floor="tradeoff(p'=2)")
        pick = floored.select(self._model())
        assert pick.rung == "tradeoff(p'=2)"
        assert ladder.budget(pick.rung) >= ladder.budget("tradeoff(p'=2)")

    def test_no_floor_is_base_policy(self, ladder):
        base = QuantileLatencyPolicy(ladder, q=0.9, overhead_s=OVERHEAD)
        free = RungFloorPolicy(ladder, q=0.9, overhead_s=OVERHEAD)
        assert free.select(self._model()).rung == \
            base.select(self._model()).rung

    def test_wide_budget_winner_passes_through(self, ladder):
        # zero overheads rank by completion alone -> bec (budget 10) wins
        zero = {r: 0.0 for r in ladder.rungs}
        floored = RungFloorPolicy(ladder, q=0.9, overhead_s=zero,
                                  floor="tradeoff(p'=2)")
        assert floored.select(self._model()).rung == "bec"

    def test_unknown_floor_raises(self, ladder):
        with pytest.raises(KeyError):
            RungFloorPolicy(ladder, floor="nonesuch", overhead_s=OVERHEAD)


class TestTenantSpecParsing:
    def test_json_string_round_trip(self):
        spec = ('{"classes": [{"name": "c", "slo_s": 5.0}], '
                '"tenants": [{"name": "a", "slo_class": "c"}]}')
        classes, tenants = parse_tenant_spec(spec)
        assert classes["c"].slo_s == 5.0
        assert tenants["a"].slo_class == "c"

    def test_sequence_defaults_classes(self):
        classes, tenants = parse_tenant_spec(
            [{"name": "a", "slo_class": "premium"}])
        assert "premium" in classes and tenants["a"].slo_class == "premium"

    def test_duplicate_and_unknown_raise(self):
        with pytest.raises(ValueError, match="duplicate"):
            parse_tenant_spec({"classes": [{"name": "c"}, {"name": "c"}],
                               "tenants": [{"name": "a", "slo_class": "c"}]})
        with pytest.raises(ValueError, match="unknown SLO class"):
            parse_tenant_spec({"classes": [{"name": "c"}],
                               "tenants": [{"name": "a", "slo_class": "x"}]})

    def test_validation(self):
        with pytest.raises(ValueError):
            SLOClass(name="c", quantile=1.5)
        with pytest.raises(ValueError):
            TenantSpec(name="a", slo_class="c", max_queue=0)


class TestSharedSerializer:
    def test_report_to_dict_drops_volatile_fields(self, ladder):
        server = AdaptiveServer(ladder, feed=lambda s, r: np.ones(K),
                                seed=0, check_exact=False)
        A = jnp.zeros(SHAPES[0], jnp.float64)
        B = jnp.zeros(SHAPES[1], jnp.float64)
        rep = server.run(1, lambda i: (A, B))[0]
        d = report_to_dict(rep)
        for field in REPORT_VOLATILE_FIELDS:
            assert field not in d
        assert d["rung"] == rep.rung and d["exact"] == rep.exact

    def test_jsonable_tuplify_round_trip(self):
        value = {"mask": (1, 0, 1), "times": np.arange(3.0),
                 "nested": {"pair": ((1, 2), (3, 4))}, "scalar": np.int64(7)}
        j = jsonable(value)
        assert j["mask"] == [1, 0, 1] and j["times"] == [0.0, 1.0, 2.0]
        assert isinstance(j["scalar"], int)
        back = tuplify(j)
        assert back["mask"] == (1, 0, 1)
        assert back["nested"]["pair"] == ((1, 2), (3, 4))

    def test_dataclass_to_dict_requires_dataclass(self):
        with pytest.raises(TypeError):
            dataclass_to_dict({"not": "a dataclass"})

    def test_request_record_new_fields_round_trip(self):
        from repro.serve import RequestRecord

        rec = RequestRecord(rid=3, tenant="a", slo_class="c", arrival_s=1.5,
                            admitted=True, slo_s=10.0, queue_delay_s=0.25)
        d = dataclass_to_dict(rec)
        assert d["tenant"] == "a" and d["queue_delay_s"] == 0.25
        assert RequestRecord(**d) == rec


class TestSplitStages:
    def test_stage_parity_and_zero_recompiles(self, ladder):
        """worker_stage + decode_stage == the one-shot facade call, bit
        for bit, on every rung — with no builds beyond prewarm."""
        builds = ladder.cache_info()["builds"]
        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.integers(-4, 5, size=SHAPES[0]), jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        for rung in ladder.rungs:
            ladder.switch(rung)
            erased = list(range(min(2, ladder.budget(rung))))
            Y, ctx = ladder.worker_stage(A, B)
            C_split = ladder.decode_stage(Y, ctx, erased=erased)
            C_one = ladder(A, B, erased=erased)
            np.testing.assert_array_equal(np.asarray(C_split),
                                          np.asarray(C_one))
        assert ladder.cache_info()["builds"] == builds

    def test_staged_batch_pads_to_bucket(self, ladder):
        builds = ladder.cache_info()["builds"]
        rng = np.random.default_rng(1)
        A = jnp.asarray(rng.integers(-4, 5, size=(3,) + SHAPES[0]),
                        jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        ladder.switch(ladder.rungs[0])
        Y, ctx = ladder.worker_stage(A, B)
        assert ctx["batch"] == 3           # padded to bucket 4, sliced back
        C = ladder.decode_stage(Y, ctx, erased=[0])
        assert C.shape[0] == 3
        oracle = np.einsum("bvr,vt->brt", np.asarray(A), np.asarray(B))
        np.testing.assert_array_equal(np.asarray(C), oracle)
        assert ladder.cache_info()["builds"] == builds

    def test_decode_follows_recorded_rung_after_switch(self, ladder):
        """A batch decoded AFTER a rung switch must use the plan that
        encoded it (the pipelined loop switches between stages)."""
        rng = np.random.default_rng(2)
        A = jnp.asarray(rng.integers(-4, 5, size=SHAPES[0]), jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)
        ladder.switch("bec")
        Y, ctx = ladder.worker_stage(A, B)
        ladder.switch("polycode")          # the loop moved on
        C = ladder.decode_stage(Y, ctx, erased=[1])
        oracle = np.einsum("vr,vt->rt", np.asarray(A), np.asarray(B))
        np.testing.assert_array_equal(np.asarray(C), oracle)


class TestMeshStageErrors:
    def _executor(self):
        from repro.runtime.executors import MeshExecutor

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        return MeshExecutor(mesh)

    def _plan(self):
        from repro.core import make_plan

        return make_plan("bec", 2, 2, 1, K=4, L=257, points="chebyshev")

    def test_partial_kinds_no_longer_raise(self):
        # mesh partial landed: ("partial", Q) must BUILD a pipeline (the
        # old NotImplementedError told users to pass --sub-tasks 1).
        # Multi-device parity lives in tests/test_mesh.py; here the plan's
        # K (4) mismatches the 1-wide axis, so the kind check passing
        # surfaces as the K-vs-axis ValueError, not NotImplementedError.
        with pytest.raises(ValueError, match="mesh axis"):
            self._executor().make_pipeline(self._plan(), ("partial", 4),
                                           jnp.float64)
        with pytest.raises(ValueError, match="mesh axis"):
            self._executor().make_pipeline(self._plan(),
                                           ("partial-traced", 2),
                                           jnp.float64)

    def test_stage_kinds_error_names_supported_backends(self):
        from repro.runtime.executors import local_backend_names

        for kind in ("products", ("decode", 0, 0)):
            with pytest.raises(NotImplementedError) as err:
                self._executor().make_pipeline(self._plan(), kind,
                                               jnp.float64)
            msg = str(err.value)
            assert "split-stage" in msg and "reference" in msg
            # the supported list is computed once from the registry, so
            # the message cannot drift from BACKENDS
            assert local_backend_names() in msg


class TestDriverSplitSteps:
    def test_begin_execute_complete_is_step(self, ladder):
        """The decomposed entry points must be BIT-IDENTICAL to step()."""
        feed = make_scenario("heavy_tail").compile(K, seed=3)
        rng = np.random.default_rng(3)
        A = jnp.asarray(rng.integers(-4, 5, size=SHAPES[0]), jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=SHAPES[1]), jnp.float64)

        ladder.switch(ladder.rungs[0])
        one = AdaptiveServer(ladder, feed=feed, seed=3, check_exact=True)
        whole = [one.step(A, B)[1] for _ in range(6)]

        ladder.switch(ladder.rungs[0])
        feed2 = make_scenario("heavy_tail").compile(K, seed=3)
        two = AdaptiveServer(ladder, feed=feed2, seed=3, check_exact=True)
        parts = []
        for _ in range(6):
            decision = two.begin_step()
            C = two.execute(decision, A, B)
            parts.append(two.complete_step(decision, C, 0.0, A, B))

        for a, b in zip(whole, parts):
            assert report_to_dict(a) == report_to_dict(b)


def _small_tier(ladder, **kw):
    classes = (SLOClass(name="premium", quantile=0.99, slo_s=12.0,
                        rung_floor="tradeoff(p'=2)"),
               SLOClass(name="standard", quantile=0.9, slo_s=60.0))
    tenants = (TenantSpec(name="gold", slo_class="premium", arrival_rps=1.0),
               TenantSpec(name="free", slo_class="standard", arrival_rps=2.0,
                          rate_rps=0.5, burst=2, max_queue=3))
    feed = make_scenario("heavy_tail").compile(K, seed=5)
    defaults = dict(classes=classes, tenants=tenants, feed=feed,
                    overhead_s=OVERHEAD, seed=5, check_exact=True,
                    keep_results=True)
    defaults.update(kw)
    return ServeTier(ladder, **defaults)


def _payload(rid):
    base = np.arange(SHAPES[0][0] * SHAPES[0][1]).reshape(SHAPES[0])
    return jnp.asarray((base * (rid + 3)) % 11 - 5, jnp.float64)


def _run_small(ladder, **kw):
    ladder.switch(ladder.rungs[0])  # order-independent under the shared fixture
    tier = _small_tier(ladder, **kw)
    B = jnp.asarray(np.arange(SHAPES[1][0] * SHAPES[1][1])
                    .reshape(SHAPES[1]) % 7 - 3, jnp.float64)
    return tier.run(lambda req: _payload(req.rid), B, 8), B


class TestServeTier:
    def test_every_request_accounted(self, ladder):
        result, _ = _run_small(ladder)
        assert len(result.requests) == 16
        assert len(result.admitted) + len(result.shed) == 16
        assert len(result.completed) == len(result.admitted)
        for rec in result.shed:
            assert rec.reject_reason in (REJECT_RATE_LIMITED,
                                         REJECT_QUEUE_FULL)
        # the overloaded free tenant actually sheds
        assert any(r.tenant == "free" for r in result.shed)

    def test_deterministic_replay(self, ladder):
        r1, _ = _run_small(ladder)
        r2, _ = _run_small(ladder)
        t1, t2 = ServeTrace.from_result(r1), ServeTrace.from_result(r2)
        assert t1.diff(t2) == []

    def test_results_bit_identical_to_facade(self, ladder):
        result, B = _run_small(ladder)
        cm = ladder.facade(ladder.rungs[0])
        for rec in result.completed:
            C_sync = np.asarray(cm(_payload(rec.rid), B))
            np.testing.assert_array_equal(result.results[rec.rid], C_sync)

    def test_latency_bookkeeping(self, ladder):
        result, _ = _run_small(ladder)
        for rec in result.completed:
            assert rec.queue_delay_s >= -1e-9
            assert rec.latency_s == pytest.approx(
                rec.completion_s - rec.arrival_s)
            assert rec.violated == (rec.latency_s > rec.slo_s)
        for b in result.batches:
            assert b.size <= 4 and b.size <= b.bucket
            assert b.report.get("exact") is True

    def test_pipeline_beats_serial_on_drain_time(self, ladder):
        fast, _ = _run_small(ladder)
        slow, _ = _run_small(ladder, pipelined=False, max_batch=1)
        assert fast.throughput_rps() > slow.throughput_rps()

    def test_rerun_raises(self, ladder):
        tier = _small_tier(ladder)
        B = jnp.zeros(SHAPES[1], jnp.float64)
        tier.run(lambda req: _payload(req.rid), B, 2)
        with pytest.raises(RuntimeError, match="fresh tier"):
            tier.run(lambda req: _payload(req.rid), B, 2)

    def test_split_stages_needs_single_sub_task(self, ladder):
        with pytest.raises(ValueError, match="sub_tasks"):
            _small_tier(ladder, sub_tasks=2, split_stages=True)

    def test_unknown_class_raises(self, ladder):
        with pytest.raises(ValueError, match="unknown SLO class"):
            ServeTier(ladder,
                      classes=(SLOClass(name="c"),),
                      tenants=(TenantSpec(name="a", slo_class="nope"),))


class TestServeTrace:
    def test_save_load_round_trip(self, ladder, tmp_path):
        result, _ = _run_small(ladder)
        trace = ServeTrace.from_result(result)
        loaded = ServeTrace.load(trace.save(tmp_path / "t.jsonl"))
        assert loaded.diff(trace) == []
        assert loaded.meta == trace.meta

    def test_diff_catches_drift(self, ladder):
        result, _ = _run_small(ladder)
        trace = ServeTrace.from_result(result)
        mutated = list(trace.requests)
        mutated[0] = dict(mutated[0], latency_s=999.0)
        drifted = dataclasses.replace(trace, requests=tuple(mutated))
        assert any("latency_s" in line for line in trace.diff(drifted))

    def test_load_rejects_foreign_files(self, tmp_path):
        bad = tmp_path / "bad.jsonl"
        bad.write_text('{"kind": "nonsense"}\n')
        with pytest.raises(ValueError, match="header"):
            ServeTrace.load(bad)
        bad.write_text('{"kind": "header", "version": 99}\n')
        with pytest.raises(ValueError, match="version"):
            ServeTrace.load(bad)


class TestGoldenServeTrace:
    """Drift check: today's serve tier vs. the checked-in recording.

    On an INTENDED behaviour change, regenerate via
    ``PYTHONPATH=src python scripts/regen_golden_traces.py --serve`` and
    commit the diff.
    """

    def test_golden_serve_replays_bit_exactly(self):
        from repro.serve import GOLDEN_SERVE_SCENARIO, golden_serve_trace

        recorded = ServeTrace.load(
            GOLDEN_DIR / f"serve_{GOLDEN_SERVE_SCENARIO}.jsonl")
        fresh = golden_serve_trace()
        drift = fresh.diff(recorded)
        assert drift == [], "\n".join(drift[:20])
        # the recording must actually exercise the tier: batching,
        # shedding, and both SLO classes (otherwise the replay is vacuous)
        sizes = {b["size"] for b in recorded.batches}
        assert any(s > 1 for s in sizes)
        assert any(not r["admitted"] for r in recorded.requests)
        assert {b["slo_class"] for b in recorded.batches} == \
            {"premium", "standard"}
