"""Per-arch smoke tests (reduced same-family configs) + decode consistency.

Assignment requirement: every architecture instantiates a REDUCED config and
runs one forward/train step on CPU asserting output shapes + no NaNs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.models import (
    decode_step,
    init_params,
    prefill,
    train_loss,
)

B, S = 2, 64


def _batch(cfg, key, S_=S):
    toks = jax.random.randint(key, (B, S_), 0, cfg.vocab)
    batch = {"labels": toks}
    if cfg.input_mode == "tokens":
        batch["tokens"] = toks
    else:
        base = jnp.arange(cfg.d_model, dtype=jnp.float32)
        emb = jnp.sin(toks[..., None].astype(jnp.float32) * 0.01 + base * 0.1) * 0.1
        batch["embeds"] = emb.astype(jnp.bfloat16)
        if cfg.pos == "mrope":
            batch["pos_ids"] = jnp.broadcast_to(
                jnp.arange(S_)[None, None], (3, B, S_)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
class TestSmoke:
    def test_train_step(self, arch):
        cfg = get_smoke_config(arch)
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        batch = _batch(cfg, key)
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch)))(params)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
        gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        assert bool(jnp.isfinite(gn)), f"{arch}: grads not finite"
        assert float(gn) > 0, f"{arch}: zero grads"

    def test_forward_shapes(self, arch):
        cfg = get_smoke_config(arch)
        key = jax.random.PRNGKey(1)
        params = init_params(cfg, key)
        batch = _batch(cfg, key)
        batch.pop("labels")
        logits, cache = jax.jit(lambda p, b: prefill(p, cfg, b))(params, batch)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_full_forward(arch):
    """prefill(S) + decode(1) logits == full forward logits at position S."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    toks_batch = _batch(cfg, key, S + 1)
    toks_batch.pop("labels")
    full_logits, _ = jax.jit(lambda p, b: prefill(p, cfg, b))(params, toks_batch)

    pre_batch = jax.tree.map(
        lambda x: x[:, :S] if x.ndim == 2 else
        (x[:, :, :S] if x.shape[0] == 3 else x[:, :S]), toks_batch)
    _, cache = jax.jit(lambda p, b: prefill(p, cfg, b, S_max=S + 4))(
        params, pre_batch)
    step_batch = jax.tree.map(
        lambda x: x[:, S:S + 1] if x.ndim == 2 else
        (x[:, :, S:S + 1] if x.shape[0] == 3 else x[:, S:S + 1]), toks_batch)
    if "pos_ids" in step_batch:
        # decode_step adds ``pos`` itself; pass relative-zero ids
        step_batch["pos_ids"] = jnp.zeros_like(step_batch["pos_ids"])
    dec_logits, _ = jax.jit(
        lambda p, c, b, pos: decode_step(p, cfg, c, b, pos))(
        params, cache, step_batch, jnp.int32(S))
    ref = np.asarray(full_logits, np.float32)
    out = np.asarray(dec_logits, np.float32)
    denom = np.max(np.abs(ref)) + 1e-6
    assert np.max(np.abs(ref - out)) / denom < 0.05, \
        f"{arch}: decode diverges from full forward"
