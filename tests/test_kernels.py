"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.block_matmul import matmul_t_pallas


def _tol(dtype):
    return {"bfloat16": 2e-2, "float32": 2e-5, "float64": 1e-12}[np.dtype(dtype).name]


class TestEncodeKernel:
    @pytest.mark.parametrize("K,P,E", [(4, 4, 256), (10, 8, 2048),
                                       (16, 16, 4096), (3, 6, 1000),
                                       (1, 1, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, rng, K, P, E, dtype):
        coeff = jnp.asarray(rng.normal(size=(K, P)), dtype)
        blocks = jnp.asarray(rng.normal(size=(P, E)), dtype)
        out = ops.encode(coeff, blocks)
        exp = ref.encode_ref(coeff, blocks)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=_tol(dtype), atol=_tol(dtype))

    def test_non_pow2_padding(self, rng):
        coeff = jnp.asarray(rng.normal(size=(5, 3)), jnp.float32)
        blocks = jnp.asarray(rng.normal(size=(3, 777)), jnp.float32)
        out = ops.encode(coeff, blocks)
        exp = ref.encode_ref(coeff, blocks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5)

    def test_complex_falls_back_to_ref(self, rng):
        coeff = jnp.asarray(rng.normal(size=(4, 4)) + 1j * rng.normal(size=(4, 4)))
        blocks = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
        out = ops.encode(coeff, blocks)
        exp = ref.encode_ref(coeff, blocks.astype(coeff.dtype))
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-6)


class TestDecodeKernel:
    @pytest.mark.parametrize("mn,tau,E", [(4, 4, 512), (4, 9, 2048),
                                          (6, 11, 1024), (1, 1, 128)])
    def test_sweep(self, rng, mn, tau, E):
        W = jnp.asarray(rng.normal(size=(mn, tau)), jnp.float32)
        Y = jnp.asarray(rng.integers(-50, 50, size=(tau, E)), jnp.float32)
        for s in (64.0, 1024.0):
            out = ops.decode(W, Y, s)
            exp = ref.decode_ref(W, Y, s)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    def test_extract_false_polycode_path(self, rng):
        W = jnp.asarray(rng.normal(size=(4, 9)), jnp.float32)
        Y = jnp.asarray(rng.integers(-50, 50, size=(9, 256)), jnp.float32)
        out = ops.decode(W, Y, 64.0, extract=False)
        exp = jnp.round(W @ Y)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


class TestBlockMatmulKernel:
    @pytest.mark.parametrize("v,r,t", [(128, 128, 128), (512, 256, 384),
                                       (300, 200, 150), (64, 640, 64),
                                       (1024, 128, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, rng, v, r, t, dtype):
        A = jnp.asarray(rng.normal(size=(v, r)), dtype)
        B = jnp.asarray(rng.normal(size=(v, t)), dtype)
        out = ops.matmul_t(A, B)
        exp = ref.matmul_t_ref(A, B)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=_tol(dtype) * v ** 0.5, atol=_tol(dtype) * v ** 0.5)

    @pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (64, 128, 256)])
    def test_block_shapes(self, rng, bm, bn, bk):
        A = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
        B = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
        out = matmul_t_pallas(A, B, bm=bm, bn=bn, bk=bk, interpret=True)
        exp = ref.matmul_t_ref(A, B)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-3, atol=1e-3)


class TestMambaScanKernel:
    @pytest.mark.parametrize("B,S,d,s,chunk,d_blk", [
        (2, 64, 32, 8, 16, 16), (1, 128, 16, 4, 32, 16),
        (3, 48, 24, 16, 16, 8)])
    def test_fwd_sweep(self, rng, B, S, d, s, chunk, d_blk):
        import jax
        import jax.numpy as jnp
        from repro.kernels.mamba_scan import mamba_scan_pallas
        dt = jnp.asarray(jax.nn.softplus(rng.normal(size=(B, S, d))), jnp.float32)
        x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, s)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, s)), jnp.float32)
        A_log = jnp.asarray(rng.uniform(0.1, 1.0, size=(d, s)), jnp.float32)
        D = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        y, hf, _ = mamba_scan_pallas(dt, x, Bm, Cm, A_log, D, chunk=chunk,
                                     d_blk=d_blk, interpret=True)
        y0, h0 = ref.mamba_scan_ref(dt, x, Bm, Cm, A_log, D)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(h0),
                                   rtol=1e-4, atol=1e-4)

    def test_custom_vjp_matches_autodiff(self, rng):
        import jax
        import jax.numpy as jnp
        from repro.models.mamba import mamba_scan_fused
        B, S, d, s = 2, 32, 16, 4
        dt = jnp.asarray(jax.nn.softplus(rng.normal(size=(B, S, d))), jnp.float32)
        x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, s)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, s)), jnp.float32)
        A_log = jnp.asarray(rng.uniform(0.1, 1.0, size=(d, s)), jnp.float32)
        D = jnp.asarray(rng.normal(size=(d,)), jnp.float32)

        def loss_fused(*a):
            y, hf = mamba_scan_fused(*a)
            return jnp.sum(jnp.sin(y)) + 0.3 * jnp.sum(hf)

        def loss_ref(*a):
            y, hf = ref.mamba_scan_ref(*a)
            return jnp.sum(jnp.sin(y)) + 0.3 * jnp.sum(hf)

        g1 = jax.grad(loss_fused, argnums=tuple(range(6)))(dt, x, Bm, Cm, A_log, D)
        g0 = jax.grad(loss_ref, argnums=tuple(range(6)))(dt, x, Bm, Cm, A_log, D)
        for a, b in zip(g1, g0):
            sc = float(jnp.max(jnp.abs(b))) + 1e-9
            assert float(jnp.max(jnp.abs(a - b))) / sc < 1e-4


class TestWkvScanKernel:
    @pytest.mark.parametrize("B,S,H,dk,chunk", [(2, 64, 3, 8, 16),
                                                (1, 48, 2, 16, 8)])
    def test_fwd_sweep(self, rng, B, S, H, dk, chunk):
        import jax.numpy as jnp
        from repro.kernels.wkv_scan import wkv_scan_pallas
        from repro.models.rwkv6 import _wkv_chunked
        w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(B, S, H, dk)))), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
        r = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(H, dk)), jnp.float32)
        y1, s1, _ = wkv_scan_pallas(w, k, v, r, u, chunk=chunk, interpret=True)
        S0 = jnp.zeros((B, H, dk, dk), jnp.float32)
        y0, s0 = _wkv_chunked(w, k, v, r, u, S0, 16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                                   rtol=1e-4, atol=1e-4)

    def test_custom_vjp_matches_autodiff(self, rng):
        import jax
        import jax.numpy as jnp
        from repro.models.rwkv6 import _wkv_chunked, wkv_fused
        B, S, H, dk = 2, 32, 2, 8
        w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(B, S, H, dk)))), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
        r = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(H, dk)), jnp.float32)
        S0 = jnp.zeros((B, H, dk, dk), jnp.float32)

        def lf(*a):
            y, sf = wkv_fused(*a)
            return jnp.sum(jnp.sin(y)) + 0.3 * jnp.sum(sf)

        def lr(*a):
            y, sf = _wkv_chunked(*a, S0, 16)
            return jnp.sum(jnp.sin(y)) + 0.3 * jnp.sum(sf)

        g1 = jax.grad(lf, argnums=tuple(range(5)))(w, k, v, r, u)
        g0 = jax.grad(lr, argnums=tuple(range(5)))(w, k, v, r, u)
        for a, b in zip(g1, g0):
            sc = float(jnp.max(jnp.abs(b))) + 1e-9
            assert float(jnp.max(jnp.abs(a - b))) / sc < 1e-4

    def test_rwkv_model_parity(self, rng):
        """Full rwkv6 smoke model: kernel path == chunked path."""
        import dataclasses

        import jax
        import jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import init_params, train_loss
        cfg0 = get_smoke_config("rwkv6_3b")
        cfg1 = dataclasses.replace(cfg0, rwkv_kernel=True)
        key = jax.random.PRNGKey(0)
        params = init_params(cfg0, key)
        batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg0.vocab),
                 "labels": jax.random.randint(key, (2, 32), 0, cfg0.vocab)}
        l0 = jax.jit(lambda p: train_loss(p, cfg0, batch))(params)
        l1 = jax.jit(lambda p: train_loss(p, cfg1, batch))(params)
        assert abs(float(l0) - float(l1)) < 5e-3


class TestKernelPipelineEndToEnd:
    """encode -> worker matmul -> decode through the kernels == coded_matmul."""

    def test_full_pipeline(self, rng):
        import jax as _jax
        _jax.config.update("jax_enable_x64", True)
        from repro.core import make_plan, uncoded_matmul
        from repro.core.partition import block_decompose, block_recompose, unpad
        from repro.core.vandermonde import inverse_vandermonde

        v, r, t = 64, 48, 40
        A = jnp.asarray(rng.integers(-4, 5, size=(v, r)), jnp.float64)
        B = jnp.asarray(rng.integers(-4, 5, size=(v, t)), jnp.float64)
        L = v * 4 * 4 + 1
        plan = make_plan("bec", 2, 2, 2, K=6, L=L, points="chebyshev")
        g = plan.scheme.grid
        ab = block_decompose(A, g.p, g.m)
        bb = block_decompose(B, g.p, g.n)
        bv, br = ab.shape[2], ab.shape[3]
        bt = bb.shape[3]
        coeff_a = jnp.asarray(plan.coeff_a.reshape(plan.K, -1))
        coeff_b = jnp.asarray(plan.coeff_b.reshape(plan.K, -1))
        at = ops.encode(coeff_a, ab.reshape(g.p * g.m, -1)).reshape(plan.K, bv, br)
        btl = ops.encode(coeff_b, bb.reshape(g.p * g.n, -1)).reshape(plan.K, bv, bt)
        Y = jnp.stack([ops.matmul_t(at[k], btl[k]) for k in range(plan.tau)])
        Winv = inverse_vandermonde(plan.z_points[: plan.tau])
        useful = plan.scheme.useful_z_exp().reshape(-1)
        W = jnp.asarray(Winv[useful])
        C_blocks = ops.decode(W, Y.reshape(plan.tau, -1), plan.s)
        C = block_recompose(C_blocks.reshape(g.m, g.n, br, bt))
        C = unpad(C, (r, t))
        np.testing.assert_array_equal(np.asarray(C),
                                      np.asarray(uncoded_matmul(A, B)))
